"""Calibration + design-time constant generation (paper §III-A).

The paper fixes every scaling factor at design time; this module is the
"design time".  Calibration runs the float model over a sample batch,
records max-abs statistics at every tap the hardware requantizes at, and
turns them into:

  * symmetric INT8 scales for activations and weights,
  * dyadic (b, 2^c) constants for every Requantization / residual-align /
    Scale block,
  * the q1..q8-style polynomial constants for Softmax / GELU / LayerNorm.

Everything downstream (the L2 quantized graph, the AOT artifacts, and the
rust simulator via ``manifest.json``) consumes the output of this module.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from .intops import (
    LN_P,
    SM_UNIT,
    Dyadic,
    GeluConsts,
    LayerNormConsts,
    SoftmaxConsts,
)


def int8_scale(max_abs: float, margin: float = 1.0) -> float:
    """Symmetric INT8 scale for a tensor with the given max-abs statistic."""
    m = max(float(max_abs), 1e-8) * margin
    return m / 127.0


def quantize_tensor(x: np.ndarray, scale: float) -> np.ndarray:
    """Round-to-nearest symmetric quantization to INT8 range (build-time)."""
    q = np.rint(np.asarray(x, dtype=np.float64) / scale)
    return np.clip(q, -128, 127).astype(np.int32)


def quantize_bias(bias: np.ndarray, acc_scale: float) -> np.ndarray:
    """Bias folds into the INT32 accumulator, so it quantizes at the
    accumulator scale s_x * s_w (paper Fig. 6's readout-time addition)."""
    q = np.rint(np.asarray(bias, dtype=np.float64) / acc_scale)
    lo, hi = -(2**31), 2**31 - 1
    return np.clip(q, lo, hi).astype(np.int32)


@dataclass(frozen=True)
class AttnScales:
    """Per-layer activation scales picked by calibration (MHSA path)."""

    s_x: float      # INT8 input to the encoder layer
    s_q8: float     # INT8 Q after requant
    s_k8: float     # INT8 K after requant
    s_v8: float     # INT8 V after requant
    s_ctx: float    # INT8 attention context (after P.V requant)


@dataclass(frozen=True)
class FfnScales:
    s_x2: float     # INT8 input to FFN (after LN1 requant)
    s_h: float      # INT8 hidden after GELU requant
    s_out: float    # INT8 layer output (after LN2 requant) == next s_x


@dataclass(frozen=True)
class LayerCalibration:
    """All calibration statistics for one encoder layer."""

    attn: AttnScales
    ffn: FfnScales
    s_gamma1: float
    s_gamma2: float


@dataclass
class Calibrator:
    """Accumulates max-abs statistics over calibration batches."""

    taps: dict = field(default_factory=dict)

    def observe(self, name: str, x) -> None:
        m = float(np.max(np.abs(np.asarray(x)))) if np.asarray(x).size else 0.0
        self.taps[name] = max(self.taps.get(name, 0.0), m)

    def scale(self, name: str) -> float:
        return int8_scale(self.taps[name])


# --- per-layer integer parameter bundle --------------------------------------

@dataclass(frozen=True)
class QuantLayerParams:
    """Everything one encoder layer's hardware needs, all integers.

    Weight layout (d = model dim, k heads, dh = d/k, dff = FFN dim):
      wq/wk/wv: (d, d) INT8   bq/bk/bv: (d,) INT32 at s_x*s_w
      wo: (d, d) INT8         bo: (d,) INT32
      w1: (d, dff) INT8       b1: (dff,) INT32
      w2: (dff, d) INT8       b2: (d,) INT32
      gamma1/gamma2: (d,) INT8 at s_gamma; beta1/beta2: (d,) INT32 at s_ln_out
    """

    # quantized weights
    wq: np.ndarray; wk: np.ndarray; wv: np.ndarray; wo: np.ndarray
    bq: np.ndarray; bk: np.ndarray; bv: np.ndarray; bo: np.ndarray
    w1: np.ndarray; w2: np.ndarray
    b1: np.ndarray; b2: np.ndarray
    gamma1: np.ndarray; beta1: np.ndarray
    gamma2: np.ndarray; beta2: np.ndarray
    # requantization dyadics
    dy_q: Dyadic; dy_k: Dyadic; dy_v: Dyadic      # QKV acc -> INT8
    dy_scale: Dyadic                              # attention Scale (1/sqrt(dh))
    dy_ctx: Dyadic                                # P.V acc -> INT8 context
    dy_res1: Dyadic                               # attn-out acc -> s_x align
    dy_ln1: Dyadic                                # LN1 out -> INT8 s_x2
    dy_gelu: Dyadic                               # GELU out -> INT8 s_h
    dy_res2: Dyadic                               # FFN-out acc -> s_x2 align
    dy_ln2: Dyadic                                # LN2 out -> INT8 s_out
    # nonlinear design-time constants
    sm: SoftmaxConsts
    gelu: GeluConsts
    ln1: LayerNormConsts
    ln2: LayerNormConsts
    # the calibrated scales (kept for validation / manifest)
    cal: LayerCalibration


def design_layer(
    float_weights: dict, cal: LayerCalibration, d: int, heads: int,
    weight_scales: dict | None = None,
) -> QuantLayerParams:
    """Turn one layer's float weights + calibration into integer params.

    ``float_weights`` keys: wq wk wv wo bq bk bv bo w1 b1 w2 b2
    gamma1 beta1 gamma2 beta2 (numpy arrays, float).  ``weight_scales``
    optionally overrides the per-tensor weight scales (used by the unified
    shaped-model artifacts, where every layer must share one set of
    design-time constants so a single HLO executable serves all layers).
    """
    fw = float_weights
    a = cal.attn
    f = cal.ffn
    dh = d // heads

    ws = weight_scales or {}

    def wscale(name):
        return ws.get(name) or int8_scale(np.abs(fw[name]).max())

    s_wq = wscale("wq")
    s_wk = wscale("wk")
    s_wv = wscale("wv")
    s_wo = wscale("wo")
    s_w1 = wscale("w1")
    s_w2 = wscale("w2")

    # ----- MHSA path -----
    # QKV projections accumulate at s_x*s_w, requantize to the INT8 scales.
    dy_q = Dyadic.approximate(a.s_x * s_wq / a.s_q8)
    dy_k = Dyadic.approximate(a.s_x * s_wk / a.s_k8)
    dy_v = Dyadic.approximate(a.s_x * s_wv / a.s_v8)

    # Attention Scale block: value-scale by 1/sqrt(dh).  The paper notes
    # this is a pure shift when the factor is a power of two — dh = 64
    # (RoBERTa and DeiT-S both) gives exactly >> 3.
    inv = 1.0 / math.sqrt(dh)
    if (1.0 / inv).is_integer() and (int(1.0 / inv) & (int(1.0 / inv) - 1)) == 0:
        dy_scale = Dyadic(b=1, c=int(math.log2(1.0 / inv)))
    else:
        dy_scale = Dyadic.approximate(inv)

    s_pe = a.s_q8 * a.s_k8  # scale of the Scale-block output (value shrunk)
    sm = SoftmaxConsts.design(s_pe)
    # probs are INT8 at 1/SM_UNIT; context acc at s_v8/SM_UNIT -> s_ctx
    dy_ctx = Dyadic.approximate(a.s_v8 / SM_UNIT / a.s_ctx)
    # output projection acc (s_ctx*s_wo) aligns to the residual scale s_x
    dy_res1 = Dyadic.approximate(a.s_ctx * s_wo / a.s_x)

    # ----- LayerNorm 1 -----
    ln1 = LayerNormConsts(s_in=a.s_x, s_gamma=cal.s_gamma1, d=d)
    dy_ln1 = Dyadic.approximate(ln1.s_out / f.s_x2)

    # ----- FFN path -----
    gelu = GeluConsts.design(f.s_x2 * s_w1)
    # GELU output scale is tiny (s_in * s_erf / 2): allow deep shifts.
    dy_gelu = Dyadic.approximate(abs(gelu.s_out) / f.s_h, bits=14, max_shift=52)
    dy_res2 = Dyadic.approximate(f.s_h * s_w2 / f.s_x2)
    ln2 = LayerNormConsts(s_in=f.s_x2, s_gamma=cal.s_gamma2, d=d)
    dy_ln2 = Dyadic.approximate(ln2.s_out / f.s_out)

    def w8(name, s):
        return quantize_tensor(fw[name], s)

    return QuantLayerParams(
        wq=w8("wq", s_wq), wk=w8("wk", s_wk), wv=w8("wv", s_wv), wo=w8("wo", s_wo),
        bq=quantize_bias(fw["bq"], a.s_x * s_wq),
        bk=quantize_bias(fw["bk"], a.s_x * s_wk),
        bv=quantize_bias(fw["bv"], a.s_x * s_wv),
        bo=quantize_bias(fw["bo"], a.s_ctx * s_wo),
        w1=w8("w1", s_w1), w2=w8("w2", s_w2),
        b1=quantize_bias(fw["b1"], f.s_x2 * s_w1),
        b2=quantize_bias(fw["b2"], f.s_h * s_w2),
        gamma1=quantize_tensor(fw["gamma1"], cal.s_gamma1),
        beta1=quantize_bias(fw["beta1"], ln1.s_out),
        gamma2=quantize_tensor(fw["gamma2"], cal.s_gamma2),
        beta2=quantize_bias(fw["beta2"], ln2.s_out),
        dy_q=dy_q, dy_k=dy_k, dy_v=dy_v, dy_scale=dy_scale, dy_ctx=dy_ctx,
        dy_res1=dy_res1, dy_ln1=dy_ln1, dy_gelu=dy_gelu, dy_res2=dy_res2,
        dy_ln2=dy_ln2,
        sm=sm, gelu=gelu, ln1=ln1, ln2=ln2, cal=cal,
    )


def calibration_from_taps(cal: Calibrator, layer: int) -> LayerCalibration:
    """Assemble one layer's calibration from tap statistics recorded by the
    float model (tap names are ``L{i}.<tap>``)."""

    def s(tap: str) -> float:
        return cal.scale(f"L{layer}.{tap}")

    return LayerCalibration(
        attn=AttnScales(
            s_x=s("x"), s_q8=s("q"), s_k8=s("k"), s_v8=s("v"), s_ctx=s("ctx")
        ),
        ffn=FfnScales(s_x2=s("x2"), s_h=s("h"), s_out=s("out")),
        s_gamma1=int8_scale(cal.taps[f"L{layer}.gamma1"]),
        s_gamma2=int8_scale(cal.taps[f"L{layer}.gamma2"]),
    )
