//! INT4 x INT8 -> INT32 matrix multiplication: the packed low-precision
//! weight tier of the cascade serving path (DESIGN.md §14).
//!
//! Weights are quantized to 4-bit two's-complement nibbles (`-8..=7`)
//! at 1/16 of the INT8 weight scale — `w4 ≈ w8 / 16`, round-half-up —
//! and packed two-per-byte along the contraction dimension `k`: byte
//! `t*n + j` holds `w[2t][j]` in its low nibble and `w[2t+1][j]` in its
//! high nibble (an odd `k` leaves the final high nibble zero).  The
//! hardware reading of this layout is the one BETA-style accelerators
//! use: one weight-SRAM word feeds *two* k-panels of the MAC array per
//! cycle, which is exactly how the cycle model charges it
//! (`sim::units::weight_matmul_cycles` halves the streamed `k`).
//!
//! Numerics follow the same conventions as the INT8 kernels
//! (`quant::matmul`): bias folds in at readout, floor rounding
//! everywhere downstream, INT32 accumulators (the nibble operands make
//! the width argument *stronger*: `|x*w| <= 128*8`, so `k` up to
//! `2^31 / 1024` is safe — guarded under `debug_assertions`).  The
//! scale change is compensated downstream by scaling the readout
//! dyadics by `2^4` ([`crate::quant::Dyadic::scale_pow2`]), which is
//! bit-exact with multiplying the accumulator by 16 first.
//!
//! Two implementations, bit-identical by construction and asserted
//! against each other on randomized shapes (`rust/tests/int4_kernels.rs`):
//! * the packed kernels ([`i_matmul_int4`] and friends), which decode
//!   nibbles inline at the MAC, and
//! * the unpacked reference ([`i_matmul_int4_ref`]), which expands the
//!   nibbles back to `i32` and runs the golden INT8 kernel — the oracle
//!   every packed variant must match bit for bit.

use super::dyadic::Dyadic;
use super::matmul::{i_matmul, Epilogue};
use super::{div_floor, i_matmul_epilogue};
use crate::util::threadpool::{default_parallelism, tile_ranges};

/// Shift that relates the INT4 and INT8 weight scales: `w8 ≈ w4 << 4`.
/// Readout dyadics of INT4 matmuls are pre-scaled by `2^INT4_SHIFT`
/// ([`Dyadic::scale_pow2`]); accumulators feeding a *non-linear* unit
/// (GELU) are rescaled by `1 << INT4_SHIFT` explicitly instead.
pub const INT4_SHIFT: u32 = 4;

/// Quantize INT8-scale weights to the INT4 grid: round-half-up to the
/// nearest multiple of 16, clamped to the nibble range `-8..=7`
/// (`127 -> 8` would overflow the nibble, so the positive rail clamps).
pub fn int4_from_int8(w: &[i32]) -> Vec<i32> {
    w.iter().map(|&v| div_floor(v as i64 + 8, 16).clamp(-8, 7) as i32).collect()
}

/// Pack nibble-range weights `(k, n)` two-per-byte along `k`: byte
/// `t*n + j` holds row `2t` (low nibble) and row `2t+1` (high nibble);
/// an odd `k` zero-fills the final high nibble.  Panics if any value is
/// outside `-8..=7`.
pub fn pack_int4(w4: &[i32], k: usize, n: usize) -> Vec<u8> {
    assert_eq!(w4.len(), k * n, "w4 shape");
    assert!(
        w4.iter().all(|&v| (-8..=7).contains(&v)),
        "pack_int4 operand outside the INT4 nibble range"
    );
    let kp = k.div_ceil(2);
    let mut packed = vec![0u8; kp * n];
    for t in 0..kp {
        for j in 0..n {
            let lo = w4[(2 * t) * n + j] as u8 & 0x0F;
            let hi = if 2 * t + 1 < k { (w4[(2 * t + 1) * n + j] as u8 & 0x0F) << 4 } else { 0 };
            packed[t * n + j] = lo | hi;
        }
    }
    packed
}

/// Sign-extend the low nibble of a packed byte.
#[inline]
fn lo_nibble(b: u8) -> i32 {
    (((b << 4) as i8) >> 4) as i32
}

/// Sign-extend the high nibble of a packed byte.
#[inline]
fn hi_nibble(b: u8) -> i32 {
    ((b as i8) >> 4) as i32
}

/// Expand a packed `(k, n)` weight tensor back to `i32` nibble values —
/// the inverse of [`pack_int4`], used by the golden reference path.
pub fn unpack_int4(packed: &[u8], k: usize, n: usize) -> Vec<i32> {
    let kp = k.div_ceil(2);
    assert_eq!(packed.len(), kp * n, "packed shape");
    let mut w4 = vec![0i32; k * n];
    for t in 0..kp {
        for j in 0..n {
            let b = packed[t * n + j];
            w4[(2 * t) * n + j] = lo_nibble(b);
            if 2 * t + 1 < k {
                w4[(2 * t + 1) * n + j] = hi_nibble(b);
            }
        }
    }
    w4
}

/// Shared shape/operand checks of the packed kernels.
#[inline]
fn check_int4(
    x: &[i32],
    packed: &[u8],
    bias: Option<&[i32]>,
    m: usize,
    k: usize,
    n: usize,
    out: usize,
) {
    assert_eq!(x.len(), m * k, "x shape");
    assert_eq!(packed.len(), k.div_ceil(2) * n, "packed w shape");
    assert_eq!(out, m * n, "out shape");
    if let Some(b) = bias {
        assert_eq!(b.len(), n, "bias shape");
    }
    debug_assert!(
        x.iter().all(|&v| (-128..=127).contains(&v)),
        "i_matmul_int4 operand outside INT8 range"
    );
    // widened-accumulator argument: |x*w| <= 128*8 per MAC, so the
    // INT32 accumulator holds contractions 16x deeper than the INT8
    // kernel's bound before bias
    debug_assert!(k <= (i32::MAX as usize) / (128 * 8), "contraction too deep for INT32");
}

/// One output row of the packed kernel: bias init, then the k-pair
/// multiply-accumulate sweep decoding two weight rows per packed byte.
/// Per-column accumulation visits `k` in ascending order, exactly like
/// the INT8 `mac_row`, so the packed result is bit-identical to the
/// unpacked reference by construction.
#[inline]
fn mac_row_int4(xrow: &[i32], packed: &[u8], bias: Option<&[i32]>, n: usize, orow: &mut [i32]) {
    match bias {
        Some(b) => orow.copy_from_slice(b),
        None => orow.fill(0),
    }
    let k = xrow.len();
    for t in 0..k.div_ceil(2) {
        let x0 = xrow[2 * t];
        // the odd-k tail byte's high nibble is packed as zero, so a
        // zero stand-in activation keeps the sweep uniform
        let x1 = if 2 * t + 1 < k { xrow[2 * t + 1] } else { 0 };
        if x0 == 0 && x1 == 0 {
            continue;
        }
        let wrow = &packed[t * n..(t + 1) * n];
        // plain i32 MACs over decoded nibbles: same autovectorization
        // story as the INT8 kernel (an i64 widening would block SIMD)
        for (o, &b) in orow.iter_mut().zip(wrow) {
            *o += x0 * lo_nibble(b) + x1 * hi_nibble(b);
        }
    }
}

/// `out[m][n] = sum_k x[m][k] * w4[k][n] (+ bias[n])` over packed INT4
/// weights, INT32 accumulators — the packed twin of
/// [`crate::quant::i_matmul`].
pub fn i_matmul_int4(
    x: &[i32],
    packed: &[u8],
    bias: Option<&[i32]>,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    check_int4(x, packed, bias, m, k, n, out.len());
    for i in 0..m {
        mac_row_int4(&x[i * k..(i + 1) * k], packed, bias, n, &mut out[i * n..(i + 1) * n]);
    }
}

/// [`i_matmul_int4`] with `epi` fused at each finished row's readout —
/// the packed twin of [`crate::quant::i_matmul_epilogue`].  For INT4
/// requantize paths the caller passes the `2^4`-scaled dyadic
/// ([`Dyadic::scale_pow2`]), which restores the INT8 accumulator scale
/// bit-exactly.
#[allow(clippy::too_many_arguments)]
pub fn i_matmul_int4_epilogue(
    x: &[i32],
    packed: &[u8],
    bias: Option<&[i32]>,
    m: usize,
    k: usize,
    n: usize,
    epi: Epilogue,
    out: &mut [i32],
) {
    check_int4(x, packed, bias, m, k, n, out.len());
    for i in 0..m {
        let orow = &mut out[i * n..(i + 1) * n];
        mac_row_int4(&x[i * k..(i + 1) * k], packed, bias, n, orow);
        epi.apply(orow);
    }
}

/// Unpacked golden reference: expand the nibbles and run the INT8
/// kernel.  Every packed variant must match this bit for bit
/// (`rust/tests/int4_kernels.rs`).
pub fn i_matmul_int4_ref(
    x: &[i32],
    packed: &[u8],
    bias: Option<&[i32]>,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    let w4 = unpack_int4(packed, k, n);
    i_matmul(x, &w4, bias, m, k, n, out);
}

/// Unpacked golden reference of the fused path: expand, then run the
/// INT8 epilogue kernel.
#[allow(clippy::too_many_arguments)]
pub fn i_matmul_int4_ref_epilogue(
    x: &[i32],
    packed: &[u8],
    bias: Option<&[i32]>,
    m: usize,
    k: usize,
    n: usize,
    epi: Epilogue,
    out: &mut [i32],
) {
    let w4 = unpack_int4(packed, k, n);
    i_matmul_epilogue(x, &w4, bias, m, k, n, epi, out);
}

/// Row-tiled parallel [`i_matmul_int4`]; same tiling contract as
/// [`crate::quant::i_matmul_tiled`] (disjoint row bands, bit-exact with
/// the serial kernel).
pub fn i_matmul_int4_tiled(
    threads: usize,
    x: &[i32],
    packed: &[u8],
    bias: Option<&[i32]>,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    check_int4(x, packed, bias, m, k, n, out.len());
    let tiles = tile_ranges(m, threads);
    if tiles.len() <= 1 {
        return i_matmul_int4(x, packed, bias, m, k, n, out);
    }
    std::thread::scope(|s| {
        let mut rem: &mut [i32] = out;
        for t in tiles {
            let rows = t.len();
            let (tile_out, rest) = std::mem::take(&mut rem).split_at_mut(rows * n);
            rem = rest;
            let x_tile = &x[t.start * k..t.end * k];
            s.spawn(move || i_matmul_int4(x_tile, packed, bias, rows, k, n, tile_out));
        }
    });
}

/// Row-tiled parallel [`i_matmul_int4_epilogue`]; the epilogue runs
/// inside each tile as its rows finish.
#[allow(clippy::too_many_arguments)]
pub fn i_matmul_int4_epilogue_tiled(
    threads: usize,
    x: &[i32],
    packed: &[u8],
    bias: Option<&[i32]>,
    m: usize,
    k: usize,
    n: usize,
    epi: Epilogue,
    out: &mut [i32],
) {
    check_int4(x, packed, bias, m, k, n, out.len());
    let tiles = tile_ranges(m, threads);
    if tiles.len() <= 1 {
        return i_matmul_int4_epilogue(x, packed, bias, m, k, n, epi, out);
    }
    std::thread::scope(|s| {
        let mut rem: &mut [i32] = out;
        for t in tiles {
            let rows = t.len();
            let (tile_out, rest) = std::mem::take(&mut rem).split_at_mut(rows * n);
            rem = rest;
            let x_tile = &x[t.start * k..t.end * k];
            s.spawn(move || {
                i_matmul_int4_epilogue(x_tile, packed, bias, rows, k, n, epi, tile_out)
            });
        }
    });
}

/// Auto-dispatching [`i_matmul_int4`]: parallel at/above
/// [`crate::quant::PAR_MIN_MACS`] multiply-accumulates, serial below —
/// the same threshold as the INT8 `_par` entry points.
pub fn i_matmul_int4_par(
    x: &[i32],
    packed: &[u8],
    bias: Option<&[i32]>,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    if m > 1 && m.saturating_mul(k).saturating_mul(n) >= super::PAR_MIN_MACS {
        i_matmul_int4_tiled(default_parallelism(), x, packed, bias, m, k, n, out)
    } else {
        i_matmul_int4(x, packed, bias, m, k, n, out)
    }
}

/// Auto-dispatching [`i_matmul_int4_epilogue`]; see
/// [`i_matmul_int4_par`].
#[allow(clippy::too_many_arguments)]
pub fn i_matmul_int4_epilogue_par(
    x: &[i32],
    packed: &[u8],
    bias: Option<&[i32]>,
    m: usize,
    k: usize,
    n: usize,
    epi: Epilogue,
    out: &mut [i32],
) {
    if m > 1 && m.saturating_mul(k).saturating_mul(n) >= super::PAR_MIN_MACS {
        i_matmul_int4_epilogue_tiled(default_parallelism(), x, packed, bias, m, k, n, epi, out)
    } else {
        i_matmul_int4_epilogue(x, packed, bias, m, k, n, epi, out)
    }
}

/// Quantize an INT8-scale bias to the INT4 accumulator scale (the
/// accumulator sits 4 bits lower, so the bias divides by 16 with the
/// same round-half-up the weights use).
pub fn bias_int4(b: &[i32]) -> Vec<i32> {
    b.iter().map(|&v| div_floor(v as i64 + 8, 16) as i32).collect()
}

/// The readout dyadic of an INT4 matmul: the INT8 dyadic scaled by
/// `2^INT4_SHIFT`, compensating the 16x-smaller accumulator bit-exactly
/// (see [`Dyadic::scale_pow2`]).
pub fn int4_readout_dyadic(dy: Dyadic) -> Dyadic {
    dy.scale_pow2(INT4_SHIFT)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{requantize, rescale};

    #[test]
    fn quantization_rounds_half_up_and_clamps() {
        // w8 = 16*w4 exactly on the grid; round-half-up between cells;
        // +127 clamps to the nibble rail
        assert_eq!(
            int4_from_int8(&[0, 16, -16, 8, 7, -8, -9, 127, -128]),
            vec![0, 1, -1, 1, 0, 0, -1, 7, -8]
        );
    }

    #[test]
    fn pack_unpack_round_trips_odd_and_even_k() {
        for (k, n) in [(1usize, 3usize), (2, 3), (5, 4), (8, 1)] {
            let w4: Vec<i32> = (0..k * n).map(|v| (v as i32 % 16) - 8).collect();
            let packed = pack_int4(&w4, k, n);
            assert_eq!(packed.len(), k.div_ceil(2) * n);
            assert_eq!(unpack_int4(&packed, k, n), w4, "k={k} n={n}");
        }
    }

    #[test]
    fn packed_matches_reference_on_identity() {
        let k = 3;
        let mut eye4 = vec![0i32; k * k];
        for i in 0..k {
            eye4[i * k + i] = 1;
        }
        let packed = pack_int4(&eye4, k, k);
        let x: Vec<i32> = vec![5, -7, 3, 0, 2, -8, 1, 1, 1];
        let mut out = vec![0i32; k * k];
        i_matmul_int4(&x, &packed, None, k, k, k, &mut out);
        assert_eq!(out, x);
    }

    #[test]
    fn scaled_dyadic_equals_prescaled_accumulator() {
        // Requant(dy.scale_pow2(4)) on acc == Requant(dy) on 16*acc —
        // the identity the whole INT4 requantize path rests on
        let mut rng = crate::util::rng::Rng::new(0x14);
        for _ in 0..2000 {
            let dy = Dyadic::approx16(0.0001 + rng.f64() * 10.0);
            let dy4 = int4_readout_dyadic(dy);
            let acc = rng.range_i64(-(1 << 24), 1 << 24);
            assert_eq!(requantize(acc, dy4), requantize(acc * 16, dy), "{dy:?} acc={acc}");
            assert_eq!(rescale(acc, dy4), rescale(acc * 16, dy), "{dy:?} acc={acc}");
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "INT8 range")]
    fn packed_kernel_rejects_out_of_range_activations_in_debug() {
        let packed = pack_int4(&[1, 1, 1, 1], 2, 2);
        let x = vec![300i32; 4];
        let mut out = vec![0i32; 4];
        i_matmul_int4(&x, &packed, None, 2, 2, 2, &mut out);
    }

    #[test]
    #[should_panic(expected = "nibble range")]
    fn pack_rejects_out_of_range_nibbles() {
        pack_int4(&[8, 0], 1, 2);
    }
}
