"""End-to-end build pipeline: float model -> calibration -> integer model.

This is the paper's Fig. 17 flow with the simulation substitutions from
DESIGN.md §5: float weights (trained or random) stand in for the
HuggingFace checkpoints, the calibrator stands in for the I-BERT
quantization pass, and the output bundle feeds both the AOT lowering and
the rust simulator/coordinator (via the artifact manifest).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from . import model as M
from .model import Geometry
from .quantize import (
    Calibrator,
    QuantLayerParams,
    calibration_from_taps,
    design_layer,
    int8_scale,
    quantize_tensor,
)


@dataclass
class QuantModel:
    """A fully designed integer model: per-layer params + I/O scales."""

    geo: Geometry
    layers: list[QuantLayerParams]
    s_in: float    # INT8 scale of the encoder input
    s_out: float   # INT8 scale of the encoder output

    def quantize_input(self, x: np.ndarray) -> np.ndarray:
        return quantize_tensor(x, self.s_in)

    def dequantize_output(self, q: np.ndarray) -> np.ndarray:
        return np.asarray(q, dtype=np.float64) * self.s_out


def calibrate_and_design(
    weights: list[dict],
    geo: Geometry,
    calib_inputs: np.ndarray,
    unify: bool = False,
) -> QuantModel:
    """Run calibration batches through the float encoder, then fix every
    design-time constant (paper §III-A: scales are frozen per layer).

    ``unify=True`` makes every layer share one set of activation/weight
    scales (max over layers) so all layers use identical design-time
    constants — required when one HLO executable serves every layer of a
    shaped model.
    """
    cal = Calibrator()
    for x in calib_inputs:
        M.float_encoder(np.asarray(x, dtype=np.float64), weights, geo, cal=cal)

    if unify:
        # fold per-layer taps into layer-0 names by max
        merged: dict[str, float] = {}
        for name, v in cal.taps.items():
            key = "L0." + name.split(".", 1)[1]
            merged[key] = max(merged.get(key, 0.0), v)
        cal.taps = merged
        lc = calibration_from_taps(cal, 0)
        wscales = {
            name: max(int8_scale(np.abs(w[name]).max()) for w in weights)
            for name in ("wq", "wk", "wv", "wo", "w1", "w2")
        }
        layers = [
            design_layer(w, lc, geo.d, geo.heads, weight_scales=wscales)
            for w in weights
        ]
    else:
        layers = []
        for i, w in enumerate(weights):
            lc = calibration_from_taps(cal, i)
            layers.append(design_layer(w, lc, geo.d, geo.heads))

    s_in = layers[0].cal.attn.s_x
    s_out = layers[-1].cal.ffn.s_out
    return QuantModel(geo=geo, layers=layers, s_in=s_in, s_out=s_out)


def run_quant(qm: QuantModel, x: np.ndarray, use_pallas: bool = True) -> np.ndarray:
    """Quantize a float input, run the integer encoder, return INT8 codes."""
    q_x = qm.quantize_input(x)
    return np.asarray(M.quant_encoder(q_x, qm.layers, qm.geo, use_pallas=use_pallas))


def run_float(weights: list[dict], geo: Geometry, x: np.ndarray) -> np.ndarray:
    return np.asarray(M.float_encoder(np.asarray(x, dtype=np.float64), weights, geo))


def quantization_error(
    qm: QuantModel, weights: list[dict], geo: Geometry, x: np.ndarray,
    use_pallas: bool = False,
):
    """Float-vs-integer encoder divergence on one input (validation metric:
    the paper's Table II accuracy deltas trace back to exactly this)."""
    f = run_float(weights, geo, x)
    q = qm.dequantize_output(run_quant(qm, x, use_pallas=use_pallas))
    err = np.abs(f - q)
    denom = max(float(np.abs(f).max()), 1e-9)
    return {
        "max_abs": float(err.max()),
        "mean_abs": float(err.mean()),
        "rel": float(err.max() / denom),
        "cos": float(
            np.dot(f.ravel(), q.ravel())
            / (np.linalg.norm(f) * np.linalg.norm(q) + 1e-30)
        ),
    }
