//! Gate-level synthesis cost model — the stand-in for the paper's
//! Synopsys DC 65 nm flow (DESIGN.md §5).
//!
//! Structure: [`tech`] holds the 65 nm node constants, [`operators`]
//! builds gate-count/delay/energy models of the arithmetic operators
//! (INT8/INT32/FP32 adders and multipliers, dividers, shifters,
//! registers), [`components`] rolls them up into the SwiftTron blocks of
//! Fig. 5, [`report`] produces the paper's Table I summary and
//! Fig. 18 breakdowns (power uses activity factors derived from the
//! cycle-accurate simulator's busy counts), and [`design_space`]
//! searches `HwConfig` candidates per workload — latency from the
//! analytical `sim::cost::CostModel`, area/power/critical-path from
//! this layer — reporting a Pareto front and a budget-constrained
//! recommendation (`swifttron tune`).
//!
//! Fidelity note: gate counts come from standard implementations
//! (carry-save MAC arrays, array multipliers, restoring dividers); they
//! reproduce *ratios and rankings* (FP32 >> INT8, MatMul dominance), not
//! a sign-off quality absolute area.  EXPERIMENTS.md reports
//! paper-vs-model side by side.

pub mod components;
pub mod design_space;
pub mod operators;
pub mod report;
pub mod tech;

pub use components::{component_breakdown, ComponentCost};
pub use design_space::{candidate_grid, explore, Budget, DesignPoint, DesignSpace};
pub use operators::{OperatorCost, Operators};
pub use report::{synthesis_report, SynthesisReport};
pub use tech::Tech65;
