//! Hot-path wallclock benches (the §Perf instrumentation): simulator,
//! functional layer model, PJRT tiny/roberta executions, softmax and
//! layernorm functional kernels.  Used for the before/after log in
//! EXPERIMENTS.md §Perf.

use swifttron::model::{Blob, Geometry, Manifest};
use swifttron::quant::{i_softmax, SoftmaxConsts};
use swifttron::runtime::{Engine, Tensor};
use swifttron::sim::functional::{layer_forward, LayerWeights};
use swifttron::sim::{simulate_encoder, HwConfig};
use swifttron::util::bench::Bench;
use swifttron::util::rng::Rng;

fn main() {
    let cfg = HwConfig::paper();
    let geo = Geometry::preset("roberta_base").unwrap();

    // simulator itself (pure timing model)
    Bench::new("sim: roberta_base full stack").iters(50).run(|| simulate_encoder(&cfg, &geo));

    // functional softmax rows (m=256 row of 256)
    let sm = SoftmaxConsts::design(0.001);
    let mut rng = Rng::new(1);
    let row: Vec<i64> = (0..256).map(|_| rng.range_i64(-4000, 4000)).collect();
    let mut out = vec![0i32; 256];
    Bench::new("quant: i_softmax 256-row").iters(200).run(|| {
        for _ in 0..256 {
            i_softmax(&row, &sm, &mut out);
        }
    });

    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        println!("(artifact benches skipped: run `make artifacts`)");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();

    // rust functional full roberta layer (the co-sim reference)
    let blob = Blob::load(&manifest.blob_prefix("roberta_base").unwrap()).unwrap();
    let w = LayerWeights::from_blob(&blob, 0).unwrap();
    let consts = manifest.preset("roberta_base").unwrap().layers[0].clone();
    let q_x: Vec<i32> = (0..geo.m * geo.d).map(|_| rng.range_i64(-127, 127) as i32).collect();
    Bench::new("functional: roberta_base layer (rust)")
        .warmup(1)
        .iters(3)
        .run(|| layer_forward(&q_x, &w, &consts, &geo));

    // PJRT executions
    let engine = Engine::cpu().unwrap();
    let exe_tiny = engine.load(&manifest.artifact_path("tiny", "int8").unwrap()).unwrap();
    let tg = manifest.preset("tiny").unwrap().geometry;
    let tiny_x: Vec<i32> = (0..tg.m * tg.d).map(|_| rng.range_i64(-127, 127) as i32).collect();
    Bench::new("pjrt: tiny 2-layer encoder").iters(50).run(|| {
        exe_tiny
            .run_i32(&[Tensor::i32(&[tg.m, tg.d], tiny_x.clone())], &[tg.m, tg.d])
            .unwrap()
    });

    let exe_rb = engine
        .load(&manifest.artifact_path("roberta_base", "int8_layer").unwrap())
        .unwrap();
    let mut inputs = vec![Tensor::i32(&[geo.m, geo.d], q_x.clone())];
    for key in [
        "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo", "w1", "b1", "w2", "b2", "gamma1",
        "beta1", "gamma2", "beta2",
    ] {
        let data = blob.i32(&format!("L0.{key}")).unwrap();
        let shape = blob.shape(&format!("L0.{key}")).unwrap().to_vec();
        inputs.push(Tensor::i32(&shape, data));
    }
    Bench::new("pjrt: roberta_base layer (pallas int8)")
        .warmup(1)
        .iters(5)
        .run(|| exe_rb.run_i32(&inputs, &[geo.m, geo.d]).unwrap());
}
