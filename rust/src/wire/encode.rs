//! `SWWIRE1` frame encoding into reusable buffers, plus the client's
//! owned response decoder (DESIGN.md §11).
//!
//! Every `encode_*` appends one complete frame to `out` — the mux
//! keeps one `Vec<u8>` write buffer per connection and reuses its
//! capacity, so the steady-state encode path allocates only when a
//! response outgrows every previous one.

use super::frame::{
    ResponseFrame, HEADER_BYTES, KIND_BUSY, KIND_ERROR, KIND_OK, KIND_OVERLOADED, KIND_REQUEST,
    MAX_FRAME,
};
use crate::coordinator::Response;

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Reserve a length prefix, run `body`, then patch the prefix with the
/// bytes the body appended.
fn framed(out: &mut Vec<u8>, body: impl FnOnce(&mut Vec<u8>)) {
    let at = out.len();
    put_u32(out, 0);
    body(out);
    let len = out.len() - at - HEADER_BYTES;
    debug_assert!(len <= MAX_FRAME);
    out[at..at + HEADER_BYTES].copy_from_slice(&(len as u32).to_le_bytes());
}

/// Append one request frame.  `model` empty targets the default model.
pub fn encode_request(out: &mut Vec<u8>, id: u64, model: &str, tokens: &[i32]) {
    assert!(model.len() <= u8::MAX as usize, "model id too long for the wire");
    assert!(tokens.len() <= u16::MAX as usize, "token count too long for the wire");
    framed(out, |out| {
        out.push(KIND_REQUEST);
        put_u64(out, id);
        out.push(model.len() as u8);
        out.extend_from_slice(model.as_bytes());
        put_u16(out, tokens.len() as u16);
        for &t in tokens {
            out.extend_from_slice(&t.to_le_bytes());
        }
    });
}

/// Append one `Ok` response frame.
pub fn encode_ok(
    out: &mut Vec<u8>,
    id: u64,
    replica: u32,
    label: u16,
    logits: &[i64],
    accel_ms: f64,
    e2e_us: f64,
) {
    framed(out, |out| {
        out.push(KIND_OK);
        put_u64(out, id);
        put_u32(out, replica);
        put_u16(out, label);
        put_f64(out, accel_ms);
        put_f64(out, e2e_us);
        put_u16(out, logits.len().min(u16::MAX as usize) as u16);
        for &l in logits.iter().take(u16::MAX as usize) {
            out.extend_from_slice(&l.to_le_bytes());
        }
    });
}

/// Append one typed `Error` response frame.
pub fn encode_error(out: &mut Vec<u8>, id: u64, message: &str) {
    let msg = &message.as_bytes()[..message.len().min(u16::MAX as usize)];
    framed(out, |out| {
        out.push(KIND_ERROR);
        put_u64(out, id);
        put_u16(out, msg.len() as u16);
        out.extend_from_slice(msg);
    });
}

/// Append one `Overloaded` admission-rejection frame: the predicted
/// queueing delay that crossed `slo_ms` (DESIGN.md §11 shed rule).
pub fn encode_overloaded(out: &mut Vec<u8>, id: u64, predicted_ms: f64, slo_ms: f64) {
    framed(out, |out| {
        out.push(KIND_OVERLOADED);
        put_u64(out, id);
        put_f64(out, predicted_ms);
        put_f64(out, slo_ms);
    });
}

/// Append one `Busy` connection-cap rejection frame (the server closes
/// the connection right after).
pub fn encode_busy(out: &mut Vec<u8>, limit: u32) {
    framed(out, |out| {
        out.push(KIND_BUSY);
        put_u64(out, 0);
        put_u32(out, limit);
    });
}

/// Encode a router [`Response`] as the frame answering client frame
/// `id` (the router's own response id is transport-internal).
pub fn encode_response(out: &mut Vec<u8>, id: u64, resp: &Response) {
    match &resp.error {
        Some(e) => encode_error(out, id, e),
        None => encode_ok(
            out,
            id,
            resp.replica.min(u32::MAX as usize) as u32,
            resp.label.min(u16::MAX as usize) as u16,
            &resp.logits,
            resp.accel_ms,
            resp.e2e_s * 1e6,
        ),
    }
}

fn take<'a>(buf: &mut &'a [u8], n: usize) -> Result<&'a [u8], String> {
    if buf.len() < n {
        return Err("response frame truncated".into());
    }
    let (head, rest) = buf.split_at(n);
    *buf = rest;
    Ok(head)
}

fn take_u64(buf: &mut &[u8]) -> Result<u64, String> {
    Ok(u64::from_le_bytes(take(buf, 8)?.try_into().unwrap()))
}

fn take_u32(buf: &mut &[u8]) -> Result<u32, String> {
    Ok(u32::from_le_bytes(take(buf, 4)?.try_into().unwrap()))
}

fn take_u16(buf: &mut &[u8]) -> Result<u16, String> {
    Ok(u16::from_le_bytes(take(buf, 2)?.try_into().unwrap()))
}

fn take_f64(buf: &mut &[u8]) -> Result<f64, String> {
    Ok(f64::from_le_bytes(take(buf, 8)?.try_into().unwrap()))
}

/// Decode one response frame off the front of `buf` (client side;
/// owned, allocation is fine here).  `Ok(None)` means more bytes are
/// needed; `Ok(Some((consumed, frame)))` yields one frame.
pub fn decode_response(buf: &[u8]) -> Result<Option<(usize, ResponseFrame)>, String> {
    if buf.len() < HEADER_BYTES {
        return Ok(None);
    }
    let len = u32::from_le_bytes(buf[..HEADER_BYTES].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        return Err(format!("response frame len {len} exceeds maximum {MAX_FRAME}"));
    }
    if buf.len() < HEADER_BYTES + len {
        return Ok(None);
    }
    let mut body = &buf[HEADER_BYTES..HEADER_BYTES + len];
    let b = &mut body;
    let kind = take(b, 1)?[0];
    let frame = match kind {
        KIND_OK => {
            let id = take_u64(b)?;
            let replica = take_u32(b)?;
            let label = take_u16(b)?;
            let accel_ms = take_f64(b)?;
            let e2e_us = take_f64(b)?;
            let n = take_u16(b)? as usize;
            let raw = take(b, 8 * n)?;
            let logits =
                raw.chunks_exact(8).map(|c| i64::from_le_bytes(c.try_into().unwrap())).collect();
            ResponseFrame::Ok { id, replica, label, logits, accel_ms, e2e_us }
        }
        KIND_ERROR => {
            let id = take_u64(b)?;
            let n = take_u16(b)? as usize;
            let message = String::from_utf8_lossy(take(b, n)?).into_owned();
            ResponseFrame::Error { id, message }
        }
        KIND_OVERLOADED => {
            let id = take_u64(b)?;
            let predicted_ms = take_f64(b)?;
            let slo_ms = take_f64(b)?;
            ResponseFrame::Overloaded { id, predicted_ms, slo_ms }
        }
        KIND_BUSY => {
            let _id = take_u64(b)?;
            let limit = take_u32(b)?;
            ResponseFrame::Busy { limit }
        }
        k => return Err(format!("unknown response frame kind {k}")),
    };
    Ok(Some((HEADER_BYTES + len, frame)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn encode_response_picks_ok_or_error_kind() {
        let ok = Response {
            id: 900,
            model: "tiny".into(),
            replica: 3,
            label: 1,
            logits: vec![4, 5],
            accel_ms: 0.5,
            e2e_s: 0.002,
            error: None,
        };
        let mut buf = Vec::new();
        encode_response(&mut buf, 42, &ok);
        let (_, frame) = decode_response(&buf).unwrap().unwrap();
        match frame {
            ResponseFrame::Ok { id, replica, label, logits, e2e_us, .. } => {
                assert_eq!(id, 42, "wire id is the client frame id, not the router id");
                assert_eq!((replica, label), (3, 1));
                assert_eq!(logits, vec![4, 5]);
                assert!((e2e_us - 2000.0).abs() < 1e-9);
            }
            other => panic!("{other:?}"),
        }

        let err = Response { error: Some("boom".into()), logits: vec![], ..ok };
        buf.clear();
        encode_response(&mut buf, 43, &err);
        let (_, frame) = decode_response(&buf).unwrap().unwrap();
        assert_eq!(frame, ResponseFrame::Error { id: 43, message: "boom".into() });
    }

    #[test]
    fn reused_buffer_appends_frames_without_clearing() {
        let mut buf = Vec::new();
        encode_busy(&mut buf, 10);
        let first = buf.len();
        encode_overloaded(&mut buf, 1, 2.0, 1.0);
        let (n, f) = decode_response(&buf).unwrap().unwrap();
        assert_eq!(n, first);
        assert_eq!(f, ResponseFrame::Busy { limit: 10 });
        let (_, f2) = decode_response(&buf[n..]).unwrap().unwrap();
        assert!(f2.is_overloaded());
    }

    #[test]
    fn long_error_messages_are_truncated_not_rejected() {
        let mut buf = Vec::new();
        let long = "x".repeat(80_000);
        encode_error(&mut buf, 1, &long);
        let (_, f) = decode_response(&buf).unwrap().unwrap();
        match f {
            ResponseFrame::Error { message, .. } => {
                assert_eq!(message.len(), u16::MAX as usize)
            }
            other => panic!("{other:?}"),
        }
    }
}
