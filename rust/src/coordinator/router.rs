//! Request router: the front half of the parallel serving pipeline
//! (DESIGN.md §2).
//!
//! `submit` enqueues requests into the dynamic [`Batcher`] (length-
//! bucketed when `BatchPolicy::bucket_width` is set, DESIGN.md §6); a
//! single dispatcher thread waits for the size-or-deadline policy to
//! release a dispatch group and hands it to the [`ReplicaPool`], which
//! fans the group out across N engine replicas on the `util` thread
//! pool.  The
//! dispatcher blocks until the group completes (the pool's join), then
//! takes the next group — so groups are pipelined back to back while
//! requests inside a group run concurrently.

use super::batcher::{BatchPolicy, Batcher};
use super::engine::EngineReplica;
use super::metrics::Metrics;
use super::pool::ReplicaPool;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub submitted: Instant,
    pub reply: Sender<Response>,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// which engine replica served this request
    pub replica: usize,
    pub label: usize,
    pub accel_ms: f64,
    pub e2e_s: f64,
    pub error: Option<String>,
}

struct Shared {
    batcher: Mutex<Batcher<Request>>,
    available: Condvar,
    shutdown: AtomicBool,
}

pub struct Router {
    shared: Arc<Shared>,
    pub metrics: Arc<Metrics>,
    dispatcher: Option<JoinHandle<()>>,
    next_id: AtomicU64,
    /// guaranteed-serveable length range of the pool: the intersection
    /// of the replicas' ranges (max of `min_seq_len`, min of
    /// `seq_len`), because dispatch is length-blind round-robin and a
    /// request outside the intersection may land on a replica that
    /// rejects it.  Bounds the padding the token metric may charge;
    /// requests outside it never pollute that metric.
    min_seq_len: usize,
    max_seq_len: usize,
}

impl Router {
    /// Start the serving pipeline over `replicas` engine replicas (the
    /// replica pool spins one worker thread per replica, plus one
    /// dispatcher thread).
    pub fn start(
        replicas: Vec<Arc<dyn EngineReplica>>,
        policy: BatchPolicy,
        metrics: Arc<Metrics>,
    ) -> Router {
        let shared = Arc::new(Shared {
            batcher: Mutex::new(Batcher::new(policy)),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let min_seq_len = replicas.iter().map(|r| r.min_seq_len()).max().unwrap_or(0);
        let max_seq_len = replicas.iter().map(|r| r.seq_len()).min().unwrap_or(0);
        let pool = ReplicaPool::new(replicas, Arc::clone(&metrics));
        let sh = Arc::clone(&shared);
        let dispatcher = std::thread::Builder::new()
            .name("swifttron-dispatch".into())
            .spawn(move || dispatch_loop(sh, pool))
            .expect("spawn dispatcher");
        Router {
            shared,
            metrics,
            dispatcher: Some(dispatcher),
            next_id: AtomicU64::new(0),
            min_seq_len,
            max_seq_len,
        }
    }

    /// Submit a request; the response arrives on `reply`.  The token
    /// count is the request's live sequence length: the batcher groups
    /// it with length-compatible requests (same padded bucket) and the
    /// padding the bucket charges is accounted in the metrics.
    pub fn submit(&self, tokens: Vec<i32>, reply: Sender<Response>) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        self.metrics.record_request();
        let len = tokens.len();
        let padded = {
            let mut b = self.shared.batcher.lock().unwrap();
            b.push_len(Request { id, tokens, submitted: Instant::now(), reply }, len)
        };
        // Token accounting only for serveable requests, and never more
        // padding than the largest geometry a replica actually runs —
        // rejected requests and bucket boundaries beyond the array must
        // not inflate the padding-waste metric.
        if len >= self.min_seq_len.max(1) && len <= self.max_seq_len {
            self.metrics.record_tokens(len, padded.min(self.max_seq_len));
        }
        self.shared.available.notify_one();
        id
    }

    pub fn queue_len(&self) -> usize {
        self.shared.batcher.lock().unwrap().len()
    }

    /// Drain the queue and stop the pipeline (joins the dispatcher,
    /// which in turn joins the replica pool's threads on drop).
    pub fn shutdown(mut self) {
        // The flag must flip while holding the mutex the dispatcher's
        // condvar predicate is checked under, or a store between the
        // predicate check and wait_timeout loses the wakeup and the
        // drain stalls for up to max_wait.
        {
            let _b = self.shared.batcher.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::SeqCst);
        }
        self.shared.available.notify_all();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

fn dispatch_loop(sh: Arc<Shared>, pool: ReplicaPool) {
    loop {
        let group = {
            let mut b = sh.batcher.lock().unwrap();
            loop {
                let shutting = sh.shutdown.load(Ordering::SeqCst);
                if b.is_empty() && shutting {
                    return;
                }
                if b.ready(Instant::now()) || (shutting && !b.is_empty()) {
                    break b.take_batch();
                }
                // park_duration never panics, whatever the queue did
                // between the predicate check and here (drained by a
                // racing shutdown flush, refilled by a submit): empty
                // queues park the bounded default, expired deadlines
                // park zero.
                let timeout = b.park_duration(Instant::now());
                let (guard, _) = sh.available.wait_timeout(b, timeout).unwrap();
                b = guard;
            }
        };
        pool.dispatch(group);
    }
}
