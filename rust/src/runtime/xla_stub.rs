//! Compile-time stand-in for the vendored `xla` crate (PJRT C API
//! bindings).  The real crate is not on crates.io, so the `pjrt`
//! feature would be uncompilable — and silently rot — whenever the
//! vendored checkout is absent.  This stub mirrors exactly the API
//! surface `executable.rs` / `tensor.rs` consume, which lets ci.sh run
//! a check-only `--features pjrt` build on every change.
//!
//! Every runtime entry point fails with a clear message (the feature
//! still has no real PJRT client), so behavior matches the
//! feature-off build: `Engine::cpu()` returns `Err` and callers fall
//! back to the functional serving path.  To link the real backend, add
//! the vendored path dependency in Cargo.toml and replace the
//! `use crate::runtime::xla_stub as xla;` aliases in `executable.rs`
//! and `tensor.rs` with the real crate.

use std::fmt;

/// Error type mirroring the vendored crate's (only `Display` is
/// consumed at the call sites).
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

fn unavailable() -> Error {
    Error(
        "vendored `xla` crate not linked: the `pjrt` feature was built against the \
         in-repo stub (runtime::xla_stub); add the path dependency to enable PJRT"
            .into(),
    )
}

pub struct PjRtClient;
pub struct PjRtLoadedExecutable;
pub struct PjRtBuffer;
pub struct HloModuleProto;
pub struct XlaComputation;
pub struct Literal;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(unavailable())
    }
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(unavailable())
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(unavailable())
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(unavailable())
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(unavailable())
    }
}
