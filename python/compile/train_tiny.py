"""Train the tiny-task model (DESIGN.md §5 accuracy substitution).

The paper reports RoBERTa accuracy on GLUE SST-2 — a sentence-level binary
classification task.  Without the pre-trained checkpoints, we train a
small encoder from scratch on a *synthetic* classification task that
needs the same machinery (attention, LayerNorm, GELU FFN) and then
measure the float-vs-integer accuracy delta the same way the paper's
Table II does.

Task ("keyed sentiment"): the vocabulary splits into a class-0 half and a
class-1 half.  A sequence's tokens are drawn with probability ``BIAS``
from its label's half (the distributional signal a sentiment task has),
and one KEY token is followed by a payload token drawn from the label's
half with certainty (a routing signal attention can sharpen).  A
bag-of-embeddings model tops out near the Bayes rate of the biased
mixture; attention over the KEY pushes past it.

The model: token+position embeddings -> ``layers``-layer encoder
(model.float_encoder) -> mean pool -> linear head.  Trained with plain
Adam, implemented here (no optax in the offline environment).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .model import Geometry

VOCAB = 64
KEY_TOKEN = VOCAB - 1
N_CLASSES = 2


BIAS = 0.65  # probability a token comes from the label's vocabulary half
HALF = (VOCAB - 1) // 2  # class-0 tokens: [0, HALF); class-1: [HALF, VOCAB-1)


def make_dataset(rng: np.random.Generator, n: int, m: int):
    """Generate ``n`` sequences of length ``m`` with labels."""
    labels = rng.integers(0, 2, n).astype(np.int32)
    own = rng.random((n, m)) < BIAS
    lo = rng.integers(0, HALF, (n, m))
    hi = rng.integers(HALF, VOCAB - 1, (n, m))
    own_tok = np.where(labels[:, None] == 1, hi, lo)
    other_tok = np.where(labels[:, None] == 1, lo, hi)
    toks = np.where(own, own_tok, other_tok)
    # keyed payload: deterministic routing signal
    pos = rng.integers(0, m - 1, n)
    payload = np.where(
        labels == 1, rng.integers(HALF, VOCAB - 1, n), rng.integers(0, HALF, n)
    )
    toks[np.arange(n), pos] = KEY_TOKEN
    toks[np.arange(n), pos + 1] = payload
    return toks.astype(np.int32), labels


@dataclass
class TinyModel:
    emb: np.ndarray      # (VOCAB, d) f32
    pos: np.ndarray      # (m, d) f32
    encoder: list[dict]  # float layer weights
    w_head: np.ndarray   # (d, 2) f32
    b_head: np.ndarray   # (2,) f32
    geo: Geometry


def _params_to_pytree(model: TinyModel):
    return {
        "emb": jnp.asarray(model.emb),
        "pos": jnp.asarray(model.pos),
        "enc": [{k: jnp.asarray(v) for k, v in w.items()} for w in model.encoder],
        "w_head": jnp.asarray(model.w_head),
        "b_head": jnp.asarray(model.b_head),
    }


def embed(params, toks):
    return params["emb"][toks] + params["pos"]


def forward_logits(params, toks, geo: Geometry):
    x = embed(params, toks)
    for w in params["enc"]:
        x = M.float_encoder_layer(x, w, geo)
    pooled = x.mean(axis=0)
    return pooled @ params["w_head"] + params["b_head"]


def init_model(seed: int, geo: Geometry) -> TinyModel:
    rng = np.random.default_rng(seed)
    encoder = M.init_encoder_weights(seed + 1, geo)
    # Post-LN transformers need identity-leaning init to train from scratch:
    # exact gamma=1/beta=0 and down-scaled residual-branch projections.
    for w in encoder:
        w["gamma1"] = np.ones(geo.d)
        w["beta1"] = np.zeros(geo.d)
        w["gamma2"] = np.ones(geo.d)
        w["beta2"] = np.zeros(geo.d)
        w["wo"] = w["wo"] * 0.3
        w["w2"] = w["w2"] * 0.3
    return TinyModel(
        emb=rng.normal(0, 0.5, (VOCAB, geo.d)).astype(np.float32),
        pos=rng.normal(0, 0.1, (geo.m, geo.d)).astype(np.float32),
        encoder=encoder,
        w_head=rng.normal(0, 0.1, (geo.d, N_CLASSES)).astype(np.float32),
        b_head=np.zeros(N_CLASSES, dtype=np.float32),
        geo=geo,
    )


def train(
    geo: Geometry,
    seed: int = 0,
    steps: int = 400,
    batch: int = 64,
    lr: float = 3e-4,
    log_every: int = 50,
    log=print,
) -> tuple[TinyModel, list[float]]:
    """Adam training loop; returns the trained model and the loss curve."""
    rng = np.random.default_rng(seed)
    model = init_model(seed, geo)
    params = _params_to_pytree(model)

    def loss_fn(p, toks, labels):
        logits = jax.vmap(lambda t: forward_logits(p, t, geo))(toks)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
        return nll

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    # --- hand-rolled Adam (optax is not in the offline environment) ---
    b1, b2, eps = 0.9, 0.999, 1e-8
    mu = jax.tree.map(jnp.zeros_like, params)
    nu = jax.tree.map(jnp.zeros_like, params)

    @jax.jit
    def adam_step(p, mu, nu, g, t):
        mu = jax.tree.map(lambda m, gg: b1 * m + (1 - b1) * gg, mu, g)
        nu = jax.tree.map(lambda v, gg: b2 * v + (1 - b2) * gg * gg, nu, g)
        def upd(pp, m, v):
            mhat = m / (1 - b1**t)
            vhat = v / (1 - b2**t)
            return pp - lr * mhat / (jnp.sqrt(vhat) + eps)
        return jax.tree.map(upd, p, mu, nu), mu, nu

    warmup = max(1, steps // 10)
    losses = []
    for step in range(1, steps + 1):
        toks, labels = make_dataset(rng, batch, geo.m)
        loss, g = grad_fn(params, jnp.asarray(toks), jnp.asarray(labels))
        # linear lr warmup (post-LN models diverge or stall without it)
        scale = min(1.0, step / warmup)
        g = jax.tree.map(lambda x: x * scale, g)
        params, mu, nu = adam_step(params, mu, nu, g, jnp.float32(step))
        losses.append(float(loss))
        if step % log_every == 0:
            log(f"  step {step:4d}  loss {float(loss):.4f}")

    model = TinyModel(
        emb=np.asarray(params["emb"]),
        pos=np.asarray(params["pos"]),
        encoder=[{k: np.asarray(v, dtype=np.float64) for k, v in w.items()}
                 for w in params["enc"]],
        w_head=np.asarray(params["w_head"]),
        b_head=np.asarray(params["b_head"]),
        geo=geo,
    )
    return model, losses


def accuracy(model: TinyModel, toks: np.ndarray, labels: np.ndarray) -> float:
    params = _params_to_pytree(model)
    fwd = jax.jit(jax.vmap(lambda t: forward_logits(params, t, model.geo)))
    preds = np.asarray(jnp.argmax(fwd(jnp.asarray(toks)), axis=-1))
    return float((preds == labels).mean())
