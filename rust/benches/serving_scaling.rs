//! Serving-scaling sweep (EXPERIMENTS.md §Scaling, §SeqLen,
//! §MultiModel, §Autoscale): closed-loop request throughput of the
//! parallel serving pipeline over replica count × dispatch-group size
//! and over request sequence length, the serial-vs-tiled `i_matmul`
//! kernel comparison, the fused-attention leg, the multi-model
//! weights sweep, the **concurrency leg** — mixed saturating
//! `roberta_base` + `tiny` traffic through the serial single-dispatcher
//! baseline vs the concurrent per-group pipeline (DESIGN.md §9) — and
//! the **CostModel fairness leg**: token-charged vs cycle-charged
//! deficit-round-robin under cross-model cost skew (DESIGN.md §12) —
//! and the **dispatch contention leg**: a many-tenant small-request
//! flood measuring submit-side throughput over producer counts on the
//! per-model-shard submit path (EXPERIMENTS.md §Contention, DESIGN.md
//! §13) — and the **cascade leg** (EXPERIMENTS.md §Cascade, DESIGN.md
//! §14): the INT4 front tier + margin-gated INT8 escalation study
//! (served-cycle reduction vs top-1 agreement over the escalation
//! threshold) plus the pool-mechanics sweep through the real cascade
//! registration; the deterministic smoke subset is pinned by the
//! committed `BENCH_cascade_smoke.json` (rebaseline with
//! `-- --smoke --update` after an intentional numerics change).
//!
//! Run: `cargo bench --bench serving_scaling` — or
//! `cargo bench --bench serving_scaling -- --smoke` for the
//! smoke-sized subset ci.sh runs (reduced scaling + concurrency legs).
//!
//! Machine-readable results: every run writes `BENCH_serving.json`
//! (throughput, p99 latency, and padding waste per leg) so the perf
//! trajectory is tracked across PRs.
//!
//! Acceptance claims this bench demonstrates: more than one replica
//! yields higher request throughput than the single-replica path on
//! the same workload; quarter-length requests yield higher
//! requests/sec than full-length ones on the variable-length Workspace
//! path; and under saturating mixed traffic the `tiny` group's p99
//! latency improves >= 2x over the serial dispatcher baseline while
//! served-token shares stay within 10% of the configured weights.

use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};
use swifttron::coordinator::{
    BatchPolicy, Batcher, EngineReplica, FunctionalEngine, Metrics, ModelGroup, ModelRegistry,
    ReplicaPool, Request, Router, SyntheticModel, DEFAULT_ESCALATE_MARGIN,
};
use swifttron::model::Geometry;
use swifttron::quant::{i_matmul, i_matmul_tiled};
use swifttron::sim::functional::{
    layer_forward_ws, layer_forward_ws_unfused, synthetic_consts, LayerWeights, Workspace,
};
use swifttron::sim::{CostModel, HwConfig};
use swifttron::util::bench::{fmt_time, merge_bench_json, Bench, Table};
use swifttron::util::json::{obj, Json};
use swifttron::util::rng::Rng;
use swifttron::util::threadpool::{default_parallelism, run_scoped, tile_ranges};

const REQUESTS: usize = 96;

/// One closed-loop run: submit every request up front, wait for all
/// replies, report wall seconds and the metrics ledger.
fn run_once(replicas: usize, max_batch: usize) -> (f64, Arc<Metrics>) {
    let engines: Vec<Arc<dyn EngineReplica>> = (0..replicas)
        .map(|_| {
            Arc::new(FunctionalEngine::synthetic("tiny", 7, HwConfig::paper()).unwrap())
                as Arc<dyn EngineReplica>
        })
        .collect();
    let m = engines[0].seq_len();
    let metrics = Arc::new(Metrics::new());
    let policy =
        BatchPolicy { max_batch, max_wait: Duration::from_micros(500), bucket_width: 0 };
    let router = Router::start(engines, policy, Arc::clone(&metrics));

    let mut rng = Rng::new(1);
    let t0 = Instant::now();
    let receivers: Vec<_> = (0..REQUESTS)
        .map(|_| {
            let tokens: Vec<i32> = (0..m).map(|_| rng.below(60) as i32).collect();
            let (tx, rx) = channel();
            router.submit(tokens, tx);
            rx
        })
        .collect();
    for rx in receivers {
        let resp = rx.recv().expect("response");
        assert!(resp.error.is_none(), "{:?}", resp.error);
    }
    let wall = t0.elapsed().as_secs_f64();
    router.shutdown();
    (wall, metrics)
}

/// One closed-loop run of `REQUESTS` requests through the bucketed
/// pipeline, each request's live length drawn from `sample_len`
/// (EXPERIMENTS.md §SeqLen).
fn run_len(
    mut sample_len: impl FnMut(&mut Rng) -> usize,
    replicas: usize,
    max_batch: usize,
    bucket_width: usize,
) -> (f64, Arc<Metrics>) {
    let engines: Vec<Arc<dyn EngineReplica>> = (0..replicas)
        .map(|_| {
            Arc::new(FunctionalEngine::synthetic("tiny", 7, HwConfig::paper()).unwrap())
                as Arc<dyn EngineReplica>
        })
        .collect();
    let metrics = Arc::new(Metrics::new());
    let policy = BatchPolicy { max_batch, max_wait: Duration::from_micros(500), bucket_width };
    let router = Router::start(engines, policy, Arc::clone(&metrics));

    let mut rng = Rng::new(1);
    let t0 = Instant::now();
    let receivers: Vec<_> = (0..REQUESTS)
        .map(|_| {
            let m_eff = sample_len(&mut rng);
            let tokens: Vec<i32> = (0..m_eff).map(|_| rng.below(60) as i32).collect();
            let (tx, rx) = channel();
            router.submit(tokens, tx);
            rx
        })
        .collect();
    for rx in receivers {
        let resp = rx.recv().expect("response");
        assert!(resp.error.is_none(), "{:?}", resp.error);
    }
    let wall = t0.elapsed().as_secs_f64();
    router.shutdown();
    (wall, metrics)
}

/// Replica count × dispatch-group size sweep; returns the JSON rows.
fn scaling_leg(replica_counts: &[usize], batch_sizes: &[usize]) -> Json {
    let mut table = Table::new(&[
        "replicas", "max_batch", "wall", "req/s", "speedup", "p99 e2e", "virtual ms/replica",
    ]);
    let mut rows = Vec::new();
    let mut baseline: Vec<f64> = Vec::new(); // req/s at 1 replica, per batch size
    for &r in replica_counts {
        for (bi, &b) in batch_sizes.iter().enumerate() {
            let (wall, metrics) = run_once(r, b);
            let rps = REQUESTS as f64 / wall;
            if r == replica_counts[0] {
                baseline.push(rps);
            }
            let speedup = rps / baseline[bi];
            let p99_ms = metrics.e2e_s.lock().unwrap().p99() * 1e3;
            let virt_per_replica = metrics.total_accel_ms() / r as f64;
            table.row(&[
                r.to_string(),
                b.to_string(),
                fmt_time(wall),
                format!("{rps:.0}"),
                format!("{speedup:.2}x"),
                format!("{p99_ms:.3}ms"),
                format!("{virt_per_replica:.2}"),
            ]);
            rows.push(obj([
                ("replicas", r.into()),
                ("max_batch", b.into()),
                ("wall_s", wall.into()),
                ("req_per_s", rps.into()),
                ("speedup_vs_1_replica", speedup.into()),
                ("p99_e2e_ms", p99_ms.into()),
                ("virtual_ms_per_replica", virt_per_replica.into()),
            ]));
        }
    }
    table.print("replica count x dispatch-group size (tiny preset)");
    println!(
        "\nspeedup column is vs the single-replica path at the same group size;\n\
         >1.0x for multi-replica rows demonstrates the pool converts replicas\n\
         into request throughput.  virtual ms/replica is simulated accelerator\n\
         time and stays constant per request — wall time drops, cycle cost\n\
         does not (the hardware claim the coordinator preserves)."
    );
    Json::Arr(rows)
}

/// Concurrency leg (EXPERIMENTS.md §Autoscale, DESIGN.md §9): mixed
/// saturating `roberta_base` + `tiny` traffic, serial single-dispatcher
/// baseline vs the concurrent per-group pipeline.  Returns the JSON
/// summary.
fn concurrency_leg(smoke: bool) -> Json {
    // Weights are configured proportional to the offered padded-token
    // volumes, so in this closed-loop run "served shares within 10% of
    // weights" is a conservation check — it catches lost, duplicated,
    // or starved-by-errors requests under concurrent dispatch, not DRR
    // arbitration (per-group dispatchers over disjoint replicas never
    // contend at the ledger).  The backlogged-regime DRR convergence
    // property is asserted where the ledger actually arbitrates:
    // `multi_model.rs` and `prop_invariants.rs`.
    let (tiny_n, heavy_n, heavy_len) = if smoke { (24usize, 3usize, 4usize) } else { (48, 6, 6) };
    let weights: [u64; 2] = [tiny_n as u64, heavy_n as u64];
    let policy =
        BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(500), bucket_width: 8 };
    let build_groups = |tiny_w: u64, heavy_w: u64| {
        let mut reg = ModelRegistry::new();
        reg.register("tiny", "tiny", 2, tiny_w, 7).unwrap();
        reg.register("roberta_base", "roberta_base", 1, heavy_w, 7).unwrap();
        reg.into_groups()
    };
    let tiny_len = |i: usize| 1 + i % 8;

    // -- serial single-dispatcher baseline ---------------------------
    let serial_metrics = Arc::new(Metrics::new());
    serial_metrics.ensure_models(&[("tiny", weights[0]), ("roberta_base", weights[1])]);
    let pool =
        ReplicaPool::new_multi(build_groups(weights[0], weights[1]), Arc::clone(&serial_metrics));
    let mut batcher: Batcher<Request> = Batcher::new(policy);
    batcher.set_model_weights(&weights);
    let mut receivers = Vec::new();
    let mut id = 0u64;
    let t0 = Instant::now();
    for i in 0..tiny_n {
        if i < heavy_n {
            let (tx, rx) = channel();
            id += 1;
            batcher.push_keyed(
                Request {
                    id,
                    model: 1,
                    tokens: (0..heavy_len).map(|t| (t % 50) as i32).collect(),
                    padded_len: policy.padded_len(heavy_len),
                    cost: policy.padded_len(heavy_len) as u64,
                    submitted: Instant::now(),
                    origin: None,
                    reply: tx,
                },
                1,
                heavy_len,
            );
            serial_metrics.record_tokens(1, heavy_len, policy.padded_len(heavy_len));
            receivers.push(rx);
        }
        let len = tiny_len(i);
        let (tx, rx) = channel();
        id += 1;
        batcher.push_keyed(
            Request {
                id,
                model: 0,
                tokens: (0..len).map(|t| (t % 50) as i32).collect(),
                padded_len: policy.padded_len(len),
                cost: policy.padded_len(len) as u64,
                submitted: Instant::now(),
                origin: None,
                reply: tx,
            },
            0,
            len,
        );
        serial_metrics.record_tokens(0, len, policy.padded_len(len));
        receivers.push(rx);
    }
    while !batcher.is_empty() {
        let group = batcher.take_batch();
        for resp in pool.dispatch(group) {
            assert!(resp.error.is_none(), "{:?}", resp.error);
        }
    }
    let serial_wall = t0.elapsed().as_secs_f64();
    drop(receivers);
    let (_, serial_tiny_p99) = serial_metrics.model(0).e2e_percentiles_ms();
    let (_, serial_heavy_p99) = serial_metrics.model(1).e2e_percentiles_ms();

    // -- concurrent per-group pipeline, identical traffic ------------
    let conc_metrics = Arc::new(Metrics::new());
    let router = Router::start_multi(
        build_groups(weights[0], weights[1]),
        policy,
        Arc::clone(&conc_metrics),
    );
    let mut receivers = Vec::new();
    let t0 = Instant::now();
    for i in 0..tiny_n {
        if i < heavy_n {
            let (tx, rx) = channel();
            router.submit_to(
                "roberta_base",
                (0..heavy_len).map(|t| (t % 50) as i32).collect(),
                tx,
            );
            receivers.push(rx);
        }
        let len = tiny_len(i);
        let (tx, rx) = channel();
        router.submit_to("tiny", (0..len).map(|t| (t % 50) as i32).collect(), tx);
        receivers.push(rx);
    }
    for rx in receivers {
        let resp = rx.recv().expect("response");
        assert!(resp.error.is_none(), "{:?}", resp.error);
    }
    let conc_wall = t0.elapsed().as_secs_f64();
    router.shutdown();
    let (_, conc_tiny_p99) = conc_metrics.model(0).e2e_percentiles_ms();
    let (_, conc_heavy_p99) = conc_metrics.model(1).e2e_percentiles_ms();

    let improvement = serial_tiny_p99 / conc_tiny_p99;
    let total = (tiny_n + heavy_n) as f64;
    let total_w = (weights[0] + weights[1]) as f64;
    let mut shares_ok = true;
    for (m, &w) in weights.iter().enumerate() {
        let share = conc_metrics.model_token_share(m);
        let target = w as f64 / total_w;
        shares_ok &= (share - target).abs() <= 0.1 * target;
    }

    let mut table = Table::new(&[
        "pipeline", "wall", "req/s", "tiny p99", "roberta p99", "tiny waste",
    ]);
    table.row(&[
        "serial".into(),
        fmt_time(serial_wall),
        format!("{:.0}", total / serial_wall),
        format!("{serial_tiny_p99:.3}ms"),
        format!("{serial_heavy_p99:.3}ms"),
        format!("{:.0}%", 100.0 * serial_metrics.model(0).padding_waste()),
    ]);
    table.row(&[
        "per-group".into(),
        fmt_time(conc_wall),
        format!("{:.0}", total / conc_wall),
        format!("{conc_tiny_p99:.3}ms"),
        format!("{conc_heavy_p99:.3}ms"),
        format!("{:.0}%", 100.0 * conc_metrics.model(0).padding_waste()),
    ]);
    table.print(
        "concurrency leg: serial dispatcher vs per-group pipeline (mixed saturating traffic)",
    );
    println!(
        "\ntiny p99 improves {improvement:.1}x with per-group dispatch (acceptance\n\
         bound: >= 2x): tiny's groups no longer queue behind roberta_base's\n\
         group barriers.  served-token shares within 10% of the configured\n\
         (offered-volume-proportional) weights: {shares_ok} — a conservation\n\
         check under concurrency; backlogged-regime DRR convergence is\n\
         asserted in multi_model.rs / prop_invariants.rs."
    );
    assert!(
        improvement >= 2.0,
        "tiny p99 improved only {improvement:.2}x (serial {serial_tiny_p99:.3}ms, \
         concurrent {conc_tiny_p99:.3}ms)"
    );
    assert!(
        shares_ok,
        "served-token shares drifted past 10% of configured weights — requests \
         lost or a tenant starved under concurrent dispatch"
    );

    obj([
        ("tiny_requests", tiny_n.into()),
        ("roberta_requests", heavy_n.into()),
        (
            "serial",
            obj([
                ("wall_s", serial_wall.into()),
                ("req_per_s", (total / serial_wall).into()),
                ("tiny_p99_ms", serial_tiny_p99.into()),
                ("roberta_p99_ms", serial_heavy_p99.into()),
                ("tiny_padding_waste", serial_metrics.model(0).padding_waste().into()),
            ]),
        ),
        (
            "concurrent",
            obj([
                ("wall_s", conc_wall.into()),
                ("req_per_s", (total / conc_wall).into()),
                ("tiny_p99_ms", conc_tiny_p99.into()),
                ("roberta_p99_ms", conc_heavy_p99.into()),
                ("tiny_padding_waste", conc_metrics.model(0).padding_waste().into()),
            ]),
        ),
        ("tiny_p99_improvement", improvement.into()),
        ("shares_within_10pct_of_weights", shares_ok.into()),
    ])
}

/// CostModel fairness leg (EXPERIMENTS.md §CostModel, DESIGN.md §12):
/// token-charged vs cycle-charged deficit-round-robin under a
/// cross-model cost skew.  Two equal-weight tenants submit requests of
/// identical token length — 8 live tokens — but one tenant runs
/// `roberta_base` and the other `tiny`, so the *predicted accelerator
/// work* per request differs by two orders of magnitude.  The same
/// backlogged arrivals go through two ledgers: one charging bucket
/// tokens (every request costs 8 — the pre-ISSUE-8 unit) and one
/// charging `CostModel::predict_cycles(8)`.  Served shares are measured
/// in predicted cycles, the unit the accelerator actually spends;
/// equal weights make the ideal split 50/50.
fn costmodel_fairness_leg(smoke: bool) -> Json {
    const LEN: usize = 8;
    let heavy_geo = Geometry::preset("roberta_base").unwrap();
    let light_geo = Geometry::preset("tiny").unwrap();
    let cm_heavy = CostModel::build(&HwConfig::sized_to(&heavy_geo), &heavy_geo).unwrap();
    let cm_light = CostModel::build(&HwConfig::sized_to(&light_geo), &light_geo).unwrap();
    let (c_heavy, c_light) = (cm_heavy.predict_cycles(LEN), cm_light.predict_cycles(LEN));
    assert!(c_heavy > c_light, "roberta_base must out-cost tiny at equal length");
    let policy =
        BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(3600), bucket_width: 8 };
    // Measurement window: the predicted-cycle volume of `window_batches`
    // all-heavy dispatch groups.  Both ledgers serve the same window, so
    // the shares compare like for like; the DRR granularity bound keeps
    // the cycle-charged share within one heavy group of 50/50, i.e.
    // within 1/(2*window_batches) — comfortably inside the 0.1 assert
    // even at smoke size.
    let window_batches: u64 = if smoke { 8 } else { 24 };
    let window = window_batches * policy.max_batch as u64 * c_heavy;
    let n_heavy = (window_batches as usize + 4) * policy.max_batch;
    let n_light = (window / c_light) as usize + 4 * policy.max_batch;

    // Serve the window under one charge unit; items carry their true
    // predicted cost so served work is measured identically either way.
    let run = |charge_heavy: u64, charge_light: u64| -> f64 {
        let mut b: Batcher<(usize, u64)> = Batcher::new(policy);
        b.set_model_weights(&[1, 1]);
        for _ in 0..n_heavy {
            b.push_costed((0, c_heavy), 0, LEN, charge_heavy);
        }
        for _ in 0..n_light {
            b.push_costed((1, c_light), 1, LEN, charge_light);
        }
        let mut served = [0u64; 2];
        while served[0] + served[1] < window {
            let batch = b.take_batch();
            assert!(!batch.is_empty(), "fairness leg ran out of queued work");
            for (m, cycles) in batch {
                served[m] += cycles;
            }
        }
        served[0] as f64 / (served[0] + served[1]) as f64
    };
    let token_share = run(LEN as u64, LEN as u64);
    let cycle_share = run(c_heavy, c_light);
    let token_err = (token_share - 0.5).abs();
    let cycle_err = (cycle_share - 0.5).abs();

    let mut table = Table::new(&["charge unit", "heavy work share", "error vs 50/50"]);
    table.row(&["tokens".into(), format!("{:.1}%", 100.0 * token_share), format!("{token_err:.3}")]);
    table.row(&["cycles".into(), format!("{:.1}%", 100.0 * cycle_share), format!("{cycle_err:.3}")]);
    table.print("CostModel fairness leg: token-charged vs cycle-charged DRR (equal weights)");
    println!(
        "\nequal-length requests, {c_heavy} vs {c_light} predicted cycles per\n\
         request: the token-charged ledger splits *requests* evenly and hands\n\
         the heavy tenant {:.0}% of the accelerator; the cycle-charged ledger\n\
         splits predicted *work* and lands within {cycle_err:.3} of 50/50.",
        100.0 * token_share
    );
    assert!(
        cycle_err < token_err,
        "cycle-charged share error {cycle_err:.3} is not better than token-charged {token_err:.3}"
    );
    assert!(cycle_err <= 0.1, "cycle-charged share drifted {cycle_err:.3} from the ideal 50/50");

    obj([
        ("request_len", LEN.into()),
        ("heavy_cycles_per_req", (c_heavy as i64).into()),
        ("light_cycles_per_req", (c_light as i64).into()),
        ("work_window_cycles", (window as i64).into()),
        ("token_charged_heavy_work_share", token_share.into()),
        ("cycle_charged_heavy_work_share", cycle_share.into()),
        ("token_charged_error", token_err.into()),
        ("cycle_charged_error", cycle_err.into()),
    ])
}

/// Dispatch-contention leg (EXPERIMENTS.md §Contention, DESIGN.md
/// §13): many tenants, small-request flood, producer counts 1/2/4
/// hammering `Router::submit_to` concurrently.  The measured quantity
/// is *submit-side* throughput — wall time of the submit loops alone,
/// replies drained afterwards — which is exactly the path that used to
/// serialize on the global batcher mutex and its `notify_all`: every
/// producer, every model, one lock.  With the per-model shards a
/// submit locks only its target model's shard, so aggregate submit
/// throughput should hold or scale as producers are added instead of
/// flatlining.  No hard scaling assertion: single-core CI boxes can't
/// promise parallel speedup — the leg records the trajectory and
/// asserts only conservation (every request answered, no errors).
fn dispatch_contention_leg(smoke: bool) -> Json {
    use swifttron::workload::DelayReplica;
    let tenants = if smoke { 4usize } else { 8 };
    let per_producer = if smoke { 2_000usize } else { 8_000 };
    let policy =
        BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200), bucket_width: 8 };
    let tenant_groups = || -> Vec<ModelGroup> {
        (0..tenants)
            .map(|i| {
                let replicas: Vec<Arc<dyn EngineReplica>> =
                    vec![Arc::new(DelayReplica::from_ms(0))];
                ModelGroup::fixed(format!("t{i}"), replicas, 1)
            })
            .collect()
    };

    let mut table = Table::new(&["producers", "requests", "submit wall", "submits/s"]);
    let mut runs = Vec::new();
    for &producers in &[1usize, 2, 4] {
        let metrics = Arc::new(Metrics::new());
        let router =
            Arc::new(Router::start_multi(tenant_groups(), policy, Arc::clone(&metrics)));
        let total = producers * per_producer;
        let (coll_tx, coll_rx) = channel();
        let t0 = Instant::now();
        let handles: Vec<_> = (0..producers)
            .map(|p| {
                let router = Arc::clone(&router);
                let coll_tx = coll_tx.clone();
                std::thread::spawn(move || {
                    for i in 0..per_producer {
                        let model = format!("t{}", (p + i) % tenants);
                        let len = 1 + i % 6;
                        let (tx, rx) = channel();
                        router.submit_to(&model, vec![1; len], tx);
                        coll_tx.send(rx).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let submit_wall = t0.elapsed().as_secs_f64();
        drop(coll_tx);
        let mut answered = 0usize;
        for rx in coll_rx.iter() {
            let resp = rx.recv().expect("response");
            assert!(resp.error.is_none(), "{:?}", resp.error);
            answered += 1;
        }
        match Arc::try_unwrap(router) {
            Ok(r) => r.shutdown(),
            Err(_) => unreachable!("producers joined"),
        }
        assert_eq!(answered, total, "flood lost requests under contention");
        let rate = total as f64 / submit_wall;
        table.row(&[
            producers.to_string(),
            total.to_string(),
            fmt_time(submit_wall),
            format!("{rate:.0}"),
        ]);
        runs.push(obj([
            ("producers", producers.into()),
            ("requests", total.into()),
            ("submit_wall_s", submit_wall.into()),
            ("submits_per_s", rate.into()),
        ]));
    }
    table.print(&format!(
        "dispatch contention leg: {tenants} tenants, small-request flood, \
         per-model shard submit path (DESIGN.md §13)"
    ));
    println!(
        "\nsubmit wall times the producer loops only — the submit->pop hot\n\
         path that previously serialized every producer on one batcher\n\
         mutex.  Per-producer submit rate holding steady as producers are\n\
         added is the sharding win; absolute scaling depends on host cores."
    );

    obj([
        ("tenants", tenants.into()),
        ("per_producer", per_producer.into()),
        ("runs", Json::Arr(runs)),
    ])
}

const CASCADE_SNAPSHOT_PATH: &str = "BENCH_cascade_smoke.json";
const CASCADE_SNAPSHOT_SCHEMA: &str = "swifttron-cascade-smoke-v1";
/// The deterministic request count the committed snapshot pins; the
/// full (non-smoke) run extends the same rng stream, so its first
/// `CASCADE_SMOKE_REQUESTS` records reproduce the smoke subset
/// byte-for-byte.
const CASCADE_SMOKE_REQUESTS: usize = 200;
const CASCADE_MODEL_SEED: u64 = 11;
const CASCADE_REQUEST_SEED: u64 = 0xCA5CADE;
/// Escalation thresholds swept by the acceptance study; must include
/// `DEFAULT_ESCALATE_MARGIN` (the CLI default the assertions gate on).
const CASCADE_THRESHOLDS: [i64; 6] = [0, 2000, 4000, 6000, 8000, 12000];

/// One acceptance record: the INT4 tier's confidence margin and
/// whether its label agrees with the INT8 tier on one request.
struct CascadeRec {
    len: usize,
    agree: bool,
    margin4: i64,
}

/// Top-1 logit margin — the pool's confidence gate
/// (`coordinator::pool`), mirrored here so the offline study sweeps
/// the exact quantity the serving path escalates on.
fn top1_margin(logits: &[i64]) -> i64 {
    if logits.len() < 2 {
        return i64::MAX;
    }
    let (mut top, mut second) = (i64::MIN, i64::MIN);
    for &l in logits {
        if l > top {
            second = top;
            top = l;
        } else if l > second {
            second = l;
        }
    }
    top.saturating_sub(second)
}

/// Cascade acceptance leg (EXPERIMENTS.md §Cascade, DESIGN.md §14):
/// every request served by both the packed-INT4 tier and the INT8 tier
/// of the same synthetic bundle (one encoder layer at `roberta_base`
/// width — the depth the INT4 grid holds its accuracy at), then the
/// escalation threshold swept offline over the recorded margins.
/// Served cost is charged in `CostModel` cycles on the equal-silicon
/// pair (`HwConfig::sized_to` vs its `int4_variant`): the cascade at a
/// threshold serves every request on INT4 and re-serves the
/// below-margin ones on INT8, so its cycles are `c4 + esc * c8`
/// against the pure-INT8 baseline's `c8`.  Hard acceptance bounds at
/// `DEFAULT_ESCALATE_MARGIN`: top-1 agreement >= 99% of the INT8
/// labels AND served-cycle reduction >= 25%.  Returns the JSON leg and
/// the deterministic snapshot payload for the smoke-subset gate.
fn cascade_acceptance_leg(smoke: bool) -> (Json, String) {
    let geo = Geometry::new(768, 12, 256, 3072, 1);
    let n = if smoke { CASCADE_SMOKE_REQUESTS } else { 2 * CASCADE_SMOKE_REQUESTS };
    let model = Arc::new(SyntheticModel::build_geo(&geo, CASCADE_MODEL_SEED));
    let layers4 = Arc::new(model.quantize_int4());
    let hw8 = HwConfig::sized_to(&geo);
    let hw4 = hw8.int4_variant();
    let cost8 = Arc::new(CostModel::build(&hw8, &geo).expect("INT8 cost model"));
    let cost4 = Arc::new(CostModel::build(&hw4, &geo).expect("INT4 cost model"));

    let mut rng = Rng::new(CASCADE_REQUEST_SEED);
    let requests: Vec<Vec<i32>> = (0..n)
        .map(|_| {
            let len = 8 + rng.below(33) as usize;
            (0..len).map(|_| rng.below(64) as i32).collect()
        })
        .collect();

    // Both precisions over every request, tiled across cores.  Each
    // tile builds its own engine pair: an engine serializes predicts
    // on its internal Workspace mutex, so sharing one across tiles
    // would serialize the sweep.
    let tiles = tile_ranges(n, default_parallelism());
    let slots: Vec<Mutex<Vec<CascadeRec>>> = tiles.iter().map(|_| Mutex::new(Vec::new())).collect();
    let t0 = Instant::now();
    run_scoped(
        tiles
            .iter()
            .cloned()
            .zip(&slots)
            .map(|(range, slot)| {
                let (model, layers4) = (&model, &layers4);
                let (cost8, cost4) = (&cost8, &cost4);
                let requests = &requests;
                move || {
                    let e8 = FunctionalEngine::from_model_with_cost(
                        Arc::clone(model),
                        hw8,
                        Arc::clone(cost8),
                    );
                    let e4 = FunctionalEngine::from_model_int4(
                        Arc::clone(model),
                        Arc::clone(layers4),
                        hw4,
                        Arc::clone(cost4),
                    );
                    let mut out = Vec::with_capacity(range.len());
                    for toks in &requests[range] {
                        let p8 = e8.predict(toks).expect("INT8 predict");
                        let p4 = e4.predict(toks).expect("INT4 predict");
                        out.push(CascadeRec {
                            len: toks.len(),
                            agree: p4.label == p8.label,
                            margin4: top1_margin(&p4.logits),
                        });
                    }
                    *slot.lock().unwrap() = out;
                }
            })
            .collect(),
    );
    let wall = t0.elapsed().as_secs_f64();
    let recs: Vec<CascadeRec> = slots.into_iter().flat_map(|s| s.into_inner().unwrap()).collect();

    // (escalated, served-label agreements, cascade served cycles) at
    // one threshold: escalated requests serve the INT8 label by
    // construction, everything else serves the INT4 one.
    let stats = |subset: &[CascadeRec], thr: i64| -> (u64, u64, u64) {
        let (mut esc, mut agree, mut served) = (0u64, 0u64, 0u64);
        for r in subset {
            served += cost4.predict_cycles(r.len);
            if r.margin4 < thr {
                esc += 1;
                agree += 1;
                served += cost8.predict_cycles(r.len);
            } else if r.agree {
                agree += 1;
            }
        }
        (esc, agree, served)
    };
    let baseline = |subset: &[CascadeRec]| -> u64 {
        subset.iter().map(|r| cost8.predict_cycles(r.len)).sum()
    };

    let base = baseline(&recs);
    let mut table =
        Table::new(&["margin", "escalated", "agreement", "served Mcyc/req", "reduction"]);
    let mut json_rows = Vec::new();
    let mut at_default = None;
    for &thr in &CASCADE_THRESHOLDS {
        let (esc, agree, served) = stats(&recs, thr);
        let rate = esc as f64 / n as f64;
        let agreement = agree as f64 / n as f64;
        let reduction = 1.0 - served as f64 / base as f64;
        if thr == DEFAULT_ESCALATE_MARGIN {
            at_default = Some((rate, agreement, reduction));
        }
        table.row(&[
            thr.to_string(),
            format!("{esc} ({:.1}%)", 100.0 * rate),
            format!("{agreement:.4}"),
            format!("{:.2}", served as f64 / n as f64 / 1e6),
            format!("{:.1}%", 100.0 * reduction),
        ]);
        json_rows.push(obj([
            ("margin", thr.into()),
            ("escalated", (esc as i64).into()),
            ("escalation_rate", rate.into()),
            ("top1_agreement", agreement.into()),
            ("served_cycles", (served as i64).into()),
            ("served_cycle_reduction", reduction.into()),
        ]));
    }
    table.print(&format!(
        "cascade acceptance leg: INT4 front tier + margin-gated INT8 escalation \
         (d=768, 12 heads, d_ff=3072, 1 layer, {n} requests, {wall:.1}s)"
    ));
    let (rate, agreement, reduction) =
        at_default.expect("DEFAULT_ESCALATE_MARGIN must be in CASCADE_THRESHOLDS");
    println!(
        "\nbaseline {:.2} Mcycles/request pure-INT8; at the default margin\n\
         ({DEFAULT_ESCALATE_MARGIN}) the cascade escalates {:.1}% of requests, keeps\n\
         {:.2}% top-1 agreement with the INT8 labels, and cuts served\n\
         accelerator cycles {:.1}% — the equal-silicon INT4 array finishes a\n\
         request in under half the cycles, so even with every escalation\n\
         re-served at INT8 the fleet comes out ahead.",
        base as f64 / n as f64 / 1e6,
        100.0 * rate,
        100.0 * agreement,
        100.0 * reduction
    );
    assert!(
        agreement >= 0.99,
        "cascade top-1 agreement {agreement:.4} fell below the 0.99 acceptance bound \
         at the default margin {DEFAULT_ESCALATE_MARGIN}"
    );
    assert!(
        reduction >= 0.25,
        "cascade served-cycle reduction {reduction:.4} fell below the 0.25 acceptance \
         bound at the default margin {DEFAULT_ESCALATE_MARGIN}"
    );

    // Deterministic smoke-subset snapshot: integer counts only, so the
    // committed baseline is byte-stable across hosts.
    let subset = &recs[..CASCADE_SMOKE_REQUESTS];
    let thr_rows: Vec<Json> = CASCADE_THRESHOLDS
        .iter()
        .map(|&thr| {
            let (esc, agree, served) = stats(subset, thr);
            Json::Obj(BTreeMap::from([
                ("margin".to_string(), thr.into()),
                ("escalated".to_string(), (esc as i64).into()),
                ("agree".to_string(), (agree as i64).into()),
                ("served_cycles".to_string(), (served as i64).into()),
            ]))
        })
        .collect();
    let snapshot = format!(
        "{}\n",
        Json::Obj(BTreeMap::from([
            ("schema".to_string(), CASCADE_SNAPSHOT_SCHEMA.into()),
            ("requests".to_string(), CASCADE_SMOKE_REQUESTS.into()),
            ("model_seed".to_string(), (CASCADE_MODEL_SEED as i64).into()),
            ("baseline_cycles".to_string(), (baseline(subset) as i64).into()),
            ("thresholds".to_string(), Json::Arr(thr_rows)),
        ]))
    );

    let leg = obj([
        ("requests", n.into()),
        ("wall_s", wall.into()),
        ("default_margin", DEFAULT_ESCALATE_MARGIN.into()),
        ("escalation_rate_at_default", rate.into()),
        ("top1_agreement_at_default", agreement.into()),
        ("served_cycle_reduction_at_default", reduction.into()),
        ("baseline_cycles_per_req", ((base / n as u64) as i64).into()),
        ("sweep", Json::Arr(json_rows)),
    ]);
    (leg, snapshot)
}

/// Cascade pool-mechanics leg: the same gate exercised through the
/// real `register_cascade_scaled` registration and the concurrent
/// router.  A margin sweep over one `tiny` cascade pair checks the
/// ledger invariants (front completions + escalations == submissions,
/// the INT8 sibling serves exactly the escalations, every escalated
/// completion lands in the cascade latency series) and that the
/// escalation count is monotone in the threshold — 0 at margin 0, all
/// requests at `i64::MAX`.  A two-tenant run then replays identical
/// traffic through two pairs with different per-tenant margins: the
/// looser tenant must escalate strictly more, demonstrating the knob
/// is per-tenant, not global.
fn cascade_mechanics_leg(smoke: bool) -> Json {
    let n = if smoke { 32usize } else { 96 };
    let policy =
        BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(500), bucket_width: 8 };
    let gen_requests = |n: usize| -> Vec<Vec<i32>> {
        let mut rng = Rng::new(0xE5CA);
        (0..n)
            .map(|_| {
                let len = 1 + rng.below(8) as usize;
                (0..len).map(|_| rng.below(64) as i32).collect()
            })
            .collect()
    };

    // -- margin sweep: one cascade pair, identical traffic per run ---
    let run = |margin: i64| -> u64 {
        let mut reg = ModelRegistry::new();
        reg.register_cascade_scaled("t", "tiny", 1, 1, 1, None, 7, margin).unwrap();
        let metrics = Arc::new(Metrics::new());
        let router = Router::start_multi(reg.into_groups(), policy, Arc::clone(&metrics));
        let receivers: Vec<_> = gen_requests(n)
            .into_iter()
            .map(|tokens| {
                let (tx, rx) = channel();
                router.submit_to("t", tokens, tx);
                rx
            })
            .collect();
        for rx in receivers {
            let resp = rx.recv().expect("response");
            assert!(resp.error.is_none(), "{:?}", resp.error);
        }
        router.shutdown();
        let esc = metrics.model(0).escalated.load(Ordering::Relaxed);
        let front = metrics.model(0).completed.load(Ordering::Relaxed);
        let sibling = metrics.model(1).completed.load(Ordering::Relaxed);
        assert_eq!(
            front + esc,
            n as u64,
            "margin {margin}: front tier must answer or escalate every request"
        );
        assert_eq!(sibling, esc, "margin {margin}: the INT8 sibling serves the escalations");
        assert_eq!(
            metrics.cascade_e2e_s.lock().unwrap().len() as u64,
            esc,
            "margin {margin}: every escalated completion must land in the cascade series"
        );
        esc
    };

    let margins: [i64; 4] = [0, 5_000, 20_000, i64::MAX];
    let mut table = Table::new(&["margin", "escalated", "rate"]);
    let mut rows = Vec::new();
    let mut escs = Vec::new();
    for &m in &margins {
        let esc = run(m);
        escs.push(esc);
        let shown = if m == i64::MAX { "MAX".to_string() } else { m.to_string() };
        table.row(&[shown, esc.to_string(), format!("{:.1}%", 100.0 * esc as f64 / n as f64)]);
        rows.push(obj([("margin", m.into()), ("escalated", (esc as i64).into())]));
    }
    assert!(escs.windows(2).all(|w| w[0] <= w[1]), "escalations must be monotone in the margin");
    assert_eq!(escs[0], 0, "margin 0 disables the gate (strict less-than)");
    assert_eq!(escs[margins.len() - 1], n as u64, "an unbounded margin escalates everything");

    // -- per-tenant knob: two pairs, identical traffic, two margins --
    let (lo_margin, hi_margin) = (2_000i64, 30_000i64);
    let mut reg = ModelRegistry::new();
    reg.register_cascade_scaled("lo", "tiny", 1, 1, 1, None, 7, lo_margin).unwrap();
    reg.register_cascade_scaled("hi", "tiny", 1, 1, 1, None, 7, hi_margin).unwrap();
    let metrics = Arc::new(Metrics::new());
    let router = Router::start_multi(reg.into_groups(), policy, Arc::clone(&metrics));
    let mut receivers = Vec::new();
    for tokens in gen_requests(n) {
        for tenant in ["lo", "hi"] {
            let (tx, rx) = channel();
            router.submit_to(tenant, tokens.clone(), tx);
            receivers.push(rx);
        }
    }
    for rx in receivers {
        let resp = rx.recv().expect("response");
        assert!(resp.error.is_none(), "{:?}", resp.error);
    }
    router.shutdown();
    let esc_lo = metrics.model(0).escalated.load(Ordering::Relaxed);
    let esc_hi = metrics.model(2).escalated.load(Ordering::Relaxed);
    assert_eq!(metrics.model(0).escalate_margin.load(Ordering::Relaxed), lo_margin as u64);
    assert_eq!(metrics.model(2).escalate_margin.load(Ordering::Relaxed), hi_margin as u64);
    assert!(
        esc_lo < esc_hi,
        "identical traffic: the looser per-tenant margin must escalate more \
         (lo {esc_lo}, hi {esc_hi})"
    );
    let report = metrics.report();
    assert!(
        report.contains("escalated="),
        "Metrics::report must surface per-tenant escalation counters"
    );
    table.row(&[
        format!("lo={lo_margin}"),
        esc_lo.to_string(),
        format!("{:.1}%", 100.0 * esc_lo as f64 / n as f64),
    ]);
    table.row(&[
        format!("hi={hi_margin}"),
        esc_hi.to_string(),
        format!("{:.1}%", 100.0 * esc_hi as f64 / n as f64),
    ]);
    table.print(&format!(
        "cascade mechanics leg: tiny cascade pair through the real router, {n} requests"
    ));
    println!(
        "\nfront completions + escalations == submissions at every margin, the\n\
         INT8 sibling serves exactly the escalations, and the per-tenant\n\
         margins (lo/hi rows, one run) produce different escalation rates on\n\
         identical traffic — the threshold is a per-tenant knob, not a\n\
         global one."
    );

    obj([
        ("requests", n.into()),
        ("sweep", Json::Arr(rows)),
        (
            "tenants",
            obj([
                ("lo_margin", lo_margin.into()),
                ("hi_margin", hi_margin.into()),
                ("lo_escalated", (esc_lo as i64).into()),
                ("hi_escalated", (esc_hi as i64).into()),
            ]),
        ),
    ])
}

/// Compare (or initialize/update) the committed cascade smoke
/// snapshot.  Returns false when the comparison failed.
fn check_cascade_snapshot(update: bool, payload: &str) -> bool {
    let on_disk = std::fs::read_to_string(CASCADE_SNAPSHOT_PATH).ok();
    let initialized = on_disk
        .as_deref()
        .and_then(|s| Json::parse(s.trim()).ok())
        .is_some_and(|j| {
            j.get("thresholds").is_some()
                && j.get("schema").and_then(|s| s.as_str()) == Some(CASCADE_SNAPSHOT_SCHEMA)
        });
    if update || !initialized {
        match std::fs::write(CASCADE_SNAPSHOT_PATH, payload) {
            Ok(()) => println!(
                "\n{} {CASCADE_SNAPSHOT_PATH} — commit it to pin the cascade baseline",
                if update { "updated" } else { "initialized" }
            ),
            Err(e) => eprintln!("\nfailed to write {CASCADE_SNAPSHOT_PATH}: {e}"),
        }
        return true;
    }
    if on_disk.as_deref() == Some(payload) {
        println!(
            "\ncascade smoke snapshot matches {CASCADE_SNAPSHOT_PATH} (deterministic \
             cascade verified)"
        );
        true
    } else {
        eprintln!(
            "\ncascade smoke snapshot MISMATCH against {CASCADE_SNAPSHOT_PATH}: the\n\
             INT4/INT8 margin study changed.  If the kernel or consts change is\n\
             intentional, re-baseline with\n\
             `cargo bench --bench serving_scaling -- --smoke --update` and commit the\n\
             snapshot; otherwise this is a numerics regression.\n\
             expected (committed):\n{}\n\
             got (this run):\n{}",
            on_disk.as_deref().unwrap_or("<unreadable>").trim_end(),
            payload.trim_end()
        );
        false
    }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let update = std::env::args().any(|a| a == "--update");
    println!(
        "serving-scaling sweep{}: {REQUESTS} closed-loop requests, tiny preset, \
         functional replicas (host parallelism {})",
        if smoke { " [smoke]" } else { "" },
        default_parallelism()
    );

    // warm up allocators / thread spawning before timing
    run_once(1, 8);

    let mut legs: Vec<(&'static str, Json)> = vec![
        ("schema", "swifttron-serving-bench-v1".into()),
        ("smoke", smoke.into()),
        ("host_parallelism", default_parallelism().into()),
    ];

    let scaling = if smoke {
        scaling_leg(&[1, 2], &[8])
    } else {
        scaling_leg(&[1, 2, 4], &[1, 4, 8, 16])
    };
    legs.push(("scaling", scaling));

    if !smoke {
        // --- sequence-length leg (EXPERIMENTS.md §SeqLen) --------------
        // Same pipeline, requests shaped to m_eff <= m: the Workspace
        // path runs exactly m_eff rows, so wall time AND simulated
        // accelerator time drop together — unlike the replica leg,
        // where virtual time per request is invariant.
        let m_full = Geometry::preset("tiny").unwrap().m;
        let (replicas, max_batch) = (2usize, 8usize);
        let bucket = (m_full / 4).max(1);
        let lens = [m_full / 4, m_full / 2, m_full];
        let mut rows: Vec<(usize, f64, f64, f64)> = Vec::new(); // (m_eff, rps, p99 ms, virt ms/req)
        for &len in &lens {
            let (wall, metrics) = run_len(|_| len, replicas, max_batch, bucket);
            let rps = REQUESTS as f64 / wall;
            let virt = metrics.total_accel_ms() / REQUESTS as f64;
            let p99 = metrics.e2e_s.lock().unwrap().p99() * 1e3;
            rows.push((len, rps, p99, virt));
        }
        let full_rps = rows.last().expect("full-length row").1;
        let mut table = Table::new(&["m_eff", "req/s", "vs full len", "p99 e2e", "virtual ms/req"]);
        let mut json_rows = Vec::new();
        for &(len, rps, p99, virt) in &rows {
            table.row(&[
                len.to_string(),
                format!("{rps:.0}"),
                format!("{:.2}x", rps / full_rps),
                format!("{p99:.3}ms"),
                format!("{virt:.3}"),
            ]);
            json_rows.push(obj([
                ("m_eff", len.into()),
                ("req_per_s", rps.into()),
                ("p99_e2e_ms", p99.into()),
                ("virtual_ms_per_req", virt.into()),
            ]));
        }
        table.print(&format!(
            "sequence-length sweep ({replicas} replicas, max_batch {max_batch}, bucket width {bucket})"
        ));
        println!(
            "\nshort requests run exactly m_eff rows on the resident Workspace\n\
             (no padded compute): requests/sec rises and simulated accelerator\n\
             ms/request falls as m_eff shrinks.  At m_eff = m the path is\n\
             bit-exact with the fixed-geometry pipeline."
        );
        legs.push(("seqlen", Json::Arr(json_rows)));

        // mixed-length traffic: bucketed dispatch + the padding-waste metric
        let (_, metrics) = run_len(
            |rng| 1 + rng.below(m_full as u64) as usize,
            replicas,
            max_batch,
            bucket,
        );
        println!(
            "\nmixed-length traffic (uniform 1..={m_full}, bucket width {bucket}): \
             padding waste {:.1}% of bucket-padded tokens",
            100.0 * metrics.padding_waste()
        );
        legs.push(("mixed_length_padding_waste", metrics.padding_waste().into()));

        // --- kernel leg: serial vs row-tiled parallel i_matmul ---------
        let (m, k, n) = (256, 768, 768); // roberta_base projection shape
        let mut rng = Rng::new(2);
        let x: Vec<i32> = (0..m * k).map(|_| rng.range_i64(-128, 127) as i32).collect();
        let w: Vec<i32> = (0..k * n).map(|_| rng.range_i64(-128, 127) as i32).collect();
        let mut out = vec![0i32; m * n];
        let serial =
            Bench::new("i_matmul serial 256x768x768").iters(12).run(|| {
                i_matmul(&x, &w, None, m, k, n, &mut out);
                out[0]
            });
        let threads = default_parallelism();
        let tiled = Bench::new("i_matmul tiled  256x768x768")
            .iters(12)
            .run(|| {
                i_matmul_tiled(threads, &x, &w, None, m, k, n, &mut out);
                out[0]
            });
        println!(
            "kernel speedup {:.2}x with {threads} threads (bit-exact; threshold \
             PAR_MIN_MACS gates the auto path)",
            serial.p50() / tiled.p50()
        );
        legs.push(("kernel_speedup", (serial.p50() / tiled.p50()).into()));

        // --- attention leg: head-parallel fused vs serial unfused ------
        // One d=768 encoder layer (roberta_base-scale), heads x m_eff
        // sweep (EXPERIMENTS.md §Perf).  Both paths are bit-exact
        // (asserted per cell); the delta is pure wall clock: fused
        // epilogues drop the full-tensor requantization passes and the
        // scoped parallel-for runs all heads' MatMul->Softmax->MatMul
        // pipelines concurrently.
        println!();
        let mut table = Table::new(&["heads", "m_eff", "unfused p50", "fused p50", "speedup"]);
        for &heads in &[4usize, 12] {
            let geo = Geometry::new(768, heads, 256, 3072, 1);
            let mut rng = Rng::new(3);
            let w = LayerWeights::synthetic(&mut rng, &geo);
            let c = synthetic_consts(&geo);
            let mut ws_u = Workspace::new(&geo);
            let mut ws_f = Workspace::new(&geo);
            for &m_eff in &[16usize, 64, 256] {
                let x: Vec<i32> =
                    (0..m_eff * geo.d).map(|_| rng.range_i64(-127, 127) as i32).collect();
                let mut out_u = vec![0i32; m_eff * geo.d];
                let mut out_f = vec![0i32; m_eff * geo.d];
                let mut iters = Vec::new();
                let name_u = format!("layer unfused h={heads} m={m_eff}");
                let unfused = Bench::new(&name_u).warmup(1).iters(4).run(|| {
                    iters.clear();
                    layer_forward_ws_unfused(
                        &x, &w, &c, &geo, m_eff, &mut ws_u, &mut out_u, &mut iters,
                    );
                    out_u[0]
                });
                let name_f = format!("layer fused   h={heads} m={m_eff}");
                let fused = Bench::new(&name_f).warmup(1).iters(4).run(|| {
                    iters.clear();
                    layer_forward_ws(&x, &w, &c, &geo, m_eff, &mut ws_f, &mut out_f, &mut iters);
                    out_f[0]
                });
                assert_eq!(out_u, out_f, "fused attention must stay bit-exact");
                table.row(&[
                    heads.to_string(),
                    m_eff.to_string(),
                    fmt_time(unfused.p50()),
                    fmt_time(fused.p50()),
                    format!("{:.2}x", unfused.p50() / fused.p50()),
                ]);
            }
        }
        table.print("attention leg: serial unfused vs head-parallel fused (d=768, 1 layer)");
        println!(
            "\nfused runs every head concurrently with the INT32->INT8\n\
             requantization fused into the matmul readout — identical bits,\n\
             less wall clock once per-head work clears ATTN_PAR_MIN_MACS\n\
             (short m_eff rows stay serial by design; the m_eff=16 row\n\
             documents that gate, not a regression)."
        );

        // --- multi-model leg (EXPERIMENTS.md §MultiModel) --------------
        // Mixed RoBERTa/DeiT/tiny traffic through one pool: per weight
        // config, every model is kept backlogged with equal-cost (1
        // live token, 8-token bucket) requests while the weighted-fair
        // dispatcher runs a fixed number of groups; the served-token
        // shares land on the configured weights.  The loop drives the
        // real batcher + registry groups + pool deterministically
        // (dispatcher threads bypassed so the measurement window is
        // exact).
        println!();
        let weight_configs: [[u64; 3]; 3] = [[1, 1, 1], [2, 1, 1], [4, 2, 1]];
        let names = ["tiny", "deit_s", "roberta_base"];
        let mut table = Table::new(&[
            "weights", "tiny share", "deit_s share", "roberta share", "wall", "waste/model",
        ]);
        let mut json_rows = Vec::new();
        for weights in &weight_configs {
            let mut reg = ModelRegistry::new();
            for (m, &name) in names.iter().enumerate() {
                reg.register(name, name, 1, weights[m], 7).unwrap();
            }
            let metrics = Arc::new(Metrics::new());
            metrics.ensure_models(&[
                (names[0], weights[0]),
                (names[1], weights[1]),
                (names[2], weights[2]),
            ]);
            let wait = Duration::from_secs(3600);
            let policy = BatchPolicy { max_batch: 4, max_wait: wait, bucket_width: 8 };
            let pool = ReplicaPool::new_multi(reg.into_groups(), Arc::clone(&metrics));
            let mut batcher: Batcher<Request> = Batcher::new(policy);
            batcher.set_model_weights(weights);
            let batches = 32usize;
            let mut rng = Rng::new(9);
            let mut receivers = Vec::new();
            for i in 0..batches * 4 {
                for m in 0..names.len() {
                    let len = 1 + rng.below(6) as usize; // 1..=6 -> 8-token bucket
                    let (tx, rx) = channel();
                    batcher.push_keyed(
                        Request {
                            id: i as u64,
                            model: m,
                            tokens: (0..len).map(|_| rng.below(60) as i32).collect(),
                            padded_len: 8,
                            cost: 8,
                            submitted: Instant::now(),
                            origin: None,
                            reply: tx,
                        },
                        m,
                        len,
                    );
                    receivers.push(rx);
                    metrics.record_tokens(m, len, 8);
                }
            }
            let t0 = Instant::now();
            for _ in 0..batches {
                let batch = batcher.take_batch();
                assert!(batch.iter().all(|r| r.model == batch[0].model));
                for resp in pool.dispatch(batch) {
                    assert!(resp.error.is_none(), "{:?}", resp.error);
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            drop(receivers); // unserved backlog is measurement headroom
            let waste: Vec<String> = (0..names.len())
                .map(|m| format!("{:.0}%", 100.0 * metrics.model(m).padding_waste()))
                .collect();
            table.row(&[
                format!("{}:{}:{}", weights[0], weights[1], weights[2]),
                format!("{:.1}%", 100.0 * metrics.model_token_share(0)),
                format!("{:.1}%", 100.0 * metrics.model_token_share(1)),
                format!("{:.1}%", 100.0 * metrics.model_token_share(2)),
                fmt_time(wall),
                waste.join("/"),
            ]);
            json_rows.push(obj([
                (
                    "weights",
                    Json::Arr(weights.iter().map(|&w| (w as i64).into()).collect()),
                ),
                (
                    "shares",
                    Json::Arr((0..3).map(|m| metrics.model_token_share(m).into()).collect()),
                ),
                ("wall_s", wall.into()),
            ]));
        }
        table.print("multi-model leg: served-token shares vs configured weights (32 groups)");
        println!(
            "\nshares are measured over dispatched bucket-padded tokens while\n\
             every model stays backlogged: the deficit-round-robin ledger\n\
             drives them onto the weight ratios within one dispatch group.\n\
             waste/model is each model's own padding ratio — per-model\n\
             ledgers keep a short-sequence tenant's bucket overhead visible\n\
             next to a full-length one (ISSUE 4 metrics fix)."
        );
        legs.push(("multi_model", Json::Arr(json_rows)));
    }

    // --- concurrency leg (DESIGN.md §9): always runs, smoke-sized in CI
    println!();
    legs.push(("concurrency", concurrency_leg(smoke)));
    legs.push(("dispatch", dispatch_contention_leg(smoke)));

    // --- CostModel fairness leg (DESIGN.md §12): always runs; lands
    // under the shared `costmodel` key next to the design-space leg the
    // table1_synthesis bench owns (merge_bench_json merges one level
    // deep, so neither binary clobbers the other's sub-leg).
    println!();
    legs.push(("costmodel", obj([("fairness", costmodel_fairness_leg(smoke))])));

    // --- cascade leg (DESIGN.md §14): always runs, smoke-sized in CI
    println!();
    let (cascade_acceptance, cascade_snapshot) = cascade_acceptance_leg(smoke);
    println!();
    let cascade_mechanics = cascade_mechanics_leg(smoke);
    legs.push((
        "cascade",
        obj([("acceptance", cascade_acceptance), ("mechanics", cascade_mechanics)]),
    ));

    // merge, don't overwrite: the `openloop` key written by the
    // serving_openloop bench lives in the same file
    let path = "BENCH_serving.json";
    match merge_bench_json(path, legs) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }

    // --- determinism gate: the committed cascade smoke snapshot ----
    if !check_cascade_snapshot(update, &cascade_snapshot) {
        std::process::exit(1);
    }
}
