//! Concurrent per-group dispatch + SLO-aware autoscaling integration
//! tests (ISSUE 5, DESIGN.md §9): the acceptance claims — an
//! autoscaled group grows to `max` under saturating backlog and drains
//! back to `min` when load stops with zero request loss, a cheap
//! model's tail latency decouples from a heavy model's groups versus
//! the serial single-dispatcher baseline, and the one-group
//! configuration of the per-group pipeline stays bit-equivalent to the
//! serial dispatch path.
//!
//! Mock engines with pinned service times keep every claim
//! deterministic-by-construction (generous factors absorb scheduler
//! noise); the real-preset traffic runs in `multi_model.rs` and the
//! `serving_scaling` bench's concurrency leg.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};
use swifttron::coordinator::{
    decide, tick_group, AutoscalePolicy, BatchPolicy, Batcher, EngineReplica, FunctionalEngine,
    GroupScaleState, Metrics, ModelRegistry, Prediction, ReplicaFactory, ReplicaPool, Request,
    RequestError, Router, ScaleDecision,
};
use swifttron::sim::HwConfig;

/// Deterministic mock replica: fixed service time, label = first token.
struct TimedReplica {
    delay: Duration,
}

impl EngineReplica for TimedReplica {
    fn predict(&self, tokens: &[i32]) -> Result<Prediction, RequestError> {
        if tokens.is_empty() {
            return Err(RequestError::BadLength { got: 0, min: 1, max: 1 << 20 });
        }
        std::thread::sleep(self.delay);
        Ok(Prediction {
            label: tokens[0] as usize % 2,
            logits: vec![tokens[0] as i64, tokens.len() as i64],
            accel_cycles: 100,
            accel_ms: 0.001,
        })
    }

    fn seq_len(&self) -> usize {
        1 << 20
    }

    fn min_seq_len(&self) -> usize {
        1
    }
}

fn timed_factory(delay_ms: u64, spawned: Arc<AtomicUsize>) -> ReplicaFactory {
    Arc::new(move || {
        spawned.fetch_add(1, Ordering::SeqCst);
        Ok(Arc::new(TimedReplica { delay: Duration::from_millis(delay_ms) })
            as Arc<dyn EngineReplica>)
    })
}

fn fast_autoscale() -> AutoscalePolicy {
    AutoscalePolicy {
        interval: Duration::from_millis(2),
        grow_ratio: 1.0,
        shrink_ratio: 0.25,
        hold_ticks: 1,
        default_service_ms: 1.0,
    }
}

/// Poll `f` until it holds or `timeout` elapses; returns whether it
/// held.
fn eventually(timeout: Duration, mut f: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if f() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    f()
}

#[test]
fn autoscaler_grows_to_max_under_backlog_and_drains_to_min_without_loss() {
    // The ISSUE 5 acceptance claim: saturating backlog against a 10 ms
    // SLO on 3 ms-per-request replicas grows the group 1 -> 4; once
    // the flood is fully served the idle backlog drains it 4 -> 1; no
    // request is lost or errored anywhere in between.
    const REQUESTS: usize = 240;
    let spawned = Arc::new(AtomicUsize::new(0));
    let mut reg = ModelRegistry::new();
    reg.register_group_scaled(
        "slow",
        1,
        4,
        1,
        Some(10.0),
        timed_factory(3, Arc::clone(&spawned)),
    )
    .unwrap();
    let metrics = Arc::new(Metrics::new());
    let policy =
        BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(500), bucket_width: 0 };
    let router =
        Router::start_multi_with(reg.into_groups(), policy, fast_autoscale(), Arc::clone(&metrics));
    assert_eq!(router.active_replicas("slow"), Some(1), "group starts at min");

    let receivers: Vec<_> = (0..REQUESTS)
        .map(|i| {
            let (tx, rx) = channel();
            router.submit_to("slow", vec![i as i32 % 50, 1, 2], tx);
            rx
        })
        .collect();

    assert!(
        eventually(Duration::from_secs(10), || router.active_replicas("slow") == Some(4)),
        "backlogged group never grew to max (at {:?})",
        router.active_replicas("slow")
    );
    // every request answered exactly once, none errored, none lost —
    // scaling actions mid-flight must not drop work
    for (i, rx) in receivers.iter().enumerate() {
        let resp = rx.recv_timeout(Duration::from_secs(30)).expect("response lost");
        assert!(resp.error.is_none(), "request {i}: {:?}", resp.error);
    }
    // load stopped: the idle backlog drains the group back to min
    assert!(
        eventually(Duration::from_secs(10), || router.active_replicas("slow") == Some(1)),
        "idle group never drained to min (at {:?})",
        router.active_replicas("slow")
    );
    router.shutdown();

    let stats = metrics.model(0);
    assert_eq!(stats.completed.load(Ordering::SeqCst), REQUESTS as u64);
    assert_eq!(stats.errors.load(Ordering::SeqCst), 0);
    assert_eq!(stats.backlog.load(Ordering::SeqCst), 0, "backlog gauge settled");
    assert!(
        stats.scale_ups.load(Ordering::SeqCst) >= 3,
        "grew at least min..max"
    );
    assert!(stats.scale_downs.load(Ordering::SeqCst) >= 3, "drained back down");
    assert!(
        spawned.load(Ordering::SeqCst) >= 4,
        "factory spawned the grown replicas (plus the initial one)"
    );
    // the per-model latency ledger saw every completion
    assert_eq!(stats.e2e_s.lock().unwrap().len(), REQUESTS);
}

#[test]
fn cost_modeled_group_grows_before_its_first_completion() {
    // ISSUE 8 cold-start fix: a freshly registered preset group has
    // zero completions, so the legacy mean_exec_ms signal — poisoned
    // here with a 0 ms service prior — sees no work at all and would
    // hold forever.  The group's CostModel prices the queued requests
    // from registration time, so the very first autoscaler tick must
    // grow the group, before any completion lands.
    let mut reg = ModelRegistry::new();
    reg.register_scaled("heavy", "tiny", 1, 4, 1, Some(0.05), 11).unwrap();
    let groups = reg.into_groups();
    let cm = groups[0].cost.clone().expect("preset groups carry a cost model");
    let metrics = Arc::new(Metrics::new());
    metrics.ensure_models(&[("heavy", 1)]);
    let pool = ReplicaPool::new_multi(groups, Arc::clone(&metrics));
    let rt = pool.group(0).unwrap();
    assert_eq!(rt.active_replicas(), 1);

    // 32 full-length requests submitted, none completed yet
    let backlog = 32usize;
    let cost = cm.predict_cycles(32);
    assert!(cost > 0);
    for _ in 0..backlog {
        metrics.record_request_for(0, cost);
    }
    let mut policy = fast_autoscale();
    policy.default_service_ms = 0.0; // poison the legacy prior
    let mut state = GroupScaleState::new();
    let d = tick_group(rt, &mut state, backlog, &metrics, &policy);
    assert_eq!(
        d,
        ScaleDecision::Grow,
        "zero-completion group must scale up on its predicted work"
    );
    assert_eq!(rt.active_replicas(), 2);
    // the request-count signal under the same poisoned prior scores
    // zero work — exactly the blind spot the cost model closes
    assert_eq!(decide(0.0, 1, 1, 4, 0.05, &policy), ScaleDecision::Hold);
}

#[test]
fn groups_without_slo_never_scale() {
    let spawned = Arc::new(AtomicUsize::new(0));
    let mut reg = ModelRegistry::new();
    // max > min but no SLO: the autoscaler must leave the group alone
    reg.register_group_scaled("fixed", 1, 4, 1, None, timed_factory(1, Arc::clone(&spawned)))
        .unwrap();
    let metrics = Arc::new(Metrics::new());
    let policy =
        BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(500), bucket_width: 0 };
    let router =
        Router::start_multi_with(reg.into_groups(), policy, fast_autoscale(), Arc::clone(&metrics));
    let receivers: Vec<_> = (0..64)
        .map(|i| {
            let (tx, rx) = channel();
            router.submit_to("fixed", vec![i as i32 % 50], tx);
            rx
        })
        .collect();
    for rx in receivers {
        assert!(rx.recv_timeout(Duration::from_secs(30)).unwrap().error.is_none());
    }
    assert_eq!(router.active_replicas("fixed"), Some(1));
    router.shutdown();
    assert_eq!(metrics.model(0).scale_ups.load(Ordering::SeqCst), 0);
    assert_eq!(spawned.load(Ordering::SeqCst), 1, "only the initial replica was built");
}

#[test]
fn cheap_model_p99_decouples_from_heavy_groups() {
    // The tentpole claim at test scale: heavy (20 ms/request) and tiny
    // (1 ms/request) groups with disjoint replicas, saturating mixed
    // traffic submitted up front.  The serial single-dispatcher
    // baseline interleaves tiny groups behind heavy group barriers, so
    // tiny's p99 inherits heavy's service time; the per-group pipeline
    // runs tiny's dispatches concurrently and its p99 collapses.  The
    // acceptance bound is >= 2x; the construction yields far more.
    const HEAVY: usize = 12; // 3 groups x 4 x 20 ms = 240 ms of heavy work
    const TINY: usize = 48; // 12 groups x 4 x 1 ms
    let policy =
        BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(200), bucket_width: 0 };

    let build_groups = || {
        let mut reg = ModelRegistry::new();
        reg.register_group(
            "heavy",
            vec![Arc::new(TimedReplica { delay: Duration::from_millis(20) })
                as Arc<dyn EngineReplica>],
            1,
        )
        .unwrap();
        reg.register_group(
            "tiny",
            vec![Arc::new(TimedReplica { delay: Duration::from_millis(1) })
                as Arc<dyn EngineReplica>],
            1,
        )
        .unwrap();
        reg.into_groups()
    };

    // -- serial baseline: one dispatcher over both models ------------
    let serial_metrics = Arc::new(Metrics::new());
    serial_metrics.ensure_models(&[("heavy", 1), ("tiny", 1)]);
    let pool = ReplicaPool::new_multi(build_groups(), Arc::clone(&serial_metrics));
    let mut batcher: Batcher<Request> = Batcher::new(policy);
    batcher.set_model_weights(&[1, 1]);
    let mut receivers = Vec::new();
    let mut id = 0u64;
    for i in 0..TINY {
        // interleave so both models stay backlogged from the start
        if i < HEAVY {
            let (tx, rx) = channel();
            id += 1;
            batcher.push_keyed(
                Request {
                    id,
                    model: 0,
                    tokens: vec![1; 4],
                    padded_len: 4,
                    cost: 4,
                    submitted: Instant::now(),
                    origin: None,
                    reply: tx,
                },
                0,
                4,
            );
            receivers.push(rx);
        }
        let (tx, rx) = channel();
        id += 1;
        batcher.push_keyed(
            Request {
                id,
                model: 1,
                tokens: vec![1; 1],
                padded_len: 1,
                cost: 1,
                submitted: Instant::now(),
                origin: None,
                reply: tx,
            },
            1,
            1,
        );
        receivers.push(rx);
    }
    while !batcher.is_empty() {
        let group = batcher.take_batch();
        assert!(!group.is_empty());
        pool.dispatch(group);
    }
    drop(receivers);
    let (_, serial_tiny_p99) = serial_metrics.model(1).e2e_percentiles_ms();

    // -- concurrent per-group pipeline over identical traffic --------
    let conc_metrics = Arc::new(Metrics::new());
    let router = Router::start_multi(build_groups(), policy, Arc::clone(&conc_metrics));
    let mut receivers = Vec::new();
    for i in 0..TINY {
        if i < HEAVY {
            let (tx, rx) = channel();
            router.submit_to("heavy", vec![1; 4], tx);
            receivers.push(rx);
        }
        let (tx, rx) = channel();
        router.submit_to("tiny", vec![1; 1], tx);
        receivers.push(rx);
    }
    for rx in receivers {
        let resp = rx.recv_timeout(Duration::from_secs(30)).expect("response");
        assert!(resp.error.is_none(), "{:?}", resp.error);
    }
    router.shutdown();
    let (_, conc_tiny_p99) = conc_metrics.model(1).e2e_percentiles_ms();

    assert!(
        serial_tiny_p99 >= 2.0 * conc_tiny_p99,
        "tiny p99 serial {serial_tiny_p99:.3} ms vs concurrent {conc_tiny_p99:.3} ms — \
         expected >= 2x improvement"
    );
    // the heavy model was NOT starved to achieve it: all its requests
    // completed in both runs
    assert_eq!(
        conc_metrics.model(0).completed.load(Ordering::SeqCst),
        HEAVY as u64
    );
}

#[test]
fn fully_retired_group_keeps_a_finite_drain_estimate_and_floor_repairs() {
    // ISSUE 9 regression through the floor-repair path: fault recovery
    // retires a group's only replica (active = 0) and the factory
    // refuses to respawn for a while.  The drain-time signal divides
    // by active — unclamped, a fully-retired group scored inf/NaN and
    // the autoscaler (plus wire admission, which shares the estimate)
    // went blind.  The estimate must stay finite at zero replicas, the
    // dead window must fail typed, and floor repair must regrow the
    // group once the factory recovers.
    use swifttron::workload::{ChaosReplica, DelayReplica};
    let builds = Arc::new(AtomicUsize::new(0));
    let allow_respawn = Arc::new(AtomicUsize::new(0));
    let factory: ReplicaFactory = {
        let builds = Arc::clone(&builds);
        let allow = Arc::clone(&allow_respawn);
        Arc::new(move || {
            if builds.fetch_add(1, Ordering::SeqCst) == 0 {
                // the group's founding replica panics on its first request
                let inner: Arc<dyn EngineReplica> = Arc::new(DelayReplica::from_ms(0));
                Ok(Arc::new(ChaosReplica::panic_at(inner, 0)) as Arc<dyn EngineReplica>)
            } else if allow.load(Ordering::SeqCst) == 0 {
                Err("factory down (chaos)".to_string())
            } else {
                Ok(Arc::new(DelayReplica::from_ms(0)) as Arc<dyn EngineReplica>)
            }
        })
    };
    let mut reg = ModelRegistry::new();
    reg.register_group_scaled("flappy", 1, 2, 1, Some(20.0), factory).unwrap();
    let metrics = Arc::new(Metrics::new());
    let router = Router::start_multi_with(
        reg.into_groups(),
        BatchPolicy::default(),
        fast_autoscale(),
        Arc::clone(&metrics),
    );

    let ask = |tokens: Vec<i32>| {
        let (tx, rx) = channel();
        router.submit_to("flappy", tokens, tx);
        rx.recv_timeout(Duration::from_secs(10)).expect("reply channel served")
    };
    // the founding replica panics; no peer => typed error + retirement
    let first = ask(vec![1, 2]);
    assert!(
        first.error.as_deref().unwrap_or("").contains("panicked"),
        "expected the backend panic error, got {:?}",
        first.error
    );
    assert!(
        eventually(Duration::from_secs(10), || router.active_replicas("flappy") == Some(0)),
        "faulted slot never retired (at {:?})",
        router.active_replicas("flappy")
    );

    // the dead window: estimates stay finite, requests fail typed
    for i in 0..5 {
        let d = router.predicted_delay_ms(0, 1.0);
        assert!(
            d.is_finite() && d >= 0.0,
            "drain estimate went non-finite at zero replicas: {d}"
        );
        let r = ask(vec![1, 2, 3]);
        assert!(
            r.error.as_deref().unwrap_or("").contains("no active replicas"),
            "request {i}: expected the typed dead-tenant error, got {:?}",
            r.error
        );
    }

    // factory heals: floor repair regrows the group and it serves again
    allow_respawn.store(1, Ordering::SeqCst);
    assert!(
        eventually(Duration::from_secs(10), || router.active_replicas("flappy") >= Some(1)),
        "floor repair never restored the floor after the factory recovered"
    );
    assert!(
        eventually(Duration::from_secs(10), || ask(vec![4, 5]).error.is_none()),
        "recovered group never served"
    );
    router.shutdown();
}

#[test]
fn one_group_pipeline_is_bit_equivalent_to_serial_dispatch() {
    // The degenerate configuration the tentpole preserves: with one
    // model group, the per-group pipeline must produce byte-identical
    // predictions to driving the batcher + pool serially by hand.
    let preset = "tiny";
    let seed = 7;
    let hw = HwConfig::sized_to(&swifttron::model::Geometry::preset(preset).unwrap());
    let make_replicas = || {
        FunctionalEngine::replica_group(preset, seed, hw, 2).unwrap()
    };
    let lens: Vec<usize> = (0..24).map(|i| 1 + (i * 5) % 32).collect();
    let tokens_of = |len: usize| -> Vec<i32> { (0..len).map(|t| (t * 7 % 50) as i32).collect() };

    // serial: hand-driven batcher + pool
    let serial_metrics = Arc::new(Metrics::new());
    let pool = ReplicaPool::new(make_replicas(), Arc::clone(&serial_metrics));
    let policy = BatchPolicy { max_batch: 4, max_wait: Duration::ZERO, bucket_width: 8 };
    let mut batcher: Batcher<Request> = Batcher::new(policy);
    let mut serial_rx = Vec::new();
    for (i, &len) in lens.iter().enumerate() {
        let (tx, rx) = channel();
        batcher.push_keyed(
            Request {
                id: i as u64,
                model: 0,
                tokens: tokens_of(len),
                padded_len: policy.padded_len(len),
                cost: policy.padded_len(len) as u64,
                submitted: Instant::now(),
                origin: None,
                reply: tx,
            },
            0,
            len,
        );
        serial_rx.push(rx);
    }
    while !batcher.is_empty() {
        pool.dispatch(batcher.take_batch());
    }
    let serial: Vec<_> = serial_rx.iter().map(|rx| rx.recv().unwrap()).collect();

    // concurrent pipeline, one group == one dispatcher
    let conc_metrics = Arc::new(Metrics::new());
    let router = Router::start(make_replicas(), policy, conc_metrics);
    let conc_rx: Vec<_> = lens
        .iter()
        .map(|&len| {
            let (tx, rx) = channel();
            router.submit(tokens_of(len), tx);
            rx
        })
        .collect();
    let concurrent: Vec<_> =
        conc_rx.iter().map(|rx| rx.recv_timeout(Duration::from_secs(30)).unwrap()).collect();
    router.shutdown();

    for (s, c) in serial.iter().zip(&concurrent) {
        assert!(s.error.is_none() && c.error.is_none());
        assert_eq!(s.label, c.label, "labels diverged between pipelines");
        assert_eq!(s.logits, c.logits, "logits diverged between pipelines");
    }
}
