//! `SWWIRE1` byte layout (DESIGN.md §11).
//!
//! A binary connection opens with the 8-byte preamble [`PREAMBLE`];
//! everything after it is a stream of frames:
//!
//! ```text
//! u32  len        # bytes that follow this field (little-endian)
//! u8   kind       # KIND_*
//! ...  payload    # kind-specific, all integers little-endian
//! ```
//!
//! Request (`kind = 1`, client → server):
//!
//! ```text
//! u64  id          # client-chosen frame id, echoed on the response
//! u8   model_len   # 0 = default model (index 0)
//! [u8] model       # utf-8 model id, model_len bytes
//! u16  n_tokens
//! [i32] tokens     # n_tokens little-endian i32s
//! ```
//!
//! Responses (server → client) echo the request's frame id:
//! `Ok` (`kind = 2`) carries replica / label / logits / timing,
//! `Error` (`kind = 3`) a typed message, `Overloaded` (`kind = 4`) the
//! predicted queueing delay and the SLO it crossed (admission
//! rejection — resubmit later), and `Busy` (`kind = 5`, id 0) the
//! connection cap that refused the whole connection.

/// Connection preamble a binary client sends first.  The legacy text
/// protocol is detected by the first byte that diverges from this
/// sequence — text lines start with a printable token digit or model
/// character, never `0x00`-terminated magic.
pub const PREAMBLE: [u8; 8] = *b"SWWIRE1\0";

/// Frame length prefix size (the `u32 len` field).
pub const HEADER_BYTES: usize = 4;

/// Request frame payload kind.
pub const KIND_REQUEST: u8 = 1;
/// Successful response payload kind.
pub const KIND_OK: u8 = 2;
/// Typed error response payload kind.
pub const KIND_ERROR: u8 = 3;
/// SLO admission rejection payload kind.
pub const KIND_OVERLOADED: u8 = 4;
/// Connection-cap rejection payload kind (sent once, then close).
pub const KIND_BUSY: u8 = 5;

/// Fixed request payload bytes around the variable model / token
/// sections: kind + id + model_len + n_tokens.
pub const REQUEST_FIXED: usize = 1 + 8 + 1 + 2;

/// Hard ceiling on a frame's `len` field, independent of (and above)
/// any per-connection buffer bound.  A 64 KiB ring fits ~16k-token
/// requests; 1 MiB is far past any serveable sequence.
pub const MAX_FRAME: usize = 1 << 20;

/// A request parsed *in place* out of a connection's read buffer: the
/// model id and token bytes borrow the buffer, nothing is copied or
/// allocated (the zero-copy half of the decode hot path).
#[derive(Debug, Clone, Copy)]
pub struct RequestView<'a> {
    /// client-chosen frame id (echoed on the response)
    pub id: u64,
    /// model id; empty targets the default model (index 0)
    pub model: &'a str,
    /// raw little-endian token bytes, length `4 · n_tokens`
    tokens: &'a [u8],
}

impl<'a> RequestView<'a> {
    pub(crate) fn new(id: u64, model: &'a str, tokens: &'a [u8]) -> RequestView<'a> {
        debug_assert_eq!(tokens.len() % 4, 0);
        RequestView { id, model, tokens }
    }

    pub fn token_count(&self) -> usize {
        self.tokens.len() / 4
    }

    /// Decode tokens on the fly, no allocation.
    pub fn tokens(&self) -> impl Iterator<Item = i32> + 'a {
        self.tokens.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
    }

    /// Append the decoded tokens to `out` (clears it first).  With a
    /// warm `out` capacity this allocates nothing.
    pub fn read_tokens_into(&self, out: &mut Vec<i32>) {
        out.clear();
        out.extend(self.tokens());
    }
}

/// A decoded response frame, owned — the *client* side of the
/// protocol (tests, socket replay, benches), where per-frame
/// allocation is fine.
#[derive(Debug, Clone, PartialEq)]
pub enum ResponseFrame {
    Ok { id: u64, replica: u32, label: u16, logits: Vec<i64>, accel_ms: f64, e2e_us: f64 },
    Error { id: u64, message: String },
    Overloaded { id: u64, predicted_ms: f64, slo_ms: f64 },
    Busy { limit: u32 },
}

impl ResponseFrame {
    /// The request frame id this response answers (0 for `Busy`,
    /// which rejects the connection, not a frame).
    pub fn id(&self) -> u64 {
        match self {
            ResponseFrame::Ok { id, .. }
            | ResponseFrame::Error { id, .. }
            | ResponseFrame::Overloaded { id, .. } => *id,
            ResponseFrame::Busy { .. } => 0,
        }
    }

    pub fn is_ok(&self) -> bool {
        matches!(self, ResponseFrame::Ok { .. })
    }

    pub fn is_overloaded(&self) -> bool {
        matches!(self, ResponseFrame::Overloaded { .. })
    }
}
