//! Global core budget for replica execution (DESIGN.md §13).
//!
//! PR 5 gave every model group a private [`ThreadPool`] sized to its
//! `max_replicas`, so total executor threads = Σ maxima — with many
//! tenants that oversubscribes the host by the sum of worst cases even
//! when most groups sit idle.  [`BudgetExec`] replaces the private
//! pools with one router-owned worker pool of exactly `budget` threads
//! that groups borrow against: each group enqueues cost-tagged jobs
//! into its own queue, and workers pick the next job from the group
//! with the least CostModel-charged work per unit weight (the same
//! deficit-round-robin rule the dispatch ledger uses), so cross-model
//! fairness is enforced at the executor too and Σ `max_replicas` can
//! exceed the budget safely.
//!
//! [`ThreadPool`]: crate::util::threadpool::ThreadPool

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Poison-recovering lock (the ISSUE 9 rule for every serving-path
/// mutex): a worker that panicked between statements leaves the queue
/// structurally sound, so taking the guard over beats cascading the
/// panic into every producer and worker that touches the pool next.
fn lock_recover<S>(m: &Mutex<S>) -> MutexGuard<'_, S> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

struct ExecState {
    /// One FIFO of `(cost, job)` per group.
    queues: Vec<VecDeque<(u64, Job)>>,
    /// Executor-side DRR ledger: cost charged per group at job pickup.
    charged: Vec<u64>,
    /// Queued + running jobs across all groups; the decrement that
    /// lands on zero resets the ledger (idle pool carries no debt).
    outstanding: usize,
}

struct Inner {
    state: Mutex<ExecState>,
    work: Condvar,
    /// Fair-share weight per group (fixed at construction).
    weights: Vec<u64>,
    stop: AtomicBool,
    panics: AtomicUsize,
}

/// Count-down latch for one [`BudgetExec::run_batch`] call.  Jobs hold
/// a [`LatchGuard`] whose `Drop` counts down, so a panicking job still
/// releases the waiting dispatcher instead of deadlocking it.
struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
}

impl Latch {
    fn count_down(&self) {
        let mut r = lock_recover(&self.remaining);
        *r -= 1;
        if *r == 0 {
            self.done.notify_all();
        }
    }

    fn wait(&self) {
        let mut r = lock_recover(&self.remaining);
        while *r > 0 {
            r = match self.done.wait(r) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        }
    }
}

struct LatchGuard(Arc<Latch>);

impl Drop for LatchGuard {
    fn drop(&mut self) {
        self.0.count_down();
    }
}

/// A fixed budget of worker threads shared by every model group, with
/// weighted-fair job pickup across per-group queues.
pub struct BudgetExec {
    inner: Arc<Inner>,
    workers: Vec<JoinHandle<()>>,
}

impl BudgetExec {
    /// `budget` worker threads over `weights.len()` group queues.
    /// Weights must be positive (they are the same per-model fair-share
    /// weights the dispatch ledger uses).
    pub fn new(budget: usize, weights: &[u64]) -> Self {
        assert!(budget > 0, "core budget must be positive");
        assert!(!weights.is_empty(), "an executor needs at least one group");
        assert!(weights.iter().all(|&w| w > 0), "group weights must be positive");
        let inner = Arc::new(Inner {
            state: Mutex::new(ExecState {
                queues: (0..weights.len()).map(|_| VecDeque::new()).collect(),
                charged: vec![0; weights.len()],
                outstanding: 0,
            }),
            work: Condvar::new(),
            weights: weights.to_vec(),
            stop: AtomicBool::new(false),
            panics: AtomicUsize::new(0),
        });
        let workers = (0..budget)
            .map(|i| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("swifttron-exec-{i}"))
                    .spawn(move || worker_loop(inner))
                    .expect("spawn budget worker")
            })
            .collect();
        BudgetExec { inner, workers }
    }

    /// Number of worker threads — the whole core budget, regardless of
    /// how many groups share it.
    pub fn threads(&self) -> usize {
        self.workers.len()
    }

    /// Number of group queues.
    pub fn groups(&self) -> usize {
        self.inner.weights.len()
    }

    /// Cost charged to `group`'s executor ledger so far this epoch.
    pub fn charged(&self, group: usize) -> u64 {
        lock_recover(&self.inner.state).charged.get(group).copied().unwrap_or(0)
    }

    /// Number of jobs that panicked since construction.
    pub fn panics(&self) -> usize {
        self.inner.panics.load(Ordering::SeqCst)
    }

    /// Enqueue one cost-tagged job on `group`'s queue.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, group: usize, cost: u64, f: F) {
        let mut st = lock_recover(&self.inner.state);
        assert!(group < st.queues.len(), "unknown executor group {group}");
        st.queues[group].push_back((cost, Box::new(f)));
        st.outstanding += 1;
        drop(st);
        self.inner.work.notify_one();
    }

    /// Run a batch of cost-tagged jobs for `group`, blocking until all
    /// have finished and returning their values in input order.  Panics
    /// if a job panicked (mirroring `ThreadPool::run_batch`); the latch
    /// still counts a panicked job down, so the caller is released —
    /// never deadlocked — before the panic is re-reported.
    pub fn run_batch<T, F>(&self, group: usize, jobs: Vec<(u64, F)>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        let slots: Arc<Mutex<Vec<Option<T>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let latch = Arc::new(Latch { remaining: Mutex::new(n), done: Condvar::new() });
        for (i, (cost, job)) in jobs.into_iter().enumerate() {
            let slots = Arc::clone(&slots);
            let guard = LatchGuard(Arc::clone(&latch));
            self.execute(group, cost, move || {
                let _count_down_even_on_panic = guard;
                let v = job();
                lock_recover(&slots)[i] = Some(v);
            });
        }
        latch.wait();
        Arc::try_unwrap(slots)
            .unwrap_or_else(|_| panic!("batch slots still shared"))
            .into_inner()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .into_iter()
            .map(|o| o.expect("job panicked — see panics()"))
            .collect()
    }
}

/// The group whose next job should run: least charged cost per unit
/// weight among nonempty queues (u128 cross-multiplication, no
/// division), ties to the lowest group index.
fn pick(st: &ExecState, weights: &[u64]) -> Option<usize> {
    let mut best: Option<(usize, u64, u64)> = None; // (group, charged, weight)
    for (g, q) in st.queues.iter().enumerate() {
        if q.is_empty() {
            continue;
        }
        let cg = st.charged[g];
        let wg = weights.get(g).copied().unwrap_or(1).max(1);
        let better = match best {
            None => true,
            Some((_, cb, wb)) => (cg as u128) * wb as u128 < (cb as u128) * wg as u128,
        };
        if better {
            best = Some((g, cg, wg));
        }
    }
    best.map(|(g, _, _)| g)
}

fn worker_loop(inner: Arc<Inner>) {
    loop {
        let picked = {
            let mut st = lock_recover(&inner.state);
            loop {
                if let Some(g) = pick(&st, &inner.weights) {
                    let (cost, job) = st.queues[g].pop_front().expect("picked queue nonempty");
                    // charge at pickup so concurrent picks see the debt
                    // immediately; zero-cost jobs still pay one unit so
                    // a flood of them cannot starve the ledger
                    st.charged[g] = st.charged[g].saturating_add(cost.max(1));
                    break Some(job);
                }
                // pick-before-stop ordering drains every queue before a
                // worker exits: shutdown completes queued work
                if inner.stop.load(Ordering::SeqCst) {
                    break None;
                }
                st = match inner.work.wait(st) {
                    Ok(g) => g,
                    Err(p) => p.into_inner(),
                };
            }
        };
        let Some(job) = picked else { return };
        if catch_unwind(AssertUnwindSafe(job)).is_err() {
            inner.panics.fetch_add(1, Ordering::SeqCst);
        }
        let mut st = lock_recover(&inner.state);
        st.outstanding -= 1;
        if st.outstanding == 0 {
            // idle executor carries no fairness debt forward (the same
            // epoch-reset contract as the dispatch ledger)
            st.charged.iter_mut().for_each(|c| *c = 0);
        }
    }
}

impl Drop for BudgetExec {
    fn drop(&mut self) {
        self.inner.stop.store(true, Ordering::SeqCst);
        {
            let _guard = lock_recover(&self.inner.state);
            self.inner.work.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::time::Duration;

    #[test]
    fn thread_count_is_the_budget_not_the_group_sum() {
        let exec = BudgetExec::new(3, &[1, 1, 1, 1, 1, 1, 1, 1]);
        assert_eq!(exec.threads(), 3);
        assert_eq!(exec.groups(), 8);
    }

    #[test]
    fn runs_all_jobs_across_groups() {
        let exec = Arc::new(BudgetExec::new(2, &[1, 1, 1]));
        let counter = Arc::new(AtomicU64::new(0));
        for i in 0..90 {
            let c = Arc::clone(&counter);
            exec.execute(i % 3, 1, move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        // drop joins the workers, which drain every queue first
        drop(Arc::try_unwrap(exec).unwrap_or_else(|_| panic!("exec still shared")));
        assert_eq!(counter.load(Ordering::SeqCst), 90);
    }

    #[test]
    fn run_batch_preserves_order() {
        let exec = BudgetExec::new(3, &[1]);
        let jobs: Vec<_> = (0..50).map(|i| (1u64, move || i * 2)).collect();
        assert_eq!(exec.run_batch(0, jobs), (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn panicking_job_releases_the_latch_and_is_counted() {
        let exec = BudgetExec::new(2, &[1]);
        let jobs: Vec<(u64, Box<dyn FnOnce() -> usize + Send>)> = vec![
            (1, Box::new(|| 7usize)),
            (1, Box::new(|| panic!("boom"))),
            (1, Box::new(|| 9usize)),
        ];
        let out = catch_unwind(AssertUnwindSafe(|| exec.run_batch(0, jobs)));
        assert!(out.is_err(), "run_batch re-reports the job panic");
        assert_eq!(exec.panics(), 1);
        // the pool survives and keeps serving
        assert_eq!(exec.run_batch(0, vec![(1u64, || 11usize)]), vec![11]);
    }

    #[test]
    fn weighted_pick_splits_worker_time_by_group_weight() {
        // One worker, two groups at weights 3:1, every job the same
        // cost and duration: the DRR pick should interleave pickups at
        // ~3:1, which shows up as charged-ledger proportionality while
        // both queues stay backlogged.
        let exec = Arc::new(BudgetExec::new(1, &[3, 1]));
        let served = Arc::new(Mutex::new(Vec::new()));
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        // hold the single worker so both queues fill before any pick
        {
            let gate = Arc::clone(&gate);
            exec.execute(0, 1, move || {
                let (lock, cv) = &*gate;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
            });
        }
        for _ in 0..40 {
            for (g, cost) in [(0usize, 10u64), (1usize, 10u64)] {
                let served = Arc::clone(&served);
                exec.execute(g, cost, move || {
                    served.lock().unwrap().push(g);
                });
            }
        }
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        // first 20 picks happen while both queues are still backlogged
        let prefix = loop {
            let s = served.lock().unwrap();
            if s.len() >= 20 {
                break s[..20].to_vec();
            }
            drop(s);
            std::thread::sleep(Duration::from_millis(1));
        };
        let g0 = prefix.iter().filter(|&&g| g == 0).count();
        assert!(
            (13..=17).contains(&g0),
            "weight-3 group took {g0}/20 of a contended worker (want ~15)"
        );
        drop(Arc::try_unwrap(exec).unwrap_or_else(|_| panic!("exec still shared")));
    }
}
