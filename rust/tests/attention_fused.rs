//! Golden bit-exactness of the head-parallel, fused-epilogue attention
//! path (DESIGN.md §7): on randomized shapes — including the
//! `heads * dh < d` zero-tail case — and live lengths
//! `m_eff ∈ {1, odd, geo.m}`, the fused forward pass (head loop forced
//! parallel AND knob-off serial) must equal the serial unfused
//! reference bit for bit, outputs and sqrt iteration counts alike.
//!
//! Setup (geometry sampling, weight stacks, activation streams) comes
//! from the shared fixture layer in `tests/common`.

mod common;

use common::{random_acts, random_geo, synthetic_layers};
use swifttron::model::Geometry;
use swifttron::sim::functional::{
    encoder_forward_ws, layer_forward, layer_forward_ws, layer_forward_ws_unfused,
    synthetic_consts, LayerWeights, Workspace,
};
use swifttron::util::rng::Rng;

#[test]
fn head_parallel_fused_matches_serial_unfused_on_randomized_shapes() {
    let mut rng = Rng::new(0xFACADE);
    for case in 0..24 {
        let geo = random_geo(&mut rng, case % 2 == 1);
        let w = LayerWeights::synthetic(&mut rng, &geo);
        let c = synthetic_consts(&geo);
        let odd = 1 + 2 * rng.below(geo.m as u64 / 2) as usize; // odd, < geo.m
        for m_eff in [1usize, odd, geo.m] {
            let x = random_acts(&mut rng, m_eff * geo.d);

            // fused, head loop FORCED parallel (threshold floored so
            // tiny shapes still exercise the scoped parallel-for)
            let mut ws = Workspace::new(&geo);
            ws.set_attn_heads_parallel(true);
            ws.set_attn_par_min_macs(0);
            let mut out_par = vec![0i32; m_eff * geo.d];
            let mut it_par = Vec::new();
            layer_forward_ws(&x, &w, &c, &geo, m_eff, &mut ws, &mut out_par, &mut it_par);

            // fused, serial head loop (the selectable knob off)
            let mut ws2 = Workspace::new(&geo);
            ws2.set_attn_heads_parallel(false);
            let mut out_ser = vec![0i32; m_eff * geo.d];
            let mut it_ser = Vec::new();
            layer_forward_ws(&x, &w, &c, &geo, m_eff, &mut ws2, &mut out_ser, &mut it_ser);

            // serial unfused reference over the same arena geometry
            let mut ws3 = Workspace::new(&geo);
            let mut out_ref = vec![0i32; m_eff * geo.d];
            let mut it_ref = Vec::new();
            layer_forward_ws_unfused(&x, &w, &c, &geo, m_eff, &mut ws3, &mut out_ref, &mut it_ref);

            let tag = format!("case {case} {geo:?} m_eff={m_eff}");
            assert_eq!(out_par, out_ref, "{tag}: parallel fused vs unfused");
            assert_eq!(it_par, it_ref, "{tag}: sqrt iters (parallel)");
            assert_eq!(out_ser, out_ref, "{tag}: serial fused vs unfused");
            assert_eq!(it_ser, it_ref, "{tag}: sqrt iters (serial)");

            // and the pre-refactor allocating wrapper agrees on the
            // truncated geometry (weights are m-independent)
            let trunc = Geometry { m: m_eff, ..geo };
            let want = layer_forward(&x, &w, &c, &trunc);
            assert_eq!(out_par, want.q_out, "{tag}: wrapper agreement");
            assert_eq!(it_par, want.sqrt_iters, "{tag}: wrapper sqrt iters");
        }
    }
}

#[test]
fn encoder_stack_fused_matches_layerwise_unfused_reference() {
    // The multi-layer workspace path (ping-pong activations) with the
    // parallel head loop forced on must equal chaining the serial
    // unfused reference layer by layer.
    let mut rng = Rng::new(0xBEEF);
    for case in 0..6 {
        let mut geo = random_geo(&mut rng, case % 2 == 0);
        geo.layers = 1 + rng.below(3) as usize;
        let layers = synthetic_layers(&mut rng, &geo);
        let m_eff = 1 + rng.below(geo.m as u64) as usize;
        let x = random_acts(&mut rng, m_eff * geo.d);

        let mut ws = Workspace::new(&geo);
        ws.set_attn_par_min_macs(0); // force the parallel head loop
        let mut out = vec![0i32; m_eff * geo.d];
        let mut iters = Vec::new();
        encoder_forward_ws(&x, &layers, &geo, m_eff, &mut ws, &mut out, &mut iters);

        let mut ws_ref = Workspace::new(&geo);
        let mut cur = x.clone();
        let mut nxt = vec![0i32; m_eff * geo.d];
        let mut it_ref = Vec::new();
        for (w, c) in &layers {
            layer_forward_ws_unfused(&cur, w, c, &geo, m_eff, &mut ws_ref, &mut nxt, &mut it_ref);
            std::mem::swap(&mut cur, &mut nxt);
        }
        assert_eq!(out, cur, "case {case} {geo:?} m_eff={m_eff}");
        assert_eq!(iters, it_ref, "case {case} sqrt iters");
    }
}

#[test]
fn zero_tail_columns_stay_inert_under_both_paths() {
    // heads * dh < d: flipping an input value in the tail columns must
    // influence both paths identically (the tail flows through the
    // projections and residuals, just not through attention) — and the
    // two paths must stay bit-exact while doing so.
    let mut rng = Rng::new(0x7A11);
    let geo = Geometry::new(2 * 8 + 1, 2, 8, 16, 1); // d=17, heads*dh=16
    assert!(geo.heads * geo.dh() < geo.d);
    let w = LayerWeights::synthetic(&mut rng, &geo);
    let c = synthetic_consts(&geo);
    let x = random_acts(&mut rng, geo.m * geo.d);
    let mut x_flip = x.clone();
    x_flip[geo.d - 1] = (x_flip[geo.d - 1] + 40).min(127); // tail column, row 0

    for input in [&x, &x_flip] {
        let mut ws = Workspace::new(&geo);
        ws.set_attn_par_min_macs(0);
        let mut out_fused = vec![0i32; geo.m * geo.d];
        let mut it_fused = Vec::new();
        layer_forward_ws(input, &w, &c, &geo, geo.m, &mut ws, &mut out_fused, &mut it_fused);
        let want = layer_forward(input, &w, &c, &geo);
        assert_eq!(out_fused, want.q_out, "zero-tail geometry diverged");
        assert_eq!(it_fused, want.sqrt_iters);
    }
}
