//! Serving metrics: counters + latency series, shared across workers.

use crate::util::stats::Series;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub errors: AtomicU64,
    /// end-to-end wallclock latency (seconds)
    pub e2e_s: Mutex<Series>,
    /// time spent queued before dispatch (seconds)
    pub queue_s: Mutex<Series>,
    /// PJRT execution wallclock (seconds)
    pub exec_s: Mutex<Series>,
    /// simulated accelerator time (milliseconds of virtual time)
    pub accel_ms: Mutex<Series>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_request(&self) {
        self.requests.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_completion(&self, e2e: f64, queued: f64, exec: f64, accel_ms: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        self.e2e_s.lock().unwrap().push(e2e);
        self.queue_s.lock().unwrap().push(queued);
        self.exec_s.lock().unwrap().push(exec);
        self.accel_ms.lock().unwrap().push(accel_ms);
    }

    pub fn record_error(&self) {
        self.errors.fetch_add(1, Ordering::Relaxed);
    }

    pub fn report(&self) -> String {
        let done = self.completed.load(Ordering::Relaxed);
        let req = self.requests.load(Ordering::Relaxed);
        let err = self.errors.load(Ordering::Relaxed);
        format!(
            "requests={req} completed={done} errors={err}\n  e2e   {}\n  queue {}\n  exec  {}\n  accel {}",
            self.e2e_s.lock().unwrap().summary("s"),
            self.queue_s.lock().unwrap().summary("s"),
            self.exec_s.lock().unwrap().summary("s"),
            self.accel_ms.lock().unwrap().summary("ms"),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_series_accumulate() {
        let m = Metrics::new();
        m.record_request();
        m.record_request();
        m.record_completion(0.01, 0.001, 0.008, 0.03);
        m.record_error();
        assert_eq!(m.requests.load(Ordering::Relaxed), 2);
        assert_eq!(m.completed.load(Ordering::Relaxed), 1);
        assert_eq!(m.errors.load(Ordering::Relaxed), 1);
        assert!(m.report().contains("completed=1"));
    }
}
