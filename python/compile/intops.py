"""Integer-only arithmetic spec for SwiftTron (I-BERT-style approximations).

This module is the *bit-exact specification* of every integer operation the
SwiftTron datapath performs.  Three implementations exist in the repo and
must agree exactly:

  1. this module (vectorized jnp, used by the L2 model graph),
  2. the Pallas kernels in ``kernels/`` (the L1 hot-path tiles),
  3. the rust ``quant`` module (the simulator's functional model).

All quantities follow the paper's convention ``a = q_a * S_a`` with
symmetric scales.  Linear ops run INT8xINT8 -> INT32; nonlinear ops run on
INT32.  Products inside requantization and the polynomial evaluations are
held in INT64, modelling the hardware multiplier's full-width product
before the shifter (the paper's Fig. 7 "INT32 multiplication + shift").

Rounding convention: *floor* everywhere (arithmetic right shift, floor
division), matching a shift-based hardware implementation.

Paper-faithful constants (from I-BERT [7], used by SwiftTron Figs. 11/14):

  exp  poly on [-ln2, 0]:  a=0.3585,  b=1.353,  c=0.344   (a(x+b)^2 + c)
  erf  poly on [0, -b]:    a=-0.2888, b=-1.769, c=1.0     (sign handled)
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp
from jax import lax

# --- polynomial coefficients (design-time constants) -----------------------

EXP_A, EXP_B, EXP_C = 0.3585, 1.353, 0.344
ERF_A, ERF_B, ERF_C = -0.2888, -1.769, 1.0
LN2 = math.log(2.0)

INT8_MIN, INT8_MAX = -128, 127
# Fixed-point precision of the normalized LayerNorm output (scale = 2^-LN_P).
LN_P = 7
# Softmax output scale = 1 / SM_UNIT (int8 => 127).
SM_UNIT = 127


# --- dyadic numbers ---------------------------------------------------------

@dataclass(frozen=True)
class Dyadic:
    """A rational b / 2^c approximating a positive real (paper Eq. (2))."""

    b: int
    c: int

    def value(self) -> float:
        return self.b / (1 << self.c)

    @staticmethod
    def approximate(x: float, bits: int = 16, max_shift: int = 30) -> "Dyadic":
        """Best b/2^c with b in [1, 2^bits) for a positive real ``x``.

        The hardware multiplies by ``b`` (one INT32 multiplier input) and
        shifts right by ``c``, so ``b`` must stay narrow; 16 bits gives a
        relative error < 2^-15, far below INT8 quantization noise.
        """
        if x <= 0:
            raise ValueError(f"dyadic approximation needs x > 0, got {x}")
        c = 0
        while x * (1 << c) < (1 << (bits - 1)) and c < max_shift:
            c += 1
        c = max(0, c - 1)
        b = round(x * (1 << c))
        if b < 1:
            b = 1
        return Dyadic(b=int(b), c=int(c))


def requantize(q, dy: Dyadic, lo: int = INT8_MIN, hi: int = INT8_MAX):
    """INT32 -> INT8 requantization: ``clamp((q * b) >> c)`` (paper Fig. 7).

    The product is taken in INT64 (hardware full-width product), the shift
    is arithmetic (floor), and the result saturates to the output range.
    """
    prod = q.astype(jnp.int64) * jnp.int64(dy.b)
    shifted = prod >> jnp.int64(dy.c)
    return jnp.clip(shifted, lo, hi).astype(jnp.int32)


def rescale(q, dy: Dyadic):
    """Dyadic rescale *without* saturation narrowing (residual-connection
    scale alignment, paper §III-I); stays INT32."""
    prod = q.astype(jnp.int64) * jnp.int64(dy.b)
    return (prod >> jnp.int64(dy.c)).astype(jnp.int32)


# --- integer exp / softmax (paper Figs. 11-12) ------------------------------

@dataclass(frozen=True)
class SoftmaxConsts:
    """Design-time constants for one Softmax unit instance.

    ``s_in`` is the scale of the INT32 input (after the Scale block).
    q_ln2 = floor(ln2 / s_in)           -- the paper's q3
    q_b   = floor(b / s_in)             -- the paper's q1
    q_c   = floor(c / (a * s_in^2))     -- the paper's q2
    """

    s_in: float
    q_ln2: int
    q_b: int
    q_c: int

    @staticmethod
    def design(s_in: float) -> "SoftmaxConsts":
        if s_in <= 0:
            raise ValueError("softmax input scale must be positive")
        q_ln2 = max(1, math.floor(LN2 / s_in))
        q_b = math.floor(EXP_B / s_in)
        q_c = math.floor(EXP_C / (EXP_A * s_in * s_in))
        return SoftmaxConsts(s_in=s_in, q_ln2=q_ln2, q_b=q_b, q_c=q_c)

    @property
    def s_exp(self) -> float:
        """Scale of the integer exponential output: a * s_in^2."""
        return EXP_A * self.s_in * self.s_in


def i_exp(q, consts: SoftmaxConsts):
    """Integer exp for non-positive ``q`` (INT32, scale ``s_in``).

    Decomposition (paper Fig. 12):  x = -z*ln2 + r with r in (-ln2, 0],
    exp(x) = 2^-z * exp(r); exp(r) by the 2nd-order polynomial.
    Returns INT64 values with scale ``consts.s_exp``.
    """
    q = q.astype(jnp.int64)
    z = (-q) // jnp.int64(consts.q_ln2)
    r = q + z * jnp.int64(consts.q_ln2)  # in (-q_ln2, 0]
    t = r + jnp.int64(consts.q_b)
    poly = t * t + jnp.int64(consts.q_c)  # scale a*s_in^2, >= 0
    z = jnp.clip(z, 0, 62)
    return poly >> z


def i_softmax(q, consts: SoftmaxConsts, axis: int = -1):
    """Integer softmax along ``axis`` (paper Fig. 11, three phases).

    Phase 1: running-max search.  Phase 2: integer exp of (q - max).
    Phase 3: divider -> INT8 output with scale 1/SM_UNIT.  The divider
    rounds to nearest (one extra adder on the ASIC): plain flooring loses
    up to n/(2*SM_UNIT) of probability mass per row, which is material at
    the paper's m=256 sequence length.
    """
    q = q.astype(jnp.int32)
    qmax = jnp.max(q, axis=axis, keepdims=True)
    e = i_exp(q - qmax, consts)  # int64, scale s_exp
    denom = jnp.sum(e, axis=axis, keepdims=True)
    denom = jnp.maximum(denom, 1)
    out = (e * jnp.int64(SM_UNIT) + (denom >> 1)) // denom
    return jnp.clip(out, 0, SM_UNIT).astype(jnp.int32)


# --- integer erf / GELU (paper Fig. 14) --------------------------------------

@dataclass(frozen=True)
class GeluConsts:
    """Design-time constants for the GELU unit.

    ``s_in`` is the scale of the INT32 GELU input; the erf polynomial is
    evaluated at scale ``s_er = s_in / sqrt(2)``:
    q_b   = floor(b / s_er)             -- the paper's q5/q6 (b < 0)
    q_c   = floor(c / (a * s_er^2))     -- the paper's q7
    q_one = floor(1 / s_erf)            -- the paper's q8
    """

    s_in: float
    q_b: int
    q_c: int
    q_one: int

    @staticmethod
    def design(s_in: float) -> "GeluConsts":
        if s_in <= 0:
            raise ValueError("gelu input scale must be positive")
        s_er = s_in / math.sqrt(2.0)
        q_b = math.floor(ERF_B / s_er)  # negative
        q_c = math.floor(ERF_C / (ERF_A * s_er * s_er))  # negative
        s_erf = ERF_A * s_er * s_er  # negative
        q_one = math.floor(1.0 / s_erf)  # negative
        return GeluConsts(s_in=s_in, q_b=q_b, q_c=q_c, q_one=q_one)

    @property
    def s_erf(self) -> float:
        s_er = self.s_in / math.sqrt(2.0)
        return ERF_A * s_er * s_er

    @property
    def s_out(self) -> float:
        """Scale of the INT GELU output: s_in * s_erf / 2."""
        return self.s_in * self.s_erf / 2.0


def i_erf_core(q, consts: GeluConsts):
    """Signed 2nd-order polynomial erf estimate (INT64, scale ``s_erf``).

    erf(x) ~ sign(x) * [a(min(|x|,-b) + b)^2 + c]; with the negative ``a``
    folded into the scale, the integer value is sign * (t^2 + q_c).
    """
    q = q.astype(jnp.int64)
    sgn = jnp.sign(q)
    qabs = jnp.minimum(jnp.abs(q), jnp.int64(-consts.q_b))
    t = qabs + jnp.int64(consts.q_b)  # in [q_b, 0]
    return sgn * (t * t + jnp.int64(consts.q_c))


def i_gelu(q, consts: GeluConsts):
    """Integer GELU: ``q * (erf_int + q_one)`` (INT64, scale ``s_out``)."""
    q64 = q.astype(jnp.int64)
    erf = i_erf_core(q64, consts)
    return q64 * (erf + jnp.int64(consts.q_one))


# --- integer sqrt / LayerNorm (paper Fig. 15) --------------------------------

ISQRT_MAX_ITERS = 32  # Babylonian from 2^ceil(bits/2) converges well within


def _bit_length(n):
    """Integer bit length of non-negative INT64 ``n`` (0 -> 0)."""
    n = n.astype(jnp.int64)
    bl = jnp.zeros_like(n)
    for shift in (32, 16, 8, 4, 2, 1):
        big = n >= (jnp.int64(1) << shift)
        bl = jnp.where(big, bl + shift, bl)
        n = jnp.where(big, n >> shift, n)
    return bl + jnp.where(n > 0, 1, 0)


def i_sqrt(n):
    """Iterative integer sqrt (paper §III-I / ref [29], Babylonian method).

    x_0 = 2^ceil(bits/2); x_{i+1} = (x_i + n // x_i) >> 1, stop when
    x_{i+1} >= x_i, answer is x_i.  (The paper's "(x_i + x_i/n)/2" is a
    typo for the Babylonian update; the cited algorithm and the I-BERT
    implementation both use (x_i + n/x_i)/2.)  Input 0 short-circuits to 0.

    Implemented as a fixed-trip-count loop with a "frozen" lane per element
    so it lowers to static HLO; the rust simulator counts the true
    data-dependent iteration count for timing.
    """
    n = n.astype(jnp.int64)
    x0 = jnp.int64(1) << ((_bit_length(n) + 1) >> 1)
    x0 = jnp.maximum(x0, 1)

    def body(_, state):
        x, done = state
        x1 = (x + n // x) >> 1
        stop = x1 >= x
        new_x = jnp.where(done | stop, x, x1)
        return new_x, done | stop

    x, _ = lax.fori_loop(
        0, ISQRT_MAX_ITERS, body, (x0, jnp.zeros_like(n, dtype=bool))
    )
    return jnp.where(n == 0, jnp.int64(0), x)


@dataclass(frozen=True)
class LayerNormConsts:
    """Design-time constants for one LayerNorm unit.

    Input: INT32 ``q`` with scale ``s_in`` (post residual alignment).
    Output: qn * q_gamma + q_beta at scale ``s_out = 2^-LN_P * s_gamma``
    where qn = floor(y * 2^LN_P / std) is the normalized value.
    """

    s_in: float
    s_gamma: float
    d: int

    @property
    def s_out(self) -> float:
        return self.s_gamma / (1 << LN_P)


def i_layernorm(q, q_gamma, q_beta, consts: LayerNormConsts, axis: int = -1):
    """Integer LayerNorm (paper Fig. 15, three phases).

    Phase 1: integer mean.  Phase 2: integer variance + iterative sqrt.
    Phase 3: divider + affine.  ``q_gamma`` INT8 (scale s_gamma), ``q_beta``
    INT32 (scale s_out).  Returns INT32 at scale ``consts.s_out``.
    """
    q = q.astype(jnp.int64)
    d = q.shape[axis]
    mean = jnp.sum(q, axis=axis, keepdims=True) // jnp.int64(d)
    y = q - mean
    var = jnp.sum(y * y, axis=axis, keepdims=True) // jnp.int64(d)
    std = jnp.maximum(i_sqrt(var), 1)
    qn = (y << LN_P) // std
    out = qn * q_gamma.astype(jnp.int64) + q_beta.astype(jnp.int64)
    return jnp.clip(out, -(2**31), 2**31 - 1).astype(jnp.int32)


# --- linear ------------------------------------------------------------------

def i_matmul(q_x, q_w, q_bias=None):
    """INT8 x INT8 -> INT32 matmul with INT32 bias (paper Fig. 6).

    ``q_x``: (m, k) INT8 activations; ``q_w``: (k, n) INT8 weights;
    ``q_bias``: (n,) INT32 at scale s_x * s_w.  Output INT32, scale
    s_x * s_w.
    """
    acc = jnp.dot(
        q_x.astype(jnp.int32),
        q_w.astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )
    if q_bias is not None:
        acc = acc + q_bias.astype(jnp.int32)
    return acc


def quantize(x, scale: float, lo: int = INT8_MIN, hi: int = INT8_MAX):
    """Float -> integer quantization (build-time only; never on the ASIC)."""
    q = jnp.round(x / scale)
    return jnp.clip(q, lo, hi).astype(jnp.int32)


def dequantize(q, scale: float):
    """Integer -> float (build-time / validation only)."""
    return q.astype(jnp.float32) * scale
