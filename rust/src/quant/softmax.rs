//! Integer Softmax unit (paper §III-F, Figs. 11-12): three phases —
//! maximum search, polynomial integer exponential, rounding divider.

use super::div_floor;

/// Output unit: probabilities quantize to `[0, SM_UNIT]` at scale 1/SM_UNIT.
pub const SM_UNIT: i64 = 127;

/// I-BERT exp polynomial coefficients on [-ln2, 0]: a(x+b)^2 + c.
pub const EXP_A: f64 = 0.3585;
pub const EXP_B: f64 = 1.353;
pub const EXP_C: f64 = 0.344;

/// Design-time constants of one Softmax unit (the paper's q1..q3).
#[derive(Clone, Copy, Debug)]
pub struct SoftmaxConsts {
    pub s_in: f64,
    pub q_ln2: i64,
    pub q_b: i64,
    pub q_c: i64,
}

impl SoftmaxConsts {
    pub fn design(s_in: f64) -> SoftmaxConsts {
        assert!(s_in > 0.0, "softmax input scale must be positive");
        SoftmaxConsts {
            s_in,
            q_ln2: ((std::f64::consts::LN_2 / s_in).floor() as i64).max(1),
            q_b: (EXP_B / s_in).floor() as i64,
            q_c: (EXP_C / (EXP_A * s_in * s_in)).floor() as i64,
        }
    }

    /// Scale of the integer exponential output: a * s_in^2.
    pub fn s_exp(&self) -> f64 {
        EXP_A * self.s_in * self.s_in
    }
}

/// Integer exp of a non-positive value (paper Fig. 12 decomposition).
#[inline]
pub fn i_exp(q: i64, c: &SoftmaxConsts) -> i64 {
    debug_assert!(q <= 0);
    let z = div_floor(-q, c.q_ln2);
    let r = q + z * c.q_ln2; // in (-q_ln2, 0]
    let t = r + c.q_b;
    let poly = t * t + c.q_c;
    poly >> z.clamp(0, 62)
}

/// Integer softmax over one row: INT32 inputs at `c.s_in`, INT8 outputs
/// at scale 1/SM_UNIT.  Returns outputs in `out`.
pub fn i_softmax(q: &[i64], c: &SoftmaxConsts, out: &mut [i32]) {
    assert_eq!(q.len(), out.len());
    if q.is_empty() {
        return;
    }
    // Phase 1: maximum search.
    let qmax = *q.iter().max().unwrap();
    // Phase 2: integer exponential (denominator accumulation).
    let mut denom: i64 = 0;
    for &v in q {
        denom += i_exp(v - qmax, c);
    }
    let denom = denom.max(1);
    // Phase 3: rounding divider.  i_exp is recomputed per element — it is
    // a handful of integer ops, cheaper than staging a wide temporary
    // (and exactly what the hardware's second pass does).
    for (o, &v) in out.iter_mut().zip(q) {
        let e = i_exp(v - qmax, c);
        *o = ((e * SM_UNIT + (denom >> 1)) / denom).clamp(0, SM_UNIT) as i32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn c() -> SoftmaxConsts {
        SoftmaxConsts::design(0.05)
    }

    #[test]
    fn design_constants_match_python() {
        // cross-checked against intops.SoftmaxConsts.design(0.05)
        let c = c();
        assert_eq!(c.q_ln2, 13);
        assert_eq!(c.q_b, 27);
        assert_eq!(c.q_c, 383);
    }

    #[test]
    fn iexp_monotone_nonincreasing() {
        let c = c();
        let mut prev = i64::MAX;
        for i in 0..500 {
            let e = i_exp(-7 * i, &c);
            assert!(e <= prev);
            prev = e;
        }
    }

    #[test]
    fn iexp_tracks_float_exp() {
        let c = SoftmaxConsts::design(0.01);
        for x in (-600..=0).step_by(13) {
            let approx = i_exp(x, &c) as f64 * c.s_exp();
            let exact = (x as f64 * 0.01).exp();
            assert!((approx - exact).abs() < 0.03, "x={x}: {approx} vs {exact}");
        }
    }

    #[test]
    fn softmax_uniform_row() {
        let c = c();
        let q = vec![37i64; 16];
        let mut out = vec![0i32; 16];
        i_softmax(&q, &c, &mut out);
        assert!(out.iter().all(|&o| o == out[0]));
        let sum: i64 = out.iter().map(|&o| o as i64).sum();
        assert!((sum - SM_UNIT).abs() <= 16, "sum {sum}");
    }

    #[test]
    fn softmax_one_hot() {
        let c = c();
        let mut q = vec![-(1i64 << 15); 16];
        q[3] = 1 << 15;
        let mut out = vec![0i32; 16];
        i_softmax(&q, &c, &mut out);
        assert_eq!(out[3], SM_UNIT as i32);
        assert!(out.iter().enumerate().all(|(i, &o)| i == 3 || o == 0));
    }

    #[test]
    fn softmax_monotone_in_input() {
        let c = c();
        let q: Vec<i64> = (0..32).map(|i| (i * 17) as i64 - 200).collect();
        let mut out = vec![0i32; 32];
        i_softmax(&q, &c, &mut out);
        for w in out.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }

    #[test]
    fn softmax_empty_row_is_noop() {
        i_softmax(&[], &c(), &mut []);
    }
}
