//! Dyadic numbers and the Requantization unit (paper §III-C, Fig. 7).

use super::{INT8_MAX, INT8_MIN};

/// A rational `b / 2^c` approximating a positive real (paper Eq. (2)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Dyadic {
    pub b: i64,
    pub c: u32,
}

impl Dyadic {
    /// Best `b/2^c` with `b` in `[1, 2^bits)` — identical to the python
    /// designer (`intops.Dyadic.approximate`) on the representable band.
    ///
    /// The mantissa contract `b < 2^bits` is enforced for *every* input
    /// (ISSUE 3): `x` at or above `2^bits` is rejected with a panic —
    /// no non-negative shift can represent it, and silently letting
    /// `b = x.round()` run past the contract breaks the `q * b` INT64
    /// no-overflow argument [`requantize`] rests on.  The rounding
    /// boundary just below `2^bits` (where `x.round()` would land
    /// exactly *on* `2^bits`) clamps to the largest legal mantissa
    /// instead, keeping the error under one ulp.
    pub fn approximate(x: f64, bits: u32, max_shift: u32) -> Dyadic {
        assert!(x > 0.0, "dyadic approximation needs x > 0, got {x}");
        assert!((1..=62).contains(&bits), "dyadic mantissa width {bits} unsupported");
        assert!(
            x < (1i64 << bits) as f64,
            "dyadic approximation: x = {x} needs a mantissa b >= 2^{bits} at c = 0, \
             outside the documented b < 2^{bits} contract — rescale the input"
        );
        let mut c = 0u32;
        while x * ((1u64 << c) as f64) < (1u64 << (bits - 1)) as f64 && c < max_shift {
            c += 1;
        }
        c = c.saturating_sub(1);
        let mut b = (x * (1u64 << c) as f64).round() as i64;
        if b >= 1i64 << bits {
            // Only reachable at c == 0 with x in [2^bits - 0.5, 2^bits):
            // any c > 0 comes out of the shift search with
            // x * 2^c < 2^(bits-1), so rounding cannot cross the
            // ceiling there.  Clamp the round-up back into the contract.
            debug_assert_eq!(c, 0, "mantissa overflow away from the c = 0 boundary");
            b = (1i64 << bits) - 1;
        }
        Dyadic { b: b.max(1), c }
    }

    pub fn approx16(x: f64) -> Dyadic {
        Dyadic::approximate(x, 16, 30)
    }

    pub fn value(&self) -> f64 {
        self.b as f64 / (1u64 << self.c) as f64
    }

    /// Multiply the dyadic by `2^p` *exactly*: shrink the shift while it
    /// lasts, then widen the mantissa.  The INT4 weight tier uses this
    /// to compensate its 16x-smaller accumulator scale at readout
    /// (`quant::int4`): for any accumulator `q`,
    /// `requantize(q, dy.scale_pow2(p)) == requantize(q << p, dy)` —
    /// shifting the product left by `p` before an arithmetic right
    /// shift by `c` is exactly a right shift by `c - p` (or a left
    /// shift by `p - c`), so the two forms are bit-identical, not
    /// approximately equal.  The widened mantissa stays far inside the
    /// `q * b` INT64 no-overflow argument (`b < 2^16` becomes
    /// `b < 2^(16+p)`; the paths that use this scale by `p = 4`).
    pub fn scale_pow2(self, p: u32) -> Dyadic {
        if self.c >= p {
            Dyadic { b: self.b, c: self.c - p }
        } else {
            Dyadic { b: self.b << (p - self.c), c: 0 }
        }
    }
}

/// INT32 -> INT8 requantization: `clamp((q * b) >> c)` (paper Fig. 7).
#[inline]
pub fn requantize(q: i64, dy: Dyadic) -> i32 {
    requantize_signed(q, dy, 1)
}

/// Requantization with a signed multiplier `sign*b` (negative-scale
/// inputs, e.g. the GELU output whose scale carries erf's `a < 0`).
#[inline]
pub fn requantize_signed(q: i64, dy: Dyadic, sign: i64) -> i32 {
    let prod = q * (sign * dy.b);
    let shifted = prod >> dy.c;
    shifted.clamp(INT8_MIN, INT8_MAX) as i32
}

/// Dyadic rescale *without* saturation (residual-connection alignment,
/// paper §III-I): stays INT32-range by design-time scale choice.
#[inline]
pub fn rescale(q: i64, dy: Dyadic) -> i64 {
    (q * dy.b) >> dy.c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn approximation_close_for_wide_range() {
        for x in [1e-4, 0.01, 0.3, 1.0, 7.7, 999.0] {
            let dy = Dyadic::approx16(x);
            assert!((dy.value() - x).abs() / x < 2f64.powi(-14), "{x} -> {dy:?}");
        }
    }

    #[test]
    fn requantize_saturates() {
        let dy = Dyadic::approx16(1.0);
        assert_eq!(requantize(1 << 30, dy), 127);
        assert_eq!(requantize(-(1 << 30), dy), -128);
        assert_eq!(requantize(0, dy), 0);
    }

    #[test]
    fn negative_inputs_floor_not_truncate() {
        let dy = Dyadic { b: 3, c: 2 }; // * 0.75
        assert_eq!(requantize(-1, dy), -1); // (-3)>>2 == -1
        assert_eq!(requantize(-2, dy), -2);
        assert_eq!(requantize(1, dy), 0);
    }

    #[test]
    fn signed_multiplier_negates() {
        let dy = Dyadic { b: 4, c: 2 };
        assert_eq!(requantize_signed(5, dy, -1), -5);
        assert_eq!(requantize_signed(-5, dy, -1), 5);
    }

    #[test]
    fn rescale_no_saturation() {
        let dy = Dyadic { b: 1, c: 0 };
        assert_eq!(rescale(1 << 40, dy), 1 << 40);
    }

    #[test]
    fn approximate_contract_holds_across_magnitudes() {
        // Property sweep (ISSUE 3): log-uniform x over the representable
        // band of several mantissa widths — including x >= 2^(bits-1),
        // where the shift search exits at c = 0 and the old code let
        // b = x.round() run past the documented contract.  Everywhere:
        // b in [1, 2^bits), c <= max_shift, and the half-ulp bound
        // |b - x*2^c| <= 1 with x*2^c >= b/2 gives rel. error <= 1/b.
        let mut rng = crate::util::rng::Rng::new(0xD7AD1C);
        for &bits in &[12u32, 16, 21] {
            let hi: f64 = ((1i64 << bits) as f64 - 1.0).min(1e6);
            let (lo_ln, hi_ln) = (1e-6f64.ln(), hi.ln());
            for case in 0..2000 {
                let x = (lo_ln + rng.f64() * (hi_ln - lo_ln)).exp();
                let dy = Dyadic::approximate(x, bits, 30);
                assert!(
                    dy.b >= 1 && dy.b < 1i64 << bits,
                    "b contract violated: bits={bits} case={case} x={x} -> {dy:?}"
                );
                assert!(dy.c <= 30, "shift contract: x={x} -> {dy:?}");
                let rel = (dy.value() - x).abs() / x;
                assert!(
                    rel <= 1.0 / dy.b as f64,
                    "relative error: bits={bits} x={x} -> {dy:?} rel={rel}"
                );
            }
        }
    }

    #[test]
    fn approximate_clamps_rounding_boundary_into_contract() {
        // x just below 2^16: round(x * 2^0) == 65536 == 2^16, one past
        // the contract — must clamp to the largest legal mantissa
        let dy = Dyadic::approximate(65535.7, 16, 30);
        assert_eq!((dy.b, dy.c), (65535, 0));
        // the rest of the high band (c = 0, no shift) stays exact
        let dy = Dyadic::approximate(40000.0, 16, 30);
        assert_eq!((dy.b, dy.c), (40000, 0));
    }

    #[test]
    #[should_panic(expected = "rescale the input")]
    fn approximate_rejects_x_beyond_mantissa_ceiling() {
        // 2^16 <= x: unrepresentable with b < 2^16 and a non-negative
        // shift — a clear panic, not a silent contract violation
        Dyadic::approximate(66000.0, 16, 30);
    }

    #[test]
    fn matches_python_designer_examples() {
        // values cross-checked against intops.Dyadic.approximate
        let dy = Dyadic::approx16(0.004123251145568775);
        assert_eq!((dy.b, dy.c), (17294, 22));
    }
}
