#!/usr/bin/env bash
# Tier-1 gate: release build, tests, formatting, clippy, and rustdoc
# with warnings denied — the doc pass makes dangling references (e.g.
# to DESIGN.md sections that were renamed away) fail fast instead of
# rotting.  `set -euo pipefail` makes every stage a hard gate: a
# mid-script failure (or formatting drift at the fmt stage) stops the
# pipeline instead of scrolling past.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
cargo test -q
cargo fmt --check
cargo clippy --all-targets -- -D warnings
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
echo "ci.sh: all green"
