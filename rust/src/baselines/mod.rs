//! Comparison points of the paper's evaluation:
//! * [`gpu`] — an RTX 2080 Ti roofline cost model (Table II's "Speedup
//!   w.r.t. GPU" column; DESIGN.md §5 documents the substitution),
//! * [`fp32_asic`] — a hypothetical FP32-datapath SwiftTron, quantifying
//!   Fig. 1a/Fig. 2's point that FP arithmetic forfeits the efficiency,
//! * [`comparison`] — the qualitative feature matrix of Table III.

pub mod comparison;
pub mod fp32_asic;
pub mod gpu;

pub use comparison::{comparison_table, RelatedWork};
pub use fp32_asic::fp32_asic_report;
pub use gpu::{gpu_inference_ms, GpuModel};
