//! Multi-tenant serving demo (DESIGN.md §8, §9): three resident models
//! — `tiny` (weight 2, two replicas), `deit_s` (weight 1), and
//! `roberta_base` (weight 1, autoscaled 1..=2 replicas under a 50 ms
//! SLO) — behind one router, flooded with short variable-length
//! traffic.  Each model group runs its own dispatcher concurrently, so
//! cheap `tiny` groups never queue behind a `roberta_base` barrier,
//! and the autoscaler grows the backlogged roberta group toward its
//! max while the flood lasts.  A mid-flight metrics snapshot shows the
//! per-model ledgers (backlog, active replicas, p50/p99, shares);
//! shutdown then drains the tail — submissions are weight-proportional
//! and everything completes, so the final served-token shares land on
//! the weight ratios.
//!
//! Run: `cargo run --release --example serving -- [requests_per_weight] [max_len]`

use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::{Duration, Instant};
use swifttron::coordinator::{BatchPolicy, Metrics, ModelRegistry, Router};
use swifttron::util::rng::Rng;

fn main() -> Result<(), String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // requests submitted per model = per_weight x that model's weight,
    // so under fair sharing every backlog drains at a similar pace
    let per_weight: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(24);
    let max_len: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8).max(1);

    let models: [(&str, &str, usize, u64); 3] = [
        ("tiny", "tiny", 2, 2),
        ("deit_s", "deit_s", 1, 1),
        ("roberta_base", "roberta_base", 1, 1),
    ];
    let mut reg = ModelRegistry::new();
    for &(name, preset, replicas, weight) in &models {
        if name == "roberta_base" {
            // the heavy tenant is SLO-managed: the autoscaler may grow
            // it to 2 replicas while the flood keeps its backlog over
            // the 50 ms latency class (DESIGN.md §9)
            reg.register_scaled(name, preset, replicas, 2, weight, Some(50.0), 7)?;
        } else {
            reg.register(name, preset, replicas, weight, 7)?;
        }
    }
    let max_lens: Vec<usize> =
        models.iter().map(|&(name, ..)| reg.max_seq_len(name).unwrap().min(max_len)).collect();

    let metrics = Arc::new(Metrics::new());
    // long max_wait: under flood the weighted-fair ledger (not deadline
    // expiry) picks the next model; shutdown drains whatever remains
    let wait = Duration::from_secs(30);
    let policy = BatchPolicy { max_batch: 4, max_wait: wait, bucket_width: 8 };
    let router = Router::start_multi(reg.into_groups(), policy, Arc::clone(&metrics));

    let total: usize = models.iter().map(|&(.., w)| per_weight * w as usize).sum();
    println!(
        "multi-model flood: {total} requests over {} models (lengths 1..=len_cap, bucket 8)",
        models.len()
    );
    for (&(name, _, replicas, weight), &cap) in models.iter().zip(&max_lens) {
        println!("  {name:13} replicas={replicas} weight={weight} len_cap={cap}");
    }

    let mut rng = Rng::new(2024);
    let t0 = Instant::now();
    let mut receivers = Vec::with_capacity(total);
    // interleave submissions round-robin so every model is backlogged
    // from the first dispatch
    for i in 0..per_weight * models.iter().map(|&(.., w)| w as usize).max().unwrap() {
        for (&(name, _, _, weight), &cap) in models.iter().zip(&max_lens) {
            if i >= per_weight * weight as usize {
                continue;
            }
            let len = 1 + rng.below(cap as u64) as usize;
            let tokens: Vec<i32> = (0..len).map(|_| rng.below(60) as i32).collect();
            let (tx, rx) = channel();
            router.submit_to(name, tokens, tx);
            receivers.push(rx);
        }
    }

    // snapshot mid-flood: per-model backlog, active replicas (watch
    // roberta_base grown past its min), and per-tenant p50/p99
    let deadline = Instant::now() + Duration::from_secs(120);
    while metrics.completed.load(Ordering::Relaxed) < (total / 2) as u64
        && Instant::now() < deadline
    {
        std::thread::sleep(Duration::from_millis(5));
    }
    println!("\n-- mid-flight snapshot (~half served) --");
    println!("{}", metrics.report());
    let total_w: u64 = models.iter().map(|&(.., w)| w).sum();
    for (m, &(name, .., weight)) in models.iter().enumerate() {
        let share = 100.0 * metrics.model_token_share(m);
        let target = 100.0 * weight as f64 / total_w as f64;
        println!(
            "  {name:13} served-token share {share:5.1}% (offered {target:5.1}%), \
             replicas={}",
            router.active_replicas(name).unwrap_or(0)
        );
    }

    // drain the tail and collect every reply
    router.shutdown();
    let mut errors = 0;
    for rx in receivers {
        if rx.recv().map(|r| r.error.is_some()).unwrap_or(true) {
            errors += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "\ncompleted {total} requests in {wall:.2}s ({:.1} req/s, {errors} errors)",
        total as f64 / wall
    );
    println!("\n-- final report --\n{}", metrics.report());
    Ok(())
}
