"""Flat binary tensor container (build-time writer; rust reads it).

No serde/npz on the rust side (offline crate set), so artifacts use the
simplest possible layout: one ``.bin`` file holding raw little-endian
tensor data back-to-back, plus a JSON index mapping
``name -> {dtype, shape, offset, nbytes}``.  dtypes: i8, i32, i64, f32.
"""

from __future__ import annotations

import json
import os

import numpy as np

_DTYPES = {
    "i8": np.int8,
    "i32": np.int32,
    "i64": np.int64,
    "f32": np.float32,
}
_NAMES = {v: k for k, v in _DTYPES.items()}


class BlobWriter:
    def __init__(self) -> None:
        self._entries: dict[str, dict] = {}
        self._chunks: list[bytes] = []
        self._offset = 0

    def add(self, name: str, arr: np.ndarray, dtype: str | None = None) -> None:
        if name in self._entries:
            raise KeyError(f"duplicate tensor {name!r}")
        a = np.asarray(arr)
        if dtype is None:
            dtype = _NAMES[a.dtype.type]
        a = np.ascontiguousarray(a.astype(_DTYPES[dtype]))
        raw = a.tobytes()
        self._entries[name] = {
            "dtype": dtype,
            "shape": list(a.shape),
            "offset": self._offset,
            "nbytes": len(raw),
        }
        self._chunks.append(raw)
        self._offset += len(raw)

    def write(self, path_prefix: str) -> None:
        os.makedirs(os.path.dirname(path_prefix) or ".", exist_ok=True)
        with open(path_prefix + ".bin", "wb") as f:
            for c in self._chunks:
                f.write(c)
        with open(path_prefix + ".json", "w") as f:
            json.dump({"tensors": self._entries}, f, indent=1, sort_keys=True)


def read_blob(path_prefix: str) -> dict[str, np.ndarray]:
    """Python-side reader (used by tests to round-trip what rust reads)."""
    with open(path_prefix + ".json") as f:
        index = json.load(f)["tensors"]
    with open(path_prefix + ".bin", "rb") as f:
        raw = f.read()
    out = {}
    for name, e in index.items():
        buf = raw[e["offset"] : e["offset"] + e["nbytes"]]
        out[name] = np.frombuffer(buf, dtype=_DTYPES[e["dtype"]]).reshape(e["shape"])
    return out
