//! Hardware configuration of a SwiftTron instance.
//!
//! The paper fixes one configuration for its evaluation (§IV-B: d=768,
//! k=12, m=256, d_ff=3072, 7 ns clock) but stresses that array size and
//! head parallelism are design-time tunables (§III-D).  [`HwConfig`]
//! captures those knobs; [`HwConfig::paper`] is the §IV-B instance.

use crate::model::Geometry;

#[derive(Clone, Copy, Debug)]
pub struct HwConfig {
    /// MAC array rows (output-stationary; matches sentence length m in
    /// the paper's configuration).
    pub array_rows: usize,
    /// MAC array columns (matches model dimension d in the paper's
    /// configuration).
    pub array_cols: usize,
    /// Attention-head units instantiated in parallel (paper Fig. 9).
    pub parallel_heads: usize,
    /// Row-parallel Softmax units (paper §III-F: m instances).
    pub softmax_units: usize,
    /// Element-parallel LayerNorm lanes (paper §III-I: d instances).
    pub layernorm_lanes: usize,
    /// Clock period in nanoseconds (paper: 7 ns -> ~143 MHz).
    pub clock_ns: f64,
    /// Pipeline depth of the Softmax / LayerNorm units (paper §IV-B:
    /// partitioned into three pipeline stages to meet timing).
    pub pipeline_stages: u64,
    /// Charge the LayerNorm sqrt its worst-case iteration count (paper
    /// footnote 3).  `false` uses the co-simulated data-dependent count.
    pub worst_case_sqrt: bool,
    /// Execute attention heads concurrently on *host* threads in the
    /// functional model (DESIGN.md §7).  Purely an execution knob —
    /// numerics and simulated cycles are identical either way (the
    /// hardware's own head concurrency is
    /// [`parallel_heads`](HwConfig::parallel_heads)); off forces the
    /// serial head loop.
    pub attn_heads_parallel: bool,
    /// Weight precision of the MAC array's weight port in bits: 8
    /// (the paper's uniform INT8 datapath) or 4 (the packed cascade
    /// tier, DESIGN.md §14).  At 4 bits one weight-SRAM word carries
    /// two k-panels, so *weight-stationary* matmuls (the Q/K/V/output
    /// projections and both FFN matmuls) stream their contraction in
    /// `ceil(k/2)` cycles ([`crate::sim::units::weight_matmul_cycles`]);
    /// activation-activation matmuls (Q.K^T, P.V) are unaffected.
    pub weight_bits: u8,
}

impl HwConfig {
    /// The paper's synthesized configuration (§IV-B, Table I).
    pub fn paper() -> HwConfig {
        HwConfig {
            array_rows: 256,
            array_cols: 768,
            parallel_heads: 12,
            softmax_units: 256,
            layernorm_lanes: 768,
            clock_ns: 7.0,
            pipeline_stages: 3,
            worst_case_sqrt: true,
            attn_heads_parallel: true,
            weight_bits: 8,
        }
    }

    /// An instance sized to a workload geometry (paper §III-D: array
    /// size and head parallelism are design-time tunables): array rows
    /// follow the sentence length, columns the model dimension, one
    /// Softmax unit per row, one LayerNorm lane per column, one head
    /// unit per model head.  For the roberta_base geometry this is
    /// exactly [`HwConfig::paper`]; the multi-tenant registry gives
    /// every resident model its own sized instance.
    pub fn sized_to(geo: &Geometry) -> HwConfig {
        HwConfig {
            array_rows: geo.m.max(1),
            array_cols: geo.d.max(1),
            parallel_heads: geo.heads.max(1),
            softmax_units: geo.m.max(1),
            layernorm_lanes: geo.d.max(1),
            clock_ns: 7.0,
            pipeline_stages: 3,
            worst_case_sqrt: true,
            attn_heads_parallel: true,
            weight_bits: 8,
        }
    }

    /// A smaller edge-class instance (used by the design-space example).
    pub fn edge() -> HwConfig {
        HwConfig {
            array_rows: 64,
            array_cols: 256,
            parallel_heads: 4,
            softmax_units: 64,
            layernorm_lanes: 256,
            clock_ns: 7.0,
            pipeline_stages: 3,
            worst_case_sqrt: true,
            attn_heads_parallel: true,
            weight_bits: 8,
        }
    }

    /// The INT4 tier of this instance on the *same silicon budget*
    /// (DESIGN.md §14): a 4-bit multiplier takes roughly a quarter of
    /// an 8-bit one's area, so the equal-area INT4 array instantiates
    /// twice the rows and twice the columns, and its weight port
    /// streams two packed k-panels per cycle (`weight_bits: 4`).
    /// Everything else — head units, softmax/layernorm lanes, clock —
    /// is shared infrastructure and carries over unchanged.
    pub fn int4_variant(&self) -> HwConfig {
        HwConfig {
            array_rows: self.array_rows * 2,
            array_cols: self.array_cols * 2,
            weight_bits: 4,
            ..*self
        }
    }

    /// Clock frequency in MHz.
    pub fn clock_mhz(&self) -> f64 {
        1000.0 / self.clock_ns
    }

    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 * self.clock_ns * 1e-6
    }

    /// Sanity-check a configuration against a workload geometry.
    pub fn validate(&self, geo: &Geometry) -> Result<(), String> {
        if self.array_rows == 0 || self.array_cols == 0 {
            return Err("MAC array must be non-empty".into());
        }
        if self.parallel_heads == 0 || self.parallel_heads > geo.heads.max(1) * 4 {
            return Err(format!(
                "parallel_heads {} unreasonable for {} heads",
                self.parallel_heads, geo.heads
            ));
        }
        if self.clock_ns <= 0.0 {
            return Err("clock period must be positive".into());
        }
        if self.weight_bits != 8 && self.weight_bits != 4 {
            return Err(format!(
                "weight_bits {} unsupported (the datapath packs 8- or 4-bit weights)",
                self.weight_bits
            ));
        }
        Ok(())
    }

    /// Total MAC elements (for the synthesis area model).
    pub fn mac_count(&self) -> u64 {
        self.array_rows as u64 * self.array_cols as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_is_143mhz() {
        let c = HwConfig::paper();
        assert!((c.clock_mhz() - 142.857).abs() < 0.01);
    }

    #[test]
    fn cycles_to_ms() {
        let c = HwConfig::paper();
        // 1 M cycles at 7 ns = 7 ms
        assert!((c.cycles_to_ms(1_000_000) - 7.0).abs() < 1e-9);
    }

    #[test]
    fn validate_rejects_zero_array() {
        let mut c = HwConfig::paper();
        c.array_rows = 0;
        assert!(c.validate(&Geometry::preset("tiny").unwrap()).is_err());
    }

    #[test]
    fn sized_to_matches_paper_instance_for_roberta_base() {
        // the paper's §IV-B instance IS the roberta_base-sized one
        let geo = Geometry::preset("roberta_base").unwrap();
        let c = HwConfig::sized_to(&geo);
        let p = HwConfig::paper();
        assert_eq!(c.array_rows, p.array_rows);
        assert_eq!(c.array_cols, p.array_cols);
        assert_eq!(c.parallel_heads, p.parallel_heads);
        assert_eq!(c.softmax_units, p.softmax_units);
        assert_eq!(c.layernorm_lanes, p.layernorm_lanes);
        assert_eq!(c.mac_count(), geo.m as u64 * geo.d as u64);
    }

    #[test]
    fn sized_to_validates_for_every_preset() {
        for name in Geometry::PRESET_NAMES {
            let geo = Geometry::preset(name).unwrap();
            HwConfig::sized_to(&geo).validate(&geo).unwrap();
        }
    }

    #[test]
    fn int4_variant_doubles_the_array_on_the_same_budget() {
        for name in Geometry::PRESET_NAMES {
            let geo = Geometry::preset(name).unwrap();
            let hw8 = HwConfig::sized_to(&geo);
            let hw4 = hw8.int4_variant();
            hw4.validate(&geo).unwrap();
            assert_eq!(hw4.weight_bits, 4);
            assert_eq!(hw4.array_rows, 2 * hw8.array_rows);
            assert_eq!(hw4.array_cols, 2 * hw8.array_cols);
            // equal silicon: 4x the MAC sites at a quarter the area each
            assert_eq!(hw4.mac_count(), 4 * hw8.mac_count());
            assert_eq!(hw4.parallel_heads, hw8.parallel_heads);
            assert_eq!(hw4.softmax_units, hw8.softmax_units);
        }
    }

    #[test]
    fn validate_rejects_unsupported_weight_bits() {
        let geo = Geometry::preset("tiny").unwrap();
        for bits in [0u8, 1, 2, 16] {
            let hw = HwConfig { weight_bits: bits, ..HwConfig::sized_to(&geo) };
            assert!(hw.validate(&geo).is_err(), "weight_bits={bits} must be rejected");
        }
    }
}
