//! Tiny declarative CLI argument parser (no clap in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments, with generated `--help` text.

use std::collections::BTreeMap;

#[derive(Clone, Debug)]
struct Spec {
    name: &'static str,
    help: &'static str,
    takes_value: bool,
    default: Option<String>,
}

/// Declarative argument set for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    program: String,
    about: &'static str,
    specs: Vec<Spec>,
    values: BTreeMap<&'static str, String>,
    flags: BTreeMap<&'static str, bool>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &'static str) -> Self {
        Args { program: program.to_string(), about, ..Default::default() }
    }

    /// Declare `--name <value>` with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.specs.push(Spec { name, help, takes_value: true, default: Some(default.into()) });
        self
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(Spec { name, help, takes_value: false, default: None });
        self
    }

    /// Parse; returns Err with a usage string on bad input or `--help`.
    pub fn parse(mut self, argv: &[String]) -> Result<Parsed, String> {
        for s in &self.specs {
            if s.takes_value {
                self.values.insert(s.name, s.default.clone().unwrap_or_default());
            } else {
                self.flags.insert(s.name, false);
            }
        }
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline) = match stripped.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (stripped, None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n{}", self.usage()))?
                    .clone();
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{key} needs a value"))?
                            .clone(),
                    };
                    self.values.insert(spec.name, v);
                } else {
                    self.flags.insert(spec.name, true);
                }
            } else {
                self.positional.push(a.clone());
            }
        }
        Ok(Parsed { values: self.values, flags: self.flags, positional: self.positional })
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.program, self.about);
        for spec in &self.specs {
            let left = if spec.takes_value {
                format!("--{} <v>", spec.name)
            } else {
                format!("--{}", spec.name)
            };
            let def = spec
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  {left:22} {}{def}\n", spec.help));
        }
        s
    }
}

/// Parsed argument values with typed accessors.
#[derive(Debug)]
pub struct Parsed {
    values: BTreeMap<&'static str, String>,
    flags: BTreeMap<&'static str, bool>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> &str {
        self.values.get(name).map(|s| s.as_str()).unwrap_or("")
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name}: expected integer, got {:?}", self.get(name)))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name}: expected integer, got {:?}", self.get(name)))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, String> {
        self.get(name)
            .parse()
            .map_err(|_| format!("--{name}: expected number, got {:?}", self.get(name)))
    }

    pub fn is_set(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let p = Args::new("t", "test")
            .opt("count", "4", "how many")
            .flag("verbose", "talk")
            .parse(&argv(&["--count", "9"]))
            .unwrap();
        assert_eq!(p.get_usize("count").unwrap(), 9);
        assert!(!p.is_set("verbose"));
    }

    #[test]
    fn equals_syntax_and_flags() {
        let p = Args::new("t", "test")
            .opt("name", "x", "")
            .flag("fast", "")
            .parse(&argv(&["--name=abc", "--fast", "pos1"]))
            .unwrap();
        assert_eq!(p.get("name"), "abc");
        assert!(p.is_set("fast"));
        assert_eq!(p.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_option_errors() {
        assert!(Args::new("t", "").parse(&argv(&["--nope"])).is_err());
    }

    #[test]
    fn help_is_err_with_usage() {
        let e = Args::new("t", "about")
            .opt("x", "1", "the x")
            .parse(&argv(&["--help"]))
            .unwrap_err();
        assert!(e.contains("about") && e.contains("--x"));
    }

    #[test]
    fn bad_number_reports_option() {
        let p = Args::new("t", "").opt("n", "1", "").parse(&argv(&["--n", "zz"])).unwrap();
        assert!(p.get_usize("n").unwrap_err().contains("--n"));
    }
}
