//! SLO-aware backlog autoscaler (DESIGN.md §9): a small control loop
//! that moves each scalable model group's replica count within its
//! `min..=max` bounds as the group's backlog-vs-SLO ratio crosses
//! hysteresis thresholds.
//!
//! The demand signal per tick is the *estimated drain time* of the
//! model's live backlog: `backlog_requests × mean_exec_ms ÷
//! active_replicas` — queued requests still in the batcher plus popped
//! groups in flight, times the model's own measured per-request
//! execution wall time (a prior before the first completion), divided
//! by the replicas currently serving.  Judged against the model's
//! `slo_ms` latency class:
//!
//! ```text
//!            drain_ms > grow_ratio · slo, below max ──► GROW  (spawn replica
//!                                                       from the factory,
//!                                                       shared Arc weights)
//!   shrink_ratio · slo > drain_ms, above min      ──► SHRINK (drain-then-
//!                                                       retire one replica)
//!            otherwise                             ──► HOLD
//! ```
//!
//! Hysteresis is two-fold: the dead band between `shrink_ratio` and
//! `grow_ratio` (a group sitting near its SLO neither grows nor
//! shrinks), plus a per-group cooldown of `hold_ticks` ticks after any
//! applied action so one burst cannot slam the group from min to max
//! and back within a few control intervals.  The decision function
//! [`decide`] is pure and unit-tested; the loop in
//! `coordinator::router` merely samples the signals and applies it.
//!
//! Floor repair (DESIGN.md §10): a group observed *below* its `min` —
//! possible only because the pool retires a panicked replica's slot on
//! the spot — is regrown immediately, bypassing both the cooldown and
//! the SLO gate; losing a replica is a fault to heal, not a load signal
//! to damp.  Any group with a factory gets this, including fixed-size
//! `min == max` groups the policy half of the loop never touches.

use super::metrics::Metrics;
use super::pool::GroupRuntime;
use std::sync::Arc;
use std::time::Duration;

/// Autoscaler tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct AutoscalePolicy {
    /// Control-loop tick interval.
    pub interval: Duration,
    /// Grow when estimated drain time exceeds `grow_ratio · slo_ms`.
    pub grow_ratio: f64,
    /// Shrink when estimated drain time falls below
    /// `shrink_ratio · slo_ms` (must sit well below `grow_ratio` — the
    /// gap is the hysteresis dead band).
    pub shrink_ratio: f64,
    /// Ticks a group holds after an applied grow/shrink before it may
    /// act again (cooldown half of the hysteresis).
    pub hold_ticks: u32,
    /// Service-time prior (ms per request) before a model's first
    /// completion.
    pub default_service_ms: f64,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy {
            interval: Duration::from_millis(5),
            grow_ratio: 1.0,
            shrink_ratio: 0.25,
            hold_ticks: 2,
            default_service_ms: 1.0,
        }
    }
}

/// One tick's verdict for one group.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleDecision {
    Grow,
    Shrink,
    Hold,
}

/// Pure scaling decision for one group at one tick: backlog (queued +
/// in-flight requests), active replica count and bounds, the model's
/// per-request service estimate, and its SLO class.
pub fn decide(
    backlog: usize,
    active: usize,
    min: usize,
    max: usize,
    service_ms: f64,
    slo_ms: f64,
    policy: &AutoscalePolicy,
) -> ScaleDecision {
    let active = active.max(1);
    let drain_ms = backlog as f64 * service_ms / active as f64;
    if drain_ms > policy.grow_ratio * slo_ms && active < max {
        ScaleDecision::Grow
    } else if drain_ms < policy.shrink_ratio * slo_ms && active > min {
        ScaleDecision::Shrink
    } else {
        ScaleDecision::Hold
    }
}

/// Per-group cooldown state for the control loop.
pub struct GroupScaleState {
    cooldown: u32,
}

impl GroupScaleState {
    pub fn new() -> GroupScaleState {
        GroupScaleState { cooldown: 0 }
    }
}

impl Default for GroupScaleState {
    fn default() -> Self {
        GroupScaleState::new()
    }
}

/// One autoscaler tick over one group: sample the signals, apply
/// [`decide`] under the cooldown, execute the action on the runtime.
/// Returns the decision actually applied (Hold during cooldown or when
/// the runtime refused).  `queued` is the group's batcher backlog
/// (queued + in flight), sampled by the caller under the batcher lock.
pub fn tick_group(
    rt: &Arc<GroupRuntime>,
    state: &mut GroupScaleState,
    queued: usize,
    metrics: &Metrics,
    policy: &AutoscalePolicy,
) -> ScaleDecision {
    // Floor repair outranks both the cooldown and the SLO gate: a group
    // below its `min` lost a replica to a fault (panic retirement),
    // which is a capacity hole to fix now, not a load signal to damp.
    // Applies to any group with a factory — `scalable()` (max > min and
    // an SLO class) is not required to get back to the floor.
    let (min, _) = rt.replica_bounds();
    if rt.active_replicas() < min && rt.can_respawn() {
        match rt.grow() {
            Ok(true) => {
                state.cooldown = policy.hold_ticks;
                return ScaleDecision::Grow;
            }
            Ok(false) => {}
            Err(e) => {
                eprintln!("autoscaler: model {:?} floor repair failed: {e}", rt.model());
                state.cooldown = policy.hold_ticks;
                return ScaleDecision::Hold;
            }
        }
    }
    if state.cooldown > 0 {
        state.cooldown -= 1;
        return ScaleDecision::Hold;
    }
    let Some(slo_ms) = rt.slo_ms() else { return ScaleDecision::Hold };
    let (min, max) = rt.replica_bounds();
    let active = rt.active_replicas();
    let service_ms = metrics.model(rt.model_index()).mean_exec_ms(policy.default_service_ms);
    let decision = decide(queued, active, min, max, service_ms, slo_ms, policy);
    let applied = match decision {
        ScaleDecision::Grow => match rt.grow() {
            Ok(applied) => applied,
            Err(e) => {
                // A failing factory must not fail silently: the group
                // would sit pinned at its floor blowing its SLO with
                // nothing explaining why.  Surface it, and take the
                // normal cooldown before retrying — a persistent
                // failure must not be re-invoked (and re-logged) at
                // tick frequency.
                eprintln!("autoscaler: model {:?} replica spawn failed: {e}", rt.model());
                state.cooldown = policy.hold_ticks;
                false
            }
        },
        ScaleDecision::Shrink => rt.shrink(),
        ScaleDecision::Hold => false,
    };
    if applied {
        state.cooldown = policy.hold_ticks;
        decision
    } else {
        ScaleDecision::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn policy() -> AutoscalePolicy {
        AutoscalePolicy {
            interval: Duration::from_millis(1),
            grow_ratio: 1.0,
            shrink_ratio: 0.25,
            hold_ticks: 2,
            default_service_ms: 1.0,
        }
    }

    #[test]
    fn grows_when_drain_time_exceeds_slo() {
        // 100 queued x 2 ms / 1 replica = 200 ms drain vs 20 ms SLO
        let p = policy();
        assert_eq!(decide(100, 1, 1, 4, 2.0, 20.0, &p), ScaleDecision::Grow);
        // at max: hold, never exceed the bound
        assert_eq!(decide(100, 4, 1, 4, 2.0, 20.0, &p), ScaleDecision::Hold);
    }

    #[test]
    fn shrinks_only_below_the_dead_band_and_above_min() {
        let p = policy();
        // idle: 0 ms drain < 0.25 x 20 ms
        assert_eq!(decide(0, 4, 1, 4, 2.0, 20.0, &p), ScaleDecision::Shrink);
        // at min: hold
        assert_eq!(decide(0, 1, 1, 4, 2.0, 20.0, &p), ScaleDecision::Hold);
        // inside the dead band (drain 10 ms, band 5..20 ms): hold —
        // a group near its SLO must not flap
        assert_eq!(decide(20, 4, 1, 4, 2.0, 20.0, &p), ScaleDecision::Hold);
    }

    #[test]
    fn capacity_scales_the_drain_estimate() {
        let p = policy();
        // the same backlog that overwhelms 1 replica is inside the SLO
        // for 4: 40 x 2 / 1 = 80 ms vs 40 x 2 / 4 = 20 ms against SLO 30
        assert_eq!(decide(40, 1, 1, 4, 2.0, 30.0, &p), ScaleDecision::Grow);
        assert_eq!(decide(40, 4, 1, 4, 2.0, 30.0, &p), ScaleDecision::Hold);
    }

    #[test]
    fn zero_active_is_treated_as_one_not_a_division_by_zero() {
        let p = policy();
        assert_eq!(decide(100, 0, 1, 4, 2.0, 1.0, &p), ScaleDecision::Grow);
    }
}
