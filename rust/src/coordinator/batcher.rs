//! Dynamic batcher: groups queued requests so the worker pool stays busy
//! without letting early arrivals wait unboundedly.
//!
//! SwiftTron processes one sequence at a time (the array is loaded per
//! sentence), so a "batch" here is a *dispatch group*: up to
//! `max_batch` requests released together to the engine replicas, or
//! whatever has queued when `max_wait` elapses — the standard
//! size-or-deadline policy of serving systems.
//!
//! With variable-length requests (DESIGN.md §6) the batcher additionally
//! buckets by sequence length: requests whose lengths round up to the
//! same multiple of [`BatchPolicy::bucket_width`] share a dispatch
//! group, so a group's per-request cost is uniform (no short request
//! rides behind a full-length straggler at the group barrier) and the
//! padding a bucket-configured accelerator would waste is bounded by the
//! bucket width and reported by `coordinator::metrics`.  A width of 0
//! disables bucketing — every request of one model shares one queue, the
//! seed behavior.
//!
//! With multiple resident models (DESIGN.md §8) the queue key becomes
//! `(model, padded_len)`, so a dispatch group is always
//! model-homogeneous, and model selection among full buckets is
//! *weighted-fair*: a deficit-round-robin variant over models where each
//! dispatch charges the model its group's *cost* and the next dispatch
//! goes to the backlogged model with the least normalized (charge ÷
//! weight) service.  The cost unit is the caller's: the serving router
//! charges `CostModel`-predicted accelerator cycles per request
//! ([`Batcher::push_costed`], DESIGN.md §12), so a 512-token
//! roberta_base request and a 512-token tiny request no longer count
//! the same; cost-agnostic callers ([`Batcher::push_keyed`]) fall back
//! to bucket-padded tokens.  A flood of cheap-model traffic therefore
//! cannot starve a heavy model past its share — while a deadline-expired
//! request still outranks any full bucket, whatever the weights say.
//!
//! With the concurrent per-group dispatch pipeline (DESIGN.md §9) the
//! batcher is additionally a *per-model work source for concurrent
//! poppers*: each model group's dispatcher calls
//! [`Batcher::take_batch_for`] for its own model while other groups'
//! dispatchers run their pops in parallel (all under the shared lock,
//! held only for the pop itself).  Fairness is charged **at pop time**,
//! never at completion time — every pop (including a deadline-expired
//! jump) immediately charges its model's deficit ledger — and popped
//! groups are tracked per model as *in flight* until the dispatcher
//! reports [`Batcher::complete`].  In-flight work counts as backlog for
//! the idle re-entry floor and blocks the epoch reset, so a model that
//! momentarily drains its queue while a group is still executing keeps
//! its fairness position instead of being treated as newly arrived.
//! The cross-model [`Batcher::take_batch`] survives as the degenerate
//! serial (single-dispatcher) pop and is bit-equivalent to the
//! per-model path for a one-model configuration.
//!
//! [`ShardedBatcher`] (DESIGN.md §13) is the concurrent serving form of
//! the same semantics: one shard per model — its own lock, its own
//! condvar, its own bucket queues — with the DRR ledger mirrored into
//! per-shard atomics so a submit touches exactly one shard and reads
//! every other model's fairness state lock-free.  `Batcher` stays as
//! the serial reference the sharded pop is asserted bit-equivalent to.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Fallback park interval for a dispatcher polling an empty queue (no
/// deadline to sleep toward): bounds how long a lost wakeup can stall
/// the drain.  See [`Batcher::park_duration`].
pub const DEFAULT_PARK: Duration = Duration::from_millis(50);

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Sequence-length bucket width for length-bucketed dispatch: a
    /// request of `len` tokens queues under the bucket boundary
    /// `ceil(len / bucket_width) * bucket_width`, and a dispatch group
    /// only ever contains one bucket.  0 disables bucketing.
    pub bucket_width: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2), bucket_width: 0 }
    }
}

impl BatchPolicy {
    /// The bucket boundary a request of `len` tokens pads up to
    /// (identity when bucketing is disabled).
    pub fn padded_len(&self, len: usize) -> usize {
        if self.bucket_width == 0 || len == 0 {
            len
        } else {
            len.div_ceil(self.bucket_width) * self.bucket_width
        }
    }

    /// Length half of the queue key for a request of `len` tokens: the
    /// bucket boundary, or the single shared queue when bucketing is
    /// off — width 0 must never split lengths into separate queues (the
    /// seed behavior).
    fn bucket_key(&self, len: usize) -> usize {
        if self.bucket_width == 0 {
            0
        } else {
            self.padded_len(len)
        }
    }
}

/// One queued entry: the item, its arrival time, and the cost its
/// dispatch will charge to the owning model (predicted accelerator
/// cycles on the serving path; bucket-padded tokens for cost-agnostic
/// callers).
type Entry<T> = (T, Instant, u64);

#[derive(Debug)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    /// Per-bucket FIFO queues keyed by `(model, padded length)`.
    /// Model- and length-agnostic callers ([`Batcher::push`]) share
    /// bucket `(0, 0)`.
    buckets: BTreeMap<(usize, usize), VecDeque<Entry<T>>>,
    queued: usize,
    /// Fair-share weight per model index (missing / unset => 1).
    weights: Vec<u64>,
    /// Cumulative cost dispatched per model — the deficit-round-robin
    /// ledger.  The next full-bucket dispatch goes to the backlogged
    /// model minimizing `charged / weight`.  Charged at pop time (every
    /// pop path, expired jumps included), never at completion time.
    charged: Vec<u64>,
    /// Requests popped by [`Batcher::take_batch_for`] whose dispatch has
    /// not yet reported [`Batcher::complete`], per model.  In-flight
    /// work counts as backlog for the re-entry floor and holds the
    /// epoch reset open (DESIGN.md §9).  The serial `take_batch` path
    /// never populates it (pop == completion when one dispatcher blocks
    /// on every group).
    in_flight: Vec<usize>,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher {
            policy,
            buckets: BTreeMap::new(),
            queued: 0,
            weights: Vec::new(),
            charged: Vec::new(),
            in_flight: Vec::new(),
        }
    }

    /// Configure per-model fair-share weights (index = model id).
    /// Models beyond the slice keep weight 1.
    pub fn set_model_weights(&mut self, weights: &[u64]) {
        assert!(weights.iter().all(|&w| w > 0), "model weights must be positive");
        self.weights = weights.to_vec();
        if self.charged.len() < weights.len() {
            self.charged.resize(weights.len(), 0);
        }
    }

    fn weight(&self, model: usize) -> u64 {
        self.weights.get(model).copied().unwrap_or(1).max(1)
    }

    /// Cost dispatched for `model` so far (the weighted-fair ledger;
    /// exposed for tests and reporting).  Unit is whatever the pushes
    /// charged: predicted accelerator cycles on the serving path,
    /// bucket-padded tokens for cost-agnostic callers.
    pub fn charged_cost(&self, model: usize) -> u64 {
        self.charged.get(model).copied().unwrap_or(0)
    }

    /// `a` has strictly less normalized (charge ÷ weight) service than
    /// `b`: `charged[a]/w[a] < charged[b]/w[b]`, cross-multiplied so the
    /// comparison stays exact in integers.
    fn norm_less(&self, a: usize, b: usize) -> bool {
        (self.charged_cost(a) as u128) * self.weight(b) as u128
            < (self.charged_cost(b) as u128) * self.weight(a) as u128
    }

    /// Requests popped for `model` whose dispatch has not yet completed
    /// (concurrent per-group pipeline only; the serial path keeps this
    /// at zero).
    pub fn in_flight_for(&self, model: usize) -> usize {
        self.in_flight.get(model).copied().unwrap_or(0)
    }

    fn total_in_flight(&self) -> usize {
        self.in_flight.iter().sum()
    }

    /// Report `n` requests of `model` (one popped dispatch group) as
    /// completed; called by the group's dispatcher after the replica
    /// pool's group barrier.
    pub fn complete(&mut self, model: usize, n: usize) {
        if let Some(f) = self.in_flight.get_mut(model) {
            *f = f.saturating_sub(n);
        }
        self.maybe_reset_epoch();
    }

    fn note_in_flight(&mut self, model: usize, n: usize) {
        if self.in_flight.len() <= model {
            self.in_flight.resize(model + 1, 0);
        }
        self.in_flight[model] += n;
    }

    /// A model is backlogged when it has queued buckets OR popped work
    /// still executing — an in-flight group must keep the model's
    /// fairness position (it is being serviced, not idle).
    fn has_backlog(&self, model: usize) -> bool {
        self.buckets.range((model, 0)..=(model, usize::MAX)).next().is_some()
            || self.in_flight_for(model) > 0
    }

    /// Backlogged model with the least normalized service, if any.
    fn min_norm_backlogged(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        let mut last = usize::MAX;
        let queued_models = self.buckets.keys().map(|&(m, _)| m);
        let in_flight_models = self
            .in_flight
            .iter()
            .enumerate()
            .filter(|&(_, &f)| f > 0)
            .map(|(m, _)| m);
        for m in queued_models.chain(in_flight_models) {
            if m == last {
                continue;
            }
            last = m;
            best = Some(match best {
                None => m,
                Some(b) if self.norm_less(m, b) => m,
                Some(b) => b,
            });
        }
        best
    }

    /// Enqueue into the single default bucket (model- and
    /// length-agnostic callers).
    pub fn push(&mut self, item: T) {
        self.push_keyed(item, 0, 0);
    }

    /// Enqueue a request of sequence length `len` under model 0 (the
    /// single-model path); returns the padded bucket boundary.
    pub fn push_len(&mut self, item: T, len: usize) -> usize {
        self.push_keyed(item, 0, len)
    }

    /// Enqueue a request of sequence length `len` for `model`, charged
    /// at its bucket-padded token count (the cost-agnostic fallback);
    /// returns the padded bucket boundary (== `len` when bucketing is
    /// disabled), which the caller can feed to the padding-waste
    /// metric.  A dispatch group never mixes models or buckets.
    pub fn push_keyed(&mut self, item: T, model: usize, len: usize) -> usize {
        let padded = self.policy.padded_len(len);
        self.push_costed(item, model, len, padded as u64)
    }

    /// Enqueue a request of sequence length `len` for `model`, charging
    /// the deficit ledger an explicit `cost` at dispatch time — the
    /// serving path passes `CostModel::predict_cycles(len)` so fairness
    /// is measured in predicted accelerator work, not tokens
    /// (DESIGN.md §12).  Returns the padded bucket boundary.
    pub fn push_costed(&mut self, item: T, model: usize, len: usize, cost: u64) -> usize {
        if self.charged.len() <= model {
            self.charged.resize(model + 1, 0);
        }
        // A model returning from idle re-enters at the backlog's
        // current normalized service level: it competes fairly from
        // now on instead of replaying the share it queued no work for.
        if !self.has_backlog(model) {
            if let Some(j) = self.min_norm_backlogged() {
                let floor = (self.charged_cost(j) as u128) * self.weight(model) as u128
                    / self.weight(j) as u128;
                let floor = floor.min(u64::MAX as u128) as u64;
                if floor > self.charged[model] {
                    self.charged[model] = floor;
                }
            }
        }
        let key = (model, self.policy.bucket_key(len));
        let padded = self.policy.padded_len(len);
        self.buckets.entry(key).or_default().push_back((item, Instant::now(), cost));
        self.queued += 1;
        padded
    }

    pub fn len(&self) -> usize {
        self.queued
    }

    pub fn is_empty(&self) -> bool {
        self.queued == 0
    }

    /// Queued requests of one model (all its buckets).
    pub fn queued_for(&self, model: usize) -> usize {
        self.buckets
            .range((model, 0)..=(model, usize::MAX))
            .map(|(_, q)| q.len())
            .sum()
    }

    /// The bucket whose front (oldest) request arrived earliest.
    fn oldest_bucket(&self) -> Option<((usize, usize), Instant)> {
        self.buckets
            .iter()
            .filter_map(|(k, q)| q.front().map(|&(_, t, _)| (*k, t)))
            .min_by_key(|&(_, t)| t)
    }

    /// Whether a batch should be released now: some bucket reached
    /// `max_batch`, or the oldest queued request's deadline expired.
    pub fn ready(&self, now: Instant) -> bool {
        if self.buckets.values().any(|q| q.len() >= self.policy.max_batch) {
            return true;
        }
        match self.oldest_bucket() {
            Some((_, t)) => now.duration_since(t) >= self.policy.max_wait,
            None => false,
        }
    }

    /// Among full buckets, the one owned by the model with the least
    /// normalized service (deficit round-robin over models); ties
    /// broken by oldest front, then key order.  Falls back to `oldest`
    /// when no bucket is full (the deadline path).
    fn full_bucket_fair(&self, oldest: (usize, usize)) -> (usize, usize) {
        let mut best: Option<((usize, usize), Instant)> = None;
        for (&k, q) in self.buckets.iter() {
            if q.len() < self.policy.max_batch {
                continue;
            }
            let Some(&(_, t, _)) = q.front() else { continue };
            best = Some(match best {
                None => (k, t),
                Some((bk, bt)) => {
                    if self.norm_less(k.0, bk.0) || (!self.norm_less(bk.0, k.0) && t < bt) {
                        (k, t)
                    } else {
                        (bk, bt)
                    }
                }
            });
        }
        best.map_or(oldest, |(k, _)| k)
    }

    /// Pop one dispatch group (oldest first within its bucket).  A
    /// deadline-expired oldest request outranks any full bucket — a
    /// minority-length (or minority-model) bucket must never be starved
    /// past `max_wait` by a hot bucket that keeps refilling to
    /// `max_batch`.  Otherwise a full bucket goes, chosen by the
    /// weighted-fair ledger across models (ties by oldest front), then
    /// the bucket holding the oldest request; other buckets stay queued
    /// for their own group.  Every dispatch charges its model the
    /// group's cost as stored at push time.
    pub fn take_batch(&mut self) -> Vec<T> {
        let now = Instant::now();
        let key = match self.oldest_bucket() {
            None => return Vec::new(),
            Some((k, t)) if now.duration_since(t) >= self.policy.max_wait => k,
            Some((oldest_key, _)) => self.full_bucket_fair(oldest_key),
        };
        let out = self.pop_bucket(key);
        self.maybe_reset_epoch();
        out
    }

    /// Drain one dispatch group from `key`'s bucket and charge the
    /// owning model — the single pop body shared by the serial
    /// [`Batcher::take_batch`] and per-model [`Batcher::take_batch_for`]
    /// paths, so the charge semantics (and their asserted
    /// bit-equivalence) live in exactly one place.
    ///
    /// Totality: `key` is always derived from a live entry one
    /// statement ago, so the bucket exists today; stay total anyway —
    /// an empty batch beats panicking a dispatcher thread if that
    /// invariant ever drifts (ISSUE 3 hardening; the cross-call races
    /// live in ready()/park_duration()/take_batch() sequencing, covered
    /// by the regression test below).
    ///
    /// Charging: every pop path charges at pop time — the expired-jump
    /// pop included — and charges the *stored* per-entry cost, so both
    /// pop paths use the same unit as normal dispatches.  An uncharged
    /// (or differently-charged) expiry dispatch would let a model whose
    /// deadline keeps firing (short max_wait, trickle arrival) consume
    /// service the deficit ledger never sees, drifting the served
    /// shares off the configured weights (ISSUE 5 regression test
    /// `expired_dispatch_still_charges_its_model`, extended to the
    /// cycle-charged ledger in ISSUE 8).
    fn pop_bucket(&mut self, key: (usize, usize)) -> Vec<T> {
        let Some(q) = self.buckets.get_mut(&key) else {
            return Vec::new();
        };
        let n = q.len().min(self.policy.max_batch);
        let mut cost: u64 = 0;
        let out: Vec<T> = q
            .drain(..n)
            .map(|(item, _, c)| {
                cost = cost.saturating_add(c);
                item
            })
            .collect();
        if q.is_empty() {
            self.buckets.remove(&key);
        }
        self.queued -= out.len();
        if self.charged.len() <= key.0 {
            self.charged.resize(key.0 + 1, 0);
        }
        self.charged[key.0] = self.charged[key.0].saturating_add(cost);
        out
    }

    /// Epoch reset: an idle pool carries no fairness debt forward.
    /// Without it a model that served alone, drained, and later resumed
    /// would keep a stale surplus against a tenant that first arrived
    /// into the empty queue at charge zero — the one direction the
    /// re-entry floor in `push_keyed` cannot cover.  With concurrent
    /// poppers the pool is only idle once in-flight groups have also
    /// completed; resetting while a group executes would erase service
    /// that model is consuming right now.
    fn maybe_reset_epoch(&mut self) {
        if self.queued == 0 && self.total_in_flight() == 0 {
            self.charged.iter_mut().for_each(|c| *c = 0);
        }
    }

    /// Whether `model` should release a batch now: one of its buckets
    /// reached `max_batch`, or its oldest queued request's deadline
    /// expired.  The per-model half of [`Batcher::ready`], used by the
    /// concurrent per-group dispatchers (DESIGN.md §9).
    pub fn ready_for(&self, model: usize, now: Instant) -> bool {
        let mut buckets = self.buckets.range((model, 0)..=(model, usize::MAX));
        buckets.any(|(_, q)| {
            q.len() >= self.policy.max_batch
                || q.front()
                    .is_some_and(|&(_, t, _)| now.duration_since(t) >= self.policy.max_wait)
        })
    }

    /// The bucket of `model` whose front (oldest) request arrived
    /// earliest.
    fn oldest_bucket_for(&self, model: usize) -> Option<((usize, usize), Instant)> {
        self.buckets
            .range((model, 0)..=(model, usize::MAX))
            .filter_map(|(k, q)| q.front().map(|&(_, t, _)| (*k, t)))
            .min_by_key(|&(_, t)| t)
    }

    /// Pop one dispatch group for `model` — the concurrent per-group
    /// pipeline's pop contract.  Bucket choice mirrors the cross-model
    /// [`Batcher::take_batch`] restricted to this model: an expired
    /// oldest request outranks any full bucket, otherwise a full bucket
    /// (oldest front first), otherwise (the deadline path) the oldest
    /// bucket.  The pop charges the model's deficit ledger immediately
    /// and records the group as in flight until [`Batcher::complete`];
    /// a single-model configuration pops the exact groups `take_batch`
    /// would (asserted bit-equivalent in tests).
    pub fn take_batch_for(&mut self, model: usize) -> Vec<T> {
        let now = Instant::now();
        let key = match self.oldest_bucket_for(model) {
            None => return Vec::new(),
            Some((k, t)) if now.duration_since(t) >= self.policy.max_wait => k,
            Some((oldest_key, _)) => {
                // among this model's full buckets: oldest front first
                self.buckets
                    .range((model, 0)..=(model, usize::MAX))
                    .filter(|(_, q)| q.len() >= self.policy.max_batch)
                    .filter_map(|(k, q)| q.front().map(|&(_, t, _)| (*k, t)))
                    .min_by_key(|&(_, t)| t)
                    .map_or(oldest_key, |(k, _)| k)
            }
        };
        let out = self.pop_bucket(key);
        self.note_in_flight(model, out.len());
        out
    }

    /// How long `model`'s dispatcher may park: the time until its own
    /// oldest request's deadline (zero if expired), or [`DEFAULT_PARK`]
    /// when the model has nothing queued.  Per-model so a group with an
    /// empty queue never wakes for another model's deadline.
    pub fn park_duration_for(&self, model: usize, now: Instant) -> Duration {
        match self.oldest_bucket_for(model) {
            Some((_, t)) => (t + self.policy.max_wait).saturating_duration_since(now),
            None => DEFAULT_PARK,
        }
    }

    /// Deadline of the oldest queued request (for poll sleeping).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.oldest_bucket().map(|(_, t)| t + self.policy.max_wait)
    }

    /// How long a dispatcher may park before re-checking: the time
    /// until the oldest queued request's deadline (zero if already
    /// expired), or [`DEFAULT_PARK`] when the queue is empty.  Never
    /// panics — the queue draining between an emptiness check and this
    /// call just yields the default (ISSUE 3: the dispatcher path must
    /// not `unwrap()` a deadline it observed one lock ago).
    pub fn park_duration(&self, now: Instant) -> Duration {
        match self.next_deadline() {
            Some(d) => d.saturating_duration_since(now),
            None => DEFAULT_PARK,
        }
    }
}

// ---------------------------------------------------------------------
// Sharded dispatch path (DESIGN.md §13)
// ---------------------------------------------------------------------

/// Lock with poison recovery: every mutation under a shard lock is
/// either a single statement or re-validated by the next reader, so a
/// thread that panicked while holding the lock leaves the data
/// structurally sound.  Taking the guard over instead of `unwrap()`ing
/// keeps one crashed thread from cascading the panic into every other
/// thread that touches the shard (the ISSUE 9 poisoned-lock fix).
fn lock_recover<S>(m: &Mutex<S>) -> MutexGuard<'_, S> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// One model's private slice of the dispatch path: its bucket queues
/// under their own lock, its own wakeup signal, and its fairness state
/// mirrored into atomics so *other* models' submits read it without
/// ever taking this shard's lock.
struct Shard<T> {
    state: Mutex<ShardState<T>>,
    /// Signalled by pushes into THIS shard only (`notify_one`: there is
    /// exactly one dispatcher per model) and broadcast at shutdown.
    available: Condvar,
    /// Cumulative dispatched cost — this model's slot in the
    /// deficit-round-robin ledger, readable lock-free.
    charged: AtomicU64,
    /// Mirror of `state.queued` for lock-free backlog reads.
    queued: AtomicUsize,
    /// Popped-but-not-completed requests (counts as backlog).
    in_flight: AtomicUsize,
}

struct ShardState<T> {
    /// Per-bucket FIFO queues keyed by padded length (one model only).
    buckets: BTreeMap<usize, VecDeque<Entry<T>>>,
    queued: usize,
}

/// Per-model sharded batcher (DESIGN.md §13): the concurrent serving
/// replacement for `Mutex<Batcher>` + one shared `Condvar`.
///
/// `submit` locks only the target model's shard and `notify_one`s only
/// that model's dispatcher; a dispatcher pop never contends with other
/// models.  The weighted-fair semantics of [`Batcher`] carry over
/// unchanged — charge-at-pop (expired jumps included, at the stored
/// per-entry cost), the idle re-entry floor, and the empty-pool epoch
/// reset — but the DRR ledger lives in per-shard atomics reconciled at
/// pop time instead of under a global lock: the re-entry floor reads
/// other shards' `charged`/backlog atomics lock-free, and the epoch
/// reset fires on the `outstanding` decrement that empties the pool.
/// A push racing that reset lands just after it with a level ledger,
/// which is indistinguishable from arriving into a fresh epoch.
///
/// Every lock acquisition recovers from poisoning, so a thread that
/// panics while holding a shard lock degrades exactly one model — and
/// only until the next pop — instead of panicking the whole router.
///
/// For a single model the pop order is bit-equivalent to the serial
/// [`Batcher::take_batch`] (asserted in tests): the bucket choice in
/// [`ShardedBatcher::take_batch_for`] is the same
/// expired-oldest-outranks-full / fullest-oldest / oldest cascade.
pub struct ShardedBatcher<T> {
    policy: BatchPolicy,
    shards: Vec<Shard<T>>,
    /// Fair-share weight per model (fixed at construction).
    weights: Vec<u64>,
    /// Queued + in-flight across all shards; the decrement that lands
    /// on zero performs the epoch reset.
    outstanding: AtomicUsize,
    stop: AtomicBool,
}

impl<T> ShardedBatcher<T> {
    /// One shard per weight entry (index = model id); weights must be
    /// positive, mirroring [`Batcher::set_model_weights`].
    pub fn new(policy: BatchPolicy, weights: &[u64]) -> Self {
        assert!(!weights.is_empty(), "a sharded batcher needs at least one model");
        assert!(weights.iter().all(|&w| w > 0), "model weights must be positive");
        let shards = (0..weights.len())
            .map(|_| Shard {
                state: Mutex::new(ShardState { buckets: BTreeMap::new(), queued: 0 }),
                available: Condvar::new(),
                charged: AtomicU64::new(0),
                queued: AtomicUsize::new(0),
                in_flight: AtomicUsize::new(0),
            })
            .collect();
        ShardedBatcher {
            policy,
            shards,
            weights: weights.to_vec(),
            outstanding: AtomicUsize::new(0),
            stop: AtomicBool::new(false),
        }
    }

    pub fn models(&self) -> usize {
        self.shards.len()
    }

    pub fn policy(&self) -> BatchPolicy {
        self.policy
    }

    fn weight(&self, model: usize) -> u64 {
        self.weights.get(model).copied().unwrap_or(1).max(1)
    }

    /// Lock-free read of the model's DRR ledger slot (same unit the
    /// pushes charged — predicted cycles on the serving path).
    pub fn charged_cost(&self, model: usize) -> u64 {
        self.shards.get(model).map_or(0, |s| s.charged.load(Ordering::SeqCst))
    }

    /// Lock-free read of the model's queued count.
    pub fn queued_for(&self, model: usize) -> usize {
        self.shards.get(model).map_or(0, |s| s.queued.load(Ordering::SeqCst))
    }

    /// Lock-free read of the model's popped-but-running count.
    pub fn in_flight_for(&self, model: usize) -> usize {
        self.shards.get(model).map_or(0, |s| s.in_flight.load(Ordering::SeqCst))
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.queued.load(Ordering::SeqCst)).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_shutting_down(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// A model returning from idle re-enters at the backlog's current
    /// normalized service level — the serial path's re-entry floor —
    /// computed entirely from other shards' atomics: the submit path
    /// never takes a second shard's lock.  The raise is `fetch_max`, so
    /// a concurrent pop charging the same slot is never undone.
    fn raise_reentry_floor(&self, model: usize) {
        let mut best: Option<(u64, u64)> = None; // (charged_j, weight_j)
        for (j, s) in self.shards.iter().enumerate() {
            if j == model {
                continue;
            }
            if s.queued.load(Ordering::SeqCst) == 0 && s.in_flight.load(Ordering::SeqCst) == 0 {
                continue;
            }
            let cj = s.charged.load(Ordering::SeqCst);
            let wj = self.weight(j);
            best = Some(match best {
                None => (cj, wj),
                Some((cb, wb)) => {
                    if (cj as u128) * wb as u128 < (cb as u128) * wj as u128 {
                        (cj, wj)
                    } else {
                        (cb, wb)
                    }
                }
            });
        }
        if let Some((cj, wj)) = best {
            let floor = ((cj as u128) * self.weight(model) as u128 / wj as u128)
                .min(u64::MAX as u128) as u64;
            self.shards[model].charged.fetch_max(floor, Ordering::SeqCst);
        }
    }

    /// Enqueue a request of sequence length `len` for `model`, charged
    /// at its bucket-padded token count; returns the padded boundary.
    pub fn push_keyed(&self, item: T, model: usize, len: usize) -> usize {
        let padded = self.policy.padded_len(len);
        self.push_costed(item, model, len, padded as u64)
    }

    /// Enqueue a request for `model` with an explicit dispatch-time
    /// `cost` (the serving path passes `CostModel::predict_cycles`).
    /// Locks only `model`'s shard and wakes only `model`'s dispatcher.
    /// Returns the padded bucket boundary.
    pub fn push_costed(&self, item: T, model: usize, len: usize, cost: u64) -> usize {
        let shard = &self.shards[model];
        let padded = self.policy.padded_len(len);
        let key = self.policy.bucket_key(len);
        let mut st = lock_recover(&shard.state);
        if st.queued == 0 && shard.in_flight.load(Ordering::SeqCst) == 0 {
            self.raise_reentry_floor(model);
        }
        st.buckets.entry(key).or_default().push_back((item, Instant::now(), cost));
        st.queued += 1;
        shard.queued.fetch_add(1, Ordering::SeqCst);
        self.outstanding.fetch_add(1, Ordering::SeqCst);
        drop(st);
        shard.available.notify_one();
        padded
    }

    /// Whether the shard should release a group now: some bucket
    /// reached `max_batch` or some front expired — `Batcher::ready_for`
    /// restricted to one shard.
    fn ready_in(&self, st: &ShardState<T>, now: Instant) -> bool {
        st.buckets.values().any(|q| {
            q.len() >= self.policy.max_batch
                || q.front()
                    .is_some_and(|&(_, t, _)| now.duration_since(t) >= self.policy.max_wait)
        })
    }

    /// The shard bucket whose front (oldest) request arrived earliest.
    fn oldest_in(st: &ShardState<T>) -> Option<(usize, Instant)> {
        st.buckets
            .iter()
            .filter_map(|(k, q)| q.front().map(|&(_, t, _)| (*k, t)))
            .min_by_key(|&(_, t)| t)
    }

    /// Pop one dispatch group under the shard lock, mirroring the
    /// serial [`Batcher::take_batch_for`] bucket cascade exactly — an
    /// expired oldest request outranks any full bucket, otherwise the
    /// full bucket with the oldest front, otherwise the oldest bucket —
    /// and charging the stored per-entry costs at pop time.
    fn pop_locked(&self, model: usize, st: &mut ShardState<T>) -> Vec<T> {
        let now = Instant::now();
        let Some((oldest_key, t)) = Self::oldest_in(st) else {
            return Vec::new();
        };
        let key = if now.duration_since(t) >= self.policy.max_wait {
            oldest_key
        } else {
            st.buckets
                .iter()
                .filter(|(_, q)| q.len() >= self.policy.max_batch)
                .filter_map(|(k, q)| q.front().map(|&(_, t, _)| (*k, t)))
                .min_by_key(|&(_, t)| t)
                .map_or(oldest_key, |(k, _)| k)
        };
        let Some(q) = st.buckets.get_mut(&key) else {
            return Vec::new();
        };
        let n = q.len().min(self.policy.max_batch);
        let mut cost: u64 = 0;
        let out: Vec<T> = q
            .drain(..n)
            .map(|(item, _, c)| {
                cost = cost.saturating_add(c);
                item
            })
            .collect();
        if q.is_empty() {
            st.buckets.remove(&key);
        }
        st.queued -= out.len();
        let shard = &self.shards[model];
        // in_flight rises before queued falls, so a lock-free backlog
        // read on another shard's submit path never sees this model
        // transiently idle mid-pop (the floor only over-raises, never
        // under-raises).
        shard.in_flight.fetch_add(out.len(), Ordering::SeqCst);
        shard.queued.fetch_sub(out.len(), Ordering::SeqCst);
        let _ = shard
            .charged
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |c| Some(c.saturating_add(cost)));
        out
    }

    /// Non-blocking pop — the sharded counterpart of
    /// [`Batcher::take_batch_for`].  Dispatchers use the blocking
    /// [`ShardedBatcher::next_batch`]; this form serves tests, parity
    /// assertions, and hand-driven drains.
    pub fn take_batch_for(&self, model: usize) -> Vec<T> {
        let mut st = lock_recover(&self.shards[model].state);
        self.pop_locked(model, &mut st)
    }

    /// Blocking pop for `model`'s dispatcher: parks on the shard's own
    /// condvar until a group is releasable (full bucket or expired
    /// deadline), popping immediately during shutdown to drain the
    /// remaining backlog.  Returns `None` once shut down and drained.
    /// Other models' submits never signal this shard — the global
    /// `notify_all` thundering herd is gone by construction.
    pub fn next_batch(&self, model: usize) -> Option<Vec<T>> {
        let shard = &self.shards[model];
        let mut st = lock_recover(&shard.state);
        loop {
            let shutting = self.stop.load(Ordering::SeqCst);
            if st.queued == 0 {
                if shutting {
                    return None;
                }
            } else if shutting || self.ready_in(&st, Instant::now()) {
                let out = self.pop_locked(model, &mut st);
                if !out.is_empty() {
                    return Some(out);
                }
            }
            let timeout = match Self::oldest_in(&st) {
                Some((_, t)) => {
                    (t + self.policy.max_wait).saturating_duration_since(Instant::now())
                }
                None => DEFAULT_PARK,
            };
            st = match shard.available.wait_timeout(st, timeout) {
                Ok((g, _)) => g,
                Err(p) => p.into_inner().0,
            };
        }
    }

    /// Report `n` popped requests of `model` complete.  The decrement
    /// that empties the whole pool (nothing queued or in flight on any
    /// shard) performs the epoch reset, zeroing every shard's ledger —
    /// the serial `maybe_reset_epoch` contract.  A push racing the
    /// reset lands just after it with a level ledger, which is exactly
    /// what arriving into a fresh epoch means.
    pub fn complete(&self, model: usize, n: usize) {
        if n == 0 {
            return;
        }
        let shard = &self.shards[model];
        let _ = shard
            .in_flight
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| Some(v.saturating_sub(n)));
        let prev = self.outstanding.fetch_sub(n, Ordering::SeqCst);
        debug_assert!(prev >= n, "complete({model}, {n}) exceeds outstanding work ({prev})");
        if prev == n {
            for s in &self.shards {
                s.charged.store(0, Ordering::SeqCst);
            }
        }
    }

    /// Begin shutdown: the flag is stored before each shard's lock is
    /// bounced and its condvar broadcast, so a dispatcher that read the
    /// flag as false under its lock is either already parked (and gets
    /// the wakeup) or will re-check after its timed park — no
    /// lost-signal window.  Dispatchers drain their remaining backlog
    /// and then observe `None` from [`ShardedBatcher::next_batch`].
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        for shard in &self.shards {
            let _guard = lock_recover(&shard.state);
            shard.available.notify_all();
        }
    }

    /// Test instrumentation for the poisoned-lock regression: panic a
    /// closure while it holds `model`'s shard lock, leaving the mutex
    /// poisoned exactly as a crashed dispatcher would.  Production code
    /// has no reason to call this.
    #[doc(hidden)]
    pub fn poison_shard(&self, model: usize) {
        let poisoned = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = self.shards[model].state.lock();
            panic!("injected shard poison");
        }));
        assert!(poisoned.is_err());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unbucketed(max_batch: usize, max_wait: Duration) -> BatchPolicy {
        BatchPolicy { max_batch, max_wait, bucket_width: 0 }
    }

    #[test]
    fn releases_on_size() {
        let mut b = Batcher::new(unbucketed(3, Duration::from_secs(60)));
        b.push(1);
        b.push(2);
        assert!(!b.ready(Instant::now()));
        b.push(3);
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn releases_on_deadline() {
        let mut b = Batcher::new(unbucketed(100, Duration::ZERO));
        b.push("x");
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch(), vec!["x"]);
    }

    #[test]
    fn batch_is_fifo_and_bounded() {
        let mut b = Batcher::new(unbucketed(2, Duration::ZERO));
        for i in 0..5 {
            b.push(i);
        }
        assert_eq!(b.take_batch(), vec![0, 1]);
        assert_eq!(b.take_batch(), vec![2, 3]);
        assert_eq!(b.take_batch(), vec![4]);
    }

    #[test]
    fn empty_queue_not_ready() {
        let b: Batcher<i32> = Batcher::new(BatchPolicy::default());
        assert!(!b.ready(Instant::now()));
        assert!(b.next_deadline().is_none());
        assert!(b.take_batch().is_empty());
    }

    #[test]
    fn releases_when_max_wait_expires() {
        // below max_batch, the group is held until the oldest request's
        // deadline passes — then released even though the batch is short
        let wait = Duration::from_millis(15);
        let mut b = Batcher::new(unbucketed(100, wait));
        b.push(1);
        b.push(2);
        let t0 = Instant::now();
        assert!(!b.ready(t0), "not ready before the deadline");
        assert!(!b.ready(t0 + wait / 2), "still inside the wait window");
        assert!(b.ready(t0 + wait + Duration::from_millis(1)), "deadline expired");
        // and with real elapsed time, not just a synthetic clock
        std::thread::sleep(wait + Duration::from_millis(2));
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch(), vec![1, 2]);
    }

    #[test]
    fn next_deadline_is_oldest_push_plus_max_wait() {
        let wait = Duration::from_millis(20);
        let mut b = Batcher::new(unbucketed(100, wait));
        let before = Instant::now();
        b.push("old");
        let after = Instant::now();
        std::thread::sleep(Duration::from_millis(2));
        b.push("new"); // must not move the deadline: oldest item governs
        let d = b.next_deadline().unwrap();
        assert!(d >= before + wait && d <= after + wait, "deadline follows the oldest item");
        // draining the oldest moves the deadline later
        let first = b.take_batch();
        assert_eq!(first, vec!["old", "new"]);
        assert!(b.next_deadline().is_none());
    }

    #[test]
    fn park_duration_defaults_when_empty_and_tracks_the_deadline() {
        let wait = Duration::from_millis(20);
        let mut b: Batcher<i32> = Batcher::new(unbucketed(8, wait));
        assert_eq!(b.park_duration(Instant::now()), DEFAULT_PARK);
        b.push(1);
        let after = Instant::now(); // push time <= after, so deadline <= after + wait
        assert!(b.park_duration(after) <= wait, "parks no longer than the deadline");
        // an already-expired deadline parks zero — never negative, never a panic
        assert_eq!(b.park_duration(after + wait + Duration::from_millis(5)), Duration::ZERO);
        // draining restores the empty-queue default
        b.take_batch();
        assert_eq!(b.park_duration(Instant::now()), DEFAULT_PARK);
    }

    #[test]
    fn dispatcher_race_between_enqueue_and_expiry_never_panics() {
        // Regression (ISSUE 3): the dispatcher reads ready() /
        // park_duration() / take_batch() under a lock it releases and
        // re-acquires between calls, so the queue can drain or refill
        // between any two of them.  Hammer that interleaving with
        // producers racing a consumer under a zero deadline (every item
        // expires the instant it lands): no call may panic, and every
        // pushed item must come back exactly once.
        use std::sync::{Arc, Mutex};
        const PRODUCERS: usize = 3;
        const PER_PRODUCER: usize = 200;
        let b = Arc::new(Mutex::new(Batcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::ZERO,
            bucket_width: 4,
        })));
        let producers: Vec<_> = (0..PRODUCERS)
            .map(|p| {
                let b = Arc::clone(&b);
                std::thread::spawn(move || {
                    for i in 0..PER_PRODUCER {
                        b.lock().unwrap().push_keyed(p * PER_PRODUCER + i, p % 2, 1 + (i % 9));
                        if i % 16 == 0 {
                            std::thread::yield_now();
                        }
                    }
                })
            })
            .collect();
        let mut seen = Vec::new();
        let give_up = Instant::now() + Duration::from_secs(30);
        while seen.len() < PRODUCERS * PER_PRODUCER {
            assert!(
                Instant::now() < give_up,
                "consumer starved at {} of {}",
                seen.len(),
                PRODUCERS * PER_PRODUCER
            );
            let now = Instant::now();
            {
                // the dispatcher's read sequence, with the lock dropped
                // in between — the drain/refill window under test
                let q = b.lock().unwrap();
                let _ = q.ready(now);
                let _ = q.park_duration(now);
            }
            seen.extend(b.lock().unwrap().take_batch());
        }
        for p in producers {
            p.join().unwrap();
        }
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), PRODUCERS * PER_PRODUCER, "each request delivered exactly once");
    }

    #[test]
    fn padded_len_rounds_up_to_bucket_boundary() {
        let p = BatchPolicy { max_batch: 8, max_wait: Duration::ZERO, bucket_width: 8 };
        assert_eq!(p.padded_len(1), 8);
        assert_eq!(p.padded_len(8), 8);
        assert_eq!(p.padded_len(9), 16);
        assert_eq!(p.padded_len(0), 0);
        let off = BatchPolicy { bucket_width: 0, ..p };
        assert_eq!(off.padded_len(13), 13);
    }

    #[test]
    fn width_zero_shares_one_queue_across_lengths() {
        // bucketing off: mixed lengths form one dispatch group exactly
        // as in the unbucketed seed, and no padding is charged
        let mut b = Batcher::new(unbucketed(3, Duration::from_secs(60)));
        assert_eq!(b.push_len("a", 3), 3);
        assert_eq!(b.push_len("b", 5), 5);
        assert!(!b.ready(Instant::now()));
        assert_eq!(b.push_len("c", 7), 7);
        assert!(b.ready(Instant::now()), "shared queue reached max_batch");
        assert_eq!(b.take_batch(), vec!["a", "b", "c"], "cross-length FIFO preserved");
    }

    #[test]
    fn buckets_group_compatible_lengths_only() {
        // widths 8: lengths 3 and 5 share the 8-bucket, 12 goes to 16 —
        // a dispatch group never mixes buckets
        let p = BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(60), bucket_width: 8 };
        let mut b = Batcher::new(p);
        assert_eq!(b.push_len("len3", 3), 8);
        assert_eq!(b.push_len("len12", 12), 16);
        assert!(!b.ready(Instant::now()), "no bucket full yet");
        assert_eq!(b.push_len("len5", 5), 8);
        assert!(b.ready(Instant::now()), "the 8-bucket is full");
        assert_eq!(b.take_batch(), vec!["len3", "len5"], "FIFO within the full bucket");
        assert_eq!(b.len(), 1);
        assert_eq!(b.take_batch(), vec!["len12"]);
        assert!(b.is_empty());
    }

    #[test]
    fn expired_minority_bucket_is_not_starved_by_a_full_bucket() {
        // max_wait ZERO: the lone long request's deadline has expired,
        // so it dispatches ahead of the short bucket even though the
        // short bucket is full — a hot bucket refilling to max_batch
        // must not starve minority lengths past their deadline.
        let p = BatchPolicy { max_batch: 2, max_wait: Duration::ZERO, bucket_width: 8 };
        let mut b = Batcher::new(p);
        b.push_len("long", 20);
        std::thread::sleep(Duration::from_millis(2));
        b.push_len("short-a", 3);
        b.push_len("short-b", 5); // the 8-bucket is now full
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch(), vec!["long"], "expired request outranks the full bucket");
        assert_eq!(b.take_batch(), vec!["short-a", "short-b"]);
    }

    #[test]
    fn full_bucket_dispatches_before_unexpired_older_request() {
        // long deadline: nothing has expired, so the full bucket goes
        // first even though another bucket holds an older request
        let p = BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(60), bucket_width: 8 };
        let mut b = Batcher::new(p);
        b.push_len("long", 20);
        b.push_len("short-a", 3);
        b.push_len("short-b", 5);
        assert!(b.ready(Instant::now()), "a bucket is full");
        assert_eq!(b.take_batch(), vec!["short-a", "short-b"]);
        assert_eq!(b.take_batch(), vec!["long"]);
    }

    #[test]
    fn deadline_releases_the_oldest_bucket_first() {
        let p = BatchPolicy { max_batch: 100, max_wait: Duration::ZERO, bucket_width: 4 };
        let mut b = Batcher::new(p);
        b.push_len("first-long", 10);
        std::thread::sleep(Duration::from_millis(2));
        b.push_len("second-short", 2);
        // nothing is full; the oldest request's bucket goes first even
        // though its key (12) sorts after the short bucket's key (4)
        assert_eq!(b.take_batch(), vec!["first-long"]);
        assert_eq!(b.take_batch(), vec!["second-short"]);
    }

    #[test]
    fn dispatch_groups_never_mix_models_even_unbucketed() {
        // width 0: lengths share one queue per model, but models stay
        // separate — a dispatch group is always model-homogeneous
        let mut b = Batcher::new(unbucketed(4, Duration::from_secs(60)));
        b.push_keyed("a0", 0, 3);
        b.push_keyed("b0", 1, 3);
        b.push_keyed("a1", 0, 5);
        b.push_keyed("b1", 1, 5);
        b.push_keyed("a2", 0, 7);
        b.push_keyed("a3", 0, 2); // model 0's queue reaches max_batch
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch(), vec!["a0", "a1", "a2", "a3"]);
        assert_eq!(b.take_batch(), vec!["b0", "b1"]);
    }

    #[test]
    fn weighted_fair_selection_tracks_the_deficit_ledger() {
        // two models, weight 2 vs 1, both buckets kept full: out of
        // every three dispatches model 0 gets two (charged tokens stay
        // within one group of the 2:1 split)
        let p = BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(3600), bucket_width: 8 };
        let mut b = Batcher::new(p);
        b.set_model_weights(&[2, 1]);
        for i in 0..24 {
            b.push_keyed((0usize, i), 0, 8);
            b.push_keyed((1usize, i), 1, 8);
        }
        let mut served = [0usize; 2];
        for _ in 0..9 {
            let batch = b.take_batch();
            assert_eq!(batch.len(), 2);
            let model = batch[0].0;
            assert!(batch.iter().all(|&(m, _)| m == model), "mixed-model group");
            served[model] += batch.len();
        }
        assert_eq!(served[0], 12, "weight-2 model takes two of every three groups");
        assert_eq!(served[1], 6);
        assert_eq!(b.charged_cost(0), 12 * 8);
        assert_eq!(b.charged_cost(1), 6 * 8);
    }

    #[test]
    fn draining_the_pool_resets_the_fairness_epoch() {
        // a model that served alone and drained must not carry its
        // charge into the next busy epoch: a tenant that first arrives
        // into the empty queue starts level, not ahead
        let p = BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(3600), bucket_width: 8 };
        let mut b = Batcher::new(p);
        b.set_model_weights(&[1, 1]);
        for i in 0..8 {
            b.push_keyed((0usize, i), 0, 8);
        }
        while !b.is_empty() {
            b.take_batch();
        }
        assert_eq!(b.charged_cost(0), 0, "idle pool carries no fairness debt");
        // next epoch: the late tenant and the returning one alternate
        for i in 0..8 {
            b.push_keyed((1usize, i), 1, 8);
            b.push_keyed((0usize, 100 + i), 0, 8);
        }
        let mut served = [0usize; 2];
        for _ in 0..8 {
            served[b.take_batch()[0].0] += 1;
        }
        assert_eq!(served, [4, 4], "fresh epoch splits evenly");
    }

    #[test]
    fn expired_dispatch_still_charges_its_model() {
        // Regression (ISSUE 5): a deadline-expired request jumps the
        // queue ahead of any full bucket — but the jump must still
        // charge its model's deficit ledger, on BOTH pop paths, or a
        // trickle-arrival model whose deadline keeps firing would
        // consume service the weighted-fair ledger never sees.
        let p = BatchPolicy { max_batch: 2, max_wait: Duration::ZERO, bucket_width: 8 };
        // cross-model serial pop
        let mut b = Batcher::new(p);
        b.set_model_weights(&[1, 1]);
        b.push_keyed("expired", 0, 5);
        std::thread::sleep(Duration::from_millis(1));
        b.push_keyed("hot-a", 1, 3);
        b.push_keyed("hot-b", 1, 3); // model 1's bucket is full
        assert_eq!(b.take_batch(), vec!["expired"], "expiry outranks the full bucket");
        assert_eq!(b.charged_cost(0), 8, "the expired jump was charged at pop time");
        // per-model concurrent pop
        let mut b = Batcher::new(p);
        b.set_model_weights(&[1, 1]);
        b.push_keyed("expired", 0, 5);
        assert_eq!(b.take_batch_for(0), vec!["expired"]);
        assert_eq!(b.charged_cost(0), 8, "take_batch_for charges expiry pops too");
        // cycle-charged ledger (ISSUE 8): when the push carries an
        // explicit predicted-cycle cost, BOTH pop paths must charge
        // that stored cost, not the padded token count — an expired
        // jump billed in a different unit would corrupt the ledger.
        let mut b = Batcher::new(p);
        b.set_model_weights(&[1, 1]);
        b.push_costed("expired", 0, 5, 123_456);
        std::thread::sleep(Duration::from_millis(1));
        b.push_costed("hot-a", 1, 3, 70);
        b.push_costed("hot-b", 1, 3, 70);
        b.push_costed("hot-c", 1, 3, 70); // keeps the queue busy: no epoch reset
        assert_eq!(b.take_batch(), vec!["expired"]);
        assert_eq!(b.charged_cost(0), 123_456, "expired jump charges the stored cost");
        assert_eq!(b.take_batch(), vec!["hot-a", "hot-b"]);
        assert_eq!(b.charged_cost(1), 140, "full-bucket pop charges the stored costs");
        let mut b = Batcher::new(p);
        b.push_costed("expired", 0, 5, 123_456);
        b.push_costed("later", 0, 5, 1);
        assert_eq!(b.take_batch_for(0), vec!["expired", "later"]);
        assert_eq!(b.in_flight_for(0), 2, "in-flight backlog counts popped requests");
        assert_eq!(b.charged_cost(0), 123_457, "take_batch_for charges the stored cost");
        b.complete(0, 2);
    }

    #[test]
    fn cycle_charged_ledger_drives_fair_selection() {
        // Same token length, wildly different predicted cost: under
        // equal weights the deficit ledger must interleave dispatches
        // so *cost* (not request count) stays balanced — one heavy
        // group is worth many cheap ones (DESIGN.md §12).
        let p = BatchPolicy { max_batch: 1, max_wait: Duration::from_secs(3600), bucket_width: 8 };
        let mut b = Batcher::new(p);
        b.set_model_weights(&[1, 1]);
        for i in 0..4 {
            b.push_costed((0usize, i), 0, 8, 1000); // heavy model
        }
        for i in 0..40 {
            b.push_costed((1usize, i), 1, 8, 100); // cheap model
        }
        let mut served_cost = [0u64; 2];
        for _ in 0..24 {
            let model = b.take_batch()[0].0;
            served_cost[model] += if model == 0 { 1000 } else { 100 };
        }
        // The ledger alternates 1 heavy : 10 cheap (ties break to the
        // older heavy front), keeping served *cost* level within one
        // heavy charge — token-charged DRR would have served the heavy
        // model only ~1/2 of dispatches, 10x the cost share.
        assert_eq!(served_cost[0], 3000, "heavy model dispatched by cost, not count");
        assert_eq!(served_cost[1], 2100);
    }

    #[test]
    fn per_model_pop_only_serves_its_own_model() {
        let p = BatchPolicy { max_batch: 2, max_wait: Duration::ZERO, bucket_width: 8 };
        let mut b = Batcher::new(p);
        b.push_keyed("a0", 0, 3);
        b.push_keyed("b0", 1, 3);
        b.push_keyed("a1", 0, 4);
        assert_eq!(b.take_batch_for(1), vec!["b0"], "model 1 pops only its own bucket");
        assert_eq!(b.queued_for(0), 2, "model 0's backlog untouched");
        assert_eq!(b.take_batch_for(1), Vec::<&str>::new(), "no work left for model 1");
        assert_eq!(b.take_batch_for(0), vec!["a0", "a1"]);
        assert!(b.is_empty());
    }

    #[test]
    fn per_model_pop_matches_serial_pop_for_one_model() {
        // Degenerate single-model configuration: take_batch_for(0) pops
        // the exact same groups, in the same order, as the serial
        // cross-model take_batch — the bit-equivalence contract the
        // per-group pipeline relies on (DESIGN.md §9).
        let p = BatchPolicy { max_batch: 3, max_wait: Duration::ZERO, bucket_width: 8 };
        let mut serial = Batcher::new(p);
        let mut concurrent = Batcher::new(p);
        for (i, &len) in [3usize, 12, 5, 9, 1, 20, 7, 8].iter().enumerate() {
            serial.push_keyed(i, 0, len);
            concurrent.push_keyed(i, 0, len);
        }
        while !serial.is_empty() {
            let want = serial.take_batch();
            let got = concurrent.take_batch_for(0);
            assert_eq!(got, want, "per-model pop diverged from the serial pop");
            concurrent.complete(0, got.len());
        }
        assert!(concurrent.is_empty());
        assert_eq!(concurrent.charged_cost(0), serial.charged_cost(0));
    }

    #[test]
    fn ready_for_and_park_duration_for_are_per_model() {
        let wait = Duration::from_millis(20);
        let p = BatchPolicy { max_batch: 2, max_wait: wait, bucket_width: 8 };
        let mut b = Batcher::new(p);
        b.push_keyed("a0", 0, 3);
        b.push_keyed("a1", 0, 5); // model 0's bucket is full
        b.push_keyed("b0", 1, 3); // model 1: one unexpired request
        let now = Instant::now();
        assert!(b.ready_for(0, now), "model 0 has a full bucket");
        assert!(!b.ready_for(1, now), "model 1 is neither full nor expired");
        assert!(b.ready_for(1, now + wait + Duration::from_millis(1)), "deadline fires");
        assert!(!b.ready_for(2, now), "unknown model is never ready");
        assert!(b.park_duration_for(1, now) <= wait, "parks toward its own deadline");
        assert_eq!(b.park_duration_for(2, now), DEFAULT_PARK, "no work parks the default");
        // model 1's park ignores model 0's (already releasable) bucket
        assert!(b.park_duration_for(1, now) > Duration::ZERO);
    }

    #[test]
    fn in_flight_work_holds_the_epoch_and_the_reentry_floor() {
        // Concurrent-pipeline fairness semantics: a popped-but-running
        // group (a) keeps the epoch from resetting even when the queue
        // drains, and (b) counts as backlog for the idle re-entry
        // floor, so a model with work in flight keeps its fairness
        // position.
        let p = BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(3600), bucket_width: 8 };
        let mut b = Batcher::new(p);
        b.set_model_weights(&[1, 1]);
        b.push_keyed((0usize, 0), 0, 8);
        b.push_keyed((0usize, 1), 0, 8);
        let popped = b.take_batch_for(0);
        assert_eq!(popped.len(), 2);
        assert_eq!(b.in_flight_for(0), 2);
        assert!(b.is_empty(), "queue drained but the group is still executing");
        assert_eq!(b.charged_cost(0), 16, "charge landed at pop time, no reset yet");
        // a tenant arriving while model 0's group is in flight enters
        // at model 0's service level, not at zero
        b.push_keyed((1usize, 0), 1, 8);
        assert_eq!(b.charged_cost(1), 16, "re-entry floor sees in-flight backlog");
        let served = b.take_batch_for(1);
        assert_eq!(served.len(), 1);
        b.complete(1, 1);
        assert_eq!(b.charged_cost(0), 16, "model 0 still in flight: no epoch reset");
        b.complete(0, 2);
        assert_eq!(b.charged_cost(0), 0, "last completion resets the idle epoch");
        assert_eq!(b.charged_cost(1), 0);
    }

    #[test]
    fn model_returning_from_idle_does_not_replay_missed_share() {
        // model 1 sits idle while model 0 serves; when model 1's work
        // arrives it re-enters at the current service level instead of
        // monopolizing dispatches until its ledger catches up
        let p = BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(3600), bucket_width: 8 };
        let mut b = Batcher::new(p);
        b.set_model_weights(&[1, 1]);
        for i in 0..16 {
            b.push_keyed((0usize, i), 0, 8);
        }
        for _ in 0..4 {
            assert_eq!(b.take_batch()[0].0, 0);
        }
        assert_eq!(b.charged_cost(0), 64);
        // model 1 arrives late while model 0 is still backlogged: its
        // ledger jumps to model 0's level instead of starting at zero
        for i in 0..8 {
            b.push_keyed((1usize, i), 1, 8);
        }
        assert_eq!(b.charged_cost(1), 64, "idle model re-enters at the current level");
        let mut served = [0usize; 2];
        for _ in 0..8 {
            served[b.take_batch()[0].0] += 1;
        }
        assert_eq!(served, [4, 4], "equal weights split evenly from the re-entry point");
    }

    // -----------------------------------------------------------------
    // ShardedBatcher (DESIGN.md §13)
    // -----------------------------------------------------------------

    #[test]
    fn sharded_single_model_pop_order_matches_the_serial_batcher() {
        // The one-group configuration must stay bit-equivalent to the
        // serial pipeline: drive the same mixed-length, mixed-expiry
        // push sequence through both batchers and compare every popped
        // group element for element.
        let p = BatchPolicy { max_batch: 3, max_wait: Duration::from_millis(40), bucket_width: 8 };
        let mut serial = Batcher::new(p);
        let sharded = ShardedBatcher::new(p, &[1]);
        let lens = [3usize, 9, 17, 8, 1, 25, 16, 9, 2, 30, 5, 11];
        // interleaved pops exercise the multi-bucket cascade (full
        // bucket vs oldest bucket) mid-stream, not just the final drain
        let pops_at = [2usize, 3, 7, 10];
        for (i, &len) in lens.iter().enumerate() {
            serial.push_keyed(i, 0, len);
            sharded.push_keyed(i, 0, len);
            if pops_at.contains(&i) {
                let group = serial.take_batch_for(0);
                let sharded_group = sharded.take_batch_for(0);
                assert_eq!(group, sharded_group, "pop after push #{i} diverged");
            }
        }
        // expire the remainder and drain both sides to empty via the
        // deadline path
        std::thread::sleep(Duration::from_millis(60));
        loop {
            let group = serial.take_batch_for(0);
            let sharded_group = sharded.take_batch_for(0);
            assert_eq!(group, sharded_group, "drain pop diverged");
            if group.is_empty() {
                break;
            }
        }
        assert!(sharded.is_empty());
        assert_eq!(serial.charged_cost(0), sharded.charged_cost(0), "charges diverged");
    }

    #[test]
    fn sharded_expired_pop_charges_the_stored_per_entry_cost() {
        let p = BatchPolicy { max_batch: 8, max_wait: Duration::ZERO, bucket_width: 8 };
        let sharded = ShardedBatcher::new(p, &[1, 1]);
        sharded.push_costed("a", 0, 4, 700);
        sharded.push_costed("b", 0, 4, 41);
        let group = sharded.take_batch_for(0);
        assert_eq!(group.len(), 2);
        assert_eq!(sharded.charged_cost(0), 741, "expiry jump charges stored costs");
        assert_eq!(sharded.in_flight_for(0), 2);
        assert_eq!(sharded.queued_for(0), 0);
        sharded.complete(0, 2);
        assert_eq!(sharded.charged_cost(0), 0, "pool drained: epoch reset");
        assert_eq!(sharded.in_flight_for(0), 0);
    }

    #[test]
    fn sharded_reentry_floor_and_epoch_reset_match_serial_semantics() {
        let p = BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(3600), bucket_width: 8 };
        let sharded = ShardedBatcher::new(p, &[1, 1]);
        sharded.push_keyed(0usize, 0, 8);
        sharded.push_keyed(1usize, 0, 8);
        let popped = sharded.take_batch_for(0);
        assert_eq!(popped.len(), 2);
        assert_eq!(sharded.charged_cost(0), 16);
        // model 1 arrives while model 0's group is in flight: its
        // ledger jumps to model 0's level (in-flight counts as backlog)
        sharded.push_keyed(2usize, 1, 8);
        assert_eq!(sharded.charged_cost(1), 16, "re-entry floor sees in-flight backlog");
        let served = sharded.take_batch_for(1);
        assert_eq!(served.len(), 1);
        sharded.complete(1, 1);
        assert_eq!(sharded.charged_cost(0), 16, "model 0 still in flight: no epoch reset");
        sharded.complete(0, 2);
        assert_eq!(sharded.charged_cost(0), 0, "last completion resets the idle epoch");
        assert_eq!(sharded.charged_cost(1), 0);
    }

    #[test]
    fn sharded_poisoned_shard_recovers_and_other_models_are_untouched() {
        // The ISSUE 9 poisoned-lock regression in miniature: a panic
        // while holding model 0's shard lock must not panic model 1's
        // path, and model 0 itself must keep serving through the
        // recovered guard.
        let p = BatchPolicy { max_batch: 1, max_wait: Duration::from_secs(3600), bucket_width: 8 };
        let sharded = ShardedBatcher::new(p, &[1, 1]);
        sharded.push_keyed("before", 0, 8);
        sharded.poison_shard(0);
        // other tenants keep serving
        sharded.push_keyed("other", 1, 8);
        assert_eq!(sharded.take_batch_for(1), vec!["other"]);
        sharded.complete(1, 1);
        // the poisoned shard itself recovers rather than cascading
        sharded.push_keyed("after", 0, 8);
        assert_eq!(sharded.take_batch_for(0), vec!["before"]);
        assert_eq!(sharded.take_batch_for(0), vec!["after"]);
        sharded.complete(0, 2);
        assert!(sharded.is_empty());
    }

    #[test]
    fn sharded_next_batch_blocks_until_work_and_drains_on_shutdown() {
        let p = BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(3600), bucket_width: 8 };
        let sharded = std::sync::Arc::new(ShardedBatcher::new(p, &[1, 1]));
        let consumer = {
            let sharded = std::sync::Arc::clone(&sharded);
            std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(group) = sharded.next_batch(0) {
                    let n = group.len();
                    got.extend(group);
                    sharded.complete(0, n);
                }
                got
            })
        };
        // a full bucket releases without waiting out max_wait
        sharded.push_keyed(10usize, 0, 8);
        sharded.push_keyed(11usize, 0, 8);
        // a straggler below max_batch is only released by the shutdown
        // drain (max_wait is an hour)
        sharded.push_keyed(12usize, 0, 8);
        std::thread::sleep(Duration::from_millis(50));
        sharded.shutdown();
        let got = consumer.join().unwrap();
        assert_eq!(got, vec![10, 11, 12], "no request lost across blocking pops and shutdown");
        // a dispatcher for an idle model parks and exits promptly on
        // shutdown instead of spinning
        assert!(sharded.next_batch(1).is_none());
    }
}
