//! Integer LayerNorm unit (paper §III-I, Fig. 15): integer mean and
//! variance, the iterative (Babylonian) integer square root, divider +
//! affine output.

use super::div_floor;

/// Fixed-point precision of the normalized output (scale = 2^-LN_P).
pub const LN_P: u32 = 7;

/// Upper bound on sqrt iterations; the cycle-accurate simulator charges
/// this worst case (paper footnote 3 does the same).
pub const ISQRT_MAX_ITERS: u32 = 32;

#[derive(Clone, Copy, Debug)]
pub struct LayerNormConsts {
    pub s_in: f64,
    pub s_gamma: f64,
    pub d: usize,
}

impl LayerNormConsts {
    pub fn s_out(&self) -> f64 {
        self.s_gamma / (1u64 << LN_P) as f64
    }
}

/// Iterative integer sqrt.  Returns `(floor(sqrt(n)), iterations)`; the
/// iteration count drives the simulator's LayerNorm timing.
///
/// x0 = 2^ceil(bits/2); x_{i+1} = (x_i + n/x_i) >> 1; stop when
/// x_{i+1} >= x_i.  (The paper prints "(x_i + x_i/n)/2" — a typo for the
/// Babylonian update of its own reference [29]; see DESIGN.md.)
pub fn i_sqrt(n: i64) -> (i64, u32) {
    debug_assert!(n >= 0);
    if n == 0 {
        return (0, 0);
    }
    let bits = 64 - (n as u64).leading_zeros();
    let mut x = 1i64 << bits.div_ceil(2);
    let mut iters = 0;
    loop {
        let x1 = (x + n / x) >> 1;
        iters += 1;
        if x1 >= x {
            return (x, iters);
        }
        x = x1;
    }
}

/// Integer LayerNorm over one row (three phases).  `gamma` is INT8 at
/// `s_gamma`, `beta` INT32 at `s_out`; output INT32 at `s_out`.
/// Returns the sqrt iteration count (for the simulator's timing model).
pub fn i_layernorm(
    q: &[i64],
    gamma: &[i64],
    beta: &[i64],
    _c: &LayerNormConsts,
    out: &mut [i32],
) -> u32 {
    let d = q.len() as i64;
    assert!(d > 0);
    assert_eq!(gamma.len(), q.len());
    assert_eq!(beta.len(), q.len());
    assert_eq!(out.len(), q.len());

    // Phase 1: integer mean.
    let sum: i64 = q.iter().sum();
    let mean = div_floor(sum, d);

    // Phase 2: integer variance + iterative sqrt.
    let mut var_sum: i64 = 0;
    for &v in q {
        let y = v - mean;
        var_sum += y * y;
    }
    let var = div_floor(var_sum, d);
    let (std, iters) = i_sqrt(var);
    let std = std.max(1);

    // Phase 3: divider + affine.
    for ((o, &v), (&g, &b)) in out.iter_mut().zip(q).zip(gamma.iter().zip(beta)) {
        let y = v - mean;
        let qn = div_floor(y << LN_P, std);
        let val = qn * g + b;
        *o = val.clamp(i32::MIN as i64, i32::MAX as i64) as i32;
    }
    iters
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn isqrt_is_floor_sqrt() {
        for n in [0i64, 1, 2, 3, 4, 15, 16, 17, 255, 256, 1 << 20, (1 << 31) - 1, 1 << 40] {
            let (s, it) = i_sqrt(n);
            assert!(s * s <= n && n < (s + 1) * (s + 1), "n={n} s={s}");
            assert!(it <= ISQRT_MAX_ITERS);
        }
    }

    #[test]
    fn isqrt_zero_shortcircuits() {
        assert_eq!(i_sqrt(0), (0, 0));
    }

    #[test]
    fn layernorm_constant_row_collapses_to_beta() {
        let d = 16;
        let c = LayerNormConsts { s_in: 0.01, s_gamma: 0.01, d };
        let q = vec![123i64; d];
        let gamma = vec![64i64; d];
        let beta: Vec<i64> = (0..d as i64).collect();
        let mut out = vec![0i32; d];
        i_layernorm(&q, &gamma, &beta, &c, &mut out);
        assert_eq!(out, (0..d as i32).collect::<Vec<_>>());
    }

    #[test]
    fn layernorm_tracks_float_reference() {
        let d = 64;
        let c = LayerNormConsts { s_in: 0.01, s_gamma: 0.01, d };
        let q: Vec<i64> = (0..d as i64).map(|i| (i * 37 % 501) - 250).collect();
        let gamma = vec![100i64; d];
        let beta = vec![0i64; d];
        let mut out = vec![0i32; d];
        i_layernorm(&q, &gamma, &beta, &c, &mut out);

        let xs: Vec<f64> = q.iter().map(|&v| v as f64 * c.s_in).collect();
        let mean = xs.iter().sum::<f64>() / d as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / d as f64;
        for (i, &o) in out.iter().enumerate() {
            let want = (xs[i] - mean) / var.sqrt();
            let got = o as f64 * c.s_out();
            assert!((got - want).abs() < 0.05, "i={i}: {got} vs {want}");
        }
    }

    #[test]
    fn sqrt_iteration_count_is_data_dependent() {
        let (_, small) = i_sqrt(4);
        let (_, large) = i_sqrt((1 << 45) + 12345);
        assert!(large > small);
    }
}
