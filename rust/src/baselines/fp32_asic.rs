//! A hypothetical FP32-datapath SwiftTron (the Fig. 1a design point):
//! identical architecture and schedule, but every MAC is an FP32
//! multiply-add and the nonlinear units keep FP32 operators.  This is the
//! ablation that quantifies *why* the paper's integer-only design wins.

use crate::model::Geometry;
use crate::sim::{simulate_encoder, HwConfig};
use crate::synthesis::operators::Operators;
use crate::synthesis::tech::Tech65;

#[derive(Clone, Debug)]
pub struct Fp32AsicReport {
    pub area_mm2: f64,
    pub power_w: f64,
    /// achievable clock (ns) limited by the FP32 MAC path
    pub clock_ns: f64,
    /// latency of one roberta_base-class inference at that clock (ms)
    pub latency_ms: f64,
    /// ratios vs the INT8 design (area, power, latency)
    pub area_ratio: f64,
    pub power_ratio: f64,
    pub latency_ratio: f64,
}

/// Build the FP32 twin of `cfg` and compare it with the integer design.
pub fn fp32_asic_report(cfg: &HwConfig, geo: &Geometry) -> Fp32AsicReport {
    let t = Tech65::new();
    let int_report = crate::synthesis::synthesis_report(cfg, geo);

    // FP32 MAC: fp multiplier + fp adder + fp32 accumulator register.
    let fp_mac_ge =
        Operators::fp32_multiplier().ge + Operators::fp32_adder().ge + Operators::register(32).ge;
    let int_mac_ge = Operators::int8_mac().ge;
    let mac_scale = fp_mac_ge / int_mac_ge;

    // Scale the MatMul component; nonlinear units grow by the FP/INT
    // operator ratio of their dominant operator (the 32b multiplier).
    let nl_scale = Operators::fp32_multiplier().ge / Operators::int_multiplier(32, 32).ge;
    let mut area = 0.0;
    let mut power = 0.0;
    for c in &int_report.components {
        let s = match c.name {
            "MatMul" => mac_scale,
            "Control" => 1.0,
            _ => nl_scale.max(1.0),
        };
        area += c.area_mm2 * s;
        power += c.power_w * s;
    }

    // FP32 MAC critical path sets the clock.
    let fp_path_ns = t.delay_ns(
        Operators::fp32_multiplier().delay_gates + Operators::fp32_adder().delay_gates,
    );
    let clock_ns = fp_path_ns.max(cfg.clock_ns);
    let fp_cfg = HwConfig { clock_ns, ..*cfg };
    let cycles = simulate_encoder(&fp_cfg, geo).total_cycles;
    let latency_ms = fp_cfg.cycles_to_ms(cycles);
    let int_latency_ms = {
        let r = simulate_encoder(cfg, geo);
        r.ms(cfg)
    };

    Fp32AsicReport {
        area_mm2: area,
        power_w: power,
        clock_ns,
        latency_ms,
        area_ratio: area / int_report.area_mm2,
        power_ratio: power / int_report.power_w,
        latency_ratio: latency_ms / int_latency_ms,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp32_design_is_order_of_magnitude_worse() {
        let r = fp32_asic_report(&HwConfig::paper(), &Geometry::preset("roberta_base").unwrap());
        // the Fig. 2 story at system level: heavy area/power penalty
        assert!(r.area_ratio > 4.0, "area ratio {}", r.area_ratio);
        assert!(r.power_ratio > 4.0, "power ratio {}", r.power_ratio);
        assert!(r.latency_ratio >= 1.0, "latency ratio {}", r.latency_ratio);
    }

    #[test]
    fn fp32_clock_no_faster_than_int() {
        let r = fp32_asic_report(&HwConfig::paper(), &Geometry::preset("roberta_base").unwrap());
        assert!(r.clock_ns >= HwConfig::paper().clock_ns);
    }
}
