//! Layer-3 coordinator: the deployable serving system around the
//! accelerator model.
//!
//! * [`engine`] — the inference engine: embedding lookup + PJRT-executed
//!   integer encoder + integer classifier head, co-reported with the
//!   cycle-accurate accelerator timing for every request.
//! * [`batcher`] — dynamic batcher (size/deadline policy).
//! * [`router`] — request router dispatching batches onto a worker pool
//!   of engine replicas (one SwiftTron instance each).
//! * [`server`] — a line-protocol TCP front-end.
//! * [`metrics`] — latency/throughput accounting.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{Batcher, BatchPolicy};
pub use engine::{InferenceEngine, Prediction};
pub use metrics::Metrics;
pub use router::{Request, Response, Router};
