"""Correctness oracles for the L1 kernels.

Two kinds of reference live here:

* ``np_*`` — *independent* pure-numpy reimplementations of the integer
  spec, written scalar-at-a-time with Python bignum arithmetic so an
  overflow or rounding bug in the jnp/Pallas versions cannot hide in a
  shared code path.  Kernel outputs must match these **bit-exactly**.
* ``f32_*`` — the true floating-point functions (softmax, gelu,
  layernorm).  Kernel outputs, dequantized, must match these within the
  approximation error budget the paper inherits from I-BERT.
"""

from __future__ import annotations

import math

import numpy as np

from ..intops import (
    LN_P,
    SM_UNIT,
    GeluConsts,
    LayerNormConsts,
    SoftmaxConsts,
)

INT8_MIN, INT8_MAX = -128, 127


def _floor_div(a: int, n: int) -> int:
    return a // n  # Python ints: true floor division, arbitrary precision


# --- integer oracles (bit-exact, scalar Python ints) -------------------------

def np_requantize(q, b: int, c: int, lo: int = INT8_MIN, hi: int = INT8_MAX):
    out = np.empty_like(q, dtype=np.int64)
    flat_in, flat_out = q.reshape(-1), out.reshape(-1)
    for i, v in enumerate(flat_in.tolist()):
        s = (v * b) >> c
        flat_out[i] = min(max(s, lo), hi)
    return out.reshape(q.shape).astype(np.int32)


def np_i_exp_scalar(x: int, c: SoftmaxConsts) -> int:
    assert x <= 0
    z = _floor_div(-x, c.q_ln2)
    r = x + z * c.q_ln2
    t = r + c.q_b
    poly = t * t + c.q_c
    return poly >> min(z, 62)


def np_i_softmax(q, c: SoftmaxConsts):
    q = np.asarray(q)
    out = np.empty(q.shape, dtype=np.int64)
    for idx in np.ndindex(q.shape[:-1]):
        row = [int(v) for v in q[idx]]
        mx = max(row)
        es = [np_i_exp_scalar(v - mx, c) for v in row]
        denom = max(sum(es), 1)
        out[idx] = [
            min(max((e * SM_UNIT + (denom >> 1)) // denom, 0), SM_UNIT) for e in es
        ]
    return out.astype(np.int32)


def np_i_erf_scalar(x: int, c: GeluConsts) -> int:
    sgn = (x > 0) - (x < 0)
    qabs = min(abs(x), -c.q_b)
    t = qabs + c.q_b
    return sgn * (t * t + c.q_c)


def np_i_gelu(q, c: GeluConsts):
    q = np.asarray(q)
    out = np.empty(q.shape, dtype=np.int64)
    flat_in, flat_out = q.reshape(-1), out.reshape(-1)
    for i, v in enumerate(flat_in.tolist()):
        flat_out[i] = v * (np_i_erf_scalar(v, c) + c.q_one)
    return out.reshape(q.shape)


def np_i_sqrt_scalar(n: int) -> tuple[int, int]:
    """Returns (isqrt, iterations) — the iteration count feeds the
    cycle-accurate simulator's LayerNorm timing."""
    if n == 0:
        return 0, 0
    x = 1 << ((n.bit_length() + 1) // 2)
    iters = 0
    while True:
        x1 = (x + n // x) >> 1
        iters += 1
        if x1 >= x:
            return x, iters
        x = x1


def np_i_layernorm(q, q_gamma, q_beta, c: LayerNormConsts):
    q = np.asarray(q)
    d = q.shape[-1]
    out = np.empty(q.shape, dtype=np.int64)
    g = [int(v) for v in np.asarray(q_gamma).reshape(-1)]
    b = [int(v) for v in np.asarray(q_beta).reshape(-1)]
    for idx in np.ndindex(q.shape[:-1]):
        row = [int(v) for v in q[idx]]
        mean = _floor_div(sum(row), d)
        y = [v - mean for v in row]
        var = _floor_div(sum(v * v for v in y), d)
        std = max(np_i_sqrt_scalar(var)[0], 1)
        out[idx] = [
            min(max((yv << LN_P) // std * g[j] + b[j], -(2**31)), 2**31 - 1)
            for j, yv in enumerate(y)
        ]
    return out.astype(np.int32)


def np_i_matmul(q_x, q_w, q_bias=None):
    acc = q_x.astype(np.int64) @ q_w.astype(np.int64)
    if q_bias is not None:
        acc = acc + q_bias.astype(np.int64)
    assert np.all(acc <= 2**31 - 1) and np.all(acc >= -(2**31)), "acc overflow"
    return acc.astype(np.int32)


# --- float references (the functions being approximated) ---------------------

def f32_softmax(x, axis=-1):
    x = np.asarray(x, dtype=np.float64)
    x = x - x.max(axis=axis, keepdims=True)
    e = np.exp(x)
    return e / e.sum(axis=axis, keepdims=True)


def f32_gelu(x):
    x = np.asarray(x, dtype=np.float64)
    return x * 0.5 * (1.0 + np.vectorize(math.erf)(x / math.sqrt(2.0)))


def f32_layernorm(x, gamma, beta, eps=0.0):
    x = np.asarray(x, dtype=np.float64)
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    return (x - mean) / np.sqrt(var + eps) * gamma + beta
