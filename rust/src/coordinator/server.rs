//! Legacy TCP text front-end: newline-delimited requests of
//! comma-separated token ids, optionally prefixed with a model id
//! (`roberta_base:3,17,42`); responses are single JSON lines carrying
//! the serving model.  One thread per connection, buffered writes, and
//! a bounded accept path: past `max_conns` concurrent connections a
//! new client gets one typed `{"error":"busy",...}` line and is
//! closed, instead of an unbounded `thread::spawn`.
//!
//! This is the compatibility path.  The scalable front door is the
//! non-blocking binary multiplexer in [`crate::wire::mux`] (DESIGN.md
//! §11), which also speaks this text protocol behind auto-detection.

use super::router::{Response, Router};
use crate::util::json::{obj, Json};
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Default cap on concurrent text connections (each one is a thread).
pub const DEFAULT_MAX_CONNS: usize = 1024;

/// Serve until the listener errors or the process exits, with the
/// default connection cap.
pub fn serve(router: Arc<Router>, addr: &str) -> Result<(), String> {
    serve_with(router, addr, DEFAULT_MAX_CONNS)
}

/// [`serve`] with an explicit cap on concurrent connections.
pub fn serve_with(router: Arc<Router>, addr: &str, max_conns: usize) -> Result<(), String> {
    let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
    eprintln!("swifttron serving on {addr} (models: {:?})", router.model_names());
    accept_loop(router, listener, max_conns, None);
    Ok(())
}

/// A text server running on its own accept thread — the stoppable form
/// tests and benches use (bind port 0, read the real address, `stop`
/// when done).  Connection handler threads exit when their client
/// disconnects.
pub struct TextServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl TextServer {
    pub fn start(router: Arc<Router>, addr: &str, max_conns: usize) -> Result<TextServer, String> {
        let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
        let addr = listener.local_addr().map_err(|e| e.to_string())?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&shutdown);
        let accept = std::thread::Builder::new()
            .name("swifttron-text-accept".into())
            .spawn(move || accept_loop(router, listener, max_conns, Some(flag)))
            .map_err(|e| e.to_string())?;
        Ok(TextServer { addr, shutdown, accept: Some(accept) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept thread.  Live connections
    /// keep their handler threads until the clients hang up.
    pub fn stop(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
    }
}

impl Drop for TextServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
    }
}

/// Accept connections until `shutdown` flips (or forever without one).
/// The listener runs non-blocking so the loop can observe the flag;
/// past the cap a client gets one typed busy line and is closed.
fn accept_loop(
    router: Arc<Router>,
    listener: TcpListener,
    max_conns: usize,
    shutdown: Option<Arc<AtomicBool>>,
) {
    if listener.set_nonblocking(true).is_err() {
        return;
    }
    let open = Arc::new(AtomicUsize::new(0));
    loop {
        if shutdown.as_ref().is_some_and(|f| f.load(Ordering::SeqCst)) {
            return;
        }
        match listener.accept() {
            Ok((s, _)) => {
                if open.load(Ordering::SeqCst) >= max_conns {
                    router.metrics.record_conn_rejected();
                    let _ = reject_busy(s, max_conns);
                    continue;
                }
                open.fetch_add(1, Ordering::SeqCst);
                router.metrics.record_conn_opened();
                let r = Arc::clone(&router);
                let open = Arc::clone(&open);
                std::thread::spawn(move || {
                    let _ = handle(Arc::clone(&r), s);
                    open.fetch_sub(1, Ordering::SeqCst);
                    r.metrics.record_conn_closed();
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) => eprintln!("accept error: {e}"),
        }
    }
}

/// One typed rejection line, then close.
fn reject_busy(stream: TcpStream, max_conns: usize) -> std::io::Result<()> {
    let mut w = BufWriter::new(stream);
    writeln!(
        w,
        "{}",
        obj([
            ("error", Json::from("busy")),
            ("max_conns", Json::from(max_conns as i64)),
        ])
    )?;
    w.flush()
}

/// One response line (shared with the multiplexer's text mode).
pub(crate) fn response_json(resp: &Response) -> String {
    let mut fields = vec![
        ("id", Json::from(resp.id as i64)),
        ("model", Json::from(resp.model.as_str())),
        ("replica", Json::from(resp.replica as i64)),
        ("accel_ms", Json::from(resp.accel_ms)),
        ("e2e_us", Json::from(resp.e2e_s * 1e6)),
    ];
    match &resp.error {
        Some(e) => fields.push(("error", Json::from(e.as_str()))),
        None => fields.push(("label", Json::from(resp.label as i64))),
    }
    obj(fields).to_string()
}

fn handle(router: Arc<Router>, stream: TcpStream) -> std::io::Result<()> {
    // the listener is non-blocking; this connection's reads must block
    stream.set_nonblocking(false)?;
    // Buffered writer: one response is assembled in memory and flushed
    // as a single write, instead of a syscall per formatted fragment.
    let mut writer = BufWriter::new(stream.try_clone()?);
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line == "quit" {
            break;
        }
        match parse_tokens(line) {
            Ok((model, tokens)) => {
                let (tx, rx) = channel();
                match model {
                    Some(m) => router.submit_to(&m, tokens, tx),
                    None => router.submit(tokens, tx),
                };
                match rx.recv() {
                    Ok(resp) => writeln!(writer, "{}", response_json(&resp))?,
                    Err(_) => writeln!(writer, "{{\"error\":\"router gone\"}}")?,
                }
            }
            Err(e) => writeln!(writer, "{}", obj([("error", Json::from(e.as_str()))]))?,
        }
        // the client blocks on this line: flush explicitly
        writer.flush()?;
    }
    Ok(())
}

/// Parse one request line into `(model, tokens)`: `"3,17,42"` targets
/// the default model, `"deit_s:3,17,42"` targets a named one.  A model
/// id starts with a letter or underscore, so a bare token list (which
/// has no `:` before a letter) is never misread.
pub fn parse_tokens(line: &str) -> Result<(Option<String>, Vec<i32>), String> {
    let (model, rest) = match line.split_once(':') {
        Some((head, rest))
            if head
                .trim()
                .chars()
                .next()
                .is_some_and(|c| c.is_ascii_alphabetic() || c == '_') =>
        {
            (Some(head.trim().to_string()), rest)
        }
        _ => (None, line),
    };
    if rest.trim().is_empty() {
        // an empty token list is a well-formed (if doomed) request; the
        // engine rejects it with a typed BadLength
        return Ok((model, Vec::new()));
    }
    let tokens = rest
        .split(',')
        .map(|t| t.trim().parse::<i32>().map_err(|_| format!("bad token {t:?}")))
        .collect::<Result<Vec<i32>, String>>()?;
    Ok((model, tokens))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_tokens_ok_and_err() {
        assert_eq!(parse_tokens("1, 2,3").unwrap(), (None, vec![1, 2, 3]));
        assert!(parse_tokens("1,x").is_err());
    }

    #[test]
    fn parse_tokens_reads_model_prefix() {
        assert_eq!(
            parse_tokens("deit_s:4,5,6").unwrap(),
            (Some("deit_s".to_string()), vec![4, 5, 6])
        );
        assert_eq!(
            parse_tokens(" tiny : 7 , 8 ").unwrap(),
            (Some("tiny".to_string()), vec![7, 8])
        );
        // empty token list stays parseable; the engine rejects it later
        assert_eq!(parse_tokens("tiny:").unwrap(), (Some("tiny".to_string()), vec![]));
        // a leading digit before ':' is not a model id
        assert!(parse_tokens("12:3,4").is_err(), "digit-led prefix is a bad token");
    }

    #[test]
    fn response_json_shapes() {
        let ok = Response {
            id: 1,
            model: "default".into(),
            replica: 0,
            label: 0,
            logits: vec![5, -3],
            accel_ms: 0.5,
            e2e_s: 0.001,
            error: None,
        };
        let s = response_json(&ok);
        assert!(s.contains("\"label\":0") && s.contains("\"accel_ms\":0.5"));
        assert!(s.contains("\"replica\":0"));
        assert!(s.contains("\"model\":\"default\""));
        let err = Response {
            id: 2,
            model: "tiny".into(),
            replica: 1,
            label: usize::MAX,
            logits: vec![],
            accel_ms: 0.0,
            e2e_s: 0.0,
            error: Some("bad".into()),
        };
        assert!(response_json(&err).contains("\"error\":\"bad\""));
    }
}
