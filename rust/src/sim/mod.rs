//! Cycle-accurate simulator of the SwiftTron architecture (paper §III).
//!
//! Stands in for the paper's QuestaSim gate-level simulation (DESIGN.md
//! §5): it counts the clock cycles the RTL would take, block by block,
//! driven by the same FSM structure the paper's control unit uses
//! (Fig. 16), and optionally executes the *functional* integer datapath
//! ([`functional`]) so data-dependent timings (the LayerNorm sqrt) and
//! numerics can be co-simulated.
//!
//! Timing model summary (per block, documented in each unit):
//! * MatMul: output-stationary R x C MAC array; an (M,N,K) product takes
//!   `ceil(M/R) * ceil(N/C)` tile passes of `K` feed cycles plus
//!   `min(N, C)` column-readout cycles (paper Fig. 6's dataflow).
//! * Softmax: m row-parallel units, three phases over an n-element row
//!   (max search, exp, divider), 3-stage pipelined (paper §IV-B).
//! * LayerNorm: d element-parallel lanes, rows streamed; per row a mean
//!   phase, a variance + iterative-sqrt phase (worst-case cycles by
//!   default, footnote 3), and an output/divider phase.
//! * GELU / Requant: combinational lanes matching the producer's readout
//!   width — they overlap with the feeding MatMul's column readout and
//!   charge only pipeline-fill cycles.

pub mod config;
pub mod control;
pub mod cost;
pub mod encoder;
pub mod functional;
pub mod units;

pub use config::HwConfig;
pub use cost::CostModel;
pub use control::{Event, FsmKind, Trace};
pub use encoder::{
    simulate_encoder, simulate_encoder_m, simulate_layer, simulate_layer_m, LatencyReport,
};
