"""AOT lowering: JAX/Pallas -> HLO text artifacts + weight/scale blobs.

Runs ONCE at build time (``make artifacts``).  Outputs, all under
``artifacts/``:

  tiny_int8.hlo.txt       integer encoder, trained tiny-task weights BAKED
  tiny_f32.hlo.txt        float twin of the same trained model (baseline)
  roberta_base_int8_layer.hlo.txt
                          one integer encoder layer, weights as ARGUMENTS
                          (unified design-time constants; the rust runtime
                          loops it 12x with per-layer weight buffers)
  tiny_task.{bin,json}    embeddings, head, test set for the e2e example
  roberta_base_weights.{bin,json}   stacked per-layer integer weights
  golden.{bin,json}       cross-language golden vectors for rust `quant`
  manifest.json           geometry + every design-time constant

Interchange is HLO *text* (never .serialize(): jax>=0.5 emits 64-bit ids
the crate's xla_extension 0.5.1 rejects; the text parser reassigns them).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import pipeline as P
from . import train_tiny as T
from .blobs import BlobWriter
from .intops import Dyadic, GeluConsts, LayerNormConsts, SoftmaxConsts
from .model import GEOMETRIES, Geometry
from .quantize import int8_scale, quantize_bias, quantize_tensor

WEIGHT_KEYS = [
    "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo",
    "w1", "b1", "w2", "b2", "gamma1", "beta1", "gamma2", "beta2",
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the default printer elides big literals as
    # "{...}", which the 0.5.1-era text parser silently zero-fills —
    # baked weights then execute as garbage (found the hard way).
    return comp.as_hlo_text(True)


def lower_tiny(qm: P.QuantModel, geo: Geometry, out_dir: str,
               tiny_model: T.TinyModel, log=print) -> dict:
    """Baked-weights artifacts for the trained tiny model (int8 + f32)."""
    layers = qm.layers

    def int_fwd(q_x):
        return (M.quant_encoder(q_x, layers, geo, use_pallas=True),)

    spec = jax.ShapeDtypeStruct((geo.m, geo.d), jnp.int32)
    t0 = time.time()
    hlo = to_hlo_text(jax.jit(int_fwd).lower(spec))
    path_i8 = os.path.join(out_dir, "tiny_int8.hlo.txt")
    with open(path_i8, "w") as f:
        f.write(hlo)
    log(f"  tiny_int8.hlo.txt        {len(hlo)/1e6:6.2f} MB  ({time.time()-t0:.1f}s)")

    fweights = [{k: jnp.asarray(v) for k, v in w.items()} for w in tiny_model.encoder]

    def f32_fwd(x):
        h = x
        for w in fweights:
            # tanh-GELU: the exact-erf opcode postdates xla_extension 0.5.1
            h = M.float_encoder_layer(h, w, geo, gelu=M.f_gelu_tanh)
        return (h.astype(jnp.float32),)

    fspec = jax.ShapeDtypeStruct((geo.m, geo.d), jnp.float32)
    t0 = time.time()
    hlo = to_hlo_text(jax.jit(f32_fwd).lower(fspec))
    path_f32 = os.path.join(out_dir, "tiny_f32.hlo.txt")
    with open(path_f32, "w") as f:
        f.write(hlo)
    log(f"  tiny_f32.hlo.txt         {len(hlo)/1e6:6.2f} MB  ({time.time()-t0:.1f}s)")
    return {"int8": "tiny_int8.hlo.txt", "f32": "tiny_f32.hlo.txt"}


def lower_shaped_layer(qm: P.QuantModel, geo: Geometry, name: str,
                       out_dir: str, log=print) -> str:
    """One encoder layer with weights as arguments (unified constants)."""
    p0 = qm.layers[0]

    def layer_fwd(q_x, *ws):
        named = dict(zip(WEIGHT_KEYS, ws))
        p = dataclasses.replace(p0, **named)
        return (M.quant_encoder_layer(q_x, p, geo, use_pallas=True),)

    specs = [jax.ShapeDtypeStruct((geo.m, geo.d), jnp.int32)]
    for k in WEIGHT_KEYS:
        specs.append(jax.ShapeDtypeStruct(np.asarray(getattr(p0, k)).shape, jnp.int32))
    t0 = time.time()
    hlo = to_hlo_text(jax.jit(layer_fwd).lower(*specs))
    fname = f"{name}_int8_layer.hlo.txt"
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(hlo)
    log(f"  {fname:24s} {len(hlo)/1e6:6.2f} MB  ({time.time()-t0:.1f}s)")
    return fname


# --- manifest helpers -----------------------------------------------------------

def dy_json(dy: Dyadic) -> dict:
    return {"b": dy.b, "c": dy.c}


def layer_json(p) -> dict:
    return {
        "dy_q": dy_json(p.dy_q), "dy_k": dy_json(p.dy_k), "dy_v": dy_json(p.dy_v),
        "dy_scale": dy_json(p.dy_scale), "dy_ctx": dy_json(p.dy_ctx),
        "dy_res1": dy_json(p.dy_res1), "dy_ln1": dy_json(p.dy_ln1),
        "dy_gelu": dy_json(p.dy_gelu), "dy_res2": dy_json(p.dy_res2),
        "dy_ln2": dy_json(p.dy_ln2),
        "softmax": {"s_in": p.sm.s_in, "q_ln2": p.sm.q_ln2,
                    "q_b": p.sm.q_b, "q_c": p.sm.q_c},
        "gelu": {"s_in": p.gelu.s_in, "q_b": p.gelu.q_b,
                 "q_c": p.gelu.q_c, "q_one": p.gelu.q_one},
        "ln1": {"s_in": p.ln1.s_in, "s_gamma": p.ln1.s_gamma, "d": p.ln1.d},
        "ln2": {"s_in": p.ln2.s_in, "s_gamma": p.ln2.s_gamma, "d": p.ln2.d},
        "scales": {
            "s_x": p.cal.attn.s_x, "s_q8": p.cal.attn.s_q8,
            "s_k8": p.cal.attn.s_k8, "s_v8": p.cal.attn.s_v8,
            "s_ctx": p.cal.attn.s_ctx, "s_x2": p.cal.ffn.s_x2,
            "s_h": p.cal.ffn.s_h, "s_out": p.cal.ffn.s_out,
        },
    }


def geo_json(geo: Geometry) -> dict:
    return {"d": geo.d, "heads": geo.heads, "m": geo.m,
            "d_ff": geo.d_ff, "layers": geo.layers}


# --- golden vectors for the rust quant module ------------------------------------

def write_golden(out_dir: str, log=print) -> None:
    """Cross-language contract: random inputs + oracle outputs for every
    integer primitive.  The rust `quant` tests replay these bit-exactly."""
    from .kernels import ref
    from . import intops

    rng = np.random.default_rng(2024)
    w = BlobWriter()
    meta: dict = {}

    # requantize
    dy = Dyadic.approximate(0.01711)
    q = rng.integers(-(2**26), 2**26, (64,)).astype(np.int64)
    w.add("requant_in", q, "i64")
    w.add("requant_out", ref.np_requantize(q, dy.b, dy.c).astype(np.int32), "i32")
    meta["requant"] = dy_json(dy)

    # softmax
    sm = SoftmaxConsts.design(0.0121)
    qs = rng.integers(-400, 400, (16, 32)).astype(np.int32)
    w.add("softmax_in", qs, "i32")
    w.add("softmax_out", ref.np_i_softmax(qs, sm), "i32")
    meta["softmax"] = {"s_in": sm.s_in, "q_ln2": sm.q_ln2, "q_b": sm.q_b, "q_c": sm.q_c}

    # gelu
    gc = GeluConsts.design(0.0177)
    qg = rng.integers(-500, 500, (128,)).astype(np.int32)
    w.add("gelu_in", qg, "i32")
    w.add("gelu_out", ref.np_i_gelu(qg, gc).astype(np.int64), "i64")
    meta["gelu"] = {"s_in": gc.s_in, "q_b": gc.q_b, "q_c": gc.q_c, "q_one": gc.q_one}

    # layernorm
    d = 48
    lc = LayerNormConsts(s_in=0.013, s_gamma=0.009, d=d)
    ql = rng.integers(-3000, 3000, (8, d)).astype(np.int32)
    g = rng.integers(-127, 128, (d,)).astype(np.int32)
    b = rng.integers(-4000, 4000, (d,)).astype(np.int32)
    w.add("ln_in", ql, "i32")
    w.add("ln_gamma", g, "i32")
    w.add("ln_beta", b, "i32")
    w.add("ln_out", ref.np_i_layernorm(ql, g, b, lc), "i32")
    meta["layernorm"] = {"s_in": lc.s_in, "s_gamma": lc.s_gamma, "d": d}

    # isqrt (+ iteration counts: the simulator's timing contract)
    ns = np.concatenate([
        np.array([0, 1, 2, 3, 4, 255, 256, (1 << 31) - 1], dtype=np.int64),
        rng.integers(0, 1 << 50, 56).astype(np.int64),
    ])
    vals, iters = zip(*[ref.np_i_sqrt_scalar(int(n)) for n in ns])
    w.add("isqrt_in", ns, "i64")
    w.add("isqrt_out", np.asarray(vals, dtype=np.int64), "i64")
    w.add("isqrt_iters", np.asarray(iters, dtype=np.int32), "i32")

    # i_exp
    qe = -rng.integers(0, 3000, (64,)).astype(np.int64)
    w.add("iexp_in", qe, "i64")
    w.add("iexp_out", np.asarray(
        [ref.np_i_exp_scalar(int(x), sm) for x in qe], dtype=np.int64), "i64")

    w.write(os.path.join(out_dir, "golden"))
    with open(os.path.join(out_dir, "golden_consts.json"), "w") as f:
        json.dump(meta, f, indent=1, sort_keys=True)
    log("  golden.{bin,json}        written")


# --- main build -------------------------------------------------------------------

def build(out_dir: str, train_steps: int = 500, log=print) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest: dict = {"presets": {}}

    # ---------- tiny: trained, baked, end-to-end ----------
    geo = GEOMETRIES["tiny"]
    log(f"[aot] training tiny model ({train_steps} steps) ...")
    tiny, losses = T.train(geo, steps=train_steps, lr=1e-3, log_every=100, log=log)

    rng = np.random.default_rng(5)
    ctoks, _ = T.make_dataset(rng, 32, geo.m)
    calib = np.stack([tiny.emb[t] + tiny.pos for t in ctoks])
    qm = P.calibrate_and_design(tiny.encoder, geo, calib)

    log("[aot] lowering tiny artifacts ...")
    paths = lower_tiny(qm, geo, out_dir, tiny, log=log)

    # head + embeddings + test set blob
    s_wh = int8_scale(np.abs(tiny.w_head).max())
    q_wh = quantize_tensor(tiny.w_head, s_wh)
    q_bh = quantize_bias(tiny.b_head, qm.s_out * s_wh)
    test_toks, test_labels = T.make_dataset(np.random.default_rng(99), 512, geo.m)

    bw = BlobWriter()
    bw.add("emb", tiny.emb.astype(np.float32), "f32")
    bw.add("pos", tiny.pos.astype(np.float32), "f32")
    bw.add("q_w_head", q_wh, "i32")
    bw.add("q_b_head", q_bh, "i32")
    bw.add("f_w_head", tiny.w_head.astype(np.float32), "f32")
    bw.add("f_b_head", tiny.b_head.astype(np.float32), "f32")
    bw.add("test_toks", test_toks, "i32")
    bw.add("test_labels", test_labels, "i32")
    bw.add("loss_curve", np.asarray(losses, dtype=np.float32), "f32")
    bw.write(os.path.join(out_dir, "tiny_task"))
    log("  tiny_task.{bin,json}     written")

    manifest["presets"]["tiny"] = {
        "geometry": geo_json(geo),
        "artifacts": paths,
        "weights_blob": "tiny_task",
        "s_in": qm.s_in,
        "s_out": qm.s_out,
        "s_w_head": s_wh,
        "vocab": T.VOCAB,
        "key_token": T.KEY_TOKEN,
        "layers": [layer_json(p) for p in qm.layers],
        "float_test_accuracy": T.accuracy(tiny, test_toks, test_labels),
    }

    # ---------- roberta_base-shaped: unified layer artifact ----------
    geo_rb = GEOMETRIES["roberta_base"]
    log("[aot] building roberta_base-shaped layer (random weights, unified scales) ...")
    weights_rb = M.init_encoder_weights(11, geo_rb)
    rngc = np.random.default_rng(13)
    calib_rb = rngc.normal(0, 1.0, (2, geo_rb.m, geo_rb.d))
    qm_rb = P.calibrate_and_design(weights_rb, geo_rb, calib_rb, unify=True)
    fname = lower_shaped_layer(qm_rb, geo_rb, "roberta_base", out_dir, log=log)

    bw = BlobWriter()
    for i, p in enumerate(qm_rb.layers):
        for k in WEIGHT_KEYS:
            arr = np.asarray(getattr(p, k))
            # INT8-valued tensors (weights, gamma) store as i8; INT32
            # accumulator-scale tensors (biases, beta) stay i32.
            dt = "i8" if arr.min() >= -128 and arr.max() <= 127 and k[0] in "wg" else "i32"
            bw.add(f"L{i}.{k}", arr.astype(np.int32), dt)
    bw.write(os.path.join(out_dir, "roberta_base_weights"))
    log("  roberta_base_weights.{bin,json} written")

    manifest["presets"]["roberta_base"] = {
        "geometry": geo_json(geo_rb),
        "artifacts": {"int8_layer": fname},
        "weights_blob": "roberta_base_weights",
        "s_in": qm_rb.s_in,
        "s_out": qm_rb.s_out,
        "layers": [layer_json(qm_rb.layers[0])],  # unified: all identical
        "weight_keys": WEIGHT_KEYS,
    }

    # ---------- simulator-only geometries (Table II) ----------
    for name in ("roberta_large", "deit_s", "small"):
        manifest["presets"][name] = {"geometry": geo_json(GEOMETRIES[name])}

    # ---------- golden vectors ----------
    write_golden(out_dir, log=log)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    log("[aot] manifest.json written — artifacts complete")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--train-steps", type=int, default=500)
    args = ap.parse_args()
    build(args.out, train_steps=args.train_steps)


if __name__ == "__main__":
    main()
