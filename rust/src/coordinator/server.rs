//! TCP front-end: newline-delimited requests of comma-separated token
//! ids, optionally prefixed with a model id (`roberta_base:3,17,42`);
//! responses are single JSON lines carrying the serving model.  One
//! thread per connection (connections are few; the router pool does the
//! real work).

use super::router::{Response, Router};
use crate::util::json::{obj, Json};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::channel;
use std::sync::Arc;

/// Serve until the listener errors or the process exits.
pub fn serve(router: Arc<Router>, addr: &str) -> Result<(), String> {
    let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
    eprintln!("swifttron serving on {addr} (models: {:?})", router.model_names());
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let r = Arc::clone(&router);
                std::thread::spawn(move || {
                    let _ = handle(r, s);
                });
            }
            Err(e) => eprintln!("accept error: {e}"),
        }
    }
    Ok(())
}

fn response_json(resp: &Response) -> String {
    let mut fields = vec![
        ("id", Json::from(resp.id as i64)),
        ("model", Json::from(resp.model.as_str())),
        ("replica", Json::from(resp.replica as i64)),
        ("accel_ms", Json::from(resp.accel_ms)),
        ("e2e_us", Json::from(resp.e2e_s * 1e6)),
    ];
    match &resp.error {
        Some(e) => fields.push(("error", Json::from(e.as_str()))),
        None => fields.push(("label", Json::from(resp.label as i64))),
    }
    obj(fields).to_string()
}

fn handle(router: Arc<Router>, stream: TcpStream) -> std::io::Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line == "quit" {
            break;
        }
        match parse_tokens(line) {
            Ok((model, tokens)) => {
                let (tx, rx) = channel();
                match model {
                    Some(m) => router.submit_to(&m, tokens, tx),
                    None => router.submit(tokens, tx),
                };
                match rx.recv() {
                    Ok(resp) => writeln!(writer, "{}", response_json(&resp))?,
                    Err(_) => writeln!(writer, "{{\"error\":\"router gone\"}}")?,
                }
            }
            Err(e) => writeln!(writer, "{}", obj([("error", Json::from(e.as_str()))]))?,
        }
    }
    eprintln!("connection {peer} closed");
    Ok(())
}

/// Parse one request line into `(model, tokens)`: `"3,17,42"` targets
/// the default model, `"deit_s:3,17,42"` targets a named one.  A model
/// id starts with a letter or underscore, so a bare token list (which
/// has no `:` before a letter) is never misread.
pub fn parse_tokens(line: &str) -> Result<(Option<String>, Vec<i32>), String> {
    let (model, rest) = match line.split_once(':') {
        Some((head, rest))
            if head
                .trim()
                .chars()
                .next()
                .is_some_and(|c| c.is_ascii_alphabetic() || c == '_') =>
        {
            (Some(head.trim().to_string()), rest)
        }
        _ => (None, line),
    };
    if rest.trim().is_empty() {
        // an empty token list is a well-formed (if doomed) request; the
        // engine rejects it with a typed BadLength
        return Ok((model, Vec::new()));
    }
    let tokens = rest
        .split(',')
        .map(|t| t.trim().parse::<i32>().map_err(|_| format!("bad token {t:?}")))
        .collect::<Result<Vec<i32>, String>>()?;
    Ok((model, tokens))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_tokens_ok_and_err() {
        assert_eq!(parse_tokens("1, 2,3").unwrap(), (None, vec![1, 2, 3]));
        assert!(parse_tokens("1,x").is_err());
    }

    #[test]
    fn parse_tokens_reads_model_prefix() {
        assert_eq!(
            parse_tokens("deit_s:4,5,6").unwrap(),
            (Some("deit_s".to_string()), vec![4, 5, 6])
        );
        assert_eq!(
            parse_tokens(" tiny : 7 , 8 ").unwrap(),
            (Some("tiny".to_string()), vec![7, 8])
        );
        // empty token list stays parseable; the engine rejects it later
        assert_eq!(parse_tokens("tiny:").unwrap(), (Some("tiny".to_string()), vec![]));
        // a leading digit before ':' is not a model id
        assert!(parse_tokens("12:3,4").is_err(), "digit-led prefix is a bad token");
    }

    #[test]
    fn response_json_shapes() {
        let ok = Response {
            id: 1,
            model: "default".into(),
            replica: 0,
            label: 0,
            logits: vec![5, -3],
            accel_ms: 0.5,
            e2e_s: 0.001,
            error: None,
        };
        let s = response_json(&ok);
        assert!(s.contains("\"label\":0") && s.contains("\"accel_ms\":0.5"));
        assert!(s.contains("\"replica\":0"));
        assert!(s.contains("\"model\":\"default\""));
        let err = Response {
            id: 2,
            model: "tiny".into(),
            replica: 1,
            label: usize::MAX,
            logits: vec![],
            accel_ms: 0.0,
            e2e_s: 0.0,
            error: Some("bad".into()),
        };
        assert!(response_json(&err).contains("\"error\":\"bad\""));
    }
}
