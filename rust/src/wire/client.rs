//! Blocking `SWWIRE1` client — the test / bench / socket-replay side
//! of the protocol, where per-frame allocation is fine (DESIGN.md
//! §11).  Supports pipelining: queue any number of request frames,
//! flush once, then pull responses (which may arrive out of request
//! order — match on [`ResponseFrame::id`]).

use super::encode::{decode_response, encode_request};
use super::frame::{ResponseFrame, PREAMBLE};
use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

pub struct WireClient {
    stream: TcpStream,
    wbuf: Vec<u8>,
    rbuf: Vec<u8>,
    /// parsed-prefix cursor into `rbuf`
    rpos: usize,
}

impl WireClient {
    /// Connect and send the binary preamble.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> std::io::Result<WireClient> {
        let mut stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        stream.write_all(&PREAMBLE)?;
        Ok(WireClient { stream, wbuf: Vec::new(), rbuf: Vec::new(), rpos: 0 })
    }

    /// Bound how long [`recv`](WireClient::recv) blocks for the next
    /// response byte (`None` = forever).
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> std::io::Result<()> {
        self.stream.set_read_timeout(timeout)
    }

    /// Queue one request frame locally (pipelining) — nothing is sent
    /// until [`flush`](WireClient::flush).
    pub fn queue(&mut self, id: u64, model: &str, tokens: &[i32]) {
        encode_request(&mut self.wbuf, id, model, tokens);
    }

    /// Write all queued frames to the socket.
    pub fn flush(&mut self) -> std::io::Result<()> {
        if !self.wbuf.is_empty() {
            self.stream.write_all(&self.wbuf)?;
            self.wbuf.clear();
        }
        Ok(())
    }

    /// Queue + flush one request.
    pub fn send(&mut self, id: u64, model: &str, tokens: &[i32]) -> std::io::Result<()> {
        self.queue(id, model, tokens);
        self.flush()
    }

    /// Send raw bytes as-is (tests inject malformed / oversized /
    /// truncated frames with this).
    pub fn send_raw(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        self.stream.write_all(bytes)
    }

    /// Block until one response frame arrives (or the read times out /
    /// the server closes, both reported as `Err`).
    pub fn recv(&mut self) -> Result<ResponseFrame, String> {
        loop {
            if let Some((n, frame)) = decode_response(&self.rbuf[self.rpos..])? {
                self.rpos += n;
                if self.rpos == self.rbuf.len() {
                    self.rbuf.clear();
                    self.rpos = 0;
                }
                return Ok(frame);
            }
            let mut chunk = [0u8; 16 * 1024];
            match self.stream.read(&mut chunk) {
                Ok(0) => return Err("server closed the connection".into()),
                Ok(n) => self.rbuf.extend_from_slice(&chunk[..n]),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(format!("read: {e}")),
            }
        }
    }

    /// Collect exactly `n` responses, in arrival order.
    pub fn recv_n(&mut self, n: usize) -> Result<Vec<ResponseFrame>, String> {
        (0..n).map(|_| self.recv()).collect()
    }
}
