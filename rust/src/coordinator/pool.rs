//! Replica pool: fans the batcher's dispatch groups out across engine
//! replicas on the in-repo `util` thread pool and re-orders results per
//! request (DESIGN.md §2, §8).
//!
//! With multiple resident models the pool is a *set of named groups*:
//! each model id owns its own replicas, requests carry their model
//! index, and a dispatch group (always model-homogeneous, by batcher
//! construction) fans out only across its model's group.  Replica ids
//! are global — group `g`'s replicas occupy a contiguous id range — so
//! the per-replica metrics ledger stays flat.
//!
//! Fan-out policy within a group: requests are assigned round-robin by
//! position (request `i` goes to replica `(start + i) mod N`, with
//! `start` rotating per dispatch so short groups spread across replicas
//! over time instead of pinning the group's first replica).  Each
//! replica processes its share serially — one sequence at a time, as
//! the hardware loads the MAC array per sentence — while the shares run
//! concurrently on dedicated pool threads.  Replies go out on each
//! request's channel the moment its prediction completes; the
//! group-level return value is re-ordered back to submission (FIFO)
//! order for consumers that want the whole group (the scaling bench,
//! tests).
//!
//! Dispatch is a barrier per group: throughput scales with a model's
//! replicas once its dispatch-group size reaches that group's replica
//! count; groups smaller than the group leave its replicas idle for
//! that dispatch (the operating regime is `max_batch >= replicas`;
//! DESIGN.md §2, EXPERIMENTS.md §Scaling).

use super::engine::{EngineReplica, RequestError};
use super::metrics::Metrics;
use super::registry::ModelGroup;
use super::router::{Request, Response};
use crate::util::threadpool::ThreadPool;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

struct Group {
    model: String,
    replicas: Vec<Arc<dyn EngineReplica>>,
    /// global id of this group's first replica
    base: usize,
    /// rotating fan-out offset (advances once per dispatch)
    next_start: AtomicUsize,
}

pub struct ReplicaPool {
    groups: Vec<Group>,
    pool: ThreadPool,
    metrics: Arc<Metrics>,
}

impl ReplicaPool {
    /// Single-model pool under the default model id (the seed serving
    /// path): one pool thread per replica, so a replica is never
    /// oversubscribed and an idle replica never queues behind a busy
    /// one.
    pub fn new(replicas: Vec<Arc<dyn EngineReplica>>, metrics: Arc<Metrics>) -> ReplicaPool {
        ReplicaPool::new_multi(
            vec![ModelGroup { model: "default".into(), replicas, weight: 1 }],
            metrics,
        )
    }

    /// Multi-model pool: one named replica group per model id, one pool
    /// thread per replica across all groups.
    pub fn new_multi(groups: Vec<ModelGroup>, metrics: Arc<Metrics>) -> ReplicaPool {
        assert!(!groups.is_empty(), "replica pool needs at least one model group");
        let total: usize = groups.iter().map(|g| g.replicas.len()).sum();
        assert!(total > 0, "replica pool needs at least one engine");
        for g in &groups {
            assert!(!g.replicas.is_empty(), "model {:?} has no replicas", g.model);
        }
        metrics.ensure_replicas(total);
        let pool = ThreadPool::new(total);
        let mut base = 0;
        let groups = groups
            .into_iter()
            .map(|g| {
                let n = g.replicas.len();
                let group = Group {
                    model: g.model,
                    replicas: g.replicas,
                    base,
                    next_start: AtomicUsize::new(0),
                };
                base += n;
                group
            })
            .collect();
        ReplicaPool { groups, pool, metrics }
    }

    /// Total number of replicas across all groups (== pool threads).
    pub fn replicas(&self) -> usize {
        self.groups.iter().map(|g| g.replicas.len()).sum()
    }

    /// Number of model groups.
    pub fn group_count(&self) -> usize {
        self.groups.len()
    }

    /// Model id of group `i`.
    pub fn model_name(&self, i: usize) -> Option<&str> {
        self.groups.get(i).map(|g| g.model.as_str())
    }

    /// Execute one dispatch group: fan out across the owning model's
    /// replicas, reply per request as it finishes, and return responses
    /// re-ordered to the group's submission order.  Dispatch groups are
    /// model-homogeneous by batcher construction; the owning group is
    /// read off the first request.
    pub fn dispatch(&self, group: Vec<Request>) -> Vec<Response> {
        let total = group.len();
        if total == 0 {
            return Vec::new();
        }
        let gidx = group[0].model;
        assert!(gidx < self.groups.len(), "request for unknown model group {gidx}");
        debug_assert!(
            group.iter().all(|r| r.model == gidx),
            "dispatch group mixes models — batcher invariant broken"
        );
        let g = &self.groups[gidx];
        let n = g.replicas.len();
        let start = g.next_start.fetch_add(1, Ordering::Relaxed) % n;
        let mut shares: Vec<Vec<(usize, Request)>> = (0..n).map(|_| Vec::new()).collect();
        for (i, req) in group.into_iter().enumerate() {
            shares[(start + i) % n].push((i, req));
        }
        let jobs: Vec<_> = shares
            .into_iter()
            .enumerate()
            .filter(|(_, share)| !share.is_empty())
            .map(|(r, share)| {
                let replica = Arc::clone(&g.replicas[r]);
                let metrics = Arc::clone(&self.metrics);
                let replica_id = g.base + r;
                let model = g.model.clone();
                move || {
                    share
                        .into_iter()
                        .map(|(i, req)| {
                            (i, serve_one(replica_id, &model, replica.as_ref(), &metrics, req))
                        })
                        .collect::<Vec<_>>()
                }
            })
            .collect();
        let mut indexed: Vec<(usize, Response)> =
            self.pool.run_batch(jobs).into_iter().flatten().collect();
        indexed.sort_unstable_by_key(|&(i, _)| i);
        assert_eq!(indexed.len(), total, "every request yields exactly one response");
        indexed.into_iter().map(|(_, resp)| resp).collect()
    }
}

/// Serve one request on one replica: predict, account (aggregate,
/// per-replica, and per-model virtual time), reply.
fn serve_one(
    replica_id: usize,
    model_name: &str,
    engine: &dyn EngineReplica,
    metrics: &Metrics,
    req: Request,
) -> Response {
    let queued = req.submitted.elapsed().as_secs_f64();
    let t0 = Instant::now();
    // A panicking replica must cost one request, not the dispatcher
    // thread: run_batch treats a panicked job as fatal, which would
    // kill the single dispatcher and hang every later submit.
    let result = catch_unwind(AssertUnwindSafe(|| engine.predict(&req.tokens)))
        .unwrap_or_else(|_| {
            Err(RequestError::Backend("replica panicked while serving request".into()))
        });
    let resp = match result {
        Ok(pred) => {
            let exec = t0.elapsed().as_secs_f64();
            let e2e = req.submitted.elapsed().as_secs_f64();
            metrics.record_completion(e2e, queued, exec, pred.accel_ms);
            metrics.record_replica(replica_id, exec, pred.accel_cycles, pred.accel_ms, false);
            metrics.record_model_served(
                req.model,
                req.tokens.len(),
                req.padded_len,
                pred.accel_cycles,
                pred.accel_ms,
                false,
            );
            Response {
                id: req.id,
                model: model_name.to_string(),
                replica: replica_id,
                label: pred.label,
                logits: pred.logits,
                accel_ms: pred.accel_ms,
                e2e_s: e2e,
                error: None,
            }
        }
        Err(e) => {
            let exec = t0.elapsed().as_secs_f64();
            metrics.record_error();
            metrics.record_replica(replica_id, exec, 0, 0.0, true);
            metrics.record_model_served(req.model, 0, 0, 0, 0.0, true);
            Response {
                id: req.id,
                model: model_name.to_string(),
                replica: replica_id,
                label: usize::MAX,
                logits: Vec::new(),
                accel_ms: 0.0,
                e2e_s: req.submitted.elapsed().as_secs_f64(),
                error: Some(e.to_string()),
            }
        }
    };
    let _ = req.reply.send(resp.clone());
    resp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::Prediction;
    use std::sync::mpsc::{channel, Receiver};
    use std::time::Duration;

    /// Deterministic-latency replica: predicts after a fixed sleep.
    struct SlowReplica {
        delay: Duration,
    }

    impl EngineReplica for SlowReplica {
        fn predict(&self, tokens: &[i32]) -> Result<Prediction, RequestError> {
            if tokens.is_empty() {
                return Err(RequestError::Backend("empty".into()));
            }
            std::thread::sleep(self.delay);
            Ok(Prediction {
                label: tokens[0] as usize % 2,
                logits: vec![0, 1],
                accel_cycles: 1000,
                accel_ms: 0.007,
            })
        }

        fn seq_len(&self) -> usize {
            4
        }
    }

    fn pool_of(n: usize, delay_ms: u64) -> (ReplicaPool, Arc<Metrics>) {
        let metrics = Arc::new(Metrics::new());
        let replicas: Vec<Arc<dyn EngineReplica>> = (0..n)
            .map(|_| {
                Arc::new(SlowReplica { delay: Duration::from_millis(delay_ms) })
                    as Arc<dyn EngineReplica>
            })
            .collect();
        (ReplicaPool::new(replicas, Arc::clone(&metrics)), metrics)
    }

    fn group_of(n: usize) -> (Vec<Request>, Vec<Receiver<Response>>) {
        group_for_model(0, n)
    }

    fn group_for_model(model: usize, n: usize) -> (Vec<Request>, Vec<Receiver<Response>>) {
        let mut group = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for id in 0..n as u64 {
            let (tx, rx) = channel();
            group.push(Request {
                id,
                model,
                tokens: vec![id as i32; 4],
                padded_len: 4,
                submitted: Instant::now(),
                reply: tx,
            });
            receivers.push(rx);
        }
        (group, receivers)
    }

    #[test]
    fn dispatch_reorders_to_submission_order_and_replies() {
        let (pool, _metrics) = pool_of(3, 0);
        let (group, receivers) = group_of(10);
        let responses = pool.dispatch(group);
        let ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<u64>>(), "submission order restored");
        for (i, rx) in receivers.into_iter().enumerate() {
            let resp = rx.recv().expect("reply sent");
            assert_eq!(resp.id, i as u64);
            assert!(resp.error.is_none());
        }
    }

    #[test]
    fn round_robin_spreads_across_replicas() {
        let (pool, metrics) = pool_of(2, 0);
        let (group, _receivers) = group_of(8);
        let responses = pool.dispatch(group);
        // first dispatch starts at offset 0: position i -> replica i mod 2
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.replica, i % 2);
        }
        assert_eq!(metrics.replica(0).requests.load(std::sync::atomic::Ordering::Relaxed), 4);
        assert_eq!(metrics.replica(1).requests.load(std::sync::atomic::Ordering::Relaxed), 4);
    }

    #[test]
    fn singleton_groups_rotate_across_replicas() {
        // the fan-out offset advances per dispatch, so back-to-back
        // one-request groups do not pin replica 0
        let (pool, _metrics) = pool_of(2, 0);
        let mut served = vec![];
        for _ in 0..4 {
            let (group, _receivers) = group_of(1);
            served.push(pool.dispatch(group)[0].replica);
        }
        assert_eq!(served, vec![0, 1, 0, 1]);
    }

    #[test]
    fn panicking_replica_costs_one_request_not_the_pool() {
        struct PanickyReplica;
        impl EngineReplica for PanickyReplica {
            fn predict(&self, tokens: &[i32]) -> Result<Prediction, RequestError> {
                if tokens[0] == 13 {
                    panic!("boom");
                }
                Ok(Prediction { label: 0, logits: vec![], accel_cycles: 1, accel_ms: 0.001 })
            }
            fn seq_len(&self) -> usize {
                4
            }
        }
        let metrics = Arc::new(Metrics::new());
        let replicas: Vec<Arc<dyn EngineReplica>> =
            vec![Arc::new(PanickyReplica) as Arc<dyn EngineReplica>];
        let pool = ReplicaPool::new(replicas, Arc::clone(&metrics));
        let (mut group, _receivers) = group_of(3);
        group[1].tokens = vec![13; 4]; // triggers the panic
        let responses = pool.dispatch(group);
        assert!(responses[0].error.is_none());
        assert!(responses[1].error.as_deref().unwrap_or("").contains("panicked"));
        assert!(responses[2].error.is_none());
        // the pool survives for the next dispatch
        let (group, _receivers) = group_of(2);
        assert!(pool.dispatch(group).iter().all(|r| r.error.is_none()));
    }

    #[test]
    fn two_replicas_run_a_group_concurrently() {
        // 8 requests x 20 ms: serial would take ~160 ms; two replicas
        // should land near 80 ms.  The generous bound still proves the
        // shares overlapped.
        let (pool, _metrics) = pool_of(2, 20);
        let (group, _receivers) = group_of(8);
        let t0 = Instant::now();
        let responses = pool.dispatch(group);
        let wall = t0.elapsed();
        assert_eq!(responses.len(), 8);
        assert!(
            wall < Duration::from_millis(140),
            "dispatch took {wall:?}, shares did not overlap"
        );
    }

    #[test]
    fn errors_are_per_request_not_per_group() {
        let (pool, metrics) = pool_of(2, 0);
        let (mut group, receivers) = group_of(4);
        group[2].tokens.clear(); // SlowReplica errors on empty tokens
        let responses = pool.dispatch(group);
        assert!(responses[2].error.is_some());
        for (i, resp) in responses.iter().enumerate() {
            assert_eq!(resp.error.is_some(), i == 2);
        }
        drop(receivers);
        assert_eq!(metrics.errors.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(metrics.completed.load(std::sync::atomic::Ordering::Relaxed), 3);
    }

    #[test]
    fn named_groups_route_by_model_with_global_replica_ids() {
        use std::sync::atomic::Ordering;
        // group "a": replicas 0..2, group "b": replica 2 — requests of
        // model 1 must land only on b's replica, with the model name on
        // the response and the served tokens on model 1's ledger
        let metrics = Arc::new(Metrics::new());
        let mk = |n: usize| -> Vec<Arc<dyn EngineReplica>> {
            (0..n)
                .map(|_| {
                    Arc::new(SlowReplica { delay: Duration::ZERO }) as Arc<dyn EngineReplica>
                })
                .collect()
        };
        let pool = ReplicaPool::new_multi(
            vec![
                ModelGroup { model: "a".into(), replicas: mk(2), weight: 1 },
                ModelGroup { model: "b".into(), replicas: mk(1), weight: 1 },
            ],
            Arc::clone(&metrics),
        );
        assert_eq!(pool.replicas(), 3);
        assert_eq!(pool.group_count(), 2);
        assert_eq!(pool.model_name(1), Some("b"));

        let (group_b, _rx_b) = group_for_model(1, 3);
        for resp in pool.dispatch(group_b) {
            assert!(resp.error.is_none());
            assert_eq!(resp.model, "b");
            assert_eq!(resp.replica, 2, "model b owns the last global replica id");
        }
        let (group_a, _rx_a) = group_for_model(0, 4);
        for resp in pool.dispatch(group_a) {
            assert_eq!(resp.model, "a");
            assert!(resp.replica < 2);
        }
        assert_eq!(metrics.model(1).completed.load(Ordering::Relaxed), 3);
        assert_eq!(metrics.model(1).served_padded_tokens.load(Ordering::Relaxed), 12);
        assert_eq!(metrics.model(0).completed.load(Ordering::Relaxed), 4);
        assert_eq!(metrics.replica(2).requests.load(Ordering::Relaxed), 3);
    }
}
