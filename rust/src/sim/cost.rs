//! Analytical cost model: O(1) predicted accelerator cycles per
//! `(Geometry, HwConfig, m_eff)` (DESIGN.md §12).
//!
//! The cycle-accurate simulator ([`simulate_encoder_m`]) walks the FSM
//! schedule block by block; every scheduling decision that wants a
//! latency estimate used to either re-run it (per-length memo in the
//! old `FunctionalEngine::accel_cycles`) or fall back to trailing
//! wall-clock means (autoscaler, wire admission).  [`CostModel`] closes
//! that gap with a *closed form* that is exact, not approximate:
//!
//! Every per-layer block count is piecewise-linear in the live length
//! `m`.  The matmul tile counts `ceil(m/array_rows)`, the softmax waves
//! `ceil(m/softmax_units)`, and the per-head tile/readout terms
//! (`ceil(m/dh)`, `min(m, dh)`) are each constant or linear between
//! consecutive multiples of their stride, so between two adjacent cut
//! points drawn from multiples of `{array_rows, softmax_units, dh}` the
//! whole non-LayerNorm layer cost is `C + S·m` with integer `C`, `S`.
//! The only non-linear term is LayerNorm's pipelined row stream,
//! `floor(m·row_cycles / pipeline_stages)`, which the model carries
//! explicitly.  [`CostModel::build`] therefore anchors each segment
//! with *two* simulator runs (its endpoints, on a 1-layer copy of the
//! geometry), recovers the exact integer slope, verifies a midpoint per
//! multi-point segment against the simulator, and tabulates per-layer
//! cycles for every `m` in `1..=geo.m`.  Layer totals are purely
//! additive (each FSM joins its predecessor), so the stack cost is
//! `layers × per_layer(m)` — asserted by the simulator's own
//! `layers_scale_linearly` test.
//!
//! Worst-case sqrt timing note: `simulate_encoder_m(.., None)` charges
//! the LayerNorm sqrt its worst-case iteration count regardless of
//! `worst_case_sqrt` (the flag only selects whether *live* data-
//! dependent counts are honored), so one build predicts the `None`
//! simulation path for any configuration.  Data-dependent timing
//! (`worst_case_sqrt: false` with live iteration counts) remains the
//! simulator's job.
//!
//! Consumers (the single source of predicted cost, ISSUE 8): the
//! `Batcher`'s deficit-round-robin ledger charges
//! [`CostModel::predict_cycles`] per request, the autoscaler scores
//! backlog in predicted work (`coordinator::autoscale`), the wire mux's
//! SLO admission estimate prices the queue per request, and the
//! `synthesis::design_space` autotuner ranks candidate `HwConfig`s by
//! [`CostModel::full_ms`].

use super::encoder::simulate_encoder_m;
use super::units;
use super::HwConfig;
use crate::model::Geometry;
use crate::quant::layernorm::ISQRT_MAX_ITERS;

/// One linear segment of the per-layer closed form: for
/// `m in lo..=hi`, the non-LayerNorm cycles are `g_lo + slope·(m-lo)`.
/// Kept for introspection/tests; prediction reads the dense table.
#[derive(Clone, Copy, Debug)]
pub struct Segment {
    pub lo: usize,
    pub hi: usize,
    /// non-LayerNorm per-layer cycles at `m = lo`
    pub g_lo: u64,
    /// exact integer cycles per extra row within the segment
    pub slope: u64,
}

/// Closed-form predicted accelerator cycles for one `(geometry,
/// hardware)` pair, built once per model from a handful of anchor
/// simulations.  `predict_cycles` is O(1) per call (a table read) and
/// agrees with `simulate_encoder_m(hw, geo, m, None)` *exactly* at
/// every length `1..=geo.m` — validated at build time, property-tested
/// in `rust/tests/cost_model.rs`.
#[derive(Clone, Debug)]
pub struct CostModel {
    hw: HwConfig,
    geo: Geometry,
    /// per-layer total cycles at `m = index + 1` (dense, exact)
    per_layer: Vec<u64>,
    segments: Vec<Segment>,
    /// simulator invocations spent building + validating the model
    anchor_sims: usize,
}

impl CostModel {
    /// Build the model: validate the configuration, derive the segment
    /// cut points, anchor-simulate each segment's endpoints on a
    /// 1-layer copy of the geometry, interpolate, and verify a midpoint
    /// per multi-point segment against the simulator.  Errs on a
    /// configuration the simulator itself cannot run (zero softmax
    /// units / layernorm lanes / pipeline stages) or on any residual
    /// between the closed form and the simulator.
    pub fn build(hw: &HwConfig, geo: &Geometry) -> Result<CostModel, String> {
        hw.validate(geo)?;
        if hw.softmax_units == 0 {
            return Err("softmax_units must be positive".into());
        }
        if hw.layernorm_lanes == 0 {
            return Err("layernorm_lanes must be positive".into());
        }
        if hw.pipeline_stages == 0 {
            return Err("pipeline_stages must be positive".into());
        }
        if geo.m == 0 || geo.layers == 0 || geo.heads == 0 || geo.d == 0 {
            return Err(format!("degenerate geometry {geo:?}"));
        }
        let one_layer = Geometry { layers: 1, ..*geo };
        // Worst-case LayerNorm row cost — constant in m; the simulator
        // charges `floor(m·rc/ps)` per LayerNorm pass (two per layer).
        let rc = units::layernorm_row_cycles(hw, geo.d, ISQRT_MAX_ITERS);
        let ps = hw.pipeline_stages;
        let ln_part = |m: usize| 2 * (m as u64 * rc / ps);
        let mut anchor_sims = 0usize;
        let mut sim = |m: usize| -> u64 {
            anchor_sims += 1;
            simulate_encoder_m(hw, &one_layer, m, None).total_cycles
        };

        // Cut points: the non-LN cost is linear between consecutive
        // multiples of the array height, the softmax unit count, and
        // the head dimension.
        let mut cuts = std::collections::BTreeSet::new();
        for stride in [hw.array_rows, hw.softmax_units, geo.dh().max(1)] {
            let mut v = stride;
            while v < geo.m {
                cuts.insert(v);
                v += stride;
            }
        }
        cuts.insert(geo.m);

        let mut per_layer = vec![0u64; geo.m];
        let mut segments = Vec::with_capacity(cuts.len());
        let mut lo = 1usize;
        for &hi in &cuts {
            let g_lo = sim(lo) - ln_part(lo);
            let slope = if hi > lo {
                let g_hi = sim(hi) - ln_part(hi);
                let span = (hi - lo) as u64;
                let rise = g_hi
                    .checked_sub(g_lo)
                    .ok_or_else(|| format!("non-monotone segment {lo}..={hi}"))?;
                if rise % span != 0 {
                    return Err(format!(
                        "segment {lo}..={hi} is not linear: rise {rise} over span {span}"
                    ));
                }
                rise / span
            } else {
                0
            };
            for m in lo..=hi {
                per_layer[m - 1] = g_lo + slope * (m - lo) as u64 + ln_part(m);
            }
            if hi - lo >= 2 {
                let mid = lo + (hi - lo) / 2;
                let want = sim(mid);
                if per_layer[mid - 1] != want {
                    return Err(format!(
                        "closed form diverged from simulator at m={mid}: \
                         predicted {} vs simulated {want}",
                        per_layer[mid - 1]
                    ));
                }
            }
            segments.push(Segment { lo, hi, g_lo, slope });
            lo = hi + 1;
        }

        Ok(CostModel { hw: *hw, geo: *geo, per_layer, segments, anchor_sims })
    }

    /// Predicted accelerator cycles for a request of `m_eff` live
    /// tokens — O(1), exact against `simulate_encoder_m(.., None)`.
    /// Out-of-range lengths clamp into `1..=geo.m` (the serveable
    /// range; the engine rejects them before execution anyway).
    pub fn predict_cycles(&self, m_eff: usize) -> u64 {
        let m = m_eff.clamp(1, self.geo.m);
        self.per_layer[m - 1] * self.geo.layers as u64
    }

    /// Predicted accelerator milliseconds (virtual time at the modeled
    /// clock) for a request of `m_eff` live tokens.
    pub fn predict_ms(&self, m_eff: usize) -> f64 {
        self.hw.cycles_to_ms(self.predict_cycles(m_eff))
    }

    /// Predicted cycles of a full-length (`m = geo.m`) inference.
    pub fn full_cycles(&self) -> u64 {
        self.predict_cycles(self.geo.m)
    }

    /// Predicted milliseconds of a full-length inference.
    pub fn full_ms(&self) -> f64 {
        self.predict_ms(self.geo.m)
    }

    /// Virtual milliseconds of one predicted cycle — the cold-start
    /// prior the autoscaler/admission paths use before any wall-clock
    /// calibration sample exists.
    pub fn ms_per_cycle(&self) -> f64 {
        self.hw.cycles_to_ms(1)
    }

    pub fn hw(&self) -> &HwConfig {
        &self.hw
    }

    pub fn geometry(&self) -> &Geometry {
        &self.geo
    }

    /// The linear segments of the per-layer closed form.
    pub fn segments(&self) -> &[Segment] {
        &self.segments
    }

    /// Simulator invocations spent building + validating this model (2
    /// per segment plus one midpoint check per multi-point segment —
    /// "a handful", not one per length).
    pub fn anchor_sims(&self) -> usize {
        self.anchor_sims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_for_every_length_of_every_preset() {
        for name in Geometry::PRESET_NAMES {
            let geo = Geometry::preset(name).unwrap();
            let hw = HwConfig::sized_to(&geo);
            let cm = CostModel::build(&hw, &geo).unwrap();
            for m in 1..=geo.m {
                assert_eq!(
                    cm.predict_cycles(m),
                    simulate_encoder_m(&hw, &geo, m, None).total_cycles,
                    "{name} m={m}"
                );
            }
            assert!(
                cm.anchor_sims() < geo.m,
                "{name}: {} anchor sims is not 'a handful' for m={}",
                cm.anchor_sims(),
                geo.m
            );
        }
    }

    #[test]
    fn paper_hw_on_roberta_base_is_exact_and_cheap_to_build() {
        let geo = Geometry::preset("roberta_base").unwrap();
        let hw = HwConfig::paper();
        let cm = CostModel::build(&hw, &geo).unwrap();
        for m in [1usize, 2, 63, 64, 65, 128, 200, 256] {
            assert_eq!(
                cm.predict_cycles(m),
                simulate_encoder_m(&hw, &geo, m, None).total_cycles,
                "m={m}"
            );
        }
        // cuts at multiples of dh=64 -> 4 segments, ~3 sims each
        assert!(cm.anchor_sims() <= 16, "{} sims", cm.anchor_sims());
    }

    #[test]
    fn clamps_out_of_range_lengths() {
        let geo = Geometry::preset("tiny").unwrap();
        let cm = CostModel::build(&HwConfig::sized_to(&geo), &geo).unwrap();
        assert_eq!(cm.predict_cycles(0), cm.predict_cycles(1));
        assert_eq!(cm.predict_cycles(geo.m + 100), cm.full_cycles());
        assert!(cm.full_ms() > 0.0);
        assert!(cm.predict_ms(1) < cm.full_ms());
    }

    #[test]
    fn rejects_unsimulatable_configs() {
        let geo = Geometry::preset("tiny").unwrap();
        let mut hw = HwConfig::sized_to(&geo);
        hw.softmax_units = 0;
        assert!(CostModel::build(&hw, &geo).is_err());
        let mut hw = HwConfig::sized_to(&geo);
        hw.array_rows = 0;
        assert!(CostModel::build(&hw, &geo).is_err());
        let mut hw = HwConfig::sized_to(&geo);
        hw.pipeline_stages = 0;
        assert!(CostModel::build(&hw, &geo).is_err());
    }

    #[test]
    fn worst_case_flag_does_not_change_the_none_path() {
        // sqrt_iters = None simulates worst-case counts either way, so
        // one CostModel serves both flag settings.
        let geo = Geometry::preset("small").unwrap();
        let hw_wc = HwConfig::sized_to(&geo);
        let hw_dd = HwConfig { worst_case_sqrt: false, ..hw_wc };
        let a = CostModel::build(&hw_wc, &geo).unwrap();
        let b = CostModel::build(&hw_dd, &geo).unwrap();
        for m in 1..=geo.m {
            assert_eq!(a.predict_cycles(m), b.predict_cycles(m), "m={m}");
        }
    }
}
