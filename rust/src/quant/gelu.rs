//! Integer GELU unit (paper §III-H, Fig. 14): clipped second-order
//! polynomial erf with sign handling, then `q * (erf + q_one)`.

/// I-BERT erf polynomial coefficients on [0, -b]: a(x+b)^2 + c.
pub const ERF_A: f64 = -0.2888;
pub const ERF_B: f64 = -1.769;
pub const ERF_C: f64 = 1.0;

/// Design-time constants of the GELU unit (the paper's q5..q8).
#[derive(Clone, Copy, Debug)]
pub struct GeluConsts {
    pub s_in: f64,
    pub q_b: i64,
    pub q_c: i64,
    pub q_one: i64,
}

impl GeluConsts {
    pub fn design(s_in: f64) -> GeluConsts {
        assert!(s_in > 0.0, "gelu input scale must be positive");
        let s_er = s_in / std::f64::consts::SQRT_2;
        let s_erf = ERF_A * s_er * s_er; // negative
        GeluConsts {
            s_in,
            q_b: (ERF_B / s_er).floor() as i64,           // negative
            q_c: (ERF_C / (ERF_A * s_er * s_er)).floor() as i64, // negative
            q_one: (1.0 / s_erf).floor() as i64,          // negative
        }
    }

    /// Scale of the erf estimate (negative: erf's `a` folds into it).
    pub fn s_erf(&self) -> f64 {
        let s_er = self.s_in / std::f64::consts::SQRT_2;
        ERF_A * s_er * s_er
    }

    /// Scale of the integer GELU output: s_in * s_erf / 2 (negative).
    pub fn s_out(&self) -> f64 {
        self.s_in * self.s_erf() / 2.0
    }
}

/// Signed polynomial erf estimate (INT64, scale `s_erf`).
#[inline]
pub fn i_erf(q: i64, c: &GeluConsts) -> i64 {
    let sgn = q.signum();
    let qabs = q.abs().min(-c.q_b);
    let t = qabs + c.q_b; // in [q_b, 0]
    sgn * (t * t + c.q_c)
}

/// Integer GELU: full-width product at scale `c.s_out()` (negative scale;
/// the downstream Requantization multiplies by the signed constant -b).
#[inline]
pub fn i_gelu(q: i64, c: &GeluConsts) -> i64 {
    q * (i_erf(q, c) + c.q_one)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn erf64(x: f64) -> f64 {
        // Abramowitz–Stegun 7.1.26 (|err| < 1.5e-7) for test reference
        let sign = x.signum();
        let x = x.abs();
        let t = 1.0 / (1.0 + 0.3275911 * x);
        let y = 1.0
            - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736)
                * t
                + 0.254829592)
                * t
                * (-x * x).exp();
        sign * y
    }

    #[test]
    fn gelu_zero_is_zero() {
        let c = GeluConsts::design(0.02);
        assert_eq!(i_gelu(0, &c), 0);
    }

    #[test]
    fn gelu_tracks_float_reference() {
        let c = GeluConsts::design(0.02);
        for q in (-300..=300).step_by(7) {
            let x = q as f64 * 0.02;
            let want = x * 0.5 * (1.0 + erf64(x / std::f64::consts::SQRT_2));
            let got = i_gelu(q, &c) as f64 * c.s_out();
            assert!((got - want).abs() < 0.05, "q={q}: {got} vs {want}");
        }
    }

    #[test]
    fn gelu_asymptotes() {
        let c = GeluConsts::design(0.05);
        let big = i_gelu(4000, &c) as f64 * c.s_out();
        let neg = i_gelu(-4000, &c) as f64 * c.s_out();
        assert!((big - 200.0).abs() < 0.5, "{big}");
        assert!(neg.abs() < 0.5, "{neg}");
    }

    #[test]
    fn erf_is_odd_and_clipped() {
        let c = GeluConsts::design(0.02);
        for q in [1, 5, 100, 10_000] {
            assert_eq!(i_erf(q, &c), -i_erf(-q, &c));
        }
        // saturates past the clip point
        assert_eq!(i_erf(100_000, &c), i_erf(200_000, &c));
    }

    #[test]
    fn design_constants_negative() {
        let c = GeluConsts::design(0.0177);
        assert!(c.q_b < 0 && c.q_c < 0 && c.q_one < 0);
    }
}
