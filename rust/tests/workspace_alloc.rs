//! Counting-allocator proofs of the zero-allocation hot paths: after
//! Workspace warm-up, `layer_forward_ws` and `encoder_forward_ws`
//! never touch the heap (DESIGN.md §6) — and after ring/scratch
//! warm-up, the `SWWIRE1` wire decode-and-encode loop doesn't either
//! (DESIGN.md §11).
//!
//! This test binary installs its own `#[global_allocator]`, so it must
//! stay a dedicated integration-test target (one allocator per binary).
//! Allocation events are counted per-thread to stay immune to anything
//! the test harness does on other threads.  Setup (weight stacks,
//! activation streams, encoded frame streams) comes before the
//! measured window.

mod common;

use common::{random_acts, synthetic_layers};
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use swifttron::model::Geometry;
use swifttron::sim::functional::{encoder_forward_ws, layer_forward_ws, Workspace};
use swifttron::util::rng::Rng;
use swifttron::wire::{encode, DecodeEvent, FrameDecoder, RingBuf};

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

fn thread_allocs() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

struct CountingAlloc;

impl CountingAlloc {
    fn bump() {
        // try_with: never panic inside the allocator (TLS teardown)
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        CountingAlloc::bump();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        CountingAlloc::bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        CountingAlloc::bump();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn forward_pass_is_allocation_free_after_warmup() {
    // tiny shapes stay below PAR_MIN_MACS, so every contraction runs the
    // serial kernel — no scoped-thread spawns on this path either
    let geo = Geometry::new(16, 2, 8, 32, 2);
    let mut rng = Rng::new(0x5EED);
    let layers = synthetic_layers(&mut rng, &geo);
    let (w, c) = &layers[0];
    let x = random_acts(&mut rng, geo.m * geo.d);

    let mut ws = Workspace::new(&geo);
    let mut out = vec![0i32; geo.m * geo.d];
    let mut iters: Vec<u32> = Vec::with_capacity(2 * geo.m * geo.layers);

    // warm-up: touches every arena buffer and sizes `iters`
    layer_forward_ws(&x, w, c, &geo, geo.m, &mut ws, &mut out, &mut iters);
    iters.clear();
    encoder_forward_ws(&x, &layers, &geo, geo.m, &mut ws, &mut out, &mut iters);

    let before = thread_allocs();
    for _ in 0..16 {
        iters.clear();
        layer_forward_ws(&x, w, c, &geo, geo.m, &mut ws, &mut out, &mut iters);
    }
    // short live lengths over the same warm arena
    for m_eff in [1usize, 3, geo.m / 2] {
        iters.clear();
        layer_forward_ws(
            &x[..m_eff * geo.d],
            w,
            c,
            &geo,
            m_eff,
            &mut ws,
            &mut out[..m_eff * geo.d],
            &mut iters,
        );
    }
    // and the full multi-layer stack
    for _ in 0..4 {
        iters.clear();
        encoder_forward_ws(&x, &layers, &geo, geo.m, &mut ws, &mut out, &mut iters);
    }
    let delta = thread_allocs() - before;
    assert_eq!(
        delta, 0,
        "hot path allocated {delta} times after Workspace warm-up"
    );
}

#[test]
fn wire_decode_and_encode_are_allocation_free_after_warmup() {
    // setup (allocates freely): a pipelined stream of request frames
    // of mixed model-name and token lengths
    let tokens: Vec<i32> = (0..48).collect();
    let mut stream = Vec::new();
    for id in 0..64u64 {
        let model = if id % 3 == 0 { "" } else { "deit_small" };
        stream.extend_from_slice(
            &encode_request_bytes(id, model, &tokens[..(id as usize % tokens.len()).max(1)]),
        );
    }

    let mut ring = RingBuf::new(256); // smaller than the stream: exercises compaction
    let mut dec = FrameDecoder::default();
    let mut scratch: Vec<i32> = Vec::new();
    let mut out: Vec<u8> = Vec::new();
    let logits = [7i64, -7, 9, -9];

    // warm-up pass sizes the scratch token buffer and the output frame
    // buffer to the largest request/response in the stream
    run_wire_loop(&stream, &mut ring, &mut dec, &mut scratch, &mut out, &logits);

    let before = thread_allocs();
    for _ in 0..8 {
        let n = run_wire_loop(&stream, &mut ring, &mut dec, &mut scratch, &mut out, &logits);
        assert_eq!(n, 64, "every frame decodes on every pass");
    }
    let delta = thread_allocs() - before;
    assert_eq!(
        delta, 0,
        "wire decode/encode loop allocated {delta} times after warm-up"
    );
}

fn encode_request_bytes(id: u64, model: &str, tokens: &[i32]) -> Vec<u8> {
    let mut buf = Vec::new();
    encode::encode_request(&mut buf, id, model, tokens);
    buf
}

/// Feed `stream` through the ring in socket-sized chunks, decode every
/// frame in place, collect its tokens into `scratch`, and encode an
/// `Ok` reply into `out` — the mux's per-request data path, minus the
/// sockets.  Returns the number of request frames decoded.
fn run_wire_loop(
    stream: &[u8],
    ring: &mut RingBuf,
    dec: &mut FrameDecoder,
    scratch: &mut Vec<i32>,
    out: &mut Vec<u8>,
    logits: &[i64],
) -> usize {
    let mut fed = 0;
    let mut decoded = 0;
    while fed < stream.len() || !ring.is_empty() {
        fed += ring.fill_from(&stream[fed..]);
        loop {
            let (n, ev) = dec.pull(ring.readable());
            match ev {
                Some(DecodeEvent::Request(r)) => {
                    r.read_tokens_into(scratch);
                    assert_eq!(scratch.len(), r.token_count());
                    out.clear();
                    encode::encode_ok(out, r.id, 0, 1, logits, 0.5, 100.0);
                    decoded += 1;
                }
                Some(other) => panic!("unexpected event: {other:?}"),
                None => {}
            }
            if n == 0 {
                break;
            }
            ring.consume(n);
        }
    }
    decoded
}
