//! `artifacts/manifest.json` reader: geometry + every design-time constant
//! the compile path fixed (paper §III-A: scales are frozen per layer).

use super::Geometry;
use crate::quant::{Dyadic, GeluConsts, LayerNormConsts, SoftmaxConsts};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One encoder layer's complete integer design (mirrors
/// `python/compile/aot.py::layer_json`).
#[derive(Clone, Debug)]
pub struct LayerConsts {
    pub dy_q: Dyadic,
    pub dy_k: Dyadic,
    pub dy_v: Dyadic,
    pub dy_scale: Dyadic,
    pub dy_ctx: Dyadic,
    pub dy_res1: Dyadic,
    pub dy_ln1: Dyadic,
    pub dy_gelu: Dyadic,
    pub dy_res2: Dyadic,
    pub dy_ln2: Dyadic,
    pub softmax: SoftmaxConsts,
    pub gelu: GeluConsts,
    pub ln1: LayerNormConsts,
    pub ln2: LayerNormConsts,
    pub scales: BTreeMap<String, f64>,
}

#[derive(Clone, Debug)]
pub struct Preset {
    pub name: String,
    pub geometry: Geometry,
    /// artifact kind -> file name, e.g. "int8" -> "tiny_int8.hlo.txt"
    pub artifacts: BTreeMap<String, String>,
    pub weights_blob: Option<String>,
    pub s_in: Option<f64>,
    pub s_out: Option<f64>,
    pub s_w_head: Option<f64>,
    pub float_test_accuracy: Option<f64>,
    pub layers: Vec<LayerConsts>,
}

pub struct Manifest {
    pub dir: PathBuf,
    pub presets: BTreeMap<String, Preset>,
}

fn dy(v: &Json) -> Result<Dyadic, String> {
    Ok(Dyadic {
        b: v.req("b")?.as_i64().ok_or("dyadic b")?,
        c: v.req("c")?.as_i64().ok_or("dyadic c")? as u32,
    })
}

fn layer(v: &Json) -> Result<LayerConsts, String> {
    let sm = v.req("softmax")?;
    let ge = v.req("gelu")?;
    let ln1 = v.req("ln1")?;
    let ln2 = v.req("ln2")?;
    let f = |j: &Json, k: &str| -> Result<f64, String> {
        j.req(k)?.as_f64().ok_or_else(|| format!("{k} not a number"))
    };
    let i = |j: &Json, k: &str| -> Result<i64, String> {
        j.req(k)?.as_i64().ok_or_else(|| format!("{k} not an int"))
    };
    let mut scales = BTreeMap::new();
    if let Some(obj) = v.get("scales").and_then(|s| s.as_obj()) {
        for (k, val) in obj {
            scales.insert(k.clone(), val.as_f64().unwrap_or(f64::NAN));
        }
    }
    Ok(LayerConsts {
        dy_q: dy(v.req("dy_q")?)?,
        dy_k: dy(v.req("dy_k")?)?,
        dy_v: dy(v.req("dy_v")?)?,
        dy_scale: dy(v.req("dy_scale")?)?,
        dy_ctx: dy(v.req("dy_ctx")?)?,
        dy_res1: dy(v.req("dy_res1")?)?,
        dy_ln1: dy(v.req("dy_ln1")?)?,
        dy_gelu: dy(v.req("dy_gelu")?)?,
        dy_res2: dy(v.req("dy_res2")?)?,
        dy_ln2: dy(v.req("dy_ln2")?)?,
        softmax: SoftmaxConsts {
            s_in: f(sm, "s_in")?,
            q_ln2: i(sm, "q_ln2")?,
            q_b: i(sm, "q_b")?,
            q_c: i(sm, "q_c")?,
        },
        gelu: GeluConsts {
            s_in: f(ge, "s_in")?,
            q_b: i(ge, "q_b")?,
            q_c: i(ge, "q_c")?,
            q_one: i(ge, "q_one")?,
        },
        ln1: LayerNormConsts {
            s_in: f(ln1, "s_in")?,
            s_gamma: f(ln1, "s_gamma")?,
            d: i(ln1, "d")? as usize,
        },
        ln2: LayerNormConsts {
            s_in: f(ln2, "s_in")?,
            s_gamma: f(ln2, "s_gamma")?,
            d: i(ln2, "d")? as usize,
        },
        scales,
    })
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path)
            .map_err(|e| format!("read {}: {e} — run `make artifacts` first", path.display()))?;
        let root = Json::parse(&src)?;
        let mut presets = BTreeMap::new();
        for (name, p) in root.req("presets")?.as_obj().ok_or("presets")? {
            let g = p.req("geometry")?;
            let gi = |k: &str| -> Result<usize, String> {
                Ok(g.req(k)?.as_i64().ok_or("geom int")? as usize)
            };
            let geometry = Geometry::new(
                gi("d")?,
                gi("heads")?,
                gi("m")?,
                gi("d_ff")?,
                gi("layers")?,
            );
            let mut artifacts = BTreeMap::new();
            if let Some(a) = p.get("artifacts").and_then(|a| a.as_obj()) {
                for (k, v) in a {
                    artifacts.insert(k.clone(), v.as_str().unwrap_or("").to_string());
                }
            }
            let layers = match p.get("layers").and_then(|l| l.as_arr()) {
                Some(ls) => ls.iter().map(layer).collect::<Result<Vec<_>, _>>()?,
                None => vec![],
            };
            presets.insert(
                name.clone(),
                Preset {
                    name: name.clone(),
                    geometry,
                    artifacts,
                    weights_blob: p
                        .get("weights_blob")
                        .and_then(|v| v.as_str())
                        .map(String::from),
                    s_in: p.get("s_in").and_then(|v| v.as_f64()),
                    s_out: p.get("s_out").and_then(|v| v.as_f64()),
                    s_w_head: p.get("s_w_head").and_then(|v| v.as_f64()),
                    float_test_accuracy: p
                        .get("float_test_accuracy")
                        .and_then(|v| v.as_f64()),
                    layers,
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), presets })
    }

    /// Default artifacts directory: `$SWIFTTRON_ARTIFACTS` or `artifacts/`
    /// relative to the workspace root.
    pub fn default_dir() -> PathBuf {
        if let Ok(p) = std::env::var("SWIFTTRON_ARTIFACTS") {
            return PathBuf::from(p);
        }
        // workspace root = two levels above this crate's src at build time;
        // at run time prefer the current directory.
        let cwd = PathBuf::from("artifacts");
        if cwd.exists() {
            return cwd;
        }
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn preset(&self, name: &str) -> Result<&Preset, String> {
        self.presets
            .get(name)
            .ok_or_else(|| format!("preset {name:?} not in manifest"))
    }

    pub fn artifact_path(&self, preset: &str, kind: &str) -> Result<PathBuf, String> {
        let p = self.preset(preset)?;
        let f = p
            .artifacts
            .get(kind)
            .ok_or_else(|| format!("preset {preset}: no {kind:?} artifact"))?;
        Ok(self.dir.join(f))
    }

    pub fn blob_prefix(&self, preset: &str) -> Result<PathBuf, String> {
        let p = self.preset(preset)?;
        let b = p
            .weights_blob
            .as_ref()
            .ok_or_else(|| format!("preset {preset}: no weights blob"))?;
        Ok(self.dir.join(b))
    }
}
