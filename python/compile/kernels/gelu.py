"""Pallas integer GELU kernel (paper Fig. 14).

gelu(x) = x * (erf(x/sqrt(2)) + 1) / 2; the erf is a clipped 2nd-order
polynomial with sign handling.  Elementwise over VMEM tiles; q5..q8 are
design-time constants.  Output is INT32 at scale s_in * s_erf / 2 —
callers follow with a Requantization block, as in the FFN (paper Fig. 13).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..intops import GeluConsts


def _gelu_kernel(q_ref, o_ref, *, q_b: int, q_c: int, q_one: int):
    q = q_ref[...].astype(jnp.int64)
    sgn = jnp.sign(q)
    qabs = jnp.minimum(jnp.abs(q), jnp.int64(-q_b))
    t = qabs + jnp.int64(q_b)
    erf = sgn * (t * t + jnp.int64(q_c))
    out = q * (erf + jnp.int64(q_one))
    o_ref[...] = out


def _pick_block(dim: int, preferred: int) -> int:
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("consts", "bm", "bn"))
def i_gelu(q, consts: GeluConsts, *, bm: int = 256, bn: int = 512):
    """Integer GELU of an INT32 (m, n) tensor; returns INT64 (full product)."""
    m, n = q.shape
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return pl.pallas_call(
        functools.partial(
            _gelu_kernel, q_b=consts.q_b, q_c=consts.q_c, q_one=consts.q_one
        ),
        grid=(m // bm, n // bn),
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int64),
        interpret=True,
    )(q)
