//! Fixed-size thread pool (no tokio offline): the coordinator's execution
//! substrate.  Work items are boxed closures on an MPMC channel built from
//! `std::sync::mpsc` + a mutex-guarded receiver; `scope`-style joining is
//! provided by [`ThreadPool::run_batch`].

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads executing boxed jobs.
pub struct ThreadPool {
    tx: Sender<Msg>,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

struct Shared {
    rx: Mutex<Receiver<Msg>>,
    in_flight: AtomicUsize,
    done: Condvar,
    done_lock: Mutex<()>,
    panics: AtomicUsize,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = channel();
        let shared = Arc::new(Shared {
            rx: Mutex::new(rx),
            in_flight: AtomicUsize::new(0),
            done: Condvar::new(),
            done_lock: Mutex::new(()),
            panics: AtomicUsize::new(0),
        });
        let workers = (0..threads)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("swifttron-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, shared, workers }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue one job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Block until every queued job has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.done_lock.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done.wait(guard).unwrap();
        }
    }

    /// Run a batch of jobs producing values, preserving input order.
    pub fn run_batch<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = jobs.len();
        let slots: Arc<Mutex<Vec<Option<T>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        for (i, job) in jobs.into_iter().enumerate() {
            let slots = Arc::clone(&slots);
            self.execute(move || {
                let v = job();
                slots.lock().unwrap()[i] = Some(v);
            });
        }
        self.wait_idle();
        Arc::try_unwrap(slots)
            .unwrap_or_else(|_| panic!("batch slots still shared"))
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|o| o.expect("job panicked — see panics()"))
            .collect()
    }

    /// Number of jobs that panicked since pool creation.
    pub fn panics(&self) -> usize {
        self.shared.panics.load(Ordering::SeqCst)
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let msg = {
            let rx = sh.rx.lock().unwrap();
            rx.recv()
        };
        match msg {
            Ok(Msg::Run(job)) => {
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    sh.panics.fetch_add(1, Ordering::SeqCst);
                }
                if sh.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
                    let _g = sh.done_lock.lock().unwrap();
                    sh.done.notify_all();
                }
            }
            Ok(Msg::Shutdown) | Err(_) => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn batch_preserves_order() {
        let pool = ThreadPool::new(3);
        let jobs: Vec<_> = (0..50).map(|i| move || i * 2).collect();
        let out = pool.run_batch(jobs);
        assert_eq!(out, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn panicking_job_is_counted_and_pool_survives() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        pool.wait_idle();
        assert_eq!(pool.panics(), 1);
        let out = pool.run_batch(vec![|| 7]);
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn wait_idle_with_no_jobs_returns() {
        ThreadPool::new(1).wait_idle();
    }
}
