//! INT8 x INT8 -> INT32 matrix multiplication (the MatMul block,
//! paper §III-B, Fig. 6) — the functional model the simulator and the
//! integer classifier head use.  Row-major `(m,k) @ (k,n) -> (m,n)`.
//!
//! Two execution strategies, bit-identical by construction:
//! * the serial kernels [`i_matmul`] / [`i_matmul_bt`], and
//! * row-tiled thread-parallel variants ([`i_matmul_tiled`] /
//!   [`i_matmul_bt_tiled`]) that split the *output rows* across scoped
//!   threads — each tile runs the serial kernel on a disjoint row band,
//!   so no accumulation order changes and the result is exactly the
//!   serial one (asserted by randomized tests below).
//!
//! [`i_matmul_par`] / [`i_matmul_bt_par`] auto-dispatch: contractions at
//! or above [`PAR_MIN_MACS`] multiply-accumulates go parallel, smaller
//! ones stay serial (thread spawn would dominate; EXPERIMENTS.md §Perf).
//!
//! All kernels are shape-agnostic in `m`: the variable-length forward
//! pass (DESIGN.md §6) calls them with the request's live row count
//! `m_eff`, never the padded geometry maximum, so both the work done
//! and the parallel-dispatch decision scale with the actual sequence.
//!
//! The epilogue-capable variants ([`i_matmul_epilogue`] and friends,
//! DESIGN.md §7) additionally fuse the INT32 -> INT8 requantization (or
//! the residual-alignment rescale) into each finished output row's
//! readout — the structure ITA and the FQ-BERT accelerator use at the
//! PE array boundary — instead of a separate full-tensor pass after the
//! kernel.  Both epilogues are elementwise, so the fused result is
//! bit-exact with kernel-then-pass by construction (and asserted on
//! randomized shapes below).

use super::dyadic::{requantize, rescale, Dyadic};
use crate::util::threadpool::{default_parallelism, tile_ranges};

/// One output row of the serial kernel: bias init, then the k-deep
/// multiply-accumulate sweep.  Shared by [`i_matmul`] and
/// [`i_matmul_epilogue`], so the fused path accumulates in exactly the
/// same order as the unfused one.
#[inline]
fn mac_row(xrow: &[i32], w: &[i32], bias: Option<&[i32]>, n: usize, orow: &mut [i32]) {
    // bias folds in at readout (paper: added when reading the output)
    match bias {
        Some(b) => orow.copy_from_slice(b),
        None => orow.fill(0),
    }
    for (kk, &xv) in xrow.iter().enumerate() {
        if xv == 0 {
            continue;
        }
        let wrow = &w[kk * n..(kk + 1) * n];
        // plain i32 multiply-accumulate: autovectorizes (an i64
        // widening here blocks SIMD); a row-blocked variant was tried
        // and reverted — W panels already hit in LLC at these sizes
        // (EXPERIMENTS.md §Perf).
        for (o, &wv) in orow.iter_mut().zip(wrow) {
            *o += xv * wv;
        }
    }
}

/// Per-row epilogue fused into a matmul's readout: maps each *finished*
/// INT32 accumulator row in place, as the row completes, instead of a
/// separate full-tensor pass after the kernel (DESIGN.md §7).  Both
/// variants are elementwise, so row-by-row application is bit-exact
/// with kernel-then-pass by construction.
#[derive(Clone, Copy, Debug)]
pub enum Epilogue {
    /// Saturating INT32 -> INT8 requantization ([`requantize`]) — the
    /// Q/K/V projection and attention-context readouts.
    Requant(Dyadic),
    /// Non-saturating dyadic rescale truncated to i32 ([`rescale`]
    /// `as i32`) — the residual-alignment readout of the output
    /// projection and FFN-out matmuls (paper §III-I).
    Rescale(Dyadic),
}

impl Epilogue {
    /// Apply to a finished accumulator slice, in place.  Elementwise,
    /// so any partitioning of the tensor (rows, tiles, the whole
    /// buffer) yields identical bits.
    #[inline]
    pub fn apply(&self, acc: &mut [i32]) {
        match *self {
            Epilogue::Requant(dy) => {
                for v in acc.iter_mut() {
                    *v = requantize(*v as i64, dy);
                }
            }
            Epilogue::Rescale(dy) => {
                for v in acc.iter_mut() {
                    *v = rescale(*v as i64, dy) as i32;
                }
            }
        }
    }
}

/// `out[m][n] = sum_k x[m][k]*w[k][n] (+ bias[n])`, INT32 accumulators.
/// Panics in debug builds if an accumulator leaves the INT32 range (the
/// hardware's accumulator width; paper-scale contractions cannot).
pub fn i_matmul(
    x: &[i32],
    w: &[i32],
    bias: Option<&[i32]>,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    assert_eq!(x.len(), m * k, "x shape");
    assert_eq!(w.len(), k * n, "w shape");
    assert_eq!(out.len(), m * n, "out shape");
    if let Some(b) = bias {
        assert_eq!(b.len(), n, "bias shape");
    }
    // INT8-range operands cannot overflow the INT32 accumulator for the
    // paper's contractions (|x*w| <= 128*128, k <= 3072 => |acc| < 2^26
    // before bias) — same argument the hardware's accumulator width
    // rests on.  Debug builds verify the operand contract.
    debug_assert!(
        x.iter().all(|&v| (-128..=127).contains(&v)),
        "i_matmul operand outside INT8 range"
    );
    debug_assert!(k <= (i32::MAX as usize) / (128 * 128), "contraction too deep for INT32");
    for i in 0..m {
        mac_row(&x[i * k..(i + 1) * k], w, bias, n, &mut out[i * n..(i + 1) * n]);
    }
}

/// [`i_matmul`] with `epi` fused at each finished row's readout: `out`
/// holds the epilogue-mapped values, never the raw INT32 accumulators.
/// Bit-exact with running [`i_matmul`] and then applying `epi` over the
/// whole tensor (per-row accumulation order untouched; DESIGN.md §7).
#[allow(clippy::too_many_arguments)]
pub fn i_matmul_epilogue(
    x: &[i32],
    w: &[i32],
    bias: Option<&[i32]>,
    m: usize,
    k: usize,
    n: usize,
    epi: Epilogue,
    out: &mut [i32],
) {
    assert_eq!(x.len(), m * k, "x shape");
    assert_eq!(w.len(), k * n, "w shape");
    assert_eq!(out.len(), m * n, "out shape");
    if let Some(b) = bias {
        assert_eq!(b.len(), n, "bias shape");
    }
    debug_assert!(
        x.iter().all(|&v| (-128..=127).contains(&v)),
        "i_matmul_epilogue operand outside INT8 range"
    );
    debug_assert!(k <= (i32::MAX as usize) / (128 * 128), "contraction too deep for INT32");
    for i in 0..m {
        let orow = &mut out[i * n..(i + 1) * n];
        mac_row(&x[i * k..(i + 1) * k], w, bias, n, orow);
        epi.apply(orow);
    }
}

/// Transposed-B variant: `(m,k) @ (n,k)^T -> (m,n)` — the Attention
/// unit's Q.K^T, where K streams in row-major like Q.
pub fn i_matmul_bt(x: &[i32], w_t: &[i32], m: usize, k: usize, n: usize, out: &mut [i32]) {
    assert_eq!(x.len(), m * k);
    assert_eq!(w_t.len(), n * k);
    assert_eq!(out.len(), m * n);
    // Same operand contract as `i_matmul` — and on this kernel both
    // sides are *activations* (Q and K), so an upstream requantization
    // bug would silently mis-accumulate here without these checks.
    debug_assert!(
        x.iter().all(|&v| (-128..=127).contains(&v)),
        "i_matmul_bt x operand outside INT8 range"
    );
    debug_assert!(
        w_t.iter().all(|&v| (-128..=127).contains(&v)),
        "i_matmul_bt w_t operand outside INT8 range"
    );
    debug_assert!(k <= (i32::MAX as usize) / (128 * 128), "contraction too deep for INT32");
    for i in 0..m {
        let xrow = &x[i * k..(i + 1) * k];
        for j in 0..n {
            let wrow = &w_t[j * k..(j + 1) * k];
            let mut acc: i32 = 0;
            for (xv, wv) in xrow.iter().zip(wrow) {
                acc += *xv * *wv;
            }
            out[i * n + j] = acc;
        }
    }
}

/// Minimum multiply-accumulate count for the parallel path to pay for
/// its scoped-thread spawns.  Below this (every tiny-preset contraction,
/// the classifier head) the serial kernel wins; at/above it (the
/// roberta-scale projections and FFN matmuls, ≥ ~2M MACs) row tiling
/// wins even on a few cores.  Swept in EXPERIMENTS.md §Perf.
pub const PAR_MIN_MACS: usize = 1 << 21;

/// Row-tiled parallel [`i_matmul`]: output rows are split into at most
/// `threads` balanced contiguous bands, each computed by the serial
/// kernel on its own scoped thread.  Bit-exact with [`i_matmul`] for
/// every input (the per-row accumulation order is untouched).
pub fn i_matmul_tiled(
    threads: usize,
    x: &[i32],
    w: &[i32],
    bias: Option<&[i32]>,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    assert_eq!(x.len(), m * k, "x shape");
    assert_eq!(w.len(), k * n, "w shape");
    assert_eq!(out.len(), m * n, "out shape");
    let tiles = tile_ranges(m, threads);
    if tiles.len() <= 1 {
        return i_matmul(x, w, bias, m, k, n, out);
    }
    std::thread::scope(|s| {
        let mut rem: &mut [i32] = out;
        for t in tiles {
            let rows = t.len();
            let (tile_out, rest) = std::mem::take(&mut rem).split_at_mut(rows * n);
            rem = rest;
            let x_tile = &x[t.start * k..t.end * k];
            s.spawn(move || i_matmul(x_tile, w, bias, rows, k, n, tile_out));
        }
    });
}

/// Row-tiled parallel [`i_matmul_bt`]; same tiling contract as
/// [`i_matmul_tiled`].
pub fn i_matmul_bt_tiled(
    threads: usize,
    x: &[i32],
    w_t: &[i32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    assert_eq!(x.len(), m * k);
    assert_eq!(w_t.len(), n * k);
    assert_eq!(out.len(), m * n);
    let tiles = tile_ranges(m, threads);
    if tiles.len() <= 1 {
        return i_matmul_bt(x, w_t, m, k, n, out);
    }
    std::thread::scope(|s| {
        let mut rem: &mut [i32] = out;
        for t in tiles {
            let rows = t.len();
            let (tile_out, rest) = std::mem::take(&mut rem).split_at_mut(rows * n);
            rem = rest;
            let x_tile = &x[t.start * k..t.end * k];
            s.spawn(move || i_matmul_bt(x_tile, w_t, rows, k, n, tile_out));
        }
    });
}

/// Row-tiled parallel [`i_matmul_epilogue`]; same tiling contract as
/// [`i_matmul_tiled`].  The epilogue runs inside each tile as its rows
/// finish, so no thread ever re-reads another tile's output.
#[allow(clippy::too_many_arguments)]
pub fn i_matmul_epilogue_tiled(
    threads: usize,
    x: &[i32],
    w: &[i32],
    bias: Option<&[i32]>,
    m: usize,
    k: usize,
    n: usize,
    epi: Epilogue,
    out: &mut [i32],
) {
    assert_eq!(x.len(), m * k, "x shape");
    assert_eq!(w.len(), k * n, "w shape");
    assert_eq!(out.len(), m * n, "out shape");
    let tiles = tile_ranges(m, threads);
    if tiles.len() <= 1 {
        return i_matmul_epilogue(x, w, bias, m, k, n, epi, out);
    }
    std::thread::scope(|s| {
        let mut rem: &mut [i32] = out;
        for t in tiles {
            let rows = t.len();
            let (tile_out, rest) = std::mem::take(&mut rem).split_at_mut(rows * n);
            rem = rest;
            let x_tile = &x[t.start * k..t.end * k];
            s.spawn(move || i_matmul_epilogue(x_tile, w, bias, rows, k, n, epi, tile_out));
        }
    });
}

/// Auto-dispatching [`i_matmul_epilogue`]; same [`PAR_MIN_MACS`]
/// threshold as [`i_matmul_par`].
#[allow(clippy::too_many_arguments)]
pub fn i_matmul_epilogue_par(
    x: &[i32],
    w: &[i32],
    bias: Option<&[i32]>,
    m: usize,
    k: usize,
    n: usize,
    epi: Epilogue,
    out: &mut [i32],
) {
    if m > 1 && m.saturating_mul(k).saturating_mul(n) >= PAR_MIN_MACS {
        i_matmul_epilogue_tiled(default_parallelism(), x, w, bias, m, k, n, epi, out)
    } else {
        i_matmul_epilogue(x, w, bias, m, k, n, epi, out)
    }
}

/// Auto-dispatching [`i_matmul`]: parallel at/above [`PAR_MIN_MACS`]
/// multiply-accumulates, serial below.
pub fn i_matmul_par(
    x: &[i32],
    w: &[i32],
    bias: Option<&[i32]>,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    if m > 1 && m.saturating_mul(k).saturating_mul(n) >= PAR_MIN_MACS {
        i_matmul_tiled(default_parallelism(), x, w, bias, m, k, n, out)
    } else {
        i_matmul(x, w, bias, m, k, n, out)
    }
}

/// Auto-dispatching [`i_matmul_bt`]; see [`i_matmul_par`].
pub fn i_matmul_bt_par(x: &[i32], w_t: &[i32], m: usize, k: usize, n: usize, out: &mut [i32]) {
    if m > 1 && m.saturating_mul(k).saturating_mul(n) >= PAR_MIN_MACS {
        i_matmul_bt_tiled(default_parallelism(), x, w_t, m, k, n, out)
    } else {
        i_matmul_bt(x, w_t, m, k, n, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity() {
        let m = 3;
        let x: Vec<i32> = (0..9).map(|v| v - 4).collect();
        let mut eye = vec![0i32; 9];
        for i in 0..m {
            eye[i * m + i] = 1;
        }
        let mut out = vec![0i32; 9];
        i_matmul(&x, &eye, None, m, m, m, &mut out);
        assert_eq!(out, x);
    }

    #[test]
    fn bias_added_per_column() {
        let x = vec![1, 0, 0, 1]; // I2
        let w = vec![5, 6, 7, 8];
        let bias = vec![100, 200];
        let mut out = vec![0i32; 4];
        i_matmul(&x, &w, Some(&bias), 2, 2, 2, &mut out);
        assert_eq!(out, vec![105, 206, 107, 208]);
    }

    #[test]
    fn bt_matches_plain_with_transpose() {
        let (m, k, n) = (4, 5, 3);
        let x: Vec<i32> = (0..m * k).map(|v| (v as i32 * 7 % 13) - 6).collect();
        let w: Vec<i32> = (0..k * n).map(|v| (v as i32 * 11 % 17) - 8).collect();
        let mut wt = vec![0i32; n * k];
        for kk in 0..k {
            for j in 0..n {
                wt[j * k + kk] = w[kk * n + j];
            }
        }
        let mut a = vec![0i32; m * n];
        let mut b = vec![0i32; m * n];
        i_matmul(&x, &w, None, m, k, n, &mut a);
        i_matmul_bt(&x, &wt, m, k, n, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn tiled_bit_exact_on_randomized_shapes() {
        // The acceptance contract of the parallel path: parallel tiled
        // output == serial output, across random shapes, random INT8
        // operands, with and without bias, for every thread count
        // (including counts exceeding the row count).
        let mut rng = crate::util::rng::Rng::new(0x7117);
        for case in 0..60 {
            let m = 1 + rng.below(17) as usize;
            let k = 1 + rng.below(33) as usize;
            let n = 1 + rng.below(19) as usize;
            let threads = 1 + rng.below(6) as usize;
            let x: Vec<i32> = (0..m * k).map(|_| rng.range_i64(-128, 127) as i32).collect();
            let w: Vec<i32> = (0..k * n).map(|_| rng.range_i64(-128, 127) as i32).collect();
            let bias: Vec<i32> = (0..n).map(|_| rng.range_i64(-5000, 5000) as i32).collect();
            let b = if case % 2 == 0 { Some(&bias[..]) } else { None };

            let mut serial = vec![0i32; m * n];
            let mut tiled = vec![0i32; m * n];
            i_matmul(&x, &w, b, m, k, n, &mut serial);
            i_matmul_tiled(threads, &x, &w, b, m, k, n, &mut tiled);
            assert_eq!(serial, tiled, "m={m} k={k} n={n} threads={threads}");

            // transposed-B variant on the same operands
            let mut wt = vec![0i32; n * k];
            for kk in 0..k {
                for j in 0..n {
                    wt[j * k + kk] = w[kk * n + j];
                }
            }
            let mut serial_bt = vec![0i32; m * n];
            let mut tiled_bt = vec![0i32; m * n];
            i_matmul_bt(&x, &wt, m, k, n, &mut serial_bt);
            i_matmul_bt_tiled(threads, &x, &wt, m, k, n, &mut tiled_bt);
            assert_eq!(serial_bt, tiled_bt, "bt m={m} k={k} n={n} threads={threads}");
        }
    }

    #[test]
    fn par_auto_dispatch_bit_exact_above_threshold() {
        // 128 * 130 * 128 = 2_129_920 MACs >= PAR_MIN_MACS: the _par entry
        // point takes the tiled path and must still match the serial kernel.
        let (m, k, n) = (128, 130, 128);
        assert!(m * k * n >= PAR_MIN_MACS);
        let mut rng = crate::util::rng::Rng::new(11);
        let x: Vec<i32> = (0..m * k).map(|_| rng.range_i64(-128, 127) as i32).collect();
        let w: Vec<i32> = (0..k * n).map(|_| rng.range_i64(-128, 127) as i32).collect();
        let mut serial = vec![0i32; m * n];
        let mut par = vec![0i32; m * n];
        i_matmul(&x, &w, None, m, k, n, &mut serial);
        i_matmul_par(&x, &w, None, m, k, n, &mut par);
        assert_eq!(serial, par);
    }

    #[test]
    fn epilogue_fused_matches_kernel_then_pass() {
        // The acceptance contract of the fused path: for random shapes,
        // operands, scales, thread counts and both epilogue kinds, the
        // fused kernel (serial, tiled, auto-dispatching) equals the
        // unfused kernel followed by a whole-tensor epilogue pass.
        let mut rng = crate::util::rng::Rng::new(0xF05E);
        for case in 0..60 {
            let m = 1 + rng.below(17) as usize;
            let k = 1 + rng.below(33) as usize;
            let n = 1 + rng.below(19) as usize;
            let threads = 1 + rng.below(6) as usize;
            let x: Vec<i32> = (0..m * k).map(|_| rng.range_i64(-128, 127) as i32).collect();
            let w: Vec<i32> = (0..k * n).map(|_| rng.range_i64(-128, 127) as i32).collect();
            let bias: Vec<i32> = (0..n).map(|_| rng.range_i64(-5000, 5000) as i32).collect();
            let b = if case % 2 == 0 { Some(&bias[..]) } else { None };
            let dy = Dyadic::approx16(0.001 + rng.f64());
            for epi in [Epilogue::Requant(dy), Epilogue::Rescale(dy)] {
                // reference: kernel, then a separate full-tensor pass
                let mut want = vec![0i32; m * n];
                i_matmul(&x, &w, b, m, k, n, &mut want);
                epi.apply(&mut want);

                let mut fused = vec![0i32; m * n];
                i_matmul_epilogue(&x, &w, b, m, k, n, epi, &mut fused);
                assert_eq!(want, fused, "serial m={m} k={k} n={n} {epi:?}");

                let mut tiled = vec![0i32; m * n];
                i_matmul_epilogue_tiled(threads, &x, &w, b, m, k, n, epi, &mut tiled);
                assert_eq!(want, tiled, "tiled m={m} k={k} n={n} threads={threads} {epi:?}");

                let mut auto = vec![0i32; m * n];
                i_matmul_epilogue_par(&x, &w, b, m, k, n, epi, &mut auto);
                assert_eq!(want, auto, "par m={m} k={k} n={n} {epi:?}");
            }
        }
    }

    #[test]
    fn epilogue_requant_saturates_and_rescale_does_not() {
        // one row whose accumulator exceeds INT8 after scaling: Requant
        // clamps to the INT8 rails, Rescale passes the wide value through
        let x = vec![127i32; 16];
        let w = vec![127i32; 16];
        let dy = Dyadic { b: 1, c: 0 };
        let mut req = vec![0i32; 1];
        i_matmul_epilogue(&x, &w, None, 1, 16, 1, Epilogue::Requant(dy), &mut req);
        assert_eq!(req[0], 127);
        let mut res = vec![0i32; 1];
        i_matmul_epilogue(&x, &w, None, 1, 16, 1, Epilogue::Rescale(dy), &mut res);
        assert_eq!(res[0], 16 * 127 * 127);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "INT8 range")]
    fn bt_rejects_out_of_range_operands_in_debug() {
        // regression (ISSUE 3): the Q.K^T kernel must catch out-of-INT8
        // operands in debug builds instead of silently mis-accumulating
        let x = vec![300i32; 4];
        let wt = vec![1i32; 4];
        let mut out = vec![0i32; 4];
        i_matmul_bt(&x, &wt, 2, 2, 2, &mut out);
    }

    #[test]
    fn worst_case_int8_no_overflow_at_dff() {
        // k = 3072 (RoBERTa d_ff) at extreme INT8 operands stays in INT32
        let k = 3072;
        let x = vec![-128i32; k];
        let w = vec![-128i32; k];
        let mut out = vec![0i32; 1];
        i_matmul(&x, &w, None, 1, k, 1, &mut out);
        assert_eq!(out[0], (k as i32) * 128 * 128);
    }
}
