//! Counting-allocator proof of the zero-allocation hot path
//! (DESIGN.md §6): after Workspace warm-up, `layer_forward_ws` and
//! `encoder_forward_ws` never touch the heap — the whole per-request
//! working set lives in the resident arena.
//!
//! This test binary installs its own `#[global_allocator]`, so it must
//! stay a dedicated integration-test target (one allocator per binary).
//! Allocation events are counted per-thread to stay immune to anything
//! the test harness does on other threads.  Setup (weight stacks,
//! activation streams) comes from the shared fixture layer in
//! `tests/common` — fixtures run before the measured window.

mod common;

use common::{random_acts, synthetic_layers};
use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use swifttron::model::Geometry;
use swifttron::sim::functional::{encoder_forward_ws, layer_forward_ws, Workspace};
use swifttron::util::rng::Rng;

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

fn thread_allocs() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

struct CountingAlloc;

impl CountingAlloc {
    fn bump() {
        // try_with: never panic inside the allocator (TLS teardown)
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        CountingAlloc::bump();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        CountingAlloc::bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        CountingAlloc::bump();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn forward_pass_is_allocation_free_after_warmup() {
    // tiny shapes stay below PAR_MIN_MACS, so every contraction runs the
    // serial kernel — no scoped-thread spawns on this path either
    let geo = Geometry::new(16, 2, 8, 32, 2);
    let mut rng = Rng::new(0x5EED);
    let layers = synthetic_layers(&mut rng, &geo);
    let (w, c) = &layers[0];
    let x = random_acts(&mut rng, geo.m * geo.d);

    let mut ws = Workspace::new(&geo);
    let mut out = vec![0i32; geo.m * geo.d];
    let mut iters: Vec<u32> = Vec::with_capacity(2 * geo.m * geo.layers);

    // warm-up: touches every arena buffer and sizes `iters`
    layer_forward_ws(&x, w, c, &geo, geo.m, &mut ws, &mut out, &mut iters);
    iters.clear();
    encoder_forward_ws(&x, &layers, &geo, geo.m, &mut ws, &mut out, &mut iters);

    let before = thread_allocs();
    for _ in 0..16 {
        iters.clear();
        layer_forward_ws(&x, w, c, &geo, geo.m, &mut ws, &mut out, &mut iters);
    }
    // short live lengths over the same warm arena
    for m_eff in [1usize, 3, geo.m / 2] {
        iters.clear();
        layer_forward_ws(
            &x[..m_eff * geo.d],
            w,
            c,
            &geo,
            m_eff,
            &mut ws,
            &mut out[..m_eff * geo.d],
            &mut iters,
        );
    }
    // and the full multi-layer stack
    for _ in 0..4 {
        iters.clear();
        encoder_forward_ws(&x, &layers, &geo, geo.m, &mut ws, &mut out, &mut iters);
    }
    let delta = thread_allocs() - before;
    assert_eq!(
        delta, 0,
        "hot path allocated {delta} times after Workspace warm-up"
    );
}
