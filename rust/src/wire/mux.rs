//! Non-blocking connection multiplexer over the `SWWIRE1` protocol
//! (DESIGN.md §11).
//!
//! One accept thread feeds connections round-robin to `io_threads`
//! event-loop threads; each loop owns its connections outright (slab
//! of slots, no cross-thread connection state) and runs level-
//! triggered over `set_nonblocking` sockets — std only, no new
//! dependencies:
//!
//! ```text
//! tick per connection:
//!   flush  write buffer -> socket     (stop on WouldBlock)
//!   read   socket -> ring buffer      (stop on WouldBlock / ring full)
//!   parse  ring buffer:
//!     Detect  compare first bytes against the SWWIRE1 preamble
//!             (mismatch => legacy text mode; nothing consumed)
//!     Binary  zero-copy pull decode; per request:
//!               admission check -> Overloaded frame   (shed)
//!               else Router::submit_index, pending[router_id] = frame
//!     Text    split lines, parse_tokens, same admission/submit path
//!   ...but only while the write buffer is under its bound —
//!   a slow reader stops being parsed, its ring fills, the kernel
//!   window closes: backpressure instead of unbounded buffering.
//! park on the response channel when nothing progressed.
//! ```
//!
//! Responses arrive on a per-io-thread mpsc channel (each submit
//! clones the thread's sender) and complete **out of order**: the
//! pending map routes a router response id back to `(connection,
//! client frame id)`, so a fast model's replies overtake a slow
//! model's on the same connection — no head-of-line blocking and no
//! thread parked per in-flight request.
//!
//! Admission control: a frame for a model whose predicted queueing
//! delay (the autoscaler's own predicted-work signal,
//! [`Router::overload_delay_ms`] — the model's `CostModel`-priced
//! backlog over its active replicas, trailing means only for cost-less
//! custom groups) exceeds `shed_ratio · slo_ms` is answered
//! immediately with a typed `Overloaded` frame (JSON error line in
//! text mode) and never enters the queue.  Models without an SLO are
//! never shed.

use super::decode::{DecodeEvent, FrameDecoder, RingBuf};
use super::encode;
use super::frame::PREAMBLE;
use crate::coordinator::server::{parse_tokens, response_json};
use crate::coordinator::{Response, Router};
use crate::util::json::{obj, Json};
use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct MuxConfig {
    /// event-loop threads; connections are dealt round-robin
    pub io_threads: usize,
    /// global cap on open connections (typed `Busy` rejection past it)
    pub max_conns: usize,
    /// per-connection ring buffer (also bounds the largest admissible
    /// frame)
    pub read_buf: usize,
    /// per-connection write-buffer bound: past it the connection stops
    /// being parsed until the client drains responses (backpressure)
    pub write_buf: usize,
    /// shed when predicted delay exceeds `shed_ratio · slo_ms`
    pub shed_ratio: f64,
    /// service-time estimate before a model's first completion —
    /// consulted only for models without a `CostModel` (mirrors
    /// `AutoscalePolicy::default_service_ms`)
    pub default_service_ms: f64,
    /// idle park on the response channel when a tick makes no progress
    pub park: Duration,
}

impl Default for MuxConfig {
    fn default() -> MuxConfig {
        MuxConfig {
            io_threads: 2,
            max_conns: 4096,
            read_buf: 64 * 1024,
            write_buf: 256 * 1024,
            shed_ratio: 1.0,
            default_service_ms: 1.0,
            park: Duration::from_millis(1),
        }
    }
}

/// The running multiplexer: accept thread + io threads.  Dropping it
/// stops the threads too ([`shutdown`](MuxServer::shutdown) is the
/// explicit form).
pub struct MuxServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    io: Vec<JoinHandle<()>>,
}

impl MuxServer {
    /// Bind `addr` (port 0 for ephemeral) and start serving `router`.
    pub fn start(router: Arc<Router>, addr: &str, cfg: MuxConfig) -> Result<MuxServer, String> {
        let cfg = MuxConfig {
            io_threads: cfg.io_threads.max(1),
            max_conns: cfg.max_conns.max(1),
            read_buf: cfg.read_buf.max(256),
            write_buf: cfg.write_buf.max(1024),
            ..cfg
        };
        let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
        let addr = listener.local_addr().map_err(|e| e.to_string())?;
        listener.set_nonblocking(true).map_err(|e| e.to_string())?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let mut intakes = Vec::new();
        let mut io = Vec::new();
        for i in 0..cfg.io_threads {
            let (tx, rx) = channel::<TcpStream>();
            intakes.push(tx);
            let router = Arc::clone(&router);
            let cfg = cfg.clone();
            let flag = Arc::clone(&shutdown);
            io.push(
                std::thread::Builder::new()
                    .name(format!("swifttron-mux-io-{i}"))
                    .spawn(move || io_loop(router, cfg, rx, flag))
                    .map_err(|e| e.to_string())?,
            );
        }
        let flag = Arc::clone(&shutdown);
        let cfg_accept = cfg.clone();
        let accept = std::thread::Builder::new()
            .name("swifttron-mux-accept".into())
            .spawn(move || accept_loop(router, listener, intakes, cfg_accept, flag))
            .map_err(|e| e.to_string())?;
        Ok(MuxServer { addr, shutdown, accept: Some(accept), io })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, let the io threads flush every pending response
    /// (bounded grace), then join everything.
    pub fn shutdown(self) {
        drop(self);
    }
}

impl Drop for MuxServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        for t in self.io.drain(..) {
            let _ = t.join();
        }
    }
}

/// Bind and serve forever (the `swifttron serve --front mux` path).
pub fn serve_mux(router: Arc<Router>, addr: &str, cfg: MuxConfig) -> Result<(), String> {
    let server = MuxServer::start(Arc::clone(&router), addr, cfg)?;
    eprintln!(
        "swifttron mux serving on {} (models: {:?})",
        server.local_addr(),
        router.model_names()
    );
    loop {
        std::thread::sleep(Duration::from_secs(3600));
    }
}

/// Accept connections and deal them round-robin to the io threads.
/// Past the cap a client is answered with both rejection dialects
/// (protocol unknown at accept time): one binary `Busy` frame plus one
/// `{"error":"busy"}` text line, then close.
fn accept_loop(
    router: Arc<Router>,
    listener: TcpListener,
    intakes: Vec<Sender<TcpStream>>,
    cfg: MuxConfig,
    shutdown: Arc<AtomicBool>,
) {
    let metrics = Arc::clone(&router.metrics);
    let mut next = 0usize;
    let mut busy = Vec::new();
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((mut s, _)) => {
                if metrics.conns_open.load(Ordering::SeqCst) >= cfg.max_conns as u64 {
                    metrics.record_conn_rejected();
                    busy.clear();
                    encode::encode_busy(&mut busy, cfg.max_conns as u32);
                    busy.extend_from_slice(
                        obj([("error", Json::from("busy"))]).to_string().as_bytes(),
                    );
                    busy.push(b'\n');
                    let _ = s.write_all(&busy);
                    continue;
                }
                metrics.record_conn_opened();
                if intakes[next % intakes.len()].send(s).is_err() {
                    // io thread gone: shutting down.  The connection was
                    // already counted open above — close it out so the
                    // gauge drains to zero instead of leaking one count
                    // per accept raced against shutdown.
                    metrics.record_conn_closed();
                    return;
                }
                next += 1;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                std::thread::sleep(cfg.park);
            }
            Err(e) => eprintln!("mux accept error: {e}"),
        }
    }
}

/// What protocol a connection speaks; decided by its first bytes.
enum Mode {
    /// not enough bytes yet to tell
    Detect,
    Binary,
    Text,
}

struct Conn {
    stream: TcpStream,
    mode: Mode,
    rbuf: RingBuf,
    dec: FrameDecoder,
    /// response bytes not yet accepted by the kernel; `wpos` is the
    /// flushed prefix
    wbuf: Vec<u8>,
    wpos: usize,
    /// requests submitted to the router, response not yet buffered
    pending: usize,
    read_closed: bool,
    /// hard error: reap without waiting for pending
    dead: bool,
}

impl Conn {
    fn new(stream: TcpStream, cfg: &MuxConfig) -> std::io::Result<Conn> {
        stream.set_nonblocking(true)?;
        stream.set_nodelay(true).ok();
        Ok(Conn {
            stream,
            mode: Mode::Detect,
            rbuf: RingBuf::new(cfg.read_buf),
            dec: FrameDecoder::new(cfg.read_buf.saturating_sub(super::frame::HEADER_BYTES)),
            wbuf: Vec::new(),
            wpos: 0,
            pending: 0,
            read_closed: false,
            dead: false,
        })
    }

    fn unflushed(&self) -> usize {
        self.wbuf.len() - self.wpos
    }

    /// Push buffered response bytes into the socket until it would
    /// block.  Returns true if any byte moved.
    fn flush(&mut self) -> bool {
        let mut progressed = false;
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => {
                    self.dead = true;
                    break;
                }
                Ok(n) => {
                    self.wpos += n;
                    progressed = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        if self.wpos == self.wbuf.len() && self.wpos > 0 {
            // fully drained: recycle the buffer's capacity
            self.wbuf.clear();
            self.wpos = 0;
        }
        progressed
    }

    /// Pull socket bytes into the ring until it would block or the
    /// ring is full (parse-side backpressure).  Returns true if any
    /// byte arrived.
    fn fill(&mut self) -> bool {
        if self.read_closed || self.dead {
            return false;
        }
        let mut progressed = false;
        loop {
            let space = self.rbuf.write_space();
            if space.is_empty() {
                break;
            }
            match self.stream.read(space) {
                Ok(0) => {
                    self.read_closed = true;
                    break;
                }
                Ok(n) => {
                    self.rbuf.commit(n);
                    progressed = true;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => {
                    self.dead = true;
                    break;
                }
            }
        }
        progressed
    }

    /// Done and safe to drop: everything parsed was answered and
    /// flushed, and no more bytes will come.
    fn finished(&self) -> bool {
        self.dead || (self.read_closed && self.pending == 0 && self.unflushed() == 0)
    }
}

/// Where a router response must be delivered: connection slot (with
/// its generation, against slot reuse), the client's frame id, and
/// the dialect to encode with.
struct PendingReply {
    slot: usize,
    gen: u64,
    frame_id: u64,
    text: bool,
}

/// One parsed request headed for admission: the model resolved to its
/// index, or the unknown name (the cold path that produces the typed
/// unknown-model error via `submit_to`).
type ResolvedModel = Result<usize, String>;

struct IoThread {
    router: Arc<Router>,
    cfg: MuxConfig,
    slots: Vec<Option<Conn>>,
    gens: Vec<u64>,
    pending: HashMap<u64, PendingReply>,
    resp_tx: Sender<Response>,
}

impl IoThread {
    /// Admission + submit for one parsed request; appends the typed
    /// rejection or registers the pending reply.  Shared by the binary
    /// and text paths.
    fn admit(
        &mut self,
        slot: usize,
        frame_id: u64,
        text: bool,
        model: ResolvedModel,
        tokens: Vec<i32>,
    ) {
        let id = match model {
            Ok(idx) => {
                if let Some((predicted, slo)) = self.router.overload_delay_ms(
                    idx,
                    self.cfg.shed_ratio,
                    self.cfg.default_service_ms,
                ) {
                    self.router.metrics.record_shed(idx);
                    let name = if text {
                        self.router.metrics.model_name(idx).unwrap_or_default()
                    } else {
                        String::new()
                    };
                    let conn = self.slots[slot].as_mut().expect("admit on live slot");
                    if text {
                        let line = obj([
                            ("error", Json::from("overloaded")),
                            ("model", Json::from(name.as_str())),
                            ("predicted_ms", Json::from(predicted)),
                            ("slo_ms", Json::from(slo)),
                        ]);
                        conn.wbuf.extend_from_slice(line.to_string().as_bytes());
                        conn.wbuf.push(b'\n');
                    } else {
                        encode::encode_overloaded(&mut conn.wbuf, frame_id, predicted, slo);
                    }
                    return;
                }
                self.router.submit_index(idx, tokens, self.resp_tx.clone())
            }
            // unknown model: submit_to answers with the typed
            // unknown-model error through the same reply channel
            Err(name) => self.router.submit_to(&name, tokens, self.resp_tx.clone()),
        };
        let conn = self.slots[slot].as_mut().expect("admit on live slot");
        conn.pending += 1;
        self.pending.insert(id, PendingReply { slot, gen: self.gens[slot], frame_id, text });
    }

    /// Deliver one router response into its connection's write buffer
    /// (dropped if the connection died first).
    fn route(&mut self, resp: Response) {
        let Some(p) = self.pending.remove(&resp.id) else { return };
        if self.gens[p.slot] != p.gen {
            return; // slot was reused; the original connection is gone
        }
        let Some(conn) = self.slots[p.slot].as_mut() else { return };
        conn.pending -= 1;
        if p.text {
            conn.wbuf.extend_from_slice(response_json(&resp).as_bytes());
            conn.wbuf.push(b'\n');
        } else {
            encode::encode_response(&mut conn.wbuf, p.frame_id, &resp);
        }
    }

    /// Parse as much of one connection's ring as the write-buffer
    /// bound allows.  Returns true on progress.
    fn parse(&mut self, slot: usize) -> bool {
        let mut progressed = false;
        loop {
            let conn = self.slots[slot].as_mut().expect("parse on live slot");
            if conn.dead || conn.unflushed() > self.cfg.write_buf {
                break; // backpressure: stop consuming, ring will fill
            }
            match conn.mode {
                Mode::Detect => {
                    let data = conn.rbuf.readable();
                    let n = data.len().min(PREAMBLE.len());
                    if data[..n] != PREAMBLE[..n] {
                        conn.mode = Mode::Text; // nothing consumed
                    } else if n == PREAMBLE.len() {
                        conn.rbuf.consume(n);
                        conn.mode = Mode::Binary;
                    } else if conn.read_closed {
                        conn.dead = true; // EOF inside the preamble
                        break;
                    } else {
                        break; // need more bytes to tell
                    }
                    progressed = true;
                }
                Mode::Binary => {
                    // Decode one frame; the event borrows the ring, so
                    // the request's model index is resolved and its
                    // tokens copied out before the bytes are retired.
                    let (consumed, parsed) = {
                        let (consumed, ev) = conn.dec.pull(conn.rbuf.readable());
                        let parsed = match ev {
                            Some(DecodeEvent::Request(r)) => {
                                let model: ResolvedModel = if r.model.is_empty() {
                                    Ok(0)
                                } else {
                                    self.router
                                        .model_index(r.model)
                                        .ok_or_else(|| r.model.to_string())
                                };
                                let mut tokens = Vec::with_capacity(r.token_count());
                                tokens.extend(r.tokens());
                                Some((r.id, model, tokens))
                            }
                            Some(DecodeEvent::Malformed { id, reason }) => {
                                encode::encode_error(&mut conn.wbuf, id, reason);
                                None
                            }
                            Some(DecodeEvent::Oversized { id, len }) => {
                                let cap = conn.rbuf.capacity();
                                encode::encode_error(
                                    &mut conn.wbuf,
                                    id,
                                    &format!("frame of {len} bytes exceeds the {cap} byte limit"),
                                );
                                None
                            }
                            None => None,
                        };
                        (consumed, parsed)
                    };
                    if consumed == 0 && parsed.is_none() {
                        if conn.read_closed && !conn.rbuf.is_empty() {
                            conn.dead = true; // EOF mid-frame: truncated
                        }
                        break;
                    }
                    conn.rbuf.consume(consumed);
                    progressed = true;
                    if let Some((frame_id, model, tokens)) = parsed {
                        self.admit(slot, frame_id, false, model, tokens);
                    }
                }
                Mode::Text => {
                    let data = conn.rbuf.readable();
                    let len = data.len();
                    let at_capacity = len == conn.rbuf.capacity();
                    let eol = data.iter().position(|&b| b == b'\n');
                    let line = eol.map(|i| String::from_utf8_lossy(&data[..i]).trim().to_string());
                    match line {
                        None => {
                            if at_capacity {
                                // a line longer than the whole ring:
                                // answer once, then hang up (the legacy
                                // server buffers without bound here)
                                let msg =
                                    obj([("error", Json::from("line too long"))]).to_string();
                                conn.wbuf.extend_from_slice(msg.as_bytes());
                                conn.wbuf.push(b'\n');
                                conn.read_closed = true;
                                conn.rbuf.consume(len);
                                progressed = true;
                            } else if conn.read_closed && len > 0 {
                                conn.rbuf.consume(len); // unterminated tail
                                progressed = true;
                            }
                            break;
                        }
                        Some(line) => {
                            conn.rbuf.consume(eol.unwrap() + 1);
                            progressed = true;
                            if line.is_empty() {
                                continue;
                            }
                            if line == "quit" {
                                conn.read_closed = true;
                                break;
                            }
                            match parse_tokens(&line) {
                                Ok((model, tokens)) => {
                                    let model: ResolvedModel = match model {
                                        None => Ok(0),
                                        Some(name) => {
                                            self.router.model_index(&name).ok_or(name)
                                        }
                                    };
                                    self.admit(slot, 0, true, model, tokens);
                                }
                                Err(e) => {
                                    let msg =
                                        obj([("error", Json::from(e.as_str()))]).to_string();
                                    conn.wbuf.extend_from_slice(msg.as_bytes());
                                    conn.wbuf.push(b'\n');
                                }
                            }
                        }
                    }
                }
            }
        }
        progressed
    }
}

fn io_loop(
    router: Arc<Router>,
    cfg: MuxConfig,
    intake: Receiver<TcpStream>,
    shutdown: Arc<AtomicBool>,
) {
    let metrics = Arc::clone(&router.metrics);
    let (resp_tx, resp_rx) = channel::<Response>();
    let mut io = IoThread {
        router,
        cfg,
        slots: Vec::new(),
        gens: Vec::new(),
        pending: HashMap::new(),
        resp_tx,
    };
    let mut draining_since: Option<Instant> = None;
    loop {
        let mut progressed = false;
        // adopt newly accepted connections
        while let Ok(stream) = intake.try_recv() {
            match Conn::new(stream, &io.cfg) {
                Ok(conn) => {
                    progressed = true;
                    match io.slots.iter().position(|s| s.is_none()) {
                        Some(i) => io.slots[i] = Some(conn),
                        None => {
                            io.slots.push(Some(conn));
                            io.gens.push(0);
                        }
                    }
                }
                Err(_) => metrics.record_conn_closed(),
            }
        }
        // drain completed responses into their write buffers
        while let Ok(resp) = resp_rx.try_recv() {
            io.route(resp);
            progressed = true;
        }
        // tick every connection: flush, read, parse
        for slot in 0..io.slots.len() {
            if io.slots[slot].is_none() {
                continue;
            }
            {
                let conn = io.slots[slot].as_mut().unwrap();
                progressed |= conn.flush();
                progressed |= conn.fill();
            }
            progressed |= io.parse(slot);
            let conn = io.slots[slot].as_mut().unwrap();
            if conn.finished() {
                // orphan its pending entries via the generation bump
                io.gens[slot] = io.gens[slot].wrapping_add(1);
                io.slots[slot] = None;
                metrics.record_conn_closed();
                progressed = true;
            }
        }
        if shutdown.load(Ordering::SeqCst) {
            let draining = !io.pending.is_empty()
                || io.slots.iter().flatten().any(|c| c.unflushed() > 0);
            let since = draining_since.get_or_insert_with(Instant::now);
            if !draining || since.elapsed() > Duration::from_secs(5) {
                break; // drained (or grace expired): drop everything
            }
        }
        if !progressed {
            // level-triggered park: wake on the next response or after
            // `park` to re-poll the sockets
            match resp_rx.recv_timeout(io.cfg.park) {
                Ok(resp) => io.route(resp),
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => {}
            }
        }
    }
    for _ in io.slots.iter().flatten() {
        metrics.record_conn_closed();
    }
}
