//! Dynamic batcher: groups queued requests so the worker pool stays busy
//! without letting early arrivals wait unboundedly.
//!
//! SwiftTron processes one sequence at a time (the array is loaded per
//! sentence), so a "batch" here is a *dispatch group*: up to
//! `max_batch` requests released together to the engine replicas, or
//! whatever has queued when `max_wait` elapses — the standard
//! size-or-deadline policy of serving systems.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) }
    }
}

#[derive(Debug)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    queue: VecDeque<(T, Instant)>,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, queue: VecDeque::new() }
    }

    pub fn push(&mut self, item: T) {
        self.queue.push_back((item, Instant::now()));
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Whether a batch should be released now.
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.len() >= self.policy.max_batch {
            return true;
        }
        match self.queue.front() {
            Some((_, t)) => now.duration_since(*t) >= self.policy.max_wait,
            None => false,
        }
    }

    /// Pop up to `max_batch` items (oldest first).
    pub fn take_batch(&mut self) -> Vec<T> {
        let n = self.queue.len().min(self.policy.max_batch);
        self.queue.drain(..n).map(|(t, _)| t).collect()
    }

    /// Deadline of the oldest item (for poll sleeping).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queue.front().map(|(_, t)| *t + self.policy.max_wait)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn releases_on_size() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(60) });
        b.push(1);
        b.push(2);
        assert!(!b.ready(Instant::now()));
        b.push(3);
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn releases_on_deadline() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 100, max_wait: Duration::ZERO });
        b.push("x");
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch(), vec!["x"]);
    }

    #[test]
    fn batch_is_fifo_and_bounded() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::ZERO });
        for i in 0..5 {
            b.push(i);
        }
        assert_eq!(b.take_batch(), vec![0, 1]);
        assert_eq!(b.take_batch(), vec![2, 3]);
        assert_eq!(b.take_batch(), vec![4]);
    }

    #[test]
    fn empty_queue_not_ready() {
        let b: Batcher<i32> = Batcher::new(BatchPolicy::default());
        assert!(!b.ready(Instant::now()));
        assert!(b.next_deadline().is_none());
    }

    #[test]
    fn releases_when_max_wait_expires() {
        // below max_batch, the group is held until the oldest request's
        // deadline passes — then released even though the batch is short
        let wait = Duration::from_millis(15);
        let mut b = Batcher::new(BatchPolicy { max_batch: 100, max_wait: wait });
        b.push(1);
        b.push(2);
        let t0 = Instant::now();
        assert!(!b.ready(t0), "not ready before the deadline");
        assert!(!b.ready(t0 + wait / 2), "still inside the wait window");
        assert!(b.ready(t0 + wait + Duration::from_millis(1)), "deadline expired");
        // and with real elapsed time, not just a synthetic clock
        std::thread::sleep(wait + Duration::from_millis(2));
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch(), vec![1, 2]);
    }

    #[test]
    fn next_deadline_is_oldest_push_plus_max_wait() {
        let wait = Duration::from_millis(20);
        let mut b = Batcher::new(BatchPolicy { max_batch: 100, max_wait: wait });
        let before = Instant::now();
        b.push("old");
        let after = Instant::now();
        std::thread::sleep(Duration::from_millis(2));
        b.push("new"); // must not move the deadline: oldest item governs
        let d = b.next_deadline().unwrap();
        assert!(d >= before + wait && d <= after + wait, "deadline follows the oldest item");
        // draining the oldest moves the deadline later
        let first = b.take_batch();
        assert_eq!(first, vec!["old", "new"]);
        assert!(b.next_deadline().is_none());
    }
}
