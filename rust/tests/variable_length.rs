//! Golden bit-exactness of the variable-length inference path
//! (DESIGN.md §6): the Workspace arena run at `m_eff` must match the
//! allocating path on a geometry truncated to `m = m_eff`, on randomized
//! shapes; the serving stack must deliver the same numerics through
//! length-bucketed dispatch; and malformed requests must surface typed
//! errors end to end.
//!
//! Setup (geometry sampling, weight stacks, token streams, replica
//! groups) comes from the shared fixture layer in `tests/common`.

mod common;

use common::{
    canonical_tokens, functional_replicas, random_acts, random_geo_small, random_tokens,
    synthetic_layers,
};
use std::sync::mpsc::channel;
use std::sync::Arc;
use std::time::Duration;
use swifttron::coordinator::{
    BatchPolicy, EngineReplica, FunctionalEngine, Metrics, RequestError, Router,
};
use swifttron::model::Geometry;
use swifttron::sim::functional::{
    encoder_forward, encoder_forward_ws, layer_forward, layer_forward_ws, synthetic_consts,
    LayerWeights, Workspace,
};
use swifttron::sim::{simulate_encoder, simulate_encoder_m, HwConfig};
use swifttron::util::rng::Rng;

#[test]
fn workspace_matches_allocation_path_on_randomized_shapes() {
    // The acceptance contract of the refactor: for random shapes and a
    // random live length, the Workspace path over the big arena equals
    // the pre-refactor allocating path on a geometry truncated to
    // m = m_eff — outputs AND data-dependent sqrt iteration counts.
    let mut rng = Rng::new(0xA11C);
    for case in 0..20 {
        let geo = random_geo_small(&mut rng);
        let w = LayerWeights::synthetic(&mut rng, &geo);
        let c = synthetic_consts(&geo);
        let m_eff = 1 + rng.below(geo.m as u64) as usize;
        let x = random_acts(&mut rng, m_eff * geo.d);

        let mut ws = Workspace::new(&geo);
        let mut out = vec![0i32; m_eff * geo.d];
        let mut iters = Vec::new();
        layer_forward_ws(&x, &w, &c, &geo, m_eff, &mut ws, &mut out, &mut iters);

        let trunc = Geometry { m: m_eff, ..geo };
        let want = layer_forward(&x, &w, &c, &trunc);
        assert_eq!(out, want.q_out, "case {case}: {geo:?} m_eff={m_eff}");
        assert_eq!(iters, want.sqrt_iters, "case {case}: {geo:?} m_eff={m_eff}");
    }
}

#[test]
fn encoder_workspace_matches_allocation_path() {
    let mut rng = Rng::new(0xB22D);
    for case in 0..6 {
        let mut geo = random_geo_small(&mut rng);
        geo.layers = 1 + rng.below(3) as usize;
        let layers = synthetic_layers(&mut rng, &geo);

        // full length: workspace path == allocating wrapper, bit for bit
        let x = random_acts(&mut rng, geo.m * geo.d);
        let mut ws = Workspace::new(&geo);
        let mut out = vec![0i32; geo.m * geo.d];
        let mut iters = Vec::new();
        encoder_forward_ws(&x, &layers, &geo, geo.m, &mut ws, &mut out, &mut iters);
        let (want_out, want_iters) = encoder_forward(&x, &layers, &geo);
        assert_eq!(out, want_out, "case {case} full length");
        assert_eq!(iters, want_iters, "case {case} full length");

        // short request over the SAME warm arena == truncated geometry
        let m_eff = 1 + rng.below(geo.m as u64) as usize;
        let xs = &x[..m_eff * geo.d];
        let mut out_s = vec![0i32; m_eff * geo.d];
        iters.clear();
        encoder_forward_ws(xs, &layers, &geo, m_eff, &mut ws, &mut out_s, &mut iters);
        let trunc = Geometry { m: m_eff, ..geo };
        let (want_s, want_iters_s) = encoder_forward(xs, &layers, &trunc);
        assert_eq!(out_s, want_s, "case {case} m_eff={m_eff}");
        assert_eq!(iters, want_iters_s, "case {case} m_eff={m_eff}");
    }
}

#[test]
fn full_length_requests_match_fixed_geometry_cycles() {
    // m_eff == geo.m through the variable-length engine must be
    // indistinguishable from the fixed-geometry pipeline: same cycle
    // count as simulate_encoder, deterministic logits across replicas.
    let hw = HwConfig::paper();
    let a = FunctionalEngine::synthetic("tiny", 7, hw).unwrap();
    let b = FunctionalEngine::synthetic("tiny", 7, hw).unwrap();
    let geo = Geometry::preset("tiny").unwrap();
    let tokens = canonical_tokens(geo.m);
    let pa = a.predict(&tokens).unwrap();
    let pb = b.predict(&tokens).unwrap();
    assert_eq!(pa.logits, pb.logits);
    assert_eq!(pa.accel_cycles, simulate_encoder(&hw, &geo).total_cycles);
    assert_eq!(
        pa.accel_cycles,
        simulate_encoder_m(&hw, &geo, geo.m, None).total_cycles
    );
}

#[test]
fn short_requests_cost_fewer_cycles() {
    // Virtual time shapes to the request: strictly monotone in m_eff,
    // and always exactly what the cycle simulator charges at that
    // length (the engine never bills the padded maximum).
    let hw = HwConfig::paper();
    let e = FunctionalEngine::synthetic("tiny", 7, hw).unwrap();
    let m = e.seq_len();
    let tokens = canonical_tokens(m);
    let mut prev = 0u64;
    for m_eff in [m / 4, m / 2, m] {
        let c = e.predict(&tokens[..m_eff]).unwrap().accel_cycles;
        assert!(c > prev, "cycles grow with m_eff ({prev} -> {c})");
        assert_eq!(
            c,
            simulate_encoder_m(&hw, &Geometry::preset("tiny").unwrap(), m_eff, None)
                .total_cycles,
            "m_eff={m_eff}"
        );
        prev = c;
    }
}

#[test]
fn typed_errors_surface_through_the_stack() {
    let e = FunctionalEngine::synthetic("tiny", 7, HwConfig::paper()).unwrap();
    let max = e.seq_len();
    assert_eq!(
        e.predict(&[]).unwrap_err(),
        RequestError::BadLength { got: 0, min: 1, max }
    );
    assert_eq!(
        e.predict(&vec![0i32; max + 3]).unwrap_err(),
        RequestError::BadLength { got: max + 3, min: 1, max }
    );
    assert!(matches!(
        e.predict(&[64]).unwrap_err(),
        RequestError::BadToken { token: 64, .. }
    ));
    // Display carries the cause to the wire format
    let msg = e.predict(&[]).unwrap_err().to_string();
    assert!(msg.contains("length 0"), "{msg}");
}

#[test]
fn bucketed_router_serves_mixed_lengths_bit_exactly() {
    // End-to-end: mixed-length traffic through length-bucketed dispatch
    // across two replicas must reproduce the reference model's labels
    // per request, and the padding-waste metric must see the bucketing.
    let reference = FunctionalEngine::synthetic("tiny", 7, HwConfig::paper()).unwrap();
    let m = reference.seq_len();
    let metrics = Arc::new(Metrics::new());
    let policy = BatchPolicy {
        max_batch: 4,
        max_wait: Duration::from_millis(1),
        bucket_width: (m / 4).max(1),
    };
    let router = Router::start(functional_replicas("tiny", 7, 2), policy, Arc::clone(&metrics));

    let mut rng = Rng::new(99);
    let mut expected = Vec::new();
    let mut receivers = Vec::new();
    for _ in 0..24 {
        let len = 1 + rng.below(m as u64) as usize;
        let tokens = random_tokens(&mut rng, len);
        let want = reference.predict(&tokens).unwrap();
        expected.push((want.label, want.accel_ms));
        let (tx, rx) = channel();
        router.submit(tokens, tx);
        receivers.push(rx);
    }
    for (rx, (label, accel_ms)) in receivers.into_iter().zip(expected) {
        let resp = rx.recv().expect("response");
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.label, label, "replica disagrees with reference");
        assert!((resp.accel_ms - accel_ms).abs() < 1e-12, "virtual time is per-length");
    }
    // a doomed over-length request is rejected with a typed error and
    // must not pollute the token/padding accounting
    use std::sync::atomic::Ordering;
    let actual_before = metrics.actual_tokens.load(Ordering::Relaxed);
    let padded_before = metrics.padded_tokens.load(Ordering::Relaxed);
    let (tx, rx) = channel();
    router.submit(vec![0i32; m + 9], tx);
    assert!(rx.recv().expect("response").error.is_some());
    assert_eq!(metrics.actual_tokens.load(Ordering::Relaxed), actual_before);
    assert_eq!(metrics.padded_tokens.load(Ordering::Relaxed), padded_before);
    router.shutdown();
    assert_eq!(
        metrics.completed.load(std::sync::atomic::Ordering::Relaxed),
        24
    );
    let actual = metrics.actual_tokens.load(std::sync::atomic::Ordering::Relaxed);
    let padded = metrics.padded_tokens.load(std::sync::atomic::Ordering::Relaxed);
    assert!(padded >= actual, "padding never shrinks tokens");
    assert!(
        metrics.padding_waste() > 0.0,
        "random lengths must incur some bucket padding (actual={actual} padded={padded})"
    );
}
