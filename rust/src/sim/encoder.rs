//! Full encoder schedule: the paper's control flow (Fig. 16) over one
//! layer — MHSA FSM, LayerNorm FSM, FFN FSM, LayerNorm FSM — repeated
//! per layer, with a handshake trace and a per-block cycle breakdown.
//!
//! Head-level dataflow (Figs. 8-10): the Q/K/V/output projections and the
//! FFN matmuls run on the central R x C MAC array; each head unit owns
//! (m x dh)-shaped attention MatMuls (Q.K^T and P.V) plus Scale, Softmax
//! and Requantization operators.  `parallel_heads` head units work
//! concurrently; extra heads serialize in waves.

use super::control::{Fsm, FsmKind, Trace};
use super::units;
use super::HwConfig;
use crate::model::Geometry;
use std::collections::BTreeMap;

/// Cycle breakdown of one simulated inference.
#[derive(Clone, Debug, Default)]
pub struct LatencyReport {
    /// total cycles from first Start to last Done
    pub total_cycles: u64,
    /// busy cycles per component class (feeds the power duty model)
    pub per_block: BTreeMap<&'static str, u64>,
    pub trace: Trace,
}

impl LatencyReport {
    pub fn ms(&self, cfg: &HwConfig) -> f64 {
        cfg.cycles_to_ms(self.total_cycles)
    }
}

/// Simulate one encoder layer starting at `start_cycle`; returns the
/// completion cycle and accumulates into the trace + per-block map
/// (split borrows of [`LatencyReport`]'s fields).
pub fn simulate_layer(
    cfg: &HwConfig,
    geo: &Geometry,
    start_cycle: u64,
    trace: &mut Trace,
    blocks: &mut BTreeMap<&'static str, u64>,
    sqrt_iters: Option<&[u32]>,
) -> u64 {
    fn add(blocks: &mut BTreeMap<&'static str, u64>, k: &'static str, v: u64) {
        *blocks.entry(k).or_insert(0) += v;
    }
    let (m, d, dff, dh) = (geo.m, geo.d, geo.d_ff, geo.dh());
    let default_iters = vec![crate::quant::layernorm::ISQRT_MAX_ITERS; m];
    let iters = sqrt_iters.unwrap_or(&default_iters);

    // ---- MHSA FSM ----
    let mhsa_done = {
        let mut fsm = Fsm::new(FsmKind::Mhsa, trace, start_cycle);
        // Q, K, V projections on the central array (requant overlapped).
        let qkv = 3 * units::matmul_cycles(cfg, m, d, d) + units::requant_cycles(cfg);
        fsm.run_block("qkv_proj", qkv);
        add(blocks, "matmul", 3 * units::matmul_cycles(cfg, m, d, d));
        add(blocks, "requant", units::requant_cycles(cfg));

        // Attention heads in waves of `parallel_heads` (Fig. 9).
        let waves = geo.heads.div_ceil(cfg.parallel_heads) as u64;
        // per head (Fig. 10): Q.K^T -> Scale -> Softmax -> Req -> P.V
        let head_cfg = HwConfig { array_rows: m, array_cols: dh, ..*cfg };
        let qkt = units::matmul_cycles(&head_cfg, m, dh, m);
        let softmax = units::softmax_cycles(cfg, m, m);
        let pv = units::matmul_cycles(&head_cfg, m, m, dh);
        let per_head = qkt + softmax + pv + 2 * units::requant_cycles(cfg);
        fsm.run_block("attention_heads", waves * per_head);
        add(blocks, "matmul", waves * (qkt + pv) * geo.heads.min(cfg.parallel_heads) as u64);
        add(blocks, "softmax", waves * softmax * geo.heads.min(cfg.parallel_heads) as u64);
        add(blocks, "requant", waves * 2 * units::requant_cycles(cfg));

        // Output projection (the extra MatMul of Fig. 9) + residual align.
        let proj = units::matmul_cycles(cfg, m, d, d) + units::residual_cycles(cfg);
        fsm.run_block("out_proj", proj);
        add(blocks, "matmul", units::matmul_cycles(cfg, m, d, d));
        add(blocks, "residual", units::residual_cycles(cfg));
        fsm.now
    };

    // ---- LayerNorm FSM (post-MHSA) ----
    let ln1_done = {
        let mut fsm = Fsm::new(FsmKind::LayerNorm, trace, 0);
        fsm.join(mhsa_done);
        let ln = units::layernorm_cycles(cfg, m, d, iters) + units::requant_cycles(cfg);
        fsm.run_block("layernorm1", ln);
        add(blocks, "layernorm", units::layernorm_cycles(cfg, m, d, iters));
        add(blocks, "requant", units::requant_cycles(cfg));
        fsm.now
    };

    // ---- FFN FSM ----
    let ffn_done = {
        let mut fsm = Fsm::new(FsmKind::Ffn, trace, 0);
        fsm.join(ln1_done);
        let mm1 = units::matmul_cycles(cfg, m, d, dff);
        let gelu = units::gelu_cycles(cfg) + units::requant_cycles(cfg);
        let mm2 = units::matmul_cycles(cfg, m, dff, d);
        fsm.run_block("ffn_mm1", mm1);
        fsm.run_block("gelu", gelu);
        fsm.run_block("ffn_mm2", mm2 + units::residual_cycles(cfg));
        add(blocks, "matmul", mm1 + mm2);
        add(blocks, "gelu", units::gelu_cycles(cfg));
        add(blocks, "requant", units::requant_cycles(cfg));
        add(blocks, "residual", units::residual_cycles(cfg));
        fsm.now
    };

    // ---- LayerNorm FSM (post-FFN) ----
    let mut fsm = Fsm::new(FsmKind::LayerNorm, trace, 0);
    fsm.join(ffn_done);
    let ln = units::layernorm_cycles(cfg, m, d, iters) + units::requant_cycles(cfg);
    fsm.run_block("layernorm2", ln);
    add(blocks, "layernorm", units::layernorm_cycles(cfg, m, d, iters));
    add(blocks, "requant", units::requant_cycles(cfg));
    fsm.now
}

/// Simulate the full encoder stack of `geo`.
pub fn simulate_encoder(cfg: &HwConfig, geo: &Geometry) -> LatencyReport {
    let mut report = LatencyReport::default();
    let mut t = 0;
    for _ in 0..geo.layers {
        t = simulate_layer(cfg, geo, t, &mut report.trace, &mut report.per_block, None);
    }
    report.total_cycles = t;
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_well_formed() {
        let r = simulate_encoder(&HwConfig::paper(), &Geometry::preset("roberta_base").unwrap());
        r.trace.check_well_formed().unwrap();
    }

    #[test]
    fn roberta_base_latency_in_paper_band() {
        // Paper Table II: 1.83 ms.  Shape target: same order, within 2x.
        let cfg = HwConfig::paper();
        let r = simulate_encoder(&cfg, &Geometry::preset("roberta_base").unwrap());
        let ms = r.ms(&cfg);
        assert!((0.9..=3.7).contains(&ms), "latency {ms} ms");
    }

    #[test]
    fn model_ranking_matches_table2() {
        // deit_s < roberta_base < roberta_large (Table II ordering)
        let cfg = HwConfig::paper();
        let base = simulate_encoder(&cfg, &Geometry::preset("roberta_base").unwrap());
        let large = simulate_encoder(&cfg, &Geometry::preset("roberta_large").unwrap());
        let deit = simulate_encoder(&cfg, &Geometry::preset("deit_s").unwrap());
        assert!(deit.total_cycles < base.total_cycles);
        assert!(base.total_cycles < large.total_cycles);
    }

    #[test]
    fn layers_scale_linearly() {
        let cfg = HwConfig::paper();
        let mut g = Geometry::preset("roberta_base").unwrap();
        let r12 = simulate_encoder(&cfg, &g);
        g.layers = 6;
        let r6 = simulate_encoder(&cfg, &g);
        assert_eq!(r12.total_cycles, 2 * r6.total_cycles);
    }

    #[test]
    fn matmul_dominates_busy_cycles() {
        let cfg = HwConfig::paper();
        let r = simulate_encoder(&cfg, &Geometry::preset("roberta_base").unwrap());
        let mm = r.per_block["matmul"];
        let total: u64 = r.per_block.values().sum();
        assert!(mm * 2 > total, "matmul {mm} of {total}");
    }

    #[test]
    fn smaller_array_is_slower() {
        let geo = Geometry::preset("roberta_base").unwrap();
        let paper = simulate_encoder(&HwConfig::paper(), &geo);
        let edge = simulate_encoder(&HwConfig::edge(), &geo);
        assert!(edge.total_cycles > paper.total_cycles);
    }
}
