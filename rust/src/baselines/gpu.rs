//! RTX 2080 Ti roofline cost model (the paper's GPU comparison point).
//!
//! The paper measured PyTorch/CUDA-10 inference of the quantized models
//! on an RTX 2080 Ti.  Offline we model that measurement with a
//! per-kernel roofline: every layer op contributes
//! `max(flops/peak', bytes/bw') + launch overhead`, where peak'/bw' are
//! the device peaks derated by a batch-1 efficiency factor.  Batch-1
//! transformer inference with m=256 is launch- and memory-bound — the
//! regime where a dedicated pipeline beats a 13.45 TFLOPS GPU by the
//! paper's ~3.6-3.9x rather than by raw-FLOPs ratios.

use crate::model::Geometry;

#[derive(Clone, Copy, Debug)]
pub struct GpuModel {
    /// peak FP32 throughput (RTX 2080 Ti: 13.45 TFLOPS)
    pub peak_tflops: f64,
    /// memory bandwidth (616 GB/s)
    pub mem_bw_gbs: f64,
    /// fraction of peak a batch-1 m=256 GEMM reaches (cuBLAS, CUDA 10)
    pub gemm_efficiency: f64,
    /// fraction of peak bandwidth elementwise/softmax kernels reach
    pub bw_efficiency: f64,
    /// per-kernel launch + framework overhead (PyTorch eager, seconds)
    pub launch_overhead_s: f64,
}

impl GpuModel {
    /// RTX 2080 Ti with CUDA 10-era PyTorch (the paper's §IV-A testbed).
    pub fn rtx_2080_ti() -> GpuModel {
        GpuModel {
            peak_tflops: 13.45,
            mem_bw_gbs: 616.0,
            gemm_efficiency: 0.35,
            bw_efficiency: 0.60,
            launch_overhead_s: 8e-6,
        }
    }

    /// Time for one GEMM (M,K)x(K,N) in FP32.
    fn gemm_s(&self, m: usize, k: usize, n: usize) -> f64 {
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        let bytes = 4.0 * (m * k + k * n + m * n) as f64;
        let compute = flops / (self.peak_tflops * 1e12 * self.gemm_efficiency);
        let memory = bytes / (self.mem_bw_gbs * 1e9 * self.bw_efficiency);
        compute.max(memory) + self.launch_overhead_s
    }

    /// Time for an elementwise/reduction kernel over `elems` f32 values
    /// with `passes` read+write sweeps (softmax: 3, layernorm: 2, ...).
    fn ew_s(&self, elems: usize, passes: f64) -> f64 {
        let bytes = passes * 8.0 * elems as f64; // read + write per pass
        bytes / (self.mem_bw_gbs * 1e9 * self.bw_efficiency) + self.launch_overhead_s
    }
}

/// Modeled batch-1 inference latency (ms) of a full encoder on the GPU.
pub fn gpu_inference_ms(gpu: &GpuModel, geo: &Geometry) -> f64 {
    let (m, d, dff, dh, h) = (geo.m, geo.d, geo.d_ff, geo.dh(), geo.heads);
    let mut per_layer = 0.0;
    // QKV + output projections (4 GEMMs)
    per_layer += 3.0 * gpu.gemm_s(m, d, d);
    per_layer += gpu.gemm_s(m, d, d);
    // attention scores + context (2 batched GEMMs over h heads)
    per_layer += gpu.gemm_s(m, dh, m * h) ;
    per_layer += gpu.gemm_s(m, m, dh * h);
    // scale + softmax + 2 x (residual + layernorm) + gelu
    per_layer += gpu.ew_s(h * m * m, 1.0); // scale
    per_layer += gpu.ew_s(h * m * m, 3.0); // softmax (max, exp-sum, div)
    per_layer += 2.0 * gpu.ew_s(m * d, 1.0); // residual adds
    per_layer += 2.0 * gpu.ew_s(m * d, 2.0); // layernorms
    per_layer += gpu.ew_s(m * dff, 1.0); // gelu
    // FFN GEMMs
    per_layer += gpu.gemm_s(m, d, dff);
    per_layer += gpu.gemm_s(m, dff, d);
    per_layer * geo.layers as f64 * 1e3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate_encoder, HwConfig};

    #[test]
    fn gpu_latency_plausible_for_roberta_base() {
        // the paper's implied GPU time: 1.83 ms x 3.81 = ~7.0 ms
        let ms = gpu_inference_ms(&GpuModel::rtx_2080_ti(), &Geometry::preset("roberta_base").unwrap());
        assert!((3.0..20.0).contains(&ms), "{ms} ms");
    }

    #[test]
    fn speedup_in_paper_band_for_all_models() {
        // Table II reports 3.58x - 3.90x; require the same shape: >1.5x
        // accelerator advantage on every model, roughly constant factor.
        let cfg = HwConfig::paper();
        let gpu = GpuModel::rtx_2080_ti();
        let mut speedups = vec![];
        for name in ["roberta_base", "roberta_large", "deit_s"] {
            let geo = Geometry::preset(name).unwrap();
            let acc = simulate_encoder(&cfg, &geo).ms(&cfg);
            let g = gpu_inference_ms(&gpu, &geo);
            speedups.push(g / acc);
        }
        for s in &speedups {
            assert!(*s > 1.5, "speedup {s}");
        }
    }

    #[test]
    fn bigger_model_takes_longer_on_gpu() {
        let gpu = GpuModel::rtx_2080_ti();
        let base = gpu_inference_ms(&gpu, &Geometry::preset("roberta_base").unwrap());
        let large = gpu_inference_ms(&gpu, &Geometry::preset("roberta_large").unwrap());
        assert!(large > 2.0 * base);
    }
}
