//! Fixed-size thread pool (no tokio offline): the coordinator's execution
//! substrate.  Work items are boxed closures on an MPMC channel built from
//! `std::sync::mpsc` + a mutex-guarded receiver; `scope`-style joining is
//! provided by [`ThreadPool::run_batch`].

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Number of threads to use for data-parallel work when the caller has
/// no better idea: the machine's available parallelism, capped so a
/// single kernel never fans out absurdly wide on large hosts.
pub fn default_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(16)
}

/// Split `0..n` into at most `tiles` contiguous, non-empty, balanced
/// ranges (sizes differ by at most one).  Returns fewer than `tiles`
/// ranges when `n < tiles`, and an empty vec when `n == 0` — so every
/// returned range carries real work.
pub fn tile_ranges(n: usize, tiles: usize) -> Vec<std::ops::Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let tiles = tiles.max(1).min(n);
    let base = n / tiles;
    let extra = n % tiles;
    let mut out = Vec::with_capacity(tiles);
    let mut start = 0;
    for i in 0..tiles {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Scoped parallel-for: run every job concurrently on scoped threads,
/// joining before returning.  Unlike [`ThreadPool::execute`] the jobs
/// may borrow from the caller's stack — which is exactly what the
/// data-parallel kernels and the head-parallel attention loop want:
/// disjoint `&mut` tiles of one resident buffer (`quant::matmul`,
/// `sim::functional`).  Zero or one job runs inline on the calling
/// thread (no spawn for degenerate fan-outs).
pub fn run_scoped<F>(jobs: Vec<F>)
where
    F: FnOnce() + Send,
{
    if jobs.len() <= 1 {
        for job in jobs {
            job();
        }
        return;
    }
    std::thread::scope(|s| {
        for job in jobs {
            s.spawn(job);
        }
    });
}

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads executing boxed jobs.
pub struct ThreadPool {
    tx: Sender<Msg>,
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
}

struct Shared {
    rx: Mutex<Receiver<Msg>>,
    in_flight: AtomicUsize,
    done: Condvar,
    done_lock: Mutex<()>,
    panics: AtomicUsize,
}

impl ThreadPool {
    pub fn new(threads: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = channel();
        let shared = Arc::new(Shared {
            rx: Mutex::new(rx),
            in_flight: AtomicUsize::new(0),
            done: Condvar::new(),
            done_lock: Mutex::new(()),
            panics: AtomicUsize::new(0),
        });
        let workers = (0..threads)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("swifttron-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx, shared, workers }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Enqueue one job.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        self.tx.send(Msg::Run(Box::new(f))).expect("pool alive");
    }

    /// Block until every queued job has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.done_lock.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done.wait(guard).unwrap();
        }
    }

    /// Run a batch of jobs producing values, preserving input order.
    pub fn run_batch<T, F>(&self, jobs: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = jobs.len();
        let slots: Arc<Mutex<Vec<Option<T>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        for (i, job) in jobs.into_iter().enumerate() {
            let slots = Arc::clone(&slots);
            self.execute(move || {
                let v = job();
                slots.lock().unwrap()[i] = Some(v);
            });
        }
        self.wait_idle();
        Arc::try_unwrap(slots)
            .unwrap_or_else(|_| panic!("batch slots still shared"))
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|o| o.expect("job panicked — see panics()"))
            .collect()
    }

    /// Number of jobs that panicked since pool creation.
    pub fn panics(&self) -> usize {
        self.shared.panics.load(Ordering::SeqCst)
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let msg = {
            let rx = sh.rx.lock().unwrap();
            rx.recv()
        };
        match msg {
            Ok(Msg::Run(job)) => {
                if catch_unwind(AssertUnwindSafe(job)).is_err() {
                    sh.panics.fetch_add(1, Ordering::SeqCst);
                }
                if sh.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
                    let _g = sh.done_lock.lock().unwrap();
                    sh.done.notify_all();
                }
            }
            Ok(Msg::Shutdown) | Err(_) => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn batch_preserves_order() {
        let pool = ThreadPool::new(3);
        let jobs: Vec<_> = (0..50).map(|i| move || i * 2).collect();
        let out = pool.run_batch(jobs);
        assert_eq!(out, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn panicking_job_is_counted_and_pool_survives() {
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("boom"));
        pool.wait_idle();
        assert_eq!(pool.panics(), 1);
        let out = pool.run_batch(vec![|| 7]);
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn wait_idle_with_no_jobs_returns() {
        ThreadPool::new(1).wait_idle();
    }

    #[test]
    fn tile_ranges_cover_exactly_and_balance() {
        for n in [0usize, 1, 2, 5, 7, 16, 100] {
            for tiles in [1usize, 2, 3, 8, 200] {
                let r = tile_ranges(n, tiles);
                // contiguous cover of 0..n
                let mut next = 0;
                for t in &r {
                    assert_eq!(t.start, next);
                    assert!(!t.is_empty(), "empty tile for n={n} tiles={tiles}");
                    next = t.end;
                }
                assert_eq!(next, n);
                assert!(r.len() <= tiles.max(1));
                // balanced: sizes differ by at most one
                if let (Some(min), Some(max)) =
                    (r.iter().map(|t| t.len()).min(), r.iter().map(|t| t.len()).max())
                {
                    assert!(max - min <= 1);
                }
            }
        }
    }

    #[test]
    fn default_parallelism_is_positive() {
        assert!(default_parallelism() >= 1);
    }

    #[test]
    fn run_scoped_fills_disjoint_tiles() {
        // the contract the kernels rely on: every job runs exactly once,
        // jobs may borrow disjoint &mut tiles, and the call joins them all
        let mut data = vec![0u64; 64];
        let jobs: Vec<_> = data
            .chunks_mut(16)
            .enumerate()
            .map(|(i, tile)| {
                move || {
                    for (j, v) in tile.iter_mut().enumerate() {
                        *v = (i * 16 + j) as u64;
                    }
                }
            })
            .collect();
        run_scoped(jobs);
        assert_eq!(data, (0u64..64).collect::<Vec<u64>>());
    }

    #[test]
    fn run_scoped_handles_empty_and_singleton() {
        run_scoped(Vec::<fn()>::new());
        let mut hit = false;
        run_scoped(vec![|| hit = true]);
        assert!(hit, "singleton job runs inline");
    }
}
