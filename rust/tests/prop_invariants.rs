//! Property-based tests (in-repo `util::prop` framework) on coordinator
//! and datapath invariants: batching (no loss, FIFO, bounds), the
//! multi-model weighted-fair scheduler (homogeneous groups, expiry
//! priority, share convergence; DESIGN.md §8), the concurrent
//! per-group pipeline's shutdown no-loss property (DESIGN.md §9), and
//! the integer-arithmetic laws the hardware relies on.

use std::time::Duration;
use swifttron::coordinator::batcher::{BatchPolicy, Batcher};
use swifttron::quant::{
    i_layernorm, i_softmax, requantize, Dyadic, LayerNormConsts, SoftmaxConsts, SM_UNIT,
};
use swifttron::util::prop::check;
use swifttron::util::rng::Rng;

// --- batcher invariants -------------------------------------------------

#[test]
fn prop_batcher_loses_nothing_and_preserves_fifo() {
    check(
        11,
        200,
        |r| {
            let n = r.below(60) as usize;
            let max_batch = 1 + r.below(10) as usize;
            (n as i64, max_batch as i64)
        },
        |&(n, max_batch)| {
            let mut b = Batcher::new(BatchPolicy {
                max_batch: max_batch as usize,
                max_wait: Duration::ZERO,
                bucket_width: 0,
            });
            for i in 0..n {
                b.push(i);
            }
            let mut drained = Vec::new();
            while !b.is_empty() {
                let batch = b.take_batch();
                if batch.is_empty() || batch.len() > max_batch as usize {
                    return false; // bounds violated
                }
                drained.extend(batch);
            }
            drained == (0..n).collect::<Vec<_>>() // no loss + FIFO
        },
    );
}

#[test]
fn prop_batcher_ready_iff_size_or_deadline() {
    check(
        12,
        200,
        |r| (r.below(20) as i64, 1 + r.below(8) as i64),
        |&(n, max_batch)| {
            let mut b = Batcher::new(BatchPolicy {
                max_batch: max_batch as usize,
                max_wait: Duration::from_secs(3600), // deadline never fires
                bucket_width: 0,
            });
            for i in 0..n {
                b.push(i);
            }
            let ready = b.ready(std::time::Instant::now());
            ready == (n >= max_batch)
        },
    );
}

// --- multi-model scheduler invariants (DESIGN.md §8) ---------------------

/// Fixed model universe for the scheduler properties: 3 models with
/// weights 3:2:1.  Randomized inputs are folded into this universe so
/// shrunken counterexamples stay valid.
const MODELS: usize = 3;
const WEIGHTS: [u64; MODELS] = [3, 2, 1];

#[test]
fn prop_multi_model_batcher_drops_nothing_and_groups_stay_homogeneous() {
    // Random multi-model traffic fully drained: every request comes
    // back exactly once, every dispatch group is bounded, non-empty,
    // single-model, single-bucket, and FIFO within its bucket.
    check(
        31,
        80,
        |r| {
            let n = r.below(60) as usize;
            (0..n)
                .map(|_| (r.below(MODELS as u64) as i64, 1 + r.below(24) as i64))
                .collect::<Vec<(i64, i64)>>()
        },
        |traffic| {
            let policy = BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_secs(3600),
                bucket_width: 8,
            };
            let mut b = Batcher::new(policy);
            b.set_model_weights(&WEIGHTS);
            for (seq, &(m, len)) in traffic.iter().enumerate() {
                let model = (m.unsigned_abs() as usize) % MODELS;
                let len = 1 + (len.unsigned_abs() as usize) % 24;
                b.push_keyed((model, seq, policy.padded_len(len)), model, len);
            }
            let mut seen = vec![false; traffic.len()];
            let mut last_seq: std::collections::BTreeMap<(usize, usize), usize> =
                std::collections::BTreeMap::new();
            while !b.is_empty() {
                let batch = b.take_batch();
                if batch.is_empty() || batch.len() > 4 {
                    return false; // bounds violated
                }
                let (model, _, bucket) = batch[0];
                for &(m, seq, pad) in &batch {
                    if m != model || pad != bucket {
                        return false; // mixed-model or mixed-bucket group
                    }
                    if seen[seq] {
                        return false; // duplicated delivery
                    }
                    seen[seq] = true;
                    if let Some(&prev) = last_seq.get(&(m, pad)) {
                        if seq <= prev {
                            return false; // FIFO within the bucket broken
                        }
                    }
                    last_seq.insert((m, pad), seq);
                }
            }
            seen.iter().all(|&s| s) // nothing dropped
        },
    );
}

#[test]
fn prop_expired_request_outranks_full_bucket_of_other_model() {
    // max_wait ZERO: a lone request of one model has expired, so it
    // dispatches before another model's full bucket — whatever the
    // weights say, deadline expiry wins over deficit round-robin.
    check(
        32,
        25,
        |r| (r.below(MODELS as u64) as i64, 1 + r.below(24) as i64),
        |&(cold, cold_len)| {
            let cold = (cold.unsigned_abs() as usize) % MODELS;
            let cold_len = 1 + (cold_len.unsigned_abs() as usize) % 24;
            let hot = (cold + 1) % MODELS;
            let mut b = Batcher::new(BatchPolicy {
                max_batch: 2,
                max_wait: Duration::ZERO,
                bucket_width: 8,
            });
            b.set_model_weights(&WEIGHTS);
            b.push_keyed("cold", cold, cold_len);
            std::thread::sleep(Duration::from_millis(2));
            b.push_keyed("hot-a", hot, 4);
            b.push_keyed("hot-b", hot, 4); // the hot bucket is now full
            b.take_batch() == vec!["cold"]
        },
    );
}

#[test]
fn prop_served_token_shares_converge_to_configured_weights() {
    // Randomized weights, every model continuously backlogged with
    // equal-cost requests: after many dispatches each model's share of
    // charged (bucket-padded) tokens sits within 10% of its configured
    // weight share — the weighted-fair acceptance bound (ISSUE 4).
    check(
        33,
        12,
        |r| {
            let k = 2 + r.below(3) as usize; // 2..=4 models
            (0..k).map(|_| 1 + r.below(5)).map(|w| w as i64).collect::<Vec<i64>>()
        },
        |weights| {
            if weights.len() < 2 {
                return true; // shrunken below the interesting regime
            }
            let ws: Vec<u64> = weights.iter().map(|w| 1 + (w.unsigned_abs() % 5)).collect();
            let k = ws.len();
            let total_w: u64 = ws.iter().sum();
            let mut b = Batcher::new(BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_secs(3600),
                bucket_width: 8,
            });
            b.set_model_weights(&ws);
            // 320 equal-cost groups of 32 padded tokens: the DRR lag
            // bound (one weight-1 group, 32 tokens) is ~6% of the
            // smallest possible share at this depth — inside the 10%
            // acceptance band with margin
            let rounds = 320usize;
            for i in 0..rounds * 4 {
                for m in 0..k {
                    b.push_keyed((m, i), m, 8); // fixed len: equal group cost
                }
            }
            for _ in 0..rounds {
                let batch = b.take_batch();
                if batch.len() != 4 {
                    return false; // a full bucket must always be available
                }
                if batch.iter().any(|&(m, _)| m != batch[0].0) {
                    return false;
                }
            }
            let total: u64 = (0..k).map(|m| b.charged_cost(m)).sum();
            (0..k).all(|m| {
                let share = b.charged_cost(m) as f64 / total as f64;
                let target = ws[m] as f64 / total_w as f64;
                (share - target).abs() <= 0.1 * target + 1e-9
            })
        },
    );
}

#[test]
fn prop_concurrent_router_shutdown_loses_nothing() {
    // The ISSUE 5 no-loss property extended to the concurrent
    // pipeline: random multi-group configurations under racing
    // producers, shut down while groups are mid-flight — every
    // submitted request must receive exactly one response (the
    // per-group dispatchers drain their own backlogs before joining).
    use std::sync::mpsc::channel;
    use std::sync::Arc;
    use swifttron::coordinator::{
        EngineReplica, Metrics, ModelRegistry, Prediction, RequestError, Router,
    };

    struct Jittery {
        delay_us: u64,
    }
    impl EngineReplica for Jittery {
        fn predict(&self, tokens: &[i32]) -> Result<Prediction, RequestError> {
            std::thread::sleep(Duration::from_micros(self.delay_us));
            Ok(Prediction {
                label: tokens.len() % 2,
                logits: vec![tokens.len() as i64],
                accel_cycles: 1,
                accel_ms: 0.001,
            })
        }
        fn seq_len(&self) -> usize {
            64
        }
        fn min_seq_len(&self) -> usize {
            1
        }
    }

    check(
        34,
        8,
        |r| {
            let models = 1 + r.below(3) as i64; // 1..=3 groups
            let requests = r.below(120) as i64;
            (models, requests)
        },
        |&(models, requests)| {
            let models = 1 + ((models.unsigned_abs() as usize).max(1) - 1) % 3;
            let requests = (requests.unsigned_abs() as usize) % 120;
            let mut reg = ModelRegistry::new();
            for m in 0..models {
                let replicas: Vec<Arc<dyn EngineReplica>> = (0..1 + m % 2)
                    .map(|_| {
                        Arc::new(Jittery { delay_us: 200 * (m as u64 + 1) })
                            as Arc<dyn EngineReplica>
                    })
                    .collect();
                reg.register_group(&format!("m{m}"), replicas, 1 + m as u64).unwrap();
            }
            let names: Vec<String> = (0..models).map(|m| format!("m{m}")).collect();
            let policy = BatchPolicy {
                max_batch: 3,
                max_wait: Duration::from_micros(300),
                bucket_width: 8,
            };
            let router = Arc::new(Router::start_multi(
                reg.into_groups(),
                policy,
                Arc::new(Metrics::new()),
            ));
            // two racing producers, then shutdown with groups mid-flight
            let mut handles = Vec::new();
            let (coll_tx, coll_rx) = channel();
            for p in 0..2usize {
                let router = Arc::clone(&router);
                let names = names.clone();
                let coll_tx = coll_tx.clone();
                handles.push(std::thread::spawn(move || {
                    for i in 0..requests / 2 {
                        let model = &names[(p + i) % names.len()];
                        let len = 1 + (i * 7 + p) % 20;
                        let (tx, rx) = channel();
                        router.submit_to(model, vec![1; len], tx);
                        coll_tx.send(rx).unwrap();
                    }
                }));
            }
            drop(coll_tx);
            for h in handles {
                h.join().unwrap();
            }
            let receivers: Vec<_> = coll_rx.iter().collect();
            let submitted = receivers.len();
            // shutdown races the in-flight groups: the drain must not
            // drop any of them
            match Arc::try_unwrap(router) {
                Ok(r) => r.shutdown(),
                Err(_) => return false, // producers joined; cannot happen
            }
            let mut answered = 0usize;
            for rx in receivers {
                match rx.recv_timeout(Duration::from_secs(10)) {
                    Ok(resp) if resp.error.is_none() => answered += 1,
                    _ => return false, // lost or errored request
                }
            }
            answered == submitted
        },
    );
}

// --- sharded dispatch-path invariants (DESIGN.md §13) --------------------

#[test]
fn prop_sharded_mpmc_loses_nothing_and_duplicates_nothing() {
    // ISSUE 9 stress property: random producer counts hammering the
    // per-model shards while one consumer per model pops concurrently
    // (`next_batch` / `complete`, exactly the router's dispatcher
    // loop).  Every pushed id must come back exactly once, on the
    // shard it was pushed to, in bounded groups — no loss, no
    // duplication, no cross-shard leakage, under a fixed seed.
    use std::sync::mpsc::channel;
    use std::sync::Arc;
    use swifttron::coordinator::batcher::ShardedBatcher;

    check(
        35,
        6,
        |r| (1 + r.below(4) as i64, r.below(150) as i64),
        |&(producers, per)| {
            let producers = 1 + (producers.unsigned_abs() as usize) % 4;
            let per = (per.unsigned_abs() as usize) % 150;
            let policy = BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_micros(200),
                bucket_width: 8,
            };
            let b = Arc::new(ShardedBatcher::new(policy, &WEIGHTS));
            let (tx, rx) = channel();
            let consumers: Vec<_> = (0..MODELS)
                .map(|m| {
                    let b = Arc::clone(&b);
                    let tx = tx.clone();
                    std::thread::spawn(move || -> bool {
                        let mut bounded = true;
                        while let Some(group) = b.next_batch(m) {
                            let n = group.len();
                            bounded &= n > 0 && n <= 4;
                            for id in group {
                                tx.send((m, id)).unwrap();
                            }
                            b.complete(m, n);
                        }
                        bounded
                    })
                })
                .collect();
            drop(tx);
            let handles: Vec<_> = (0..producers)
                .map(|p| {
                    let b = Arc::clone(&b);
                    std::thread::spawn(move || {
                        for i in 0..per {
                            let model = (p + i) % MODELS;
                            let id = (p * 1_000_000 + i) as u64;
                            let len = 1 + (i * 5 + p) % 24;
                            b.push_costed(id, model, len, len as u64);
                            if i % 16 == 0 {
                                std::thread::yield_now();
                            }
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            b.shutdown();
            let mut bounded = true;
            for c in consumers {
                bounded &= c.join().unwrap();
            }
            if !bounded {
                return false; // empty or oversized dispatch group
            }
            let mut got: Vec<u64> = Vec::new();
            for (m, id) in rx.iter() {
                let (p, i) = ((id / 1_000_000) as usize, (id % 1_000_000) as usize);
                if m != (p + i) % MODELS {
                    return false; // delivered off its own model's shard
                }
                got.push(id);
            }
            let mut want: Vec<u64> = (0..producers)
                .flat_map(|p| (0..per).map(move |i| (p * 1_000_000 + i) as u64))
                .collect();
            got.sort_unstable();
            want.sort_unstable();
            got == want // exactly-once delivery
        },
    );
}

#[test]
fn prop_sharded_charged_shares_follow_weights_under_contention() {
    // The fairness half of the ISSUE 9 stress suite: every model
    // continuously backlogged with equal-cost groups, 1..=3 racing
    // consumers arbitrating deficit-round-robin over the shards'
    // lock-free charged-cost ledgers (pick the backlogged model
    // minimizing charged/weight — the router-side pop discipline the
    // per-model ledger is designed for).  After a fixed pop depth,
    // each model's charged share must sit within 10% of its weight
    // share, races and all.
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use swifttron::coordinator::batcher::ShardedBatcher;

    check(
        36,
        4,
        |r| 1 + r.below(3) as i64,
        |&consumers| {
            let consumers = 1 + (consumers.unsigned_abs() as usize) % 3;
            let policy = BatchPolicy {
                max_batch: 4,
                max_wait: Duration::from_secs(3600),
                bucket_width: 8,
            };
            let b = Arc::new(ShardedBatcher::new(policy, &WEIGHTS));
            // 320 pops of 4 x 8-token groups against a 4x-deep backlog
            // per model: the DRR lag bound (one group per racing
            // consumer) is well inside the 10% band at this depth
            let rounds = 320usize;
            for i in 0..rounds * 4 {
                for m in 0..MODELS {
                    b.push_costed((m, i), m, 8, 8);
                }
            }
            let popped = Arc::new(AtomicUsize::new(0));
            let handles: Vec<_> = (0..consumers)
                .map(|_| {
                    let b = Arc::clone(&b);
                    let popped = Arc::clone(&popped);
                    std::thread::spawn(move || loop {
                        if popped.fetch_add(1, Ordering::SeqCst) >= rounds {
                            return;
                        }
                        let pick = (0..MODELS).filter(|&m| b.queued_for(m) > 0).min_by(
                            |&a, &c| {
                                let (ca, wa) =
                                    (b.charged_cost(a) as u128, WEIGHTS[a] as u128);
                                let (cc, wc) =
                                    (b.charged_cost(c) as u128, WEIGHTS[c] as u128);
                                (ca * wc).cmp(&(cc * wa))
                            },
                        );
                        match pick {
                            // completion deliberately withheld: the
                            // epoch must not reset mid-measurement
                            Some(m) => drop(b.take_batch_for(m)),
                            None => return,
                        }
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            let total: u64 = (0..MODELS).map(|m| b.charged_cost(m)).sum();
            let total_w: u64 = WEIGHTS.iter().sum();
            total > 0
                && (0..MODELS).all(|m| {
                    let share = b.charged_cost(m) as f64 / total as f64;
                    let target = WEIGHTS[m] as f64 / total_w as f64;
                    (share - target).abs() <= 0.1 * target + 1e-9
                })
        },
    );
}

// --- integer-arithmetic laws the blocks depend on ------------------------

#[test]
fn prop_requantize_monotone() {
    // the Requantization unit must preserve ordering (it feeds argmax
    // heads and attention comparisons downstream)
    check(
        21,
        300,
        |r| {
            let a = r.range_i64(-(1 << 26), 1 << 26);
            let b = r.range_i64(-(1 << 26), 1 << 26);
            (a, b)
        },
        |&(a, b)| {
            let dy = Dyadic::approx16(0.0173);
            let (qa, qb) = (requantize(a, dy), requantize(b, dy));
            if a <= b {
                qa <= qb
            } else {
                qa >= qb
            }
        },
    );
}

#[test]
fn prop_softmax_shift_invariance() {
    // softmax(x + c) == softmax(x): the max-subtraction must make the
    // unit exactly shift-invariant (paper Eq. 3)
    check(
        22,
        100,
        |r| {
            let n = 2 + r.below(24) as usize;
            let shift = r.range_i64(-500, 500);
            let mut v: Vec<i64> = (0..n).map(|_| r.range_i64(-2000, 2000)).collect();
            v.push(shift); // smuggle the shift in the last slot
            v
        },
        |v| {
            let (row, shift) = v.split_at(v.len() - 1);
            let shift = shift[0];
            let c = SoftmaxConsts::design(0.01);
            let shifted: Vec<i64> = row.iter().map(|&x| x + shift).collect();
            let mut a = vec![0i32; row.len()];
            let mut b = vec![0i32; row.len()];
            i_softmax(row, &c, &mut a);
            i_softmax(&shifted, &c, &mut b);
            a == b
        },
    );
}

#[test]
fn prop_softmax_normalized_and_bounded() {
    check(
        23,
        150,
        |r| {
            let n = 1 + r.below(64) as usize;
            (0..n).map(|_| r.range_i64(-3000, 3000)).collect::<Vec<i64>>()
        },
        |row| {
            let c = SoftmaxConsts::design(0.02);
            let mut out = vec![0i32; row.len()];
            i_softmax(row, &c, &mut out);
            let sum: i64 = out.iter().map(|&v| v as i64).sum();
            out.iter().all(|&v| (0..=SM_UNIT as i32).contains(&v))
                && (sum - SM_UNIT).abs() <= row.len() as i64
        },
    );
}

#[test]
fn prop_layernorm_shift_invariance() {
    // LayerNorm(x + c) == LayerNorm(x) (mean removal) — exact in the
    // integer unit up to the floor of the shared mean
    check(
        24,
        100,
        |r| {
            let d = 4 + r.below(60) as usize;
            let shift = r.range_i64(-1000, 1000) * d as i64; // multiple of d => exact
            let mut v: Vec<i64> = (0..d).map(|_| r.range_i64(-2000, 2000)).collect();
            v.push(shift);
            v
        },
        |v| {
            let (row, shift) = v.split_at(v.len() - 1);
            let shift = shift[0];
            let d = row.len();
            let c = LayerNormConsts { s_in: 0.01, s_gamma: 0.01, d };
            let gamma = vec![64i64; d];
            let beta = vec![0i64; d];
            let shifted: Vec<i64> = row.iter().map(|&x| x + shift).collect();
            let mut a = vec![0i32; d];
            let mut b = vec![0i32; d];
            i_layernorm(row, &gamma, &beta, &c, &mut a);
            i_layernorm(&shifted, &gamma, &beta, &c, &mut b);
            a == b
        },
    );
}

#[test]
fn prop_rng_shuffle_is_permutation() {
    check(
        25,
        100,
        |r| {
            let n = r.below(40) as usize;
            (0..n as i64).map(|i| i * 3).collect::<Vec<i64>>()
        },
        |v| {
            let mut rng = Rng::new(7);
            let mut shuffled = v.clone();
            rng.shuffle(&mut shuffled);
            let mut a = v.clone();
            let mut b = shuffled;
            a.sort();
            b.sort();
            a == b
        },
    );
}

#[test]
fn prop_json_number_roundtrip() {
    use swifttron::util::json::Json;
    check(
        26,
        300,
        |r| r.range_i64(-(1 << 52), 1 << 52),
        |&n| {
            let s = Json::from(n).to_string();
            Json::parse(&s).map(|v| v.as_i64() == Some(n)).unwrap_or(false)
        },
    );
}
