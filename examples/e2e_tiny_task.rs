//! End-to-end validation driver (EXPERIMENTS.md §E2E).
//!
//! Replays the full pipeline on the real (synthetic-corpus) tiny task:
//! the build step trained the model and logged the loss curve; this
//! binary loads the AOT artifacts, classifies the 512-sequence held-out
//! test set along BOTH datapaths (integer-only SwiftTron path via the
//! Pallas artifact + float twin), and reports:
//!   * training loss curve summary (from the build),
//!   * float vs quantized accuracy (the paper's Table II accuracy claim),
//!   * per-request PJRT wallclock and simulated accelerator latency.
//!
//! Run: `cargo run --release --example e2e_tiny_task`

use std::time::Instant;
use swifttron::coordinator::InferenceEngine;
use swifttron::model::{Blob, Manifest};
use swifttron::runtime::Engine;
use swifttron::sim::HwConfig;
use swifttron::util::stats::Series;

fn main() -> Result<(), String> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let engine = Engine::cpu()?;
    let eng = InferenceEngine::load(&manifest.dir, &engine, HwConfig::paper())?;
    let blob = Blob::load(&manifest.blob_prefix("tiny")?)?;

    // --- training loss curve (recorded at build time) ---
    let curve = blob.f32("loss_curve")?;
    println!("== training (build-time, {} steps) ==", curve.len());
    for (i, w) in curve.chunks(curve.len() / 8).enumerate() {
        let mean: f32 = w.iter().sum::<f32>() / w.len() as f32;
        println!("  steps {:>3}..{:>3}  mean loss {:.4}", i * w.len(), (i + 1) * w.len(), mean);
    }

    // --- test set, both datapaths ---
    let toks = blob.i32("test_toks")?;
    let labels = blob.i32("test_labels")?;
    let m = eng.geo.m;
    let n = labels.len();
    let (mut correct_q, mut correct_f) = (0usize, 0usize);
    let mut agree = 0usize;
    let mut exec = Series::new();
    for i in 0..n {
        let t = &toks[i * m..(i + 1) * m];
        let t0 = Instant::now();
        let pred = eng.predict(t)?;
        exec.push(t0.elapsed().as_secs_f64());
        let f_label = eng.predict_f32(t)?;
        correct_q += (pred.label == labels[i] as usize) as usize;
        correct_f += (f_label == labels[i] as usize) as usize;
        agree += (pred.label == f_label) as usize;
    }
    let acc_q = 100.0 * correct_q as f64 / n as f64;
    let acc_f = 100.0 * correct_f as f64 / n as f64;
    println!("\n== accuracy ({n} held-out sequences) ==");
    println!("  float twin          {acc_f:.2} %");
    println!("  integer-only (ours) {acc_q:.2} %   (delta {:+.2} pts)", acc_q - acc_f);
    println!("  prediction agreement {:.2} %", 100.0 * agree as f64 / n as f64);
    println!(
        "  build-time python float accuracy: {:.2} % (cross-check)",
        100.0 * manifest.preset("tiny")?.float_test_accuracy.unwrap_or(f64::NAN)
    );

    // --- latency ---
    let sim_ms = eng
        .predict(&toks[0..m])?
        .accel_ms;
    println!("\n== latency ==");
    println!("  PJRT (host CPU) exec: {}", exec.summary("s"));
    println!("  simulated SwiftTron accelerator: {sim_ms:.4} ms per inference");

    // paper-shape assertion: quantization must not cost accuracy
    if acc_q + 1.0 < acc_f {
        return Err(format!("quantized accuracy dropped too far: {acc_q} vs {acc_f}"));
    }
    println!("\nE2E OK");
    Ok(())
}
