//! Miniature property-testing harness (no proptest offline).
//!
//! `check(seed, cases, gen, prop)` runs `prop` on `cases` generated
//! inputs; on failure it performs greedy shrinking via the generator's
//! [`Shrink`] implementation and panics with the minimal counterexample.

use super::rng::Rng;
use std::fmt::Debug;

/// Types that can propose smaller versions of themselves.
pub trait Shrink: Sized + Clone {
    /// Candidate smaller values, roughly ordered most-aggressive first.
    fn shrink(&self) -> Vec<Self>;
}

impl Shrink for i64 {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self != 0 {
            out.push(0);
            out.push(self / 2);
            if *self < 0 {
                out.push(-self);
            }
            if self.abs() > 1 {
                out.push(self - self.signum());
            }
        }
        out.dedup();
        out
    }
}

impl Shrink for usize {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if *self > 0 {
            out.push(0);
            out.push(self / 2);
            out.push(self - 1);
        }
        out.dedup();
        out
    }
}

impl<T: Shrink> Shrink for Vec<T> {
    fn shrink(&self) -> Vec<Self> {
        let mut out = Vec::new();
        if self.is_empty() {
            return out;
        }
        out.push(self[..self.len() / 2].to_vec());
        out.push(self[1..].to_vec());
        out.push(self[..self.len() - 1].to_vec());
        // shrink one element
        for (i, x) in self.iter().enumerate() {
            for smaller in x.shrink().into_iter().take(2) {
                let mut v = self.clone();
                v[i] = smaller;
                out.push(v);
            }
        }
        out
    }
}

impl<A: Shrink, B: Shrink> Shrink for (A, B) {
    fn shrink(&self) -> Vec<Self> {
        let mut out: Vec<Self> = self
            .0
            .shrink()
            .into_iter()
            .map(|a| (a, self.1.clone()))
            .collect();
        out.extend(self.1.shrink().into_iter().map(|b| (self.0.clone(), b)));
        out
    }
}

/// Run a property over `cases` random inputs; shrink + panic on failure.
pub fn check<T, G, P>(seed: u64, cases: usize, mut gen: G, prop: P)
where
    T: Shrink + Debug,
    G: FnMut(&mut Rng) -> T,
    P: Fn(&T) -> bool,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            let minimal = shrink_loop(input, &prop);
            panic!(
                "property failed (seed {seed}, case {case});\n  minimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_loop<T: Shrink + Debug, P: Fn(&T) -> bool>(mut worst: T, prop: &P) -> T {
    // greedy descent, bounded to avoid pathological generators
    for _ in 0..200 {
        let mut advanced = false;
        for cand in worst.shrink() {
            if !prop(&cand) {
                worst = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(1, 200, |r| r.range_i64(-100, 100), |x| x * x >= 0);
    }

    #[test]
    fn failing_property_shrinks_to_minimal() {
        let caught = std::panic::catch_unwind(|| {
            check(2, 500, |r| r.range_i64(0, 1000), |&x| x < 500);
        });
        let msg = *caught.unwrap_err().downcast::<String>().unwrap();
        // greedy shrink must land on exactly 500 (the boundary)
        assert!(msg.contains("500"), "{msg}");
    }

    #[test]
    fn vec_shrink_reduces_length() {
        let v = vec![5i64, 6, 7, 8];
        assert!(v.shrink().iter().any(|s| s.len() < v.len()));
    }

    #[test]
    fn tuple_shrink_covers_both_sides() {
        let t = (4i64, 9i64);
        let shrunk = t.shrink();
        assert!(shrunk.iter().any(|(a, _)| *a != 4));
        assert!(shrunk.iter().any(|(_, b)| *b != 9));
    }
}
