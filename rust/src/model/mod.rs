//! Model metadata shared by the simulator, runtime, and coordinator:
//! geometry presets, per-layer design-time constants (parsed from
//! `artifacts/manifest.json`), and the flat binary tensor blobs the
//! compile path writes.

pub mod blob;
pub mod geometry;
pub mod manifest;

pub use blob::Blob;
pub use geometry::Geometry;
pub use manifest::{LayerConsts, Manifest, Preset};
