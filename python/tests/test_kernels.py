"""Bit-exact kernel tests: Pallas kernels vs independent numpy oracles.

This is the CORE correctness signal of the L1 layer: every SwiftTron
hardware block's Pallas kernel must agree *bit-for-bit* with the
scalar-bignum oracle in ``compile.kernels.ref``.
"""

import numpy as np
import pytest

from compile import intops
from compile import kernels as K
from compile.kernels import ref

RNG = np.random.default_rng(1234)


# --- MatMul block (Fig. 6) ----------------------------------------------------

@pytest.mark.parametrize(
    "m,k,n",
    [(1, 1, 1), (4, 4, 4), (7, 5, 3), (16, 64, 8), (48, 96, 32), (128, 256, 64)],
)
def test_int_matmul_matches_oracle(m, k, n):
    x = RNG.integers(-128, 128, (m, k)).astype(np.int8)
    w = RNG.integers(-128, 128, (k, n)).astype(np.int8)
    got = np.asarray(K.int_matmul(x, w))
    assert np.array_equal(got, ref.np_i_matmul(x, w))


@pytest.mark.parametrize("m,k,n", [(8, 16, 8), (33, 17, 9), (64, 128, 48)])
def test_int_matmul_with_bias(m, k, n):
    x = RNG.integers(-128, 128, (m, k)).astype(np.int8)
    w = RNG.integers(-128, 128, (k, n)).astype(np.int8)
    b = RNG.integers(-(2**20), 2**20, (n,)).astype(np.int32)
    got = np.asarray(K.int_matmul(x, w, b))
    assert np.array_equal(got, ref.np_i_matmul(x, w, b))


def test_int_matmul_block_shape_invariance():
    """Tiling is an implementation detail: any legal block split must give
    the identical INT32 accumulator (integer addition is associative)."""
    x = RNG.integers(-128, 128, (64, 96)).astype(np.int8)
    w = RNG.integers(-128, 128, (96, 64)).astype(np.int8)
    want = ref.np_i_matmul(x, w)
    for bm, bn, bk in [(64, 64, 96), (32, 32, 32), (16, 64, 48), (8, 8, 8)]:
        got = np.asarray(K.int_matmul(x, w, bm=bm, bn=bn, bk=bk))
        assert np.array_equal(got, want), (bm, bn, bk)


def test_int_matmul_extremes():
    """Worst-case INT8 operands must not overflow the INT32 accumulator for
    paper-scale contractions (k up to d_ff=3072: 3072*128*128 < 2^31)."""
    k = 512
    x = np.full((4, k), -128, dtype=np.int8)
    w = np.full((k, 4), -128, dtype=np.int8)
    got = np.asarray(K.int_matmul(x, w))
    assert np.all(got == k * 128 * 128)


def test_int_matmul_identity():
    eye = np.eye(32, dtype=np.int8)
    x = RNG.integers(-128, 128, (16, 32)).astype(np.int8)
    assert np.array_equal(np.asarray(K.int_matmul(x, eye)), x.astype(np.int32))


# --- Requantization unit (Fig. 7) ---------------------------------------------

@pytest.mark.parametrize("scale_ratio", [0.5, 0.01, 0.0003, 1.7, 123.4])
def test_requantize_matches_oracle(scale_ratio):
    dy = intops.Dyadic.approximate(scale_ratio)
    q = RNG.integers(-(2**26), 2**26, (32, 48)).astype(np.int32)
    got = np.asarray(K.requantize(q, dy))
    assert np.array_equal(got, ref.np_requantize(q, dy.b, dy.c))


def test_requantize_saturates():
    dy = intops.Dyadic.approximate(1.0)
    q = np.array([[2**30, -(2**30), 0, 127, -128, 128, -129]], dtype=np.int32)
    got = np.asarray(K.requantize(q, dy))
    assert got.max() == 127 and got.min() == -128


def test_requantize_negative_floor():
    """Arithmetic shift floors toward -inf; the oracle must agree on
    negative inputs (a classic trunc-vs-floor divergence spot)."""
    dy = intops.Dyadic(b=3, c=2)  # * 0.75
    q = np.array([[-1, -2, -3, -5, 1, 2, 3, 5]], dtype=np.int32)
    got = np.asarray(K.requantize(q, dy))
    assert np.array_equal(got, ref.np_requantize(q, dy.b, dy.c))
    assert got[0, 0] == -1  # (-1*3)>>2 == -1, not 0


def test_dyadic_approximation_error():
    for x in [1e-4, 0.01, 0.3, 1.0, 7.7, 999.0]:
        dy = intops.Dyadic.approximate(x)
        assert abs(dy.value() - x) / x < 2 ** -14, (x, dy)


# --- Softmax unit (Figs. 11-12) -------------------------------------------------

@pytest.mark.parametrize("s_in", [0.1, 0.05, 0.01, 0.002])
@pytest.mark.parametrize("m,n", [(1, 8), (8, 24), (32, 256)])
def test_i_softmax_matches_oracle(s_in, m, n):
    c = intops.SoftmaxConsts.design(s_in)
    lim = min(int(8.0 / s_in), 2**20)  # keep inputs in a plausible logit range
    q = RNG.integers(-lim, lim, (m, n)).astype(np.int32)
    got = np.asarray(K.i_softmax(q, c))
    assert np.array_equal(got, ref.np_i_softmax(q, c))


def test_i_softmax_float_error_budget():
    """Paper claim (via I-BERT): polynomial softmax is accurate enough to
    preserve accuracy. Dequantized outputs must be within 3/127 of the
    true softmax elementwise and sum to ~1."""
    c = intops.SoftmaxConsts.design(0.02)
    q = RNG.integers(-300, 300, (64, 128)).astype(np.int32)
    got = np.asarray(K.i_softmax(q, c)) / intops.SM_UNIT
    want = ref.f32_softmax(q * 0.02)
    assert np.abs(got - want).max() < 3.0 / 127.0
    assert np.abs(got.sum(-1) - 1.0).max() < 0.1


def test_i_softmax_constant_row():
    c = intops.SoftmaxConsts.design(0.05)
    q = np.full((4, 16), 37, dtype=np.int32)
    got = np.asarray(K.i_softmax(q, c))
    assert np.all(got == got[0, 0])  # uniform distribution


def test_i_softmax_one_hot_row():
    c = intops.SoftmaxConsts.design(0.05)
    q = np.full((1, 16), -(2**15), dtype=np.int32)
    q[0, 3] = 2**15
    got = np.asarray(K.i_softmax(q, c))
    assert got[0, 3] == intops.SM_UNIT and np.all(np.delete(got[0], 3) == 0)


def test_i_exp_monotone_nonincreasing_as_input_drops():
    c = intops.SoftmaxConsts.design(0.05)
    xs = np.arange(0, -2000, -7, dtype=np.int64)
    es = [ref.np_i_exp_scalar(int(x), c) for x in xs]
    jnp_es = np.asarray(intops.i_exp(xs, c))
    assert np.array_equal(np.asarray(es), jnp_es)
    assert all(a >= b for a, b in zip(es, es[1:]))


# --- GELU unit (Fig. 14) --------------------------------------------------------

@pytest.mark.parametrize("s_in", [0.1, 0.03, 0.005])
@pytest.mark.parametrize("m,n", [(1, 4), (16, 32), (64, 128)])
def test_i_gelu_matches_oracle(s_in, m, n):
    c = intops.GeluConsts.design(s_in)
    lim = min(int(6.0 / s_in), 2**18)
    q = RNG.integers(-lim, lim, (m, n)).astype(np.int32)
    got = np.asarray(K.i_gelu(q, c))
    assert np.array_equal(got, ref.np_i_gelu(q, c))


def test_i_gelu_float_error_budget():
    c = intops.GeluConsts.design(0.02)
    q = RNG.integers(-300, 300, (64, 64)).astype(np.int32)
    got = np.asarray(K.i_gelu(q, c)) * c.s_out
    want = ref.f32_gelu(q * 0.02)
    assert np.abs(got - want).max() < 0.05


def test_i_gelu_asymptotes():
    """GELU(x) -> x for large x, -> 0 for very negative x."""
    c = intops.GeluConsts.design(0.05)
    big, neg = 4000, -4000  # +-200 in real units... clipped erf => +-1
    got_big = float(ref.np_i_gelu(np.array([big]), c)[0] * c.s_out)
    got_neg = float(ref.np_i_gelu(np.array([neg]), c)[0] * c.s_out)
    assert abs(got_big - big * 0.05) < 0.5
    assert abs(got_neg) < 0.5


def test_i_gelu_zero():
    c = intops.GeluConsts.design(0.02)
    assert int(ref.np_i_gelu(np.array([0]), c)[0]) == 0


# --- LayerNorm unit (Fig. 15) ----------------------------------------------------

@pytest.mark.parametrize("d", [8, 32, 96, 768])
def test_i_layernorm_matches_oracle(d):
    c = intops.LayerNormConsts(s_in=0.01, s_gamma=0.01, d=d)
    q = RNG.integers(-1000, 1000, (8, d)).astype(np.int32)
    g = RNG.integers(-127, 128, (d,)).astype(np.int32)
    b = RNG.integers(-5000, 5000, (d,)).astype(np.int32)
    got = np.asarray(K.i_layernorm(q, g, b, c))
    assert np.array_equal(got, ref.np_i_layernorm(q, g, b, c))


def test_i_layernorm_float_error_budget():
    d = 128
    c = intops.LayerNormConsts(s_in=0.01, s_gamma=0.01, d=d)
    q = RNG.integers(-2000, 2000, (16, d)).astype(np.int32)
    g = RNG.integers(1, 128, (d,)).astype(np.int32)
    b = RNG.integers(-5000, 5000, (d,)).astype(np.int32)
    got = np.asarray(K.i_layernorm(q, g, b, c)) * c.s_out
    want = ref.f32_layernorm(q * 0.01, g * 0.01, b * c.s_out)
    assert np.abs(got - want).max() < 0.08


def test_i_layernorm_constant_row_is_beta():
    """A constant row has zero variance: output must collapse to beta."""
    d = 16
    c = intops.LayerNormConsts(s_in=0.01, s_gamma=0.01, d=d)
    q = np.full((2, d), 123, dtype=np.int32)
    g = np.full((d,), 64, dtype=np.int32)
    b = RNG.integers(-100, 100, (d,)).astype(np.int32)
    got = np.asarray(K.i_layernorm(q, g, b, c))
    assert np.array_equal(got, np.broadcast_to(b, (2, d)))


# --- iterative integer sqrt (paper §III-I) ---------------------------------------

@pytest.mark.parametrize(
    "n", [0, 1, 2, 3, 4, 15, 16, 17, 255, 256, 1 << 20, (1 << 31) - 1, 1 << 40]
)
def test_i_sqrt_exact(n):
    got, iters = ref.np_i_sqrt_scalar(n)
    want = int(np.sqrt(np.float64(n)))
    # Babylonian isqrt == floor(sqrt(n)), possibly off by float rounding
    assert got * got <= n < (got + 1) * (got + 1)
    assert iters <= intops.ISQRT_MAX_ITERS


def test_i_sqrt_jnp_matches_scalar():
    ns = np.array(
        [0, 1, 2, 5, 99, 1024, 123456, 10**9, 10**12, (1 << 31) - 1], dtype=np.int64
    )
    got = np.asarray(intops.i_sqrt(ns))
    want = np.array([ref.np_i_sqrt_scalar(int(n))[0] for n in ns])
    assert np.array_equal(got, want)


def test_i_sqrt_iterations_bounded_paper_worst_case():
    """The simulator charges worst-case sqrt cycles (paper footnote 3);
    verify the true iteration count never exceeds the model's bound."""
    worst = 0
    for n in [int(x) for x in RNG.integers(0, 1 << 62, 2000)]:
        worst = max(worst, ref.np_i_sqrt_scalar(n)[1])
    assert worst <= intops.ISQRT_MAX_ITERS
