//! Open-loop trace driver: submits a recorded arrival stream against a
//! [`Router`] at its recorded wall-clock offsets, whether or not
//! earlier requests have completed — the arrival process never waits on
//! the service process, so queueing under offered load is visible
//! (closed-loop drivers structurally hide it).
//!
//! Recording and replay are two views of the same [`Trace`]: a live run
//! driven by [`run_process`] records the `(t_arrival, model, len)`
//! stream it submits, and [`replay`] of that recording reproduces the
//! submission sequence bit-identically (same timestamps, same models,
//! same token vectors — the tokens are a pure function of the recorded
//! length).

use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use super::arrival::ArrivalProcess;
use super::trace::Trace;
use crate::coordinator::Router;
use crate::wire::encode::{decode_response, encode_request};
use crate::wire::frame::{ResponseFrame, PREAMBLE};

/// Outcome of one open-loop run.
#[derive(Clone, Debug)]
pub struct ReplaySummary {
    /// requests submitted (== the trace length)
    pub sent: usize,
    /// replies with no error
    pub completed: usize,
    /// replies carrying a typed error
    pub errors: usize,
    /// typed `Overloaded` admission rejections from the wire front
    /// door (DESIGN.md §11) — only [`replay_wire`] observes these;
    /// in-process [`replay`] bypasses admission control and never sheds
    pub shed: usize,
    /// requests whose reply never arrived before the drain timeout —
    /// the zero-loss chaos legs assert this is 0
    pub lost: usize,
    /// wall time from first submission to last reply (or timeout)
    pub wall_s: f64,
    /// the exact stream this run submitted; replaying it reproduces
    /// the run's submissions bit-identically
    pub recorded: Trace,
}

impl ReplaySummary {
    /// Offered arrival rate over the recorded stream's span.
    pub fn offered_rps(&self) -> f64 {
        let span = self.recorded.duration_s();
        if span > 0.0 {
            self.sent as f64 / span
        } else {
            0.0
        }
    }

    /// Successful replies per wall-clock second.
    pub fn achieved_rps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.completed as f64 / self.wall_s
        } else {
            0.0
        }
    }
}

/// Deterministic token vector for a recorded request length: replaying
/// a trace re-submits byte-identical payloads.
pub fn tokens_for(len: u16) -> Vec<i32> {
    (0..len.max(1) as i32).map(|t| t % 50).collect()
}

/// Replay `trace` open-loop against `router`.  Each event's submission
/// is paced to its recorded offset scaled by `time_scale` (1.0 = real
/// time, 0.5 = twice as fast); trace model indices map to the router's
/// model list in order.  Blocks until every reply has arrived or
/// `drain_timeout` has elapsed past the last submission; missing
/// replies are counted as `lost`, never silently dropped.
pub fn replay(
    router: &Router,
    trace: &Trace,
    time_scale: f64,
    drain_timeout: Duration,
) -> ReplaySummary {
    assert!(time_scale > 0.0, "time_scale must be positive");
    let names: Vec<String> = router.model_names().iter().map(|s| s.to_string()).collect();
    let (tx, rx) = channel();
    let mut recorded = Trace::new();
    let t0 = Instant::now();
    for ev in trace.events() {
        let target = Duration::from_secs_f64(ev.t_ns as f64 / 1e9 * time_scale);
        let now = t0.elapsed();
        if target > now {
            std::thread::sleep(target - now);
        }
        let name = names
            .get(ev.model as usize)
            .unwrap_or_else(|| panic!("trace model {} not registered on router", ev.model));
        recorded.push_event(*ev);
        router.submit_to(name, tokens_for(ev.len), tx.clone());
    }
    drop(tx);
    let sent = trace.len();
    let mut completed = 0usize;
    let mut errors = 0usize;
    let deadline = Instant::now() + drain_timeout;
    while completed + errors < sent {
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            break;
        }
        match rx.recv_timeout(left) {
            Ok(resp) => {
                if resp.error.is_none() {
                    completed += 1;
                } else {
                    errors += 1;
                }
            }
            Err(_) => break,
        }
    }
    ReplaySummary {
        sent,
        completed,
        errors,
        shed: 0,
        lost: sent - completed - errors,
        wall_s: t0.elapsed().as_secs_f64(),
        recorded,
    }
}

/// Replay `trace` open-loop over a real socket speaking the `SWWIRE1`
/// binary protocol (DESIGN.md §11) — the full-stack variant of
/// [`replay`]: the same pacing and drain contract, but requests cross
/// the wire front door, so admission-control rejections surface as
/// [`ReplaySummary::shed`] instead of never happening.  Trace model
/// indices map through `names` (the server router's
/// [`model_names`](Router::model_names), in order); responses are
/// drained concurrently with submission, so a long trace cannot
/// deadlock on a full socket buffer.
pub fn replay_wire<A: ToSocketAddrs>(
    addr: A,
    trace: &Trace,
    names: &[String],
    time_scale: f64,
    drain_timeout: Duration,
) -> Result<ReplaySummary, String> {
    assert!(time_scale > 0.0, "time_scale must be positive");
    let mut stream = TcpStream::connect(addr).map_err(|e| format!("connect: {e}"))?;
    stream.set_nodelay(true).ok();
    stream.write_all(&PREAMBLE).map_err(|e| format!("send preamble: {e}"))?;
    let reader = stream.try_clone().map_err(|e| format!("clone stream: {e}"))?;
    let sent = trace.len();
    let t0 = Instant::now();
    let deadline =
        t0 + Duration::from_secs_f64(trace.duration_s() * time_scale) + drain_timeout;
    let mut recorded = Trace::new();
    let mut wbuf = Vec::new();
    let (completed, errors, shed) = std::thread::scope(|s| {
        let drain = s.spawn(move || count_wire_responses(reader, sent, deadline));
        for (i, ev) in trace.events().iter().enumerate() {
            let target = Duration::from_secs_f64(ev.t_ns as f64 / 1e9 * time_scale);
            let now = t0.elapsed();
            if target > now {
                std::thread::sleep(target - now);
            }
            let name = names
                .get(ev.model as usize)
                .unwrap_or_else(|| panic!("trace model {} not in `names`", ev.model));
            recorded.push_event(*ev);
            wbuf.clear();
            encode_request(&mut wbuf, i as u64, name, &tokens_for(ev.len));
            if stream.write_all(&wbuf).is_err() {
                break; // the reader side reports what actually landed
            }
        }
        drain.join().expect("wire response reader panicked")
    });
    Ok(ReplaySummary {
        sent,
        completed,
        errors,
        shed,
        lost: sent - completed - errors - shed,
        wall_s: t0.elapsed().as_secs_f64(),
        recorded,
    })
}

/// Count `(completed, errors, shed)` response frames until `expected`
/// have arrived, the server closes, or `deadline` passes.
fn count_wire_responses(
    mut stream: TcpStream,
    expected: usize,
    deadline: Instant,
) -> (usize, usize, usize) {
    let (mut completed, mut errors, mut shed) = (0usize, 0usize, 0usize);
    let mut buf: Vec<u8> = Vec::new();
    let mut pos = 0usize;
    let mut chunk = [0u8; 16 * 1024];
    while completed + errors + shed < expected {
        match decode_response(&buf[pos..]) {
            Ok(Some((n, frame))) => {
                pos += n;
                match frame {
                    ResponseFrame::Ok { .. } => completed += 1,
                    ResponseFrame::Overloaded { .. } => shed += 1,
                    ResponseFrame::Error { .. } | ResponseFrame::Busy { .. } => errors += 1,
                }
                continue;
            }
            Ok(None) => {
                if pos > 0 && pos == buf.len() {
                    buf.clear();
                    pos = 0;
                }
            }
            Err(_) => break, // protocol corruption: stop counting
        }
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            break;
        }
        if stream.set_read_timeout(Some(left.min(Duration::from_millis(100)))).is_err() {
            break;
        }
        match stream.read(&mut chunk) {
            Ok(0) => break, // server closed
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) => {}
            Err(_) => break,
        }
    }
    (completed, errors, shed)
}

/// Drive an arrival process live for one tenant, recording the stream
/// it submits.  `replay(&summary.recorded, ...)` reproduces this run's
/// submissions bit-identically — that recording can also be
/// [`Trace::save`]d and reloaded byte-exactly.
pub fn run_process(
    router: &Router,
    process: &ArrivalProcess,
    seed: u64,
    horizon_s: f64,
    model: usize,
    len_range: (usize, usize),
    drain_timeout: Duration,
) -> ReplaySummary {
    let trace = Trace::from_process(process, seed, horizon_s, model, len_range);
    replay(router, &trace, 1.0, drain_timeout)
}
