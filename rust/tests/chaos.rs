//! Chaos regression tests (ISSUE 6, ISSUE 9): a replica that panics
//! mid-batch loses zero requests — the request is retried on another
//! replica or answered with a typed error — the faulted slot is
//! retired when the group can respawn, and the autoscaler's floor
//! repair brings the group's replica gauge back to its floor.  The
//! ISSUE 9 legs pin the per-model blast radius of the sharded dispatch
//! path: a poisoned shard lock or a fully-dead tenant degrades that
//! one model, never the router.  Mock engines with pinned service
//! times keep every leg deterministic under a fixed seed.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::{Duration, Instant};
use swifttron::coordinator::{
    AutoscalePolicy, BatchPolicy, EngineReplica, Metrics, ModelGroup, ModelRegistry,
    ReplicaFactory, ReplicaPool, Request, Response, Router,
};
use swifttron::workload::{ChaosReplica, DelayReplica};

fn fast_autoscale() -> AutoscalePolicy {
    AutoscalePolicy {
        interval: Duration::from_millis(2),
        grow_ratio: 1.0,
        shrink_ratio: 0.25,
        hold_ticks: 1,
        default_service_ms: 1.0,
    }
}

/// Poll `f` until it holds or `timeout` elapses; returns whether it
/// held.
fn eventually(timeout: Duration, mut f: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if f() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    f()
}

/// A dispatch group of `n` requests for model 0 (tokens are non-empty
/// so the mocks serve them).
fn group_of(n: usize) -> (Vec<Request>, Vec<Receiver<Response>>) {
    let mut group = Vec::with_capacity(n);
    let mut receivers = Vec::with_capacity(n);
    for id in 0..n as u64 {
        let (tx, rx) = channel();
        group.push(Request {
            id,
            model: 0,
            tokens: vec![id as i32 % 50, 1, 2],
            padded_len: 3,
            cost: 3,
            submitted: Instant::now(),
            origin: None,
            reply: tx,
        });
        receivers.push(rx);
    }
    (group, receivers)
}

#[test]
fn panicked_request_is_retried_on_a_peer_replica() {
    // Two fixed replicas, the first panics on its first request.  The
    // captured request must be re-served by the peer: zero errors, one
    // fault, one retry, and — with no factory — no slot retirement.
    let metrics = Arc::new(Metrics::new());
    let replicas: Vec<Arc<dyn EngineReplica>> = vec![
        Arc::new(ChaosReplica::panic_at(Arc::new(DelayReplica::from_ms(0)), 0)),
        Arc::new(DelayReplica::from_ms(0)),
    ];
    let pool =
        ReplicaPool::new_multi(vec![ModelGroup::fixed("m", replicas, 1)], Arc::clone(&metrics));
    let (group, receivers) = group_of(6);
    let responses = pool.dispatch(group);
    assert_eq!(responses.len(), 6, "every request yields exactly one response");
    for (i, resp) in responses.iter().enumerate() {
        assert!(resp.error.is_none(), "request {i} errored: {:?}", resp.error);
    }
    for rx in receivers {
        let resp = rx.recv_timeout(Duration::from_secs(5)).expect("reply channel served");
        assert!(resp.error.is_none());
    }
    let m = metrics.model(0);
    assert_eq!(m.replica_faults.load(Ordering::SeqCst), 1, "one injected panic observed");
    assert_eq!(m.retries.load(Ordering::SeqCst), 1, "the panicked request was retried");
    assert_eq!(m.completed.load(Ordering::SeqCst), 6);
    assert_eq!(m.errors.load(Ordering::SeqCst), 0);
    assert_eq!(metrics.errors.load(Ordering::SeqCst), 0);
    assert_eq!(
        pool.group(0).unwrap().active_replicas(),
        2,
        "no factory: the faulted replica stays in its slot"
    );
}

#[test]
fn panic_with_no_peer_is_a_typed_error_not_a_loss() {
    // One replica, no factory, panics on its second request: the
    // request gets a typed backend error on its reply channel; nothing
    // hangs and the pool serves the next dispatch.
    let metrics = Arc::new(Metrics::new());
    let replicas: Vec<Arc<dyn EngineReplica>> =
        vec![Arc::new(ChaosReplica::panic_at(Arc::new(DelayReplica::from_ms(0)), 1))];
    let pool =
        ReplicaPool::new_multi(vec![ModelGroup::fixed("m", replicas, 1)], Arc::clone(&metrics));
    let (group, receivers) = group_of(3);
    let responses = pool.dispatch(group);
    assert!(responses[0].error.is_none());
    assert!(
        responses[1].error.as_deref().unwrap_or("").contains("panicked"),
        "the un-retryable request carries a typed error: {:?}",
        responses[1].error
    );
    assert!(responses[2].error.is_none());
    for rx in receivers {
        rx.recv_timeout(Duration::from_secs(5)).expect("every request was answered");
    }
    let m = metrics.model(0);
    assert_eq!(m.replica_faults.load(Ordering::SeqCst), 1);
    assert_eq!(m.retries.load(Ordering::SeqCst), 0, "no peer to retry on");
    assert_eq!(m.errors.load(Ordering::SeqCst), 1);
    let (group, _rx) = group_of(2);
    assert!(pool.dispatch(group).iter().all(|r| r.error.is_none()), "pool survives");
}

#[test]
fn faulted_group_recovers_to_its_floor_with_zero_loss() {
    // The flagship chaos leg: a scaled group (min 2) whose first
    // replica panics mid-run.  The slot is retired, the request is
    // retried on the peer, the autoscaler's floor repair respawns the
    // group back to its floor, and not one of the flood's requests is
    // lost or errored.
    const REQUESTS: usize = 40;
    let spawned = Arc::new(AtomicUsize::new(0));
    let factory: ReplicaFactory = {
        let spawned = Arc::clone(&spawned);
        Arc::new(move || {
            let n = spawned.fetch_add(1, Ordering::SeqCst);
            let inner: Arc<dyn EngineReplica> = Arc::new(DelayReplica::from_ms(2));
            Ok(if n == 0 {
                // the group's first replica panics on its 6th request
                Arc::new(ChaosReplica::panic_at(inner, 5)) as Arc<dyn EngineReplica>
            } else {
                inner
            })
        })
    };
    let mut reg = ModelRegistry::new();
    reg.register_group_scaled("m", 2, 3, 1, Some(50.0), factory).unwrap();
    let metrics = Arc::new(Metrics::new());
    let policy =
        BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(500), bucket_width: 0 };
    let router =
        Router::start_multi_with(reg.into_groups(), policy, fast_autoscale(), Arc::clone(&metrics));
    assert_eq!(router.active_replicas("m"), Some(2), "group starts at its floor");

    let receivers: Vec<_> = (0..REQUESTS)
        .map(|i| {
            let (tx, rx) = channel();
            router.submit_to("m", vec![i as i32 % 50, 1], tx);
            rx
        })
        .collect();
    for (i, rx) in receivers.iter().enumerate() {
        let resp = rx.recv_timeout(Duration::from_secs(30)).expect("response lost");
        assert!(resp.error.is_none(), "request {i}: {:?}", resp.error);
    }
    assert!(
        eventually(Duration::from_secs(10), || router.active_replicas("m") >= Some(2)),
        "floor repair never restored the replica floor (at {:?})",
        router.active_replicas("m")
    );
    router.shutdown();

    let m = metrics.model(0);
    assert_eq!(m.completed.load(Ordering::SeqCst), REQUESTS as u64);
    assert_eq!(m.errors.load(Ordering::SeqCst), 0, "zero loss: the panicked request retried");
    assert_eq!(m.backlog.load(Ordering::SeqCst), 0, "backlog gauge settled");
    assert_eq!(m.replica_faults.load(Ordering::SeqCst), 1, "exactly the injected fault");
    assert_eq!(m.retries.load(Ordering::SeqCst), 1);
    assert!(
        spawned.load(Ordering::SeqCst) >= 3,
        "initial floor (2) plus the floor-repair respawn, saw {}",
        spawned.load(Ordering::SeqCst)
    );
}

/// One round trip through the router for `model`; panics if the reply
/// never arrives (a hung tenant is exactly the regression these legs
/// guard against).
fn ask(router: &Router, model: &str) -> Response {
    let (tx, rx) = channel();
    router.submit_to(model, vec![1, 2, 3], tx);
    rx.recv_timeout(Duration::from_secs(10)).expect("reply channel served")
}

#[test]
fn poisoned_shard_lock_degrades_one_tenant_not_the_router() {
    // ISSUE 9 regression: before the sharded batcher, a dispatcher
    // panicking while holding the global batcher mutex poisoned it and
    // every later `lock().unwrap()` — submit or pop, any model —
    // panicked the whole router.  Now the poison lands on one model's
    // shard, the shard recovers via lock-poison recovery, and no other
    // tenant ever observes it.
    let metrics = Arc::new(Metrics::new());
    let mk = || vec![Arc::new(DelayReplica::from_ms(0)) as Arc<dyn EngineReplica>];
    let groups =
        vec![ModelGroup::fixed("a", mk(), 1), ModelGroup::fixed("b", mk(), 1)];
    let router = Router::start_multi(groups, BatchPolicy::default(), Arc::clone(&metrics));
    // both tenants serve before the fault
    assert!(ask(&router, "a").error.is_none());
    assert!(ask(&router, "b").error.is_none());

    // panic while holding model a's shard lock (what a dispatcher
    // crashing mid-pop would leave behind)
    assert!(router.poison_model_shard("a"));

    // the untouched tenant keeps serving...
    for _ in 0..4 {
        assert!(ask(&router, "b").error.is_none());
    }
    // ...and the poisoned tenant recovers instead of cascading
    for _ in 0..4 {
        assert!(ask(&router, "a").error.is_none());
    }
    router.shutdown();
    assert_eq!(metrics.errors.load(Ordering::SeqCst), 0);
}

#[test]
fn dead_tenant_answers_typed_errors_while_others_keep_serving() {
    // ISSUE 9 regression for the other half of the cascade: a group
    // whose every replica slot was retired by fault recovery used to
    // trip `assert!(n > 0)` in the pool — a dispatcher panic.  Now the
    // dead tenant answers typed errors and tenant b never notices.
    let metrics = Arc::new(Metrics::new());
    let mut reg = ModelRegistry::new();
    // "dead": a single replica that panics on its first request, with
    // a factory that refuses to respawn — after retirement the group
    // is pinned at zero active replicas
    let dead_factory: ReplicaFactory = {
        let built = Arc::new(AtomicUsize::new(0));
        Arc::new(move || {
            if built.fetch_add(1, Ordering::SeqCst) == 0 {
                let inner: Arc<dyn EngineReplica> = Arc::new(DelayReplica::from_ms(0));
                Ok(Arc::new(ChaosReplica::panic_at(inner, 0)) as Arc<dyn EngineReplica>)
            } else {
                Err("spawn refused (chaos)".to_string())
            }
        })
    };
    reg.register_group_scaled("dead", 1, 1, 1, Some(50.0), dead_factory).unwrap();
    let live_factory: ReplicaFactory =
        Arc::new(|| Ok(Arc::new(DelayReplica::from_ms(0)) as Arc<dyn EngineReplica>));
    reg.register_group_scaled("live", 1, 1, 1, Some(50.0), live_factory).unwrap();
    let router = Router::start_multi_with(
        reg.into_groups(),
        BatchPolicy::default(),
        fast_autoscale(),
        Arc::clone(&metrics),
    );

    // first request to "dead" hits the panicking replica: no peer to
    // retry on, so it carries the backend-panic error and the slot is
    // retired on the spot
    let first = ask(&router, "dead");
    assert!(
        first.error.as_deref().unwrap_or("").contains("panicked"),
        "expected the backend panic error, got {:?}",
        first.error
    );
    assert!(
        eventually(Duration::from_secs(10), || router.active_replicas("dead") == Some(0)),
        "faulted slot never retired (at {:?})",
        router.active_replicas("dead")
    );

    // the dead tenant now fails typed — every request answered, none
    // hung, no dispatcher panic — while the live tenant keeps serving
    for i in 0..6 {
        let r = ask(&router, "dead");
        assert!(
            r.error.as_deref().unwrap_or("").contains("no active replicas"),
            "request {i}: expected the typed dead-tenant error, got {:?}",
            r.error
        );
        assert!(ask(&router, "live").error.is_none(), "live tenant degraded at {i}");
    }
    router.shutdown();
    let live = metrics.model(1);
    assert_eq!(live.errors.load(Ordering::SeqCst), 0, "live tenant saw zero errors");
}

#[test]
fn straggler_replica_slows_the_group_but_never_errors() {
    // A 10x straggler next to a clean replica: correctness is
    // untouched (no errors, no faults), only latency moves.  16
    // requests split 8/8: the clean pair finishes in ~16 ms, the
    // straggler's share alone costs ~160 ms.
    let run = |straggle: bool| -> (f64, Arc<Metrics>) {
        let metrics = Arc::new(Metrics::new());
        let mk = || Arc::new(DelayReplica::from_ms(2)) as Arc<dyn EngineReplica>;
        let second: Arc<dyn EngineReplica> = if straggle {
            Arc::new(ChaosReplica::straggler(mk(), 10.0))
        } else {
            mk()
        };
        let pool = ReplicaPool::new_multi(
            vec![ModelGroup::fixed("m", vec![mk(), second], 1)],
            Arc::clone(&metrics),
        );
        let (group, _receivers) = group_of(16);
        let t0 = Instant::now();
        let responses = pool.dispatch(group);
        assert!(responses.iter().all(|r| r.error.is_none()));
        (t0.elapsed().as_secs_f64(), metrics)
    };
    let (clean_s, _) = run(false);
    let (straggler_s, metrics) = run(true);
    let m = metrics.model(0);
    assert_eq!(m.replica_faults.load(Ordering::SeqCst), 0, "slow is not faulted");
    assert_eq!(m.errors.load(Ordering::SeqCst), 0);
    assert!(
        straggler_s > 3.0 * clean_s,
        "straggler {straggler_s:.3}s vs clean {clean_s:.3}s — expected a visible tail"
    );
}
