//! Bit-exact integer arithmetic of the SwiftTron datapath (paper §III).
//!
//! This is the third implementation of the integer spec (after
//! `python/compile/intops.py` and the Pallas kernels) and the functional
//! model the cycle-accurate simulator executes.  Agreement with the
//! python oracle is enforced by golden-vector tests
//! (`artifacts/golden.{bin,json}`, see `rust/tests/integration_golden.rs`).
//!
//! Conventions (identical across all three implementations):
//! * floor rounding everywhere: arithmetic right shifts and
//!   floor-division (`div_floor`), never truncation;
//! * INT64 holds every full-width product before a shifter narrows it,
//!   as the hardware multiplier does;
//! * saturation to `[-128, 127]` only inside Requantization blocks.

pub mod dyadic;
pub mod gelu;
pub mod int4;
pub mod layernorm;
pub mod matmul;
pub mod softmax;

pub use dyadic::{requantize, requantize_signed, rescale, Dyadic};
pub use gelu::{i_gelu, GeluConsts};
pub use int4::{
    bias_int4, i_matmul_int4, i_matmul_int4_epilogue, i_matmul_int4_epilogue_par,
    i_matmul_int4_epilogue_tiled, i_matmul_int4_par, i_matmul_int4_ref,
    i_matmul_int4_ref_epilogue, i_matmul_int4_tiled, int4_from_int8, int4_readout_dyadic,
    pack_int4, unpack_int4, INT4_SHIFT,
};
pub use layernorm::{i_layernorm, i_sqrt, LayerNormConsts, LN_P};
pub use matmul::{
    i_matmul, i_matmul_bt, i_matmul_bt_par, i_matmul_bt_tiled, i_matmul_epilogue,
    i_matmul_epilogue_par, i_matmul_epilogue_tiled, i_matmul_par, i_matmul_tiled, Epilogue,
    PAR_MIN_MACS,
};
pub use softmax::{i_exp, i_softmax, SoftmaxConsts, SM_UNIT};

pub const INT8_MIN: i64 = -128;
pub const INT8_MAX: i64 = 127;

/// Floor division (Python `//` / jnp semantics; Rust `/` truncates).
#[inline]
pub fn div_floor(a: i64, b: i64) -> i64 {
    let q = a / b;
    if (a % b != 0) && ((a < 0) != (b < 0)) {
        q - 1
    } else {
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn div_floor_matches_python() {
        // (a, b, python a//b)
        for (a, b, want) in [
            (7, 2, 3),
            (-7, 2, -4),
            (7, -2, -4),
            (-7, -2, 3),
            (6, 3, 2),
            (-6, 3, -2),
            (0, 5, 0),
            (-1, 1000, -1),
        ] {
            assert_eq!(div_floor(a, b), want, "{a}//{b}");
        }
    }
}
