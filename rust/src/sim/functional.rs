//! Functional (bit-exact) model of one SwiftTron encoder layer.
//!
//! Mirrors `python/compile/model.py::quant_encoder_layer` operation for
//! operation using the `quant` primitives; the integration tests check it
//! against the PJRT-executed Pallas artifact bit-for-bit (the same
//! software-vs-RTL triangle the paper validates with QuestaSim).
//!
//! Besides numerics it returns the data-dependent LayerNorm sqrt
//! iteration counts, which the cycle-accurate simulator can consume when
//! `worst_case_sqrt = false`.

use crate::model::{Geometry, LayerConsts};
use crate::quant::{
    self, i_layernorm, i_matmul_bt_par, i_matmul_par, i_softmax, requantize,
    requantize_signed, rescale, Dyadic, GeluConsts, LayerNormConsts, SoftmaxConsts,
};
use crate::util::rng::Rng;
use std::collections::BTreeMap;

/// One layer's integer weights, row-major (see aot.py WEIGHT_KEYS).
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub wq: Vec<i32>,
    pub bq: Vec<i32>,
    pub wk: Vec<i32>,
    pub bk: Vec<i32>,
    pub wv: Vec<i32>,
    pub bv: Vec<i32>,
    pub wo: Vec<i32>,
    pub bo: Vec<i32>,
    pub w1: Vec<i32>,
    pub b1: Vec<i32>,
    pub w2: Vec<i32>,
    pub b2: Vec<i32>,
    pub gamma1: Vec<i32>,
    pub beta1: Vec<i32>,
    pub gamma2: Vec<i32>,
    pub beta2: Vec<i32>,
}

impl LayerWeights {
    pub fn from_blob(
        blob: &crate::model::Blob,
        layer: usize,
    ) -> Result<LayerWeights, String> {
        let g = |k: &str| blob.i32(&format!("L{layer}.{k}"));
        Ok(LayerWeights {
            wq: g("wq")?, bq: g("bq")?, wk: g("wk")?, bk: g("bk")?,
            wv: g("wv")?, bv: g("bv")?, wo: g("wo")?, bo: g("bo")?,
            w1: g("w1")?, b1: g("b1")?, w2: g("w2")?, b2: g("b2")?,
            gamma1: g("gamma1")?, beta1: g("beta1")?,
            gamma2: g("gamma2")?, beta2: g("beta2")?,
        })
    }

    /// Synthetic INT8-range weights with the same shapes `from_blob`
    /// loads, deterministic in `rng` — the artifact-free model used by
    /// `coordinator::FunctionalEngine`, the serving-scaling bench, and
    /// the functional tests.
    pub fn synthetic(rng: &mut Rng, geo: &Geometry) -> LayerWeights {
        let (d, dff) = (geo.d, geo.d_ff);
        let mut w = |n: usize, lim: i64| -> Vec<i32> {
            (0..n).map(|_| rng.range_i64(-lim, lim) as i32).collect()
        };
        LayerWeights {
            wq: w(d * d, 127), bq: w(d, 1000),
            wk: w(d * d, 127), bk: w(d, 1000),
            wv: w(d * d, 127), bv: w(d, 1000),
            wo: w(d * d, 127), bo: w(d, 1000),
            w1: w(d * dff, 127), b1: w(dff, 1000),
            w2: w(dff * d, 127), b2: w(d, 1000),
            gamma1: w(d, 127), beta1: w(d, 500),
            gamma2: w(d, 127), beta2: w(d, 500),
        }
    }
}

/// A plausible integer design (dyadic scales, softmax/GELU/LayerNorm
/// constants) for a synthetic layer of geometry `geo` — the values the
/// AOT calibration pass would produce for weights in the
/// [`LayerWeights::synthetic`] range.
pub fn synthetic_consts(geo: &Geometry) -> LayerConsts {
    let dy = |x: f64| Dyadic::approx16(x);
    LayerConsts {
        dy_q: dy(0.004), dy_k: dy(0.004), dy_v: dy(0.004),
        dy_scale: Dyadic { b: 1, c: 2 },
        dy_ctx: dy(0.3), dy_res1: dy(0.08),
        dy_ln1: dy(0.005), dy_gelu: Dyadic::approximate(2.0e-7, 14, 52),
        dy_res2: dy(0.08), dy_ln2: dy(0.005),
        softmax: SoftmaxConsts::design(0.0009),
        gelu: GeluConsts::design(0.0004),
        ln1: LayerNormConsts { s_in: 0.02, s_gamma: 0.008, d: geo.d },
        ln2: LayerNormConsts { s_in: 0.02, s_gamma: 0.008, d: geo.d },
        scales: BTreeMap::new(),
    }
}

/// Output of one functional layer evaluation.
pub struct LayerOutput {
    /// INT8-coded activations (stored i32), length m*d, scale `s_out`.
    pub q_out: Vec<i32>,
    /// sqrt iteration counts: ln1 rows then ln2 rows (2*m entries).
    pub sqrt_iters: Vec<u32>,
}

fn requant_all(acc: &[i32], dy: quant::Dyadic) -> Vec<i32> {
    acc.iter().map(|&v| requantize(v as i64, dy)).collect()
}

/// Extract head `h` (columns h*dh..(h+1)*dh) into a contiguous matrix.
fn head_cols(x: &[i32], m: usize, d: usize, h: usize, dh: usize) -> Vec<i32> {
    let mut out = vec![0i32; m * dh];
    for r in 0..m {
        out[r * dh..(r + 1) * dh].copy_from_slice(&x[r * d + h * dh..r * d + (h + 1) * dh]);
    }
    out
}

/// Bit-exact integer encoder layer (paper Figs. 5, 8-15).
pub fn layer_forward(q_x: &[i32], w: &LayerWeights, c: &LayerConsts, geo: &Geometry) -> LayerOutput {
    let (m, d, dff, dh, heads) = (geo.m, geo.d, geo.d_ff, geo.dh(), geo.heads);
    assert_eq!(q_x.len(), m * d);

    // --- Q/K/V projections + Requantization ---
    let mut acc = vec![0i32; m * d];
    i_matmul_par(q_x, &w.wq, Some(&w.bq), m, d, d, &mut acc);
    let q8 = requant_all(&acc, c.dy_q);
    i_matmul_par(q_x, &w.wk, Some(&w.bk), m, d, d, &mut acc);
    let k8 = requant_all(&acc, c.dy_k);
    i_matmul_par(q_x, &w.wv, Some(&w.bv), m, d, d, &mut acc);
    let v8 = requant_all(&acc, c.dy_v);

    // --- Attention per head: MatMul -> Scale -> Softmax -> Req -> MatMul ---
    let mut ctx_acc = vec![0i32; m * d];
    let mut scores = vec![0i32; m * m];
    let mut probs = vec![0i32; m * m];
    for h in 0..heads {
        let qh = head_cols(&q8, m, d, h, dh);
        let kh = head_cols(&k8, m, d, h, dh);
        let vh = head_cols(&v8, m, d, h, dh);
        i_matmul_bt_par(&qh, &kh, m, dh, m, &mut scores);
        // Scale block + Softmax rows
        let mut row64 = vec![0i64; m];
        for r in 0..m {
            for (dst, &s) in row64.iter_mut().zip(&scores[r * m..(r + 1) * m]) {
                *dst = rescale(s as i64, c.dy_scale);
            }
            i_softmax(&row64, &c.softmax, &mut probs[r * m..(r + 1) * m]);
        }
        // P.V into the head's slice of the context accumulator
        let mut ctx_h = vec![0i32; m * dh];
        i_matmul_par(&probs, &vh, None, m, m, dh, &mut ctx_h);
        for r in 0..m {
            ctx_acc[r * d + h * dh..r * d + (h + 1) * dh]
                .copy_from_slice(&ctx_h[r * dh..(r + 1) * dh]);
        }
    }
    let ctx8 = requant_all(&ctx_acc, c.dy_ctx);

    // --- output projection + residual align + LayerNorm 1 ---
    let mut attn_acc = vec![0i32; m * d];
    i_matmul_par(&ctx8, &w.wo, Some(&w.bo), m, d, d, &mut attn_acc);
    let res1: Vec<i64> = q_x
        .iter()
        .zip(&attn_acc)
        .map(|(&x, &a)| x as i64 + rescale(a as i64, c.dy_res1) as i32 as i64)
        .collect();
    let g1: Vec<i64> = w.gamma1.iter().map(|&v| v as i64).collect();
    let b1v: Vec<i64> = w.beta1.iter().map(|&v| v as i64).collect();
    let mut ln1 = vec![0i32; m * d];
    let mut sqrt_iters = Vec::with_capacity(2 * m);
    for r in 0..m {
        let it = i_layernorm(&res1[r * d..(r + 1) * d], &g1, &b1v, &c.ln1, &mut ln1[r * d..(r + 1) * d]);
        sqrt_iters.push(it);
    }
    let x2 = requant_all(&ln1, c.dy_ln1);

    // --- FFN: MatMul -> GELU -> Req -> MatMul ---
    let mut h_acc = vec![0i32; m * dff];
    i_matmul_par(&x2, &w.w1, Some(&w.b1), m, d, dff, &mut h_acc);
    let h8: Vec<i32> = h_acc
        .iter()
        .map(|&v| requantize_signed(quant::i_gelu(v as i64, &c.gelu), c.dy_gelu, -1))
        .collect();
    let mut ffn_acc = vec![0i32; m * d];
    i_matmul_par(&h8, &w.w2, Some(&w.b2), m, dff, d, &mut ffn_acc);

    // --- residual align + LayerNorm 2 + output requant ---
    let res2: Vec<i64> = x2
        .iter()
        .zip(&ffn_acc)
        .map(|(&x, &a)| x as i64 + rescale(a as i64, c.dy_res2) as i32 as i64)
        .collect();
    let g2: Vec<i64> = w.gamma2.iter().map(|&v| v as i64).collect();
    let b2v: Vec<i64> = w.beta2.iter().map(|&v| v as i64).collect();
    let mut ln2 = vec![0i32; m * d];
    for r in 0..m {
        let it = i_layernorm(&res2[r * d..(r + 1) * d], &g2, &b2v, &c.ln2, &mut ln2[r * d..(r + 1) * d]);
        sqrt_iters.push(it);
    }
    LayerOutput { q_out: requant_all(&ln2, c.dy_ln2), sqrt_iters }
}

/// Full integer encoder stack.
pub fn encoder_forward(
    q_x: &[i32],
    layers: &[(LayerWeights, LayerConsts)],
    geo: &Geometry,
) -> (Vec<i32>, Vec<u32>) {
    let mut h = q_x.to_vec();
    let mut iters = Vec::new();
    for (w, c) in layers {
        let out = layer_forward(&h, w, c, geo);
        h = out.q_out;
        iters.extend(out.sqrt_iters);
    }
    (h, iters)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_geo() -> Geometry {
        Geometry::new(16, 2, 8, 32, 1)
    }

    fn rand_w(rng: &mut Rng, n: usize, lim: i64) -> Vec<i32> {
        (0..n).map(|_| rng.range_i64(-lim, lim) as i32).collect()
    }

    fn consts(geo: &Geometry) -> LayerConsts {
        synthetic_consts(geo)
    }

    fn weights(rng: &mut Rng, geo: &Geometry) -> LayerWeights {
        LayerWeights::synthetic(rng, geo)
    }

    #[test]
    fn output_is_int8_coded() {
        let geo = tiny_geo();
        let mut rng = Rng::new(3);
        let w = weights(&mut rng, &geo);
        let c = consts(&geo);
        let x = rand_w(&mut rng, geo.m * geo.d, 127);
        let out = layer_forward(&x, &w, &c, &geo);
        assert!(out.q_out.iter().all(|&v| (-128..=127).contains(&v)));
        assert_eq!(out.sqrt_iters.len(), 2 * geo.m);
    }

    #[test]
    fn deterministic() {
        let geo = tiny_geo();
        let mut rng = Rng::new(3);
        let w = weights(&mut rng, &geo);
        let c = consts(&geo);
        let x = rand_w(&mut rng, geo.m * geo.d, 127);
        let a = layer_forward(&x, &w, &c, &geo).q_out;
        let b = layer_forward(&x, &w, &c, &geo).q_out;
        assert_eq!(a, b);
    }

    #[test]
    fn input_sensitivity() {
        let geo = tiny_geo();
        let mut rng = Rng::new(4);
        let w = weights(&mut rng, &geo);
        let c = consts(&geo);
        let x = rand_w(&mut rng, geo.m * geo.d, 127);
        let mut x2 = x.clone();
        for v in x2.iter_mut().take(geo.d) {
            *v = (*v + 40).min(127);
        }
        let a = layer_forward(&x, &w, &c, &geo).q_out;
        let b = layer_forward(&x2, &w, &c, &geo).q_out;
        assert_ne!(a, b);
    }

    #[test]
    fn encoder_stacks_layers() {
        let geo = Geometry::new(16, 2, 8, 32, 2);
        let mut rng = Rng::new(5);
        let layers: Vec<_> = (0..2)
            .map(|_| (weights(&mut rng, &geo), consts(&geo)))
            .collect();
        let x = rand_w(&mut rng, geo.m * geo.d, 127);
        let (out, iters) = encoder_forward(&x, &layers, &geo);
        assert_eq!(out.len(), geo.m * geo.d);
        assert_eq!(iters.len(), 2 * 2 * geo.m);
    }
}
