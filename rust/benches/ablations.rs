//! Ablations over the design choices DESIGN.md calls out:
//!   1. FP32-datapath twin (why integer-only wins — Fig. 1a vs 1b),
//!   2. worst-case vs data-dependent LayerNorm sqrt timing (footnote 3),
//!   3. head-parallelism waves (Fig. 9's "choice of number of heads"),
//!   4. dyadic multiplier width (requantization precision/cost knob).

use swifttron::baselines::fp32_asic_report;
use swifttron::model::Geometry;
use swifttron::quant::Dyadic;
use swifttron::sim::{simulate_encoder, HwConfig};
use swifttron::util::bench::Table;

fn main() {
    let geo = Geometry::preset("roberta_base").unwrap();
    let paper = HwConfig::paper();

    // 1. FP32 twin
    let fp = fp32_asic_report(&paper, &geo);
    let mut t = Table::new(&["design", "area", "power", "latency"]);
    t.row(&["INT8 SwiftTron (ours)".into(), "1.00x".into(), "1.00x".into(), "1.00x".into()]);
    t.row(&[
        "FP32-datapath twin".into(),
        format!("{:.1}x", fp.area_ratio),
        format!("{:.1}x", fp.power_ratio),
        format!("{:.1}x", fp.latency_ratio),
    ]);
    t.print("ablation 1 — arithmetic choice (Fig. 1a vs 1b at system level)");

    // 2. sqrt timing policy: worst-case (32 iters, paper fn.3) vs the
    // typical data-dependent count observed in co-simulation (~12).
    let wc = simulate_encoder(&paper, &geo);
    let dd_cfg = HwConfig { worst_case_sqrt: false, ..paper };
    // 2*m entries per layer: ln1 rows then ln2 rows (the functional
    // model's sqrt_iters layout the simulator consumes)
    let typical_iters = vec![12u32; 2 * geo.m];
    let mut dd = swifttron::sim::encoder::LatencyReport::default();
    let mut t_cycles = 0;
    for _ in 0..geo.layers {
        t_cycles = swifttron::sim::simulate_layer(
            &dd_cfg, &geo, t_cycles, &mut dd.trace, &mut dd.per_block, Some(&typical_iters),
        );
    }
    dd.total_cycles = t_cycles;
    let mut t = Table::new(&["sqrt policy", "cycles", "ms"]);
    t.row(&["worst-case (paper fn.3)".into(), format!("{}", wc.total_cycles), format!("{:.3}", wc.ms(&paper))]);
    t.row(&["data-dependent (typ. 12 iters)".into(), format!("{}", dd.total_cycles), format!("{:.3}", dd.ms(&dd_cfg))]);
    t.print("ablation 2 — LayerNorm iterative-sqrt timing policy");

    // 3. head parallelism
    let mut t = Table::new(&["parallel heads", "cycles", "ms"]);
    for ph in [1, 2, 4, 6, 12] {
        let cfg = HwConfig { parallel_heads: ph, ..paper };
        let r = simulate_encoder(&cfg, &geo);
        t.row(&[format!("{ph}"), format!("{}", r.total_cycles), format!("{:.3}", r.ms(&cfg))]);
    }
    t.print("ablation 3 — attention-head parallelism (Fig. 9)");

    // 4. dyadic width: approximation error of the requantization ratio
    let mut t = Table::new(&["dyadic bits", "max rel error over 1e-4..1e2"]);
    for bits in [8u32, 12, 16, 20] {
        let mut worst: f64 = 0.0;
        let mut x = 1e-4;
        while x < 100.0 {
            let dy = Dyadic::approximate(x, bits, 40);
            worst = worst.max(((dy.value() - x) / x).abs());
            x *= 1.37;
        }
        t.row(&[format!("{bits}"), format!("{worst:.2e}")]);
    }
    t.print("ablation 4 — requantization multiplier width (Eq. 2)");
}
