"""SwiftTron compile path (build-time only; never on the request path).

Enables 64-bit mode globally: the integer spec uses INT64 full-width
products (hardware multiplier outputs) which jax silently truncates to 32
bits otherwise.
"""

import jax

jax.config.update("jax_enable_x64", True)
