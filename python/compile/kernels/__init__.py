"""L1: Pallas kernels for every SwiftTron hardware block.

All kernels run with ``interpret=True`` (the CPU PJRT client cannot run
Mosaic custom-calls); block shapes are still MXU/VMEM-shaped so the same
code targets real TPUs.  Correctness oracles live in ``ref``.
"""

from .gelu import i_gelu
from .int_matmul import int_matmul
from .layernorm import i_layernorm
from .requant import requantize
from .softmax import i_softmax

__all__ = ["i_gelu", "int_matmul", "i_layernorm", "requantize", "i_softmax"]
