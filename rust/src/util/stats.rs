//! Latency/throughput statistics for the coordinator's metrics and the
//! bench harness: exact online moments (Welford) plus bounded-memory
//! percentiles from a fixed-size reservoir.
//!
//! The original `Series` kept every sample forever and cloned + sorted
//! the full history on each `percentile()` call — fine for a bench
//! iteration, fatal for a day-long serving daemon whose metrics mutex
//! is on the request path (ROADMAP item 5c).  This revision keeps the
//! same API on O(1) space:
//!
//! * `mean` / `min` / `max` / `stddev` / `len` are **exact** for the
//!   whole stream, maintained incrementally (Welford's algorithm for
//!   the variance — numerically stable, no catastrophic cancellation).
//! * `percentile` reads a fixed-capacity uniform sample of the stream
//!   (Algorithm R reservoir sampling, seeded by a deterministic
//!   in-struct [`Rng`]): below capacity the reservoir holds every
//!   sample and percentiles are exact; beyond it each seen sample has
//!   equal probability `cap/n` of being resident, so the quantile
//!   estimate's standard error is `~sqrt(p(1-p)/cap)/f(q_p)` —
//!   with the default capacity of 4096 that is well under 1% of the
//!   distribution's scale for p50..p99 (asserted against exact
//!   percentiles on known distributions in the tests below).
//!
//! Determinism: the replacement index stream depends only on the push
//! sequence, so two `Series` fed identical samples report identical
//! percentiles — the property suite and the committed bench snapshot
//! rely on this.

use super::rng::Rng;

/// Default reservoir capacity: 32 KiB of `f64` per series, chosen so
/// p99 of a day of traffic is still resolved by ~41 samples above it.
const DEFAULT_RESERVOIR: usize = 4096;

#[derive(Clone, Debug)]
pub struct Series {
    /// total samples pushed (not the resident count)
    count: u64,
    mean: f64,
    /// Welford's running sum of squared deviations
    m2: f64,
    min: f64,
    max: f64,
    reservoir: Vec<f64>,
    cap: usize,
    rng: Rng,
}

impl Default for Series {
    fn default() -> Self {
        Series::with_capacity(DEFAULT_RESERVOIR)
    }
}

impl Series {
    pub fn new() -> Self {
        Series::default()
    }

    /// A series whose percentile reservoir holds `cap` samples (exact
    /// below `cap`, uniform subsample beyond it).
    pub fn with_capacity(cap: usize) -> Self {
        assert!(cap > 0, "reservoir capacity must be positive");
        Series {
            count: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            reservoir: Vec::new(),
            cap,
            // Fixed seed: the reservoir's sampling pattern is part of
            // the series' deterministic behavior, not entropy.
            rng: Rng::new(0x5EED_5157),
        }
    }

    pub fn push(&mut self, v: f64) {
        self.count += 1;
        let delta = v - self.mean;
        self.mean += delta / self.count as f64;
        self.m2 += delta * (v - self.mean);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        if self.reservoir.len() < self.cap {
            self.reservoir.push(v);
        } else {
            // Algorithm R: the i-th sample (1-based) replaces a
            // resident one with probability cap/i, keeping the
            // reservoir a uniform sample of everything seen.
            let j = self.rng.below(self.count);
            if (j as usize) < self.cap {
                self.reservoir[j as usize] = v;
            }
        }
    }

    /// Total samples pushed over the series' lifetime.
    pub fn len(&self) -> usize {
        self.count as usize
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Samples resident in the percentile reservoir (== `len()` until
    /// the capacity is exceeded).
    pub fn resident(&self) -> usize {
        self.reservoir.len()
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.mean
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn stddev(&self) -> f64 {
        if self.count < 2 {
            return 0.0;
        }
        (self.m2 / (self.count - 1) as f64).sqrt()
    }

    /// Percentile (nearest-rank on the sorted reservoir), p in [0,100].
    /// Exact while the stream fits the reservoir; a uniform-subsample
    /// estimate beyond that.  The sort touches at most `cap` resident
    /// samples, whatever the stream length.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.reservoir.is_empty() {
            return f64::NAN;
        }
        let mut s = self.reservoir.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
        s[rank.min(s.len() - 1)]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn summary(&self, unit: &str) -> String {
        format!(
            "n={} mean={:.3}{u} p50={:.3}{u} p99={:.3}{u} min={:.3}{u} max={:.3}{u}",
            self.len(),
            self.mean(),
            self.p50(),
            self.p99(),
            self.min(),
            self.max(),
            u = unit
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments() {
        let mut s = Series::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.push(v);
        }
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.stddev() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let mut s = Series::new();
        for v in 1..=100 {
            s.push(v as f64);
        }
        assert!((50.0..=51.0).contains(&s.p50()), "{}", s.p50());
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert!(s.p99() >= 98.0);
    }

    #[test]
    fn empty_is_nan() {
        assert!(Series::new().mean().is_nan());
        assert!(Series::new().percentile(50.0).is_nan());
    }

    #[test]
    fn space_is_bounded_and_moments_stay_exact_past_capacity() {
        let mut s = Series::with_capacity(64);
        let n = 10_000u64;
        for v in 1..=n {
            s.push(v as f64);
        }
        assert_eq!(s.len(), n as usize, "len counts the whole stream");
        assert_eq!(s.resident(), 64, "reservoir never exceeds capacity");
        // exact moments survive the subsampling
        assert!((s.mean() - (n + 1) as f64 / 2.0).abs() < 1e-9);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), n as f64);
        let exact_sd = ((n * n - 1) as f64 / 12.0).sqrt(); // uniform 1..=n
        assert!((s.stddev() - exact_sd).abs() / exact_sd < 1e-3, "{}", s.stddev());
    }

    #[test]
    fn identical_push_streams_give_identical_percentiles() {
        let mk = || {
            let mut rng = Rng::new(17);
            let mut s = Series::with_capacity(128);
            for _ in 0..5_000 {
                s.push(rng.exponential(3.0));
            }
            s
        };
        let (a, b) = (mk(), mk());
        for p in [0.0, 25.0, 50.0, 90.0, 99.0, 100.0] {
            assert_eq!(a.percentile(p), b.percentile(p), "p{p}");
        }
    }

    #[test]
    fn reservoir_percentiles_track_exact_on_known_distributions() {
        // Accuracy bound for the default 4096-slot reservoir against
        // exact percentiles of the same 200k-sample stream — uniform
        // and exponential, the shapes serving latencies actually take.
        let check = |name: &str, samples: &[f64], tol_of_scale: f64| {
            let mut s = Series::new();
            let mut exact = samples.to_vec();
            for &v in samples {
                s.push(v);
            }
            exact.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let scale = exact[exact.len() - 1] - exact[0];
            for p in [50.0, 90.0, 99.0] {
                let rank = ((p / 100.0) * (exact.len() as f64 - 1.0)).round() as usize;
                let truth = exact[rank];
                let est = s.percentile(p);
                assert!(
                    (est - truth).abs() <= tol_of_scale * scale,
                    "{name} p{p}: est {est} vs exact {truth} (scale {scale})"
                );
            }
        };
        let n = 200_000;
        let mut rng = Rng::new(23);
        let uniform: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        check("uniform[0,1)", &uniform, 0.02);
        let mut rng = Rng::new(29);
        let expo: Vec<f64> = (0..n).map(|_| rng.exponential(1.0)).collect();
        // the exponential's max stretches the scale, so the relative
        // tolerance on range is looser in absolute quantile terms
        check("exponential(1)", &expo, 0.05);
    }
}
