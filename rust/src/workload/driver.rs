//! Open-loop trace driver: submits a recorded arrival stream against a
//! [`Router`] at its recorded wall-clock offsets, whether or not
//! earlier requests have completed — the arrival process never waits on
//! the service process, so queueing under offered load is visible
//! (closed-loop drivers structurally hide it).
//!
//! Recording and replay are two views of the same [`Trace`]: a live run
//! driven by [`run_process`] records the `(t_arrival, model, len)`
//! stream it submits, and [`replay`] of that recording reproduces the
//! submission sequence bit-identically (same timestamps, same models,
//! same token vectors — the tokens are a pure function of the recorded
//! length).

use std::sync::mpsc::channel;
use std::time::{Duration, Instant};

use super::arrival::ArrivalProcess;
use super::trace::Trace;
use crate::coordinator::Router;

/// Outcome of one open-loop run.
#[derive(Clone, Debug)]
pub struct ReplaySummary {
    /// requests submitted (== the trace length)
    pub sent: usize,
    /// replies with no error
    pub completed: usize,
    /// replies carrying a typed error
    pub errors: usize,
    /// requests whose reply never arrived before the drain timeout —
    /// the zero-loss chaos legs assert this is 0
    pub lost: usize,
    /// wall time from first submission to last reply (or timeout)
    pub wall_s: f64,
    /// the exact stream this run submitted; replaying it reproduces
    /// the run's submissions bit-identically
    pub recorded: Trace,
}

impl ReplaySummary {
    /// Offered arrival rate over the recorded stream's span.
    pub fn offered_rps(&self) -> f64 {
        let span = self.recorded.duration_s();
        if span > 0.0 {
            self.sent as f64 / span
        } else {
            0.0
        }
    }

    /// Successful replies per wall-clock second.
    pub fn achieved_rps(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.completed as f64 / self.wall_s
        } else {
            0.0
        }
    }
}

/// Deterministic token vector for a recorded request length: replaying
/// a trace re-submits byte-identical payloads.
pub fn tokens_for(len: u16) -> Vec<i32> {
    (0..len.max(1) as i32).map(|t| t % 50).collect()
}

/// Replay `trace` open-loop against `router`.  Each event's submission
/// is paced to its recorded offset scaled by `time_scale` (1.0 = real
/// time, 0.5 = twice as fast); trace model indices map to the router's
/// model list in order.  Blocks until every reply has arrived or
/// `drain_timeout` has elapsed past the last submission; missing
/// replies are counted as `lost`, never silently dropped.
pub fn replay(
    router: &Router,
    trace: &Trace,
    time_scale: f64,
    drain_timeout: Duration,
) -> ReplaySummary {
    assert!(time_scale > 0.0, "time_scale must be positive");
    let names: Vec<String> = router.model_names().iter().map(|s| s.to_string()).collect();
    let (tx, rx) = channel();
    let mut recorded = Trace::new();
    let t0 = Instant::now();
    for ev in trace.events() {
        let target = Duration::from_secs_f64(ev.t_ns as f64 / 1e9 * time_scale);
        let now = t0.elapsed();
        if target > now {
            std::thread::sleep(target - now);
        }
        let name = names
            .get(ev.model as usize)
            .unwrap_or_else(|| panic!("trace model {} not registered on router", ev.model));
        recorded.push_event(*ev);
        router.submit_to(name, tokens_for(ev.len), tx.clone());
    }
    drop(tx);
    let sent = trace.len();
    let mut completed = 0usize;
    let mut errors = 0usize;
    let deadline = Instant::now() + drain_timeout;
    while completed + errors < sent {
        let left = deadline.saturating_duration_since(Instant::now());
        if left.is_zero() {
            break;
        }
        match rx.recv_timeout(left) {
            Ok(resp) => {
                if resp.error.is_none() {
                    completed += 1;
                } else {
                    errors += 1;
                }
            }
            Err(_) => break,
        }
    }
    ReplaySummary {
        sent,
        completed,
        errors,
        lost: sent - completed - errors,
        wall_s: t0.elapsed().as_secs_f64(),
        recorded,
    }
}

/// Drive an arrival process live for one tenant, recording the stream
/// it submits.  `replay(&summary.recorded, ...)` reproduces this run's
/// submissions bit-identically — that recording can also be
/// [`Trace::save`]d and reloaded byte-exactly.
pub fn run_process(
    router: &Router,
    process: &ArrivalProcess,
    seed: u64,
    horizon_s: f64,
    model: usize,
    len_range: (usize, usize),
    drain_timeout: Duration,
) -> ReplaySummary {
    let trace = Trace::from_process(process, seed, horizon_s, model, len_range);
    replay(router, &trace, 1.0, drain_timeout)
}
