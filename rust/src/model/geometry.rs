//! Transformer geometry (the paper's d, k, m, d_ff) and the presets the
//! evaluation uses (paper §IV-B, Table II).

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Geometry {
    /// model dimension d
    pub d: usize,
    /// number of attention heads k
    pub heads: usize,
    /// sentence length m
    pub m: usize,
    /// feed-forward dimension
    pub d_ff: usize,
    /// encoder layer count
    pub layers: usize,
}

impl Geometry {
    pub const fn new(d: usize, heads: usize, m: usize, d_ff: usize, layers: usize) -> Self {
        Geometry { d, heads, m, d_ff, layers }
    }

    /// Head dimension d/k.
    pub fn dh(&self) -> usize {
        self.d / self.heads
    }

    /// Total parameter count of the encoder stack (weights + biases +
    /// layernorm affines), the standard 12·d² + 13·d per layer identity
    /// for d_ff = 4d, computed exactly from the fields.
    pub fn param_count(&self) -> u64 {
        let d = self.d as u64;
        let dff = self.d_ff as u64;
        let per_layer = 4 * d * d + 4 * d      // QKV+O weights & biases
            + d * dff + dff                    // FFN in
            + dff * d + d                      // FFN out
            + 4 * d; // two layernorm affine pairs
        per_layer * self.layers as u64
    }

    /// MAC count of one full encoder forward pass (the roofline input).
    pub fn macs_per_inference(&self) -> u64 {
        let d = self.d as u64;
        let m = self.m as u64;
        let dff = self.d_ff as u64;
        let dh = self.dh() as u64;
        let heads = self.heads as u64;
        let qkv = 3 * m * d * d;
        let scores = heads * m * m * dh;
        let ctx = heads * m * m * dh;
        let proj = m * d * d;
        let ffn = m * d * dff + m * dff * d;
        (qkv + scores + ctx + proj + ffn) * self.layers as u64
    }

    /// Every name [`Geometry::preset`] accepts, in evaluation order
    /// (paper Table II) — the id space the multi-tenant registry
    /// (`coordinator::registry`) exposes.
    pub const PRESET_NAMES: [&str; 5] =
        ["tiny", "small", "roberta_base", "roberta_large", "deit_s"];

    /// Named presets matching `python/compile/model.py::GEOMETRIES`.
    pub fn preset(name: &str) -> Option<Geometry> {
        Some(match name {
            "tiny" => Geometry::new(64, 4, 32, 128, 2),
            "small" => Geometry::new(128, 4, 64, 512, 4),
            "roberta_base" => Geometry::new(768, 12, 256, 3072, 12),
            "roberta_large" => Geometry::new(1024, 16, 256, 4096, 24),
            "deit_s" => Geometry::new(384, 6, 197, 1536, 12),
            _ => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roberta_base_params_near_85m_encoder() {
        let g = Geometry::preset("roberta_base").unwrap();
        let p = g.param_count();
        // encoder-only parameter count of RoBERTa-base is ~85.0M
        assert!((84_000_000..87_000_000).contains(&p), "{p}");
    }

    #[test]
    fn head_dim_is_64_for_paper_models() {
        assert_eq!(Geometry::preset("roberta_base").unwrap().dh(), 64);
        assert_eq!(Geometry::preset("deit_s").unwrap().dh(), 64);
        assert_eq!(Geometry::preset("roberta_large").unwrap().dh(), 64);
    }

    #[test]
    fn macs_scale_superlinearly_with_d() {
        let base = Geometry::preset("roberta_base").unwrap().macs_per_inference();
        let large = Geometry::preset("roberta_large").unwrap().macs_per_inference();
        assert!(large > 2 * base);
    }

    #[test]
    fn unknown_preset_is_none() {
        assert!(Geometry::preset("gpt5").is_none());
    }

    #[test]
    fn preset_names_round_trip() {
        for name in Geometry::PRESET_NAMES {
            assert!(Geometry::preset(name).is_some(), "{name} listed but not resolvable");
        }
        assert_eq!(Geometry::PRESET_NAMES.len(), 5);
    }
}
