"""Pallas INT8 x INT8 -> INT32 tiled matmul (the MatMul block, paper Fig. 6).

TPU mapping of the ASIC's output-stationary MAC array: the grid walks
(M, N) output tiles — each grid point *is* one "MAC array load" — and an
inner K dimension streams row/column operand panels through the tile,
exactly as the ASIC scans inputs before the column-by-column readout.
Blocks are VMEM-resident (BlockSpec); the INT32 accumulator lives in the
output tile like the MAC accumulator registers.

Block shapes default to MXU-friendly multiples of 128 but shrink to the
problem size so tiny test geometries stay exact.  Defaults (256, 768,
768) come from the EXPERIMENTS.md SPerf sweep: ~2.8x over the initial
(128,128,128) tiling on the d_ff panels, with a ~1.6 MB VMEM footprint
(x-tile i8 + w-tile i8 + i32 accumulator tile) — well inside a TPU
core's ~16 MB VMEM, so the same schedule maps to real hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mm_kernel(x_ref, w_ref, o_ref, *, n_k: int):
    """One (bm, bn) output tile; grid = (M/bm, N/bn, K/bk), K minor."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.int32)


def _mm_bias_kernel(x_ref, w_ref, b_ref, o_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.int32)

    # Bias is folded in at readout time (paper: "added when reading the
    # output matrix"), i.e. on the last K panel.
    @pl.when(k == n_k - 1)
    def _bias():
        o_ref[...] += b_ref[...].astype(jnp.int32)


def _pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is <= preferred (keeps tiles exact)."""
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def int_matmul(q_x, q_w, q_bias=None, *, bm: int = 256, bn: int = 768, bk: int = 768):
    """(m, k) INT8/INT32 x (k, n) INT8/INT32 -> (m, n) INT32 (+ bias).

    ``q_bias`` is an INT32 row vector at the accumulator scale s_x * s_w.
    """
    m, k = q_x.shape
    k2, n = q_w.shape
    assert k == k2, f"contraction mismatch: {q_x.shape} @ {q_w.shape}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)

    x_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
    w_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))

    if q_bias is None:
        return pl.pallas_call(
            functools.partial(_mm_kernel, n_k=n_k),
            grid=grid,
            in_specs=[x_spec, w_spec],
            out_specs=o_spec,
            out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
            interpret=True,
        )(q_x.astype(jnp.int8), q_w.astype(jnp.int8))

    b_spec = pl.BlockSpec((1, bn), lambda i, j, kk: (0, j))
    return pl.pallas_call(
        functools.partial(_mm_bias_kernel, n_k=n_k),
        grid=grid,
        in_specs=[x_spec, w_spec, b_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=True,
    )(q_x.astype(jnp.int8), q_w.astype(jnp.int8), q_bias.reshape(1, n).astype(jnp.int32))
