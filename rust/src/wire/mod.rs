//! `SWWIRE1` — the length-prefixed binary wire protocol and the
//! non-blocking connection multiplexer behind `swifttron serve`
//! (DESIGN.md §11).
//!
//! The legacy front door (`coordinator::server`) speaks
//! newline-delimited text: one thread per connection, one `String`
//! allocation per request line, one blocking `recv` per response.
//! That was fine for a demo client and is hopeless for thousands of
//! connections.  This module replaces it with:
//!
//! * [`frame`] — the byte layout: an 8-byte connection preamble
//!   (`b"SWWIRE1\0"`) followed by little-endian length-prefixed
//!   frames.  Request frames carry id / model id / token slice;
//!   response frames carry id / label / logits / timing, plus typed
//!   `Error`, `Overloaded` (SLO admission rejection) and `Busy`
//!   (connection-cap rejection) kinds.
//! * [`decode`] — a zero-copy pull decoder in the idiom of
//!   picojson-rs's `SliceParser`: requests are parsed *in place* out
//!   of a fixed per-connection ring buffer ([`decode::RingBuf`]),
//!   yielding borrowed [`frame::RequestView`]s.  After warm-up the
//!   decode hot path performs **zero heap allocations per request**
//!   (proved by the counting-allocator harness in
//!   `rust/tests/workspace_alloc.rs`).
//! * [`encode`] — the mirror image: responses are serialized into a
//!   reusable per-connection output buffer, no intermediate strings.
//! * [`mux`] — the non-blocking multiplexer: N connections per I/O
//!   thread over `set_nonblocking` sockets in a level-triggered loop
//!   (std only, no new dependencies), bounded per-connection
//!   read/write buffers, out-of-order completion keyed by frame id,
//!   backpressure into the batcher when a write buffer fills, and
//!   SLO-derived admission control (the model's `CostModel`-priced
//!   backlog over its active replicas vs the group's `slo_ms` — the
//!   same predicted-work signal the autoscaler trusts).  The legacy
//!   text protocol survives behind auto-detection on a connection's
//!   first bytes.
//! * [`client`] — a small blocking client used by tests, the workload
//!   driver's socket replay, and the ingest benches.

pub mod client;
pub mod decode;
pub mod encode;
pub mod frame;
pub mod mux;

pub use client::WireClient;
pub use decode::{DecodeEvent, FrameDecoder, RingBuf};
pub use frame::{RequestView, ResponseFrame, PREAMBLE};
pub use mux::{MuxConfig, MuxServer};
