//! Loopback integration suite for the `SWWIRE1` binary wire protocol
//! and the non-blocking connection multiplexer (DESIGN.md §11):
//! pipelining with out-of-order completion, malformed / oversized /
//! truncated frames answered without connection teardown, text-vs-
//! binary auto-detection on one port, connection caps on both front
//! doors, SLO load shedding under a tenant flood with zero loss of
//! accepted requests, and the socket-level trace replay driver.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};
use swifttron::coordinator::server::TextServer;
use swifttron::coordinator::{
    BatchPolicy, EngineReplica, Metrics, ModelRegistry, ReplicaFactory, Router,
};
use swifttron::wire::{encode, MuxConfig, MuxServer, ResponseFrame, WireClient};
use swifttron::workload::{replay_wire, ArrivalProcess, DelayReplica, Trace};

const RECV_TIMEOUT: Duration = Duration::from_secs(30);

fn policy() -> BatchPolicy {
    BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(200), bucket_width: 0 }
}

/// Router of fixed single-replica groups: `(name, service_ms)` each.
fn router_with(models: &[(&str, u64)]) -> (Arc<Router>, Arc<Metrics>) {
    let metrics = Arc::new(Metrics::new());
    let mut reg = ModelRegistry::new();
    for (name, ms) in models {
        reg.register_group(
            name,
            vec![Arc::new(DelayReplica::from_ms(*ms)) as Arc<dyn EngineReplica>],
            1,
        )
        .unwrap();
    }
    let router = Arc::new(Router::start_multi(reg.into_groups(), policy(), Arc::clone(&metrics)));
    (router, metrics)
}

/// Best-effort router shutdown once every server clone is gone.
fn stop(router: Arc<Router>) {
    if let Ok(r) = Arc::try_unwrap(router) {
        r.shutdown();
    }
}

fn eventually(timeout: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let deadline = Instant::now() + timeout;
    while Instant::now() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    cond()
}

#[test]
fn binary_round_trip_pipelines_and_completes_out_of_order() {
    let (router, _metrics) = router_with(&[("fast", 0), ("slow", 40)]);
    let server =
        MuxServer::start(Arc::clone(&router), "127.0.0.1:0", MuxConfig::default()).unwrap();
    let mut c = WireClient::connect(server.local_addr()).unwrap();
    c.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
    // one slow request queued FIRST, then a burst of fast ones — all
    // flushed as a single pipelined write
    c.queue(100, "slow", &[1, 2, 3]);
    for id in 0..8u64 {
        c.queue(id, "fast", &[1, 2]);
    }
    c.flush().unwrap();
    let frames = c.recv_n(9).unwrap();
    // no head-of-line blocking: the slow model's reply arrives last
    assert_eq!(frames.last().unwrap().id(), 100, "slow reply should be overtaken: {frames:?}");
    let mut ids: Vec<u64> = frames.iter().map(ResponseFrame::id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1, 2, 3, 4, 5, 6, 7, 100]);
    for f in &frames {
        assert!(f.is_ok(), "{f:?}");
        if let ResponseFrame::Ok { logits, .. } = f {
            assert!(!logits.is_empty(), "ok frame must carry logits");
        }
    }
    server.shutdown();
    stop(router);
}

#[test]
fn malformed_and_oversized_frames_get_typed_errors_without_teardown() {
    let (router, _metrics) = router_with(&[("tiny", 0)]);
    let cfg = MuxConfig { read_buf: 4096, ..MuxConfig::default() };
    let server = MuxServer::start(Arc::clone(&router), "127.0.0.1:0", cfg).unwrap();
    let mut c = WireClient::connect(server.local_addr()).unwrap();
    c.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();

    // malformed: a token count that disagrees with the frame length
    let mut bad = Vec::new();
    encode::encode_request(&mut bad, 7, "tiny", &[1, 2]);
    let ntok_at = bad.len() - 8 - 2; // two i32 tokens, u16 count before them
    bad[ntok_at] = 99;
    c.send_raw(&bad).unwrap();
    match c.recv().unwrap() {
        ResponseFrame::Error { id, message } => {
            assert_eq!(id, 7, "frame id echoed on the typed error");
            assert!(message.contains("token count"), "{message}");
        }
        other => panic!("expected typed error, got {other:?}"),
    }

    // oversized: a header claiming more than the ring admits; the
    // typed error arrives immediately, before the body has streamed
    let claimed = 1_000_000u32;
    let mut over = Vec::new();
    over.extend_from_slice(&claimed.to_le_bytes());
    over.push(1); // KIND_REQUEST
    over.extend_from_slice(&9u64.to_le_bytes());
    c.send_raw(&over).unwrap();
    match c.recv().unwrap() {
        ResponseFrame::Error { id, message } => {
            assert_eq!(id, 9);
            assert!(message.contains("exceeds"), "{message}");
        }
        other => panic!("expected oversized rejection, got {other:?}"),
    }
    // now deliver the claimed body: it streams to the void
    let junk = vec![0u8; claimed as usize - 9];
    c.send_raw(&junk).unwrap();

    // the connection survived both: a good request still round-trips
    c.send(11, "tiny", &[1, 2, 3]).unwrap();
    match c.recv().unwrap() {
        ResponseFrame::Ok { id, .. } => assert_eq!(id, 11),
        other => panic!("connection should have realigned, got {other:?}"),
    }
    server.shutdown();
    stop(router);
}

#[test]
fn truncated_connection_is_reaped_without_poisoning_the_server() {
    let (router, metrics) = router_with(&[("tiny", 0)]);
    let server =
        MuxServer::start(Arc::clone(&router), "127.0.0.1:0", MuxConfig::default()).unwrap();
    {
        let mut c = WireClient::connect(server.local_addr()).unwrap();
        let mut partial = Vec::new();
        encode::encode_request(&mut partial, 1, "tiny", &[1, 2, 3]);
        c.send_raw(&partial[..partial.len() - 2]).unwrap();
    } // dropped: EOF lands mid-frame
    let m = Arc::clone(&metrics);
    assert!(
        eventually(Duration::from_secs(10), move || m.conns_open.load(Ordering::SeqCst) == 0),
        "truncated connection was never reaped (gauge {})",
        metrics.conns_open.load(Ordering::SeqCst)
    );
    // and a fresh connection is served normally
    let mut c = WireClient::connect(server.local_addr()).unwrap();
    c.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
    c.send(2, "tiny", &[4]).unwrap();
    assert!(c.recv().unwrap().is_ok());
    server.shutdown();
    stop(router);
}

#[test]
fn mux_speaks_legacy_text_behind_auto_detection() {
    let (router, _metrics) = router_with(&[("tiny", 0)]);
    let server =
        MuxServer::start(Arc::clone(&router), "127.0.0.1:0", MuxConfig::default()).unwrap();

    // a plain text client on the same port: first bytes diverge from
    // the preamble, so the connection flips to the legacy line protocol
    let stream = TcpStream::connect(server.local_addr()).unwrap();
    stream.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    let mut line = String::new();
    writeln!(w, "tiny:1,2,3").unwrap();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("\"label\""), "{line}");
    assert!(line.contains("\"model\":\"tiny\""), "{line}");
    // bad token lines get the same typed text error the legacy server sends
    line.clear();
    writeln!(w, "1,x,3").unwrap();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("\"error\""), "{line}");

    // a line sharing the preamble's first bytes must still be text:
    // detection never consumes bytes before the protocol is resolved
    let stream = TcpStream::connect(server.local_addr()).unwrap();
    stream.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut r = BufReader::new(stream);
    let mut line = String::new();
    writeln!(w, "SW:1,2").unwrap();
    r.read_line(&mut line).unwrap();
    assert!(line.contains("unknown model"), "SW-prefixed text line mangled: {line}");

    // and a binary client still works concurrently on the same port
    let mut c = WireClient::connect(server.local_addr()).unwrap();
    c.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
    c.send(5, "", &[9, 9]).unwrap();
    assert!(c.recv().unwrap().is_ok());

    server.shutdown();
    stop(router);
}

#[test]
fn mux_rejects_past_its_connection_cap_in_both_dialects() {
    let (router, metrics) = router_with(&[("tiny", 0)]);
    let cfg = MuxConfig { max_conns: 1, ..MuxConfig::default() };
    let server = MuxServer::start(Arc::clone(&router), "127.0.0.1:0", cfg).unwrap();
    // the only slot; accepted (and counted) before the probe arrives
    let held = WireClient::connect(server.local_addr()).unwrap();
    // three probes, each sending nothing: at accept time the protocol
    // is unknown, so every rejection carries both dialects, then the
    // server closes.  One connection = both payloads but exactly ONE
    // rejected count — the shed counters must not double-charge a
    // rejection just because it answers in two dialects.
    for _ in 0..3 {
        let mut probe = TcpStream::connect(server.local_addr()).unwrap();
        probe.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
        let mut bytes = Vec::new();
        probe.read_to_end(&mut bytes).unwrap();
        let (n, frame) = encode::decode_response(&bytes).unwrap().expect("busy frame first");
        assert_eq!(frame, ResponseFrame::Busy { limit: 1 });
        let rest = String::from_utf8_lossy(&bytes[n..]);
        assert!(rest.contains("\"error\":\"busy\""), "text dialect missing: {rest:?}");
    }
    assert_eq!(metrics.conns_rejected.load(Ordering::SeqCst), 3, "one count per rejected conn");
    // the held client is the only accepted connection; rejected probes
    // must touch neither the accepted counter nor the open gauge
    assert_eq!(metrics.conns_accepted.load(Ordering::SeqCst), 1);
    assert_eq!(metrics.conns_open.load(Ordering::SeqCst), 1);
    drop(held);
    // the io thread notices the hangup and settles the gauge back to
    // zero — an accepted conn is closed exactly once, never leaked
    let drained = eventually(Duration::from_secs(10), || {
        metrics.conns_open.load(Ordering::SeqCst) == 0
    });
    assert!(drained, "open-connection gauge never drained after hangup");
    assert_eq!(metrics.conns_rejected.load(Ordering::SeqCst), 3, "close must not re-count");
    server.shutdown();
    stop(router);
}

#[test]
fn text_server_rejects_past_its_connection_cap() {
    let (router, metrics) = router_with(&[("tiny", 0)]);
    let server = TextServer::start(Arc::clone(&router), "127.0.0.1:0", 2).unwrap();
    let addr = server.local_addr();
    // two held connections fill the cap (accepted in connect order)
    let mut held: Vec<TcpStream> = (0..2).map(|_| TcpStream::connect(addr).unwrap()).collect();
    let probe = TcpStream::connect(addr).unwrap();
    probe.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
    let mut line = String::new();
    BufReader::new(probe).read_line(&mut line).unwrap();
    assert!(line.contains("\"error\":\"busy\""), "{line}");
    assert!(line.contains("\"max_conns\":2"), "{line}");
    // exactly one rejection for the one probe, and the probe must not
    // have leaked into the accepted counter or the open gauge
    assert_eq!(metrics.conns_rejected.load(Ordering::SeqCst), 1);
    assert_eq!(metrics.conns_accepted.load(Ordering::SeqCst), 2);
    assert_eq!(metrics.conns_open.load(Ordering::SeqCst), 2);

    // freeing a slot re-opens the door (the handler exits on EOF, so
    // the gauge decays asynchronously — retry until admitted)
    drop(held.pop());
    let admitted = eventually(Duration::from_secs(10), || {
        let s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
        let mut w = s.try_clone().unwrap();
        let mut r = BufReader::new(s);
        writeln!(w, "1,2,3").unwrap();
        let mut line = String::new();
        r.read_line(&mut line).unwrap();
        line.contains("\"label\"")
    });
    assert!(admitted, "slot never freed after a client hung up");
    drop(held);
    server.stop();
    stop(router);
}

#[test]
fn overloaded_tenant_is_shed_while_in_slo_tenant_keeps_serving() {
    let flood_total = 400usize;
    let metrics = Arc::new(Metrics::new());
    let mut reg = ModelRegistry::new();
    // "flood": one 5 ms replica behind a 25 ms SLO — predicted delay
    // crosses the SLO as soon as ~5 requests queue up
    let flood_factory: ReplicaFactory =
        Arc::new(|| Ok(Arc::new(DelayReplica::from_ms(5)) as Arc<dyn EngineReplica>));
    reg.register_group_scaled("flood", 1, 1, 1, Some(25.0), flood_factory).unwrap();
    // "steady": instant replica behind a huge SLO — never shed
    let steady_factory: ReplicaFactory =
        Arc::new(|| Ok(Arc::new(DelayReplica::from_ms(0)) as Arc<dyn EngineReplica>));
    reg.register_group_scaled("steady", 1, 1, 1, Some(10_000.0), steady_factory).unwrap();
    let router = Arc::new(Router::start_multi(reg.into_groups(), policy(), Arc::clone(&metrics)));
    let cfg = MuxConfig { shed_ratio: 1.0, default_service_ms: 5.0, ..MuxConfig::default() };
    let server = MuxServer::start(Arc::clone(&router), "127.0.0.1:0", cfg).unwrap();

    let mut flood = WireClient::connect(server.local_addr()).unwrap();
    flood.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
    // warm up the mean-exec estimate with sequential round trips
    for id in 0..4u64 {
        flood.send(id, "flood", &[1, 2]).unwrap();
        assert!(flood.recv().unwrap().is_ok());
    }
    // now the flood: one pipelined burst far past the replica's SLO
    for id in 0..flood_total as u64 {
        flood.queue(1000 + id, "flood", &[1, 2]);
    }
    flood.flush().unwrap();

    // while the flood drains/sheds, the steady tenant keeps serving
    let mut steady = WireClient::connect(server.local_addr()).unwrap();
    steady.set_read_timeout(Some(RECV_TIMEOUT)).unwrap();
    for id in 0..30u64 {
        steady.send(id, "steady", &[3, 4, 5]).unwrap();
        let f = steady.recv().unwrap();
        assert!(f.is_ok(), "in-SLO tenant must never be shed: {f:?}");
    }

    // every accepted flood frame is answered: Ok or a typed Overloaded
    let mut ok = 0usize;
    let mut shed = 0usize;
    for f in flood.recv_n(flood_total).unwrap() {
        match f {
            ResponseFrame::Ok { .. } => ok += 1,
            ResponseFrame::Overloaded { id, predicted_ms, slo_ms } => {
                assert!(id >= 1000, "shed echoes the frame id: {id}");
                assert!(predicted_ms > slo_ms, "sheds only past the SLO");
                assert!((slo_ms - 25.0).abs() < 1e-9);
                shed += 1;
            }
            other => panic!("flood frame answered with {other:?}"),
        }
    }
    assert_eq!(ok + shed, flood_total, "zero loss: every frame answered exactly once");
    assert!(shed > 0, "a 400-deep burst against a 25ms SLO must shed");
    assert!(ok > 0, "admission control must still admit up to the SLO");
    let flood_stats = metrics.model(0);
    assert_eq!(flood_stats.shed.load(Ordering::SeqCst), shed as u64, "shed counter drifted");
    assert_eq!(
        metrics.model(1).shed.load(Ordering::SeqCst),
        0,
        "steady tenant must not be shed"
    );
    // shed requests bypass the queue entirely: request accounting only
    // covers the admitted ones, which all completed
    assert_eq!(flood_stats.backlog.load(Ordering::SeqCst), 0, "admitted flood drained");
    let (_, steady_p99) = metrics.model(1).e2e_percentiles_ms();
    assert!(
        steady_p99 < 1_000.0,
        "in-SLO tenant p99 {steady_p99:.1}ms collapsed under the flood"
    );
    server.shutdown();
    stop(router);
}

#[test]
fn replay_wire_drives_a_trace_over_the_socket() {
    let (router, _metrics) = router_with(&[("tiny", 1)]);
    let server =
        MuxServer::start(Arc::clone(&router), "127.0.0.1:0", MuxConfig::default()).unwrap();
    let trace =
        Trace::from_process(&ArrivalProcess::Poisson { rate: 200.0 }, 11, 0.3, 0, (1, 8));
    assert!(!trace.is_empty());
    let names = vec!["tiny".to_string()];
    let s = replay_wire(server.local_addr(), &trace, &names, 1.0, Duration::from_secs(20))
        .unwrap();
    assert_eq!(s.sent, trace.len());
    assert_eq!(s.completed, s.sent, "every reply must come back over the socket");
    assert_eq!(s.errors, 0);
    assert_eq!(s.shed, 0, "no queue past the SLO: nothing sheds");
    assert_eq!(s.lost, 0);
    assert_eq!(s.recorded.len(), trace.len(), "the replay records what it sent");
    server.shutdown();
    stop(router);
}
