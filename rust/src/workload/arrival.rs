//! Open-loop arrival processes for the workload harness.
//!
//! Closed-loop drivers (send, wait, send) let the service rate throttle
//! the arrival rate, which structurally hides queueing — exactly the
//! behavior the autoscaler and per-model dispatchers exist to manage.
//! These generators produce *offered* load: a list of arrival
//! timestamps fixed before the run starts, independent of how fast the
//! system drains them.  All three processes are seeded and
//! deterministic: the same `(process, seed, horizon)` triple yields the
//! same `Vec<f64>` bit-for-bit, which the property suite and the
//! committed bench snapshot rely on.

use crate::util::rng::Rng;

/// One completed sojourn of the MMPP's modulating chain, exposed so the
/// property suite can check empirical dwell times against the
/// generator's means.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Dwell {
    /// which of the two modulating states (0 or 1)
    pub state: usize,
    /// how long the chain stayed there, seconds
    pub dwell_s: f64,
}

/// A tenant whose rate multiplies by `factor` inside `[from_s, until_s)`
/// — the "suddenly 50×" chaos leg.  Extra arrivals are an independent
/// Poisson stream at `(factor - 1) · mean_rate` superposed on the base
/// process (exact for Poisson by the superposition theorem, a mean-rate
/// approximation for the modulated processes).
#[derive(Clone, Copy, Debug)]
pub struct RateSpike {
    pub from_s: f64,
    pub until_s: f64,
    pub factor: f64,
}

/// Seeded open-loop arrival process over a finite horizon.
#[derive(Clone, Copy, Debug)]
pub enum ArrivalProcess {
    /// Memoryless arrivals at a constant rate (requests/second):
    /// inter-arrival gaps are iid Exponential(rate).
    Poisson { rate: f64 },
    /// 2-state Markov-modulated Poisson process: the chain dwells in
    /// state `i` for Exponential(1/mean_dwell_s[i]) seconds emitting
    /// Poisson arrivals at `rates[i]`, then flips.  With a high-rate
    /// and a low-rate state this is the standard bursty-traffic model.
    /// A state's rate may be 0.0 (pure ON/OFF traffic).
    Mmpp2 { rates: [f64; 2], mean_dwell_s: [f64; 2] },
    /// Sinusoidal diurnal ramp between `base` and `peak` requests/s
    /// with the given period: λ(t) = base + (peak-base)·(1-cos(2πt/T))/2,
    /// so t=0 is the trough and t=T/2 the peak.  Sampled by thinning
    /// a Poisson(peak) stream.
    Diurnal { base: f64, peak: f64, period_s: f64 },
}

impl ArrivalProcess {
    /// Long-run mean arrival rate in requests/second (time-stationary
    /// average for the modulated processes).
    pub fn mean_rate(&self) -> f64 {
        match *self {
            ArrivalProcess::Poisson { rate } => rate,
            ArrivalProcess::Mmpp2 { rates, mean_dwell_s } => {
                let total = mean_dwell_s[0] + mean_dwell_s[1];
                (rates[0] * mean_dwell_s[0] + rates[1] * mean_dwell_s[1]) / total
            }
            ArrivalProcess::Diurnal { base, peak, .. } => base + (peak - base) / 2.0,
        }
    }

    /// Sorted arrival times in `[0, horizon_s)`, deterministic in
    /// `(self, seed, horizon_s)`.
    pub fn sample(&self, seed: u64, horizon_s: f64) -> Vec<f64> {
        self.sample_with_dwells(seed, horizon_s).0
    }

    /// As [`sample`](Self::sample), also returning the modulating
    /// chain's completed dwells (empty for Poisson and Diurnal).  Only
    /// sojourns that finished before the horizon are reported, so the
    /// truncated final one does not bias the empirical means.
    pub fn sample_with_dwells(&self, seed: u64, horizon_s: f64) -> (Vec<f64>, Vec<Dwell>) {
        assert!(horizon_s > 0.0, "horizon must be positive");
        let mut rng = Rng::new(seed);
        let mut arrivals = Vec::new();
        let mut dwells = Vec::new();
        match *self {
            ArrivalProcess::Poisson { rate } => {
                assert!(rate > 0.0, "Poisson rate must be positive");
                let mut t = 0.0;
                loop {
                    t += rng.exponential(rate);
                    if t >= horizon_s {
                        break;
                    }
                    arrivals.push(t);
                }
            }
            ArrivalProcess::Mmpp2 { rates, mean_dwell_s } => {
                assert!(rates[0] >= 0.0 && rates[1] >= 0.0, "MMPP rates must be non-negative");
                assert!(rates[0] > 0.0 || rates[1] > 0.0, "MMPP needs one emitting state");
                assert!(
                    mean_dwell_s[0] > 0.0 && mean_dwell_s[1] > 0.0,
                    "MMPP dwell means must be positive"
                );
                let mut t = 0.0;
                let mut state = 0usize;
                let mut dwell_start = 0.0;
                let mut dwell_end = rng.exponential(1.0 / mean_dwell_s[state]);
                loop {
                    // Candidate next arrival inside the current state;
                    // by memorylessness, discarding a candidate that
                    // falls past the state switch and resampling at the
                    // new state's rate is distribution-exact.
                    let gap = if rates[state] > 0.0 {
                        rng.exponential(rates[state])
                    } else {
                        f64::INFINITY
                    };
                    if t + gap < dwell_end {
                        t += gap;
                        if t >= horizon_s {
                            break;
                        }
                        arrivals.push(t);
                    } else {
                        t = dwell_end;
                        if t >= horizon_s {
                            break;
                        }
                        dwells.push(Dwell { state, dwell_s: t - dwell_start });
                        state ^= 1;
                        dwell_start = t;
                        dwell_end = t + rng.exponential(1.0 / mean_dwell_s[state]);
                    }
                }
            }
            ArrivalProcess::Diurnal { base, peak, period_s } => {
                assert!(base >= 0.0 && peak > 0.0 && peak >= base, "need peak >= base >= 0");
                assert!(period_s > 0.0, "period must be positive");
                let mut t = 0.0;
                loop {
                    t += rng.exponential(peak);
                    if t >= horizon_s {
                        break;
                    }
                    let lam = base
                        + (peak - base) * 0.5 * (1.0 - (std::f64::consts::TAU * t / period_s).cos());
                    if rng.f64() < lam / peak {
                        arrivals.push(t);
                    }
                }
            }
        }
        (arrivals, dwells)
    }

    /// Sample with a tenant rate spike superposed (see [`RateSpike`]).
    /// The extra stream uses an independent RNG derived from `seed`, so
    /// the base arrivals are identical with and without the spike.
    pub fn sample_spiked(&self, seed: u64, horizon_s: f64, spike: &RateSpike) -> Vec<f64> {
        assert!(spike.factor >= 1.0, "spike factor must be >= 1");
        assert!(spike.from_s <= spike.until_s, "spike window is inverted");
        let mut out = self.sample(seed, horizon_s);
        let end = spike.until_s.min(horizon_s);
        if spike.factor > 1.0 && spike.from_s < end {
            let extra_rate = (spike.factor - 1.0) * self.mean_rate();
            if extra_rate > 0.0 {
                let mut rng = Rng::new(seed ^ 0x5B1C_E5EE_D5B1_CE5E);
                let mut t = spike.from_s.max(0.0);
                loop {
                    t += rng.exponential(extra_rate);
                    if t >= end {
                        break;
                    }
                    out.push(t);
                }
                out.sort_by(|a, b| a.partial_cmp(b).unwrap());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_is_sorted_and_in_horizon() {
        let arrivals = ArrivalProcess::Poisson { rate: 100.0 }.sample(7, 5.0);
        assert!(!arrivals.is_empty());
        assert!(arrivals.windows(2).all(|w| w[0] <= w[1]));
        assert!(arrivals.iter().all(|&t| (0.0..5.0).contains(&t)));
    }

    #[test]
    fn mmpp_mean_rate_is_dwell_weighted() {
        let p = ArrivalProcess::Mmpp2 { rates: [300.0, 20.0], mean_dwell_s: [0.5, 0.125] };
        let want = (300.0 * 0.5 + 20.0 * 0.125) / 0.625;
        assert!((p.mean_rate() - want).abs() < 1e-12);
    }

    #[test]
    fn spike_only_adds_inside_window() {
        let p = ArrivalProcess::Poisson { rate: 50.0 };
        let base = p.sample(3, 2.0);
        let spiked =
            p.sample_spiked(3, 2.0, &RateSpike { from_s: 0.5, until_s: 1.0, factor: 10.0 });
        assert!(spiked.len() > base.len());
        let extra = spiked.len() - base.len();
        let in_window =
            spiked.iter().filter(|&&t| (0.5..1.0).contains(&t)).count()
                - base.iter().filter(|&&t| (0.5..1.0).contains(&t)).count();
        assert_eq!(extra, in_window, "all extra arrivals land inside the spike window");
    }
}
