//! Dyadic numbers and the Requantization unit (paper §III-C, Fig. 7).

use super::{INT8_MAX, INT8_MIN};

/// A rational `b / 2^c` approximating a positive real (paper Eq. (2)).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Dyadic {
    pub b: i64,
    pub c: u32,
}

impl Dyadic {
    /// Best `b/2^c` with `b` in `[1, 2^bits)` — identical to the python
    /// designer (`intops.Dyadic.approximate`).
    pub fn approximate(x: f64, bits: u32, max_shift: u32) -> Dyadic {
        assert!(x > 0.0, "dyadic approximation needs x > 0, got {x}");
        let mut c = 0u32;
        while x * ((1u64 << c) as f64) < (1u64 << (bits - 1)) as f64 && c < max_shift {
            c += 1;
        }
        c = c.saturating_sub(1);
        let b = (x * (1u64 << c) as f64).round() as i64;
        Dyadic { b: b.max(1), c }
    }

    pub fn approx16(x: f64) -> Dyadic {
        Dyadic::approximate(x, 16, 30)
    }

    pub fn value(&self) -> f64 {
        self.b as f64 / (1u64 << self.c) as f64
    }
}

/// INT32 -> INT8 requantization: `clamp((q * b) >> c)` (paper Fig. 7).
#[inline]
pub fn requantize(q: i64, dy: Dyadic) -> i32 {
    requantize_signed(q, dy, 1)
}

/// Requantization with a signed multiplier `sign*b` (negative-scale
/// inputs, e.g. the GELU output whose scale carries erf's `a < 0`).
#[inline]
pub fn requantize_signed(q: i64, dy: Dyadic, sign: i64) -> i32 {
    let prod = q * (sign * dy.b);
    let shifted = prod >> dy.c;
    shifted.clamp(INT8_MIN, INT8_MAX) as i32
}

/// Dyadic rescale *without* saturation (residual-connection alignment,
/// paper §III-I): stays INT32-range by design-time scale choice.
#[inline]
pub fn rescale(q: i64, dy: Dyadic) -> i64 {
    (q * dy.b) >> dy.c
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn approximation_close_for_wide_range() {
        for x in [1e-4, 0.01, 0.3, 1.0, 7.7, 999.0] {
            let dy = Dyadic::approx16(x);
            assert!((dy.value() - x).abs() / x < 2f64.powi(-14), "{x} -> {dy:?}");
        }
    }

    #[test]
    fn requantize_saturates() {
        let dy = Dyadic::approx16(1.0);
        assert_eq!(requantize(1 << 30, dy), 127);
        assert_eq!(requantize(-(1 << 30), dy), -128);
        assert_eq!(requantize(0, dy), 0);
    }

    #[test]
    fn negative_inputs_floor_not_truncate() {
        let dy = Dyadic { b: 3, c: 2 }; // * 0.75
        assert_eq!(requantize(-1, dy), -1); // (-3)>>2 == -1
        assert_eq!(requantize(-2, dy), -2);
        assert_eq!(requantize(1, dy), 0);
    }

    #[test]
    fn signed_multiplier_negates() {
        let dy = Dyadic { b: 4, c: 2 };
        assert_eq!(requantize_signed(5, dy, -1), -5);
        assert_eq!(requantize_signed(-5, dy, -1), 5);
    }

    #[test]
    fn rescale_no_saturation() {
        let dy = Dyadic { b: 1, c: 0 };
        assert_eq!(rescale(1 << 40, dy), 1 << 40);
    }

    #[test]
    fn matches_python_designer_examples() {
        // values cross-checked against intops.Dyadic.approximate
        let dy = Dyadic::approx16(0.004123251145568775);
        assert_eq!((dy.b, dy.c), (17294, 22));
    }
}
