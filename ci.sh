#!/usr/bin/env bash
# Tier-1 gate: release build, tests, formatting, clippy, and rustdoc
# with warnings denied — the doc pass makes dangling references (e.g.
# to DESIGN.md sections that were renamed away) fail fast instead of
# rotting.  `set -euo pipefail` makes every stage a hard gate: a
# mid-script failure (or formatting drift at the fmt stage) stops the
# pipeline instead of scrolling past.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
cargo test -q

# Integration-test timing summary: each [[test]] target re-run on its
# own (--nocapture streams long-running targets live) with wall seconds
# per target, collected into the per-test summary printed at the end.
echo "-- integration-test timing (cargo test -q --test '*' -- --nocapture) --"
suite_start=$SECONDS
timing_rows=()
for t in $(awk '/^\[\[test\]\]/{grab=1;next} grab&&/^name = /{gsub(/"/,""); print $3; grab=0}' Cargo.toml); do
  t_start=$SECONDS
  cargo test -q --test "$t" -- --nocapture
  row="  $t: $((SECONDS-t_start))s"
  timing_rows+=("$row")
  echo "$row"
done
echo "  total: $((SECONDS-suite_start))s"

# Timing-sensitive suites (the autoscaler control loop, per-model
# latency/p99 assertions, the chaos recovery legs, the wire-protocol
# loopback suite with its SLO shedding leg) re-run under --release,
# where debug-build slowness cannot eat the timing margins.
echo "-- release leg: timing-sensitive autoscaler/latency tests --"
for t in autoscale chaos prop_invariants wire_protocol; do
  t_start=$SECONDS
  cargo test -q --release --test "$t"
  row="  $t (release): $((SECONDS-t_start))s"
  timing_rows+=("$row")
  echo "$row"
done

# Debug-assertions release re-run for the sharded dispatch path
# (DESIGN.md §13): the ShardedBatcher / BudgetExec accounting guards
# (`debug_assert!` on completion underflow and ledger invariants) are
# compiled out of plain --release, so the concurrency suites re-run
# once with them forced on at release-level timing.
echo "-- release + debug-assertions leg: sharded queue invariants --"
t_start=$SECONDS
RUSTFLAGS="-C debug-assertions" cargo test -q --release --lib coordinator::batcher util::budget
row="  lib batcher/budget (release+debug-assertions): $((SECONDS-t_start))s"
timing_rows+=("$row")
echo "$row"
t_start=$SECONDS
RUSTFLAGS="-C debug-assertions" cargo test -q --release --test prop_invariants --test chaos
row="  prop_invariants+chaos (release+debug-assertions): $((SECONDS-t_start))s"
timing_rows+=("$row")
echo "$row"

# Smoke-sized serving bench leg: exercises the concurrency-leg
# acceptance assertions (tiny p99 >= 2x over the serial dispatcher,
# shares within 10% of weights), the dispatch contention smoke leg
# (many-tenant submit flood, merged under the `dispatch` key), and the
# INT4 cascade legs (DESIGN.md §14) — served-cycle reduction >= 25% at
# >= 99% top-1 agreement at the default escalation margin, the pool
# escalation-ledger invariants, and the byte-exact comparison against
# the committed BENCH_cascade_smoke.json (rebaseline with
# `-- --smoke --update` after an intentional numerics change) — and
# refreshes BENCH_serving.json.
echo "-- serving bench smoke leg --"
t_start=$SECONDS
cargo bench --bench serving_scaling -- --smoke
row="  serving_scaling --smoke: $((SECONDS-t_start))s"
timing_rows+=("$row")
echo "$row"

# Open-loop workload smoke leg: replays seeded arrival traces with the
# chaos legs (panic / straggler / 50x spike) and floods both socket
# front doors (legacy text vs SWWIRE1 mux), merges the `openloop` and
# `wire` keys into BENCH_serving.json, and exits non-zero if the run
# drifts from the committed BENCH_smoke.json schema or regresses a leg
# past its bound (rebaseline with `-- --smoke --update` after an
# intentional change).
echo "-- open-loop workload smoke leg --"
t_start=$SECONDS
cargo bench --bench serving_openloop -- --smoke
row="  serving_openloop --smoke: $((SECONDS-t_start))s"
timing_rows+=("$row")
echo "$row"

# CostModel smoke leg: Table I, the design-space sweep (merged under
# `costmodel.design_space` in BENCH_serving.json), and the determinism
# gate — the closed-form smoke subset must match the committed
# BENCH_costmodel_smoke.json byte for byte (rebaseline with
# `-- --smoke --update` after an intentional cost/synthesis change).
echo "-- costmodel design-space smoke leg --"
t_start=$SECONDS
cargo bench --bench table1_synthesis -- --smoke
row="  table1_synthesis --smoke: $((SECONDS-t_start))s"
timing_rows+=("$row")
echo "$row"

# The pjrt feature must keep compiling against the in-repo xla stub
# (check-only: there is no real PJRT client to run against here).
cargo check --features pjrt --all-targets

cargo fmt --check
cargo clippy --all-targets -- -D warnings
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
echo "-- per-test wall time summary --"
printf '%s\n' "${timing_rows[@]}"
echo "ci.sh: all green"
