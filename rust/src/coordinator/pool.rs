//! Replica pool: fans the batcher's dispatch groups out across N engine
//! replicas on the in-repo `util` thread pool and re-orders results per
//! request (DESIGN.md §2).
//!
//! Fan-out policy: requests are assigned round-robin by position within
//! the group (request `i` goes to replica `(start + i) mod N`, with
//! `start` rotating per dispatch so short groups spread across replicas
//! over time instead of pinning replica 0).  Each replica processes its
//! share serially — one sequence at a time, as the hardware loads the
//! MAC array per sentence — while the N shares run concurrently on
//! dedicated pool threads.  Replies go out on each request's channel the
//! moment its prediction completes; the group-level return value is
//! re-ordered back to submission (FIFO) order for consumers that want
//! the whole group (the scaling bench, tests).
//!
//! Dispatch is a barrier per group: throughput scales with replicas
//! once the dispatch-group size reaches the replica count; groups
//! smaller than N leave replicas idle for that dispatch (the operating
//! regime is `max_batch >= replicas`; DESIGN.md §2, EXPERIMENTS.md
//! §Scaling).

use super::engine::{EngineReplica, RequestError};
use super::metrics::Metrics;
use super::router::{Request, Response};
use crate::util::threadpool::ThreadPool;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

pub struct ReplicaPool {
    replicas: Vec<Arc<dyn EngineReplica>>,
    pool: ThreadPool,
    metrics: Arc<Metrics>,
    /// rotating fan-out offset (advances once per dispatch)
    next_start: AtomicUsize,
}

impl ReplicaPool {
    /// One pool thread per replica: a replica is never oversubscribed
    /// and an idle replica never queues behind a busy one.
    pub fn new(replicas: Vec<Arc<dyn EngineReplica>>, metrics: Arc<Metrics>) -> ReplicaPool {
        assert!(!replicas.is_empty(), "replica pool needs at least one engine");
        metrics.ensure_replicas(replicas.len());
        let pool = ThreadPool::new(replicas.len());
        ReplicaPool { replicas, pool, metrics, next_start: AtomicUsize::new(0) }
    }

    /// Number of replicas (== pool threads).
    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Execute one dispatch group: fan out across replicas, reply per
    /// request as it finishes, and return responses re-ordered to the
    /// group's submission order.
    pub fn dispatch(&self, group: Vec<Request>) -> Vec<Response> {
        let n = self.replicas.len();
        let total = group.len();
        let start = self.next_start.fetch_add(1, Ordering::Relaxed) % n;
        let mut shares: Vec<Vec<(usize, Request)>> = (0..n).map(|_| Vec::new()).collect();
        for (i, req) in group.into_iter().enumerate() {
            shares[(start + i) % n].push((i, req));
        }
        let jobs: Vec<_> = shares
            .into_iter()
            .enumerate()
            .filter(|(_, share)| !share.is_empty())
            .map(|(r, share)| {
                let replica = Arc::clone(&self.replicas[r]);
                let metrics = Arc::clone(&self.metrics);
                move || {
                    share
                        .into_iter()
                        .map(|(i, req)| (i, serve_one(r, replica.as_ref(), &metrics, req)))
                        .collect::<Vec<_>>()
                }
            })
            .collect();
        let mut indexed: Vec<(usize, Response)> =
            self.pool.run_batch(jobs).into_iter().flatten().collect();
        indexed.sort_unstable_by_key(|&(i, _)| i);
        assert_eq!(indexed.len(), total, "every request yields exactly one response");
        indexed.into_iter().map(|(_, resp)| resp).collect()
    }
}

/// Serve one request on one replica: predict, account (aggregate and
/// per-replica virtual time), reply.
fn serve_one(
    replica_id: usize,
    engine: &dyn EngineReplica,
    metrics: &Metrics,
    req: Request,
) -> Response {
    let queued = req.submitted.elapsed().as_secs_f64();
    let t0 = Instant::now();
    // A panicking replica must cost one request, not the dispatcher
    // thread: run_batch treats a panicked job as fatal, which would
    // kill the single dispatcher and hang every later submit.
    let result = catch_unwind(AssertUnwindSafe(|| engine.predict(&req.tokens)))
        .unwrap_or_else(|_| {
            Err(RequestError::Backend("replica panicked while serving request".into()))
        });
    let resp = match result {
        Ok(pred) => {
            let exec = t0.elapsed().as_secs_f64();
            let e2e = req.submitted.elapsed().as_secs_f64();
            metrics.record_completion(e2e, queued, exec, pred.accel_ms);
            metrics.record_replica(replica_id, exec, pred.accel_cycles, pred.accel_ms, false);
            Response {
                id: req.id,
                replica: replica_id,
                label: pred.label,
                accel_ms: pred.accel_ms,
                e2e_s: e2e,
                error: None,
            }
        }
        Err(e) => {
            let exec = t0.elapsed().as_secs_f64();
            metrics.record_error();
            metrics.record_replica(replica_id, exec, 0, 0.0, true);
            Response {
                id: req.id,
                replica: replica_id,
                label: usize::MAX,
                accel_ms: 0.0,
                e2e_s: req.submitted.elapsed().as_secs_f64(),
                error: Some(e.to_string()),
            }
        }
    };
    let _ = req.reply.send(resp.clone());
    resp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::Prediction;
    use std::sync::mpsc::{channel, Receiver};
    use std::time::Duration;

    /// Deterministic-latency replica: predicts after a fixed sleep.
    struct SlowReplica {
        delay: Duration,
    }

    impl EngineReplica for SlowReplica {
        fn predict(&self, tokens: &[i32]) -> Result<Prediction, RequestError> {
            if tokens.is_empty() {
                return Err(RequestError::Backend("empty".into()));
            }
            std::thread::sleep(self.delay);
            Ok(Prediction {
                label: tokens[0] as usize % 2,
                logits: vec![0, 1],
                accel_cycles: 1000,
                accel_ms: 0.007,
            })
        }

        fn seq_len(&self) -> usize {
            4
        }
    }

    fn pool_of(n: usize, delay_ms: u64) -> (ReplicaPool, Arc<Metrics>) {
        let metrics = Arc::new(Metrics::new());
        let replicas: Vec<Arc<dyn EngineReplica>> = (0..n)
            .map(|_| {
                Arc::new(SlowReplica { delay: Duration::from_millis(delay_ms) })
                    as Arc<dyn EngineReplica>
            })
            .collect();
        (ReplicaPool::new(replicas, Arc::clone(&metrics)), metrics)
    }

    fn group_of(n: usize) -> (Vec<Request>, Vec<Receiver<Response>>) {
        let mut group = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for id in 0..n as u64 {
            let (tx, rx) = channel();
            group.push(Request {
                id,
                tokens: vec![id as i32; 4],
                submitted: Instant::now(),
                reply: tx,
            });
            receivers.push(rx);
        }
        (group, receivers)
    }

    #[test]
    fn dispatch_reorders_to_submission_order_and_replies() {
        let (pool, _metrics) = pool_of(3, 0);
        let (group, receivers) = group_of(10);
        let responses = pool.dispatch(group);
        let ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<u64>>(), "submission order restored");
        for (i, rx) in receivers.into_iter().enumerate() {
            let resp = rx.recv().expect("reply sent");
            assert_eq!(resp.id, i as u64);
            assert!(resp.error.is_none());
        }
    }

    #[test]
    fn round_robin_spreads_across_replicas() {
        let (pool, metrics) = pool_of(2, 0);
        let (group, _receivers) = group_of(8);
        let responses = pool.dispatch(group);
        // first dispatch starts at offset 0: position i -> replica i mod 2
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.replica, i % 2);
        }
        assert_eq!(metrics.replica(0).requests.load(std::sync::atomic::Ordering::Relaxed), 4);
        assert_eq!(metrics.replica(1).requests.load(std::sync::atomic::Ordering::Relaxed), 4);
    }

    #[test]
    fn singleton_groups_rotate_across_replicas() {
        // the fan-out offset advances per dispatch, so back-to-back
        // one-request groups do not pin replica 0
        let (pool, _metrics) = pool_of(2, 0);
        let mut served = vec![];
        for _ in 0..4 {
            let (group, _receivers) = group_of(1);
            served.push(pool.dispatch(group)[0].replica);
        }
        assert_eq!(served, vec![0, 1, 0, 1]);
    }

    #[test]
    fn panicking_replica_costs_one_request_not_the_pool() {
        struct PanickyReplica;
        impl EngineReplica for PanickyReplica {
            fn predict(&self, tokens: &[i32]) -> Result<Prediction, RequestError> {
                if tokens[0] == 13 {
                    panic!("boom");
                }
                Ok(Prediction { label: 0, logits: vec![], accel_cycles: 1, accel_ms: 0.001 })
            }
            fn seq_len(&self) -> usize {
                4
            }
        }
        let metrics = Arc::new(Metrics::new());
        let replicas: Vec<Arc<dyn EngineReplica>> =
            vec![Arc::new(PanickyReplica) as Arc<dyn EngineReplica>];
        let pool = ReplicaPool::new(replicas, Arc::clone(&metrics));
        let (mut group, _receivers) = group_of(3);
        group[1].tokens = vec![13; 4]; // triggers the panic
        let responses = pool.dispatch(group);
        assert!(responses[0].error.is_none());
        assert!(responses[1].error.as_deref().unwrap_or("").contains("panicked"));
        assert!(responses[2].error.is_none());
        // the pool survives for the next dispatch
        let (group, _receivers) = group_of(2);
        assert!(pool.dispatch(group).iter().all(|r| r.error.is_none()));
    }

    #[test]
    fn two_replicas_run_a_group_concurrently() {
        // 8 requests x 20 ms: serial would take ~160 ms; two replicas
        // should land near 80 ms.  The generous bound still proves the
        // shares overlapped.
        let (pool, _metrics) = pool_of(2, 20);
        let (group, _receivers) = group_of(8);
        let t0 = Instant::now();
        let responses = pool.dispatch(group);
        let wall = t0.elapsed();
        assert_eq!(responses.len(), 8);
        assert!(
            wall < Duration::from_millis(140),
            "dispatch took {wall:?}, shares did not overlap"
        );
    }

    #[test]
    fn errors_are_per_request_not_per_group() {
        let (pool, metrics) = pool_of(2, 0);
        let (mut group, receivers) = group_of(4);
        group[2].tokens.clear(); // SlowReplica errors on empty tokens
        let responses = pool.dispatch(group);
        assert!(responses[2].error.is_some());
        for (i, resp) in responses.iter().enumerate() {
            assert_eq!(resp.error.is_some(), i == 2);
        }
        drop(receivers);
        assert_eq!(metrics.errors.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(metrics.completed.load(std::sync::atomic::Ordering::Relaxed), 3);
    }
}
