//! Request router: the front half of the concurrent serving pipeline
//! (DESIGN.md §2, §8, §9, §13).
//!
//! `submit` / `submit_to` enqueue requests into the per-model
//! [`ShardedBatcher`] (buckets keyed by padded length within each
//! model's shard; DESIGN.md §6, §8, §13): a submit locks only the
//! target model's shard and wakes only that model's dispatcher — no
//! global batcher mutex, no `notify_all` thundering herd.  Every model
//! group runs its *own* dispatcher thread parked on its own shard's
//! condvar: each waits for the size-or-deadline policy to release one
//! of its model's dispatch groups (`ShardedBatcher::next_batch`, which
//! charges the fairness ledger at pop time and tracks the group as in
//! flight), hands it to its [`GroupRuntime`](super::pool::GroupRuntime),
//! blocks on that group's barrier over the shared core budget, and
//! reports completion — so a heavy model's group mid-flight never
//! gates a cheap model's next dispatch, and a panicking dispatch (or a
//! poisoned shard lock) degrades one tenant, never the router.  Within
//! one group, groups still pipeline back to back while requests inside
//! a group run concurrently across the group's replicas.  A one-group
//! configuration degenerates to exactly the old serial pipeline
//! (asserted bit-equivalent in tests).
//!
//! Alongside the dispatchers, one autoscaler thread ticks the
//! SLO-aware control loop (`coordinator::autoscale`) over every
//! scalable group: backlog-vs-SLO crossing the hysteresis thresholds
//! grows the group toward `max_replicas` (factory spawn against the
//! shared `Arc` weight bundle) or drains it back toward
//! `min_replicas`.

use super::autoscale::{predicted_work_ms, tick_group, AutoscalePolicy, GroupScaleState};
use super::batcher::{BatchPolicy, ShardedBatcher};
use super::engine::EngineReplica;
use super::metrics::Metrics;
use super::pool::ReplicaPool;
use super::registry::ModelGroup;
use crate::sim::CostModel;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    /// model index (position of the model's group in the router)
    pub model: usize,
    pub tokens: Vec<i32>,
    /// tokens the dispatch bucket charges for this request
    /// (== `tokens.len()` when bucketing is off); fed to the per-model
    /// served-token ledger on completion
    pub padded_len: usize,
    /// predicted cost of this request in the router's single fairness /
    /// admission / autoscaling currency: `CostModel` accelerator cycles
    /// for groups with a cost model, padded bucket tokens otherwise.
    /// Charged to the batcher's deficit ledger at pop time and settled
    /// on the per-model work gauges at completion.
    pub cost: u64,
    pub submitted: Instant,
    /// cascade provenance: `Some(front_model)` once a low-precision
    /// tier escalated this request here (DESIGN.md §14).  `model` and
    /// `cost` have been rewritten to the escalation target; `submitted`
    /// keeps the original submit time, so the answering tier's e2e
    /// covers both hops (the report's "cascade e2e" series).
    pub origin: Option<usize>,
    pub reply: Sender<Response>,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// model id that served (or rejected) this request
    pub model: String,
    /// which engine replica served this request (global index)
    pub replica: usize,
    pub label: usize,
    /// classifier logits (empty on error) — lets callers check
    /// byte-identical outputs across replica counts and backends
    pub logits: Vec<i64>,
    pub accel_ms: f64,
    pub e2e_s: f64,
    pub error: Option<String>,
}

/// Per-model endpoint bookkeeping: the serveable length range of the
/// model's replica group (max of `min_seq_len`, min of `seq_len`,
/// because fan-out within the group is length-blind round-robin) plus
/// the name and fair-share weight.
struct Endpoint {
    name: String,
    weight: u64,
    min_len: usize,
    max_len: usize,
    /// the group's analytical cost model (`sim::cost`), shared with its
    /// replicas: prices every submit in predicted accelerator cycles.
    /// `None` for custom groups, which fall back to padded tokens.
    cost: Option<Arc<CostModel>>,
}

pub struct Router {
    batcher: Arc<ShardedBatcher<Request>>,
    pub metrics: Arc<Metrics>,
    /// one dispatcher per model group, in model-index order
    dispatchers: Vec<JoinHandle<()>>,
    autoscaler: Option<JoinHandle<()>>,
    /// kept alive for introspection (active replica counts in tests);
    /// the dispatchers hold their own Arcs
    pool: Arc<ReplicaPool>,
    next_id: AtomicU64,
    policy: BatchPolicy,
    endpoints: Vec<Endpoint>,
}

impl Router {
    /// Start the single-model serving pipeline over `replicas` engine
    /// replicas under the default model id (the replica pool spins one
    /// worker thread per replica, plus one dispatcher thread).
    pub fn start(
        replicas: Vec<Arc<dyn EngineReplica>>,
        policy: BatchPolicy,
        metrics: Arc<Metrics>,
    ) -> Router {
        Router::start_multi(vec![ModelGroup::fixed("default", replicas, 1)], policy, metrics)
    }

    /// Start the multi-tenant serving pipeline with the default
    /// autoscaler policy: one named replica group per model (typically
    /// [`super::ModelRegistry::into_groups`]), a shared batcher keyed
    /// by `(model, padded length)` with the groups' fair-share
    /// weights, one dispatcher thread *per group*, and the SLO
    /// autoscaler over every scalable group.
    pub fn start_multi(
        groups: Vec<ModelGroup>,
        policy: BatchPolicy,
        metrics: Arc<Metrics>,
    ) -> Router {
        Router::start_multi_with(groups, policy, AutoscalePolicy::default(), metrics)
    }

    /// [`start_multi`](Router::start_multi) with explicit autoscaler
    /// tuning (tests pin fast ticks, benches pin the control cadence).
    pub fn start_multi_with(
        groups: Vec<ModelGroup>,
        policy: BatchPolicy,
        autoscale: AutoscalePolicy,
        metrics: Arc<Metrics>,
    ) -> Router {
        Router::start_multi_cores(groups, policy, autoscale, metrics, None)
    }

    /// [`start_multi_with`](Router::start_multi_with) with an explicit
    /// global core budget: `cores` executor worker threads shared by
    /// every group (`--cores` on the CLI; `None` = Σ group widths, the
    /// no-oversubscription default).  Total executor threads stay at
    /// the budget even when Σ `max_replicas` exceeds it (DESIGN.md
    /// §13).
    pub fn start_multi_cores(
        groups: Vec<ModelGroup>,
        policy: BatchPolicy,
        autoscale: AutoscalePolicy,
        metrics: Arc<Metrics>,
        cores: Option<usize>,
    ) -> Router {
        assert!(!groups.is_empty(), "router needs at least one model group");
        for (i, g) in groups.iter().enumerate() {
            assert!(!g.replicas.is_empty(), "model {:?} has no replicas", g.model);
            assert!(
                !groups[..i].iter().any(|o| o.model == g.model),
                "duplicate model id {:?}",
                g.model
            );
        }
        let endpoints: Vec<Endpoint> = groups
            .iter()
            .map(|g| Endpoint {
                name: g.model.clone(),
                weight: g.weight.max(1),
                min_len: g.replicas.iter().map(|r| r.min_seq_len()).max().unwrap_or(0),
                max_len: g.replicas.iter().map(|r| r.seq_len()).min().unwrap_or(0),
                cost: g.cost.clone(),
            })
            .collect();
        let specs: Vec<(&str, u64)> =
            endpoints.iter().map(|e| (e.name.as_str(), e.weight)).collect();
        metrics.ensure_models(&specs);
        let weights: Vec<u64> = endpoints.iter().map(|e| e.weight).collect();
        let batcher = Arc::new(ShardedBatcher::new(policy, &weights));
        let pool =
            Arc::new(ReplicaPool::new_multi_with_budget(groups, Arc::clone(&metrics), cores));
        let dispatchers = (0..pool.group_count())
            .map(|g| {
                let batcher = Arc::clone(&batcher);
                let rt = Arc::clone(pool.group(g).expect("group exists"));
                std::thread::Builder::new()
                    .name(format!("swifttron-dispatch-{}", rt.model()))
                    .spawn(move || dispatch_group_loop(batcher, rt))
                    .expect("spawn dispatcher")
            })
            .collect();
        let autoscaler = {
            let batcher = Arc::clone(&batcher);
            let pool = Arc::clone(&pool);
            let metrics = Arc::clone(&metrics);
            std::thread::Builder::new()
                .name("swifttron-autoscale".into())
                .spawn(move || autoscale_loop(batcher, pool, metrics, autoscale))
                .expect("spawn autoscaler")
        };
        Router {
            batcher,
            metrics,
            dispatchers,
            autoscaler: Some(autoscaler),
            pool,
            next_id: AtomicU64::new(0),
            policy,
            endpoints,
        }
    }

    /// Worker threads in the router's global core budget (DESIGN.md
    /// §13).
    pub fn core_budget(&self) -> usize {
        self.pool.core_budget()
    }

    /// Active replicas currently serving `model` (autoscaler gauge read
    /// straight off the group runtime).
    pub fn active_replicas(&self, model: &str) -> Option<usize> {
        let idx = self.endpoints.iter().position(|e| e.name == model)?;
        self.pool.group(idx).map(|g| g.active_replicas())
    }

    /// Registered model ids, in model-index order.
    pub fn model_names(&self) -> Vec<&str> {
        self.endpoints.iter().map(|e| e.name.as_str()).collect()
    }

    /// Index of `model` in model-index order.  The wire multiplexer
    /// resolves a frame's model id once, then sheds or submits by
    /// index (DESIGN.md §11).
    pub fn model_index(&self, model: &str) -> Option<usize> {
        self.endpoints.iter().position(|e| e.name == model)
    }

    /// SLO class of model index `model` (`None` = no SLO: the model
    /// neither autoscales nor sheds).
    pub fn slo_ms(&self, model: usize) -> Option<f64> {
        self.pool.group(model).and_then(|g| g.slo_ms())
    }

    /// Predicted queueing delay for model index `model` in
    /// milliseconds: the model's predicted backlog work
    /// ([`predicted_work_ms`]) divided by its active replicas — the
    /// same demand signal the autoscaler's `decide()` integrates
    /// (`coordinator::autoscale`), read lock-free off the model's
    /// metrics gauges.  Groups with a [`CostModel`] price the backlog
    /// in predicted accelerator cycles (calibrated by measured
    /// ms-per-cycle, with the model's analytical clock as the
    /// cold-start prior); cost-less groups keep the legacy
    /// `backlog · mean_exec_ms` estimate, where `default_service_ms`
    /// stands in before the first completion.
    pub fn predicted_delay_ms(&self, model: usize, default_service_ms: f64) -> f64 {
        let m = self.metrics.model(model);
        let active = m.replicas.load(Ordering::Relaxed).max(1) as f64;
        let backlog = m.backlog.load(Ordering::Relaxed) as usize;
        let cost = self.endpoints.get(model).and_then(|e| e.cost.as_deref());
        predicted_work_ms(&m, cost, backlog, default_service_ms) / active
    }

    /// SLO-derived admission control (DESIGN.md §11): if model index
    /// `model` has an SLO and its predicted queueing delay exceeds
    /// `shed_ratio · slo_ms`, returns `Some((predicted_ms, slo_ms))`
    /// — the caller should answer the request with a typed
    /// `Overloaded` rejection instead of queueing it.  `None` means
    /// admit (always, for models without an SLO).
    pub fn overload_delay_ms(
        &self,
        model: usize,
        shed_ratio: f64,
        default_service_ms: f64,
    ) -> Option<(f64, f64)> {
        let slo = self.slo_ms(model)?;
        let predicted = self.predicted_delay_ms(model, default_service_ms);
        (predicted > shed_ratio * slo).then_some((predicted, slo))
    }

    /// Submit a request to the first (default) model; the response
    /// arrives on `reply`.
    pub fn submit(&self, tokens: Vec<i32>, reply: Sender<Response>) -> u64 {
        self.submit_idx(0, tokens, reply)
    }

    /// Submit a request to the named model.  An unknown model id is
    /// answered immediately with an error response (and counted as an
    /// error) instead of entering the queue.
    pub fn submit_to(&self, model: &str, tokens: Vec<i32>, reply: Sender<Response>) -> u64 {
        match self.model_index(model) {
            Some(idx) => self.submit_idx(idx, tokens, reply),
            None => {
                let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
                self.metrics.record_request();
                self.metrics.record_error();
                let _ = reply.send(Response {
                    id,
                    model: model.to_string(),
                    replica: usize::MAX,
                    label: usize::MAX,
                    logits: Vec::new(),
                    accel_ms: 0.0,
                    e2e_s: 0.0,
                    error: Some(format!(
                        "unknown model {model:?} (resident: {:?})",
                        self.model_names()
                    )),
                });
                id
            }
        }
    }

    /// Submit to a model by index (resolved once via
    /// [`model_index`](Router::model_index)) — the wire multiplexer's
    /// entry point, skipping the per-frame name comparison of
    /// [`submit_to`](Router::submit_to).
    ///
    /// # Panics
    /// If `model` is out of range (the caller resolved it against this
    /// router, so a bad index is a logic error, not traffic).
    pub fn submit_index(&self, model: usize, tokens: Vec<i32>, reply: Sender<Response>) -> u64 {
        assert!(model < self.endpoints.len(), "model index {model} out of range");
        self.submit_idx(model, tokens, reply)
    }

    /// Submit to model index `model`.  The token count is the request's
    /// live sequence length: the batcher groups it with
    /// length-compatible requests of the same model (same padded
    /// bucket) and the padding the bucket charges is accounted in the
    /// per-model metrics.
    fn submit_idx(&self, model: usize, tokens: Vec<i32>, reply: Sender<Response>) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        let ep = &self.endpoints[model];
        let len = tokens.len();
        // `padded_len` is the request's bucket boundary; `cost` is the
        // scheduler charge.  The cost stored on the request must equal
        // what the batcher's deficit ledger counts at pop time and what
        // the metrics work gauges settle at completion, or the
        // reported served-work shares would drift from the fairness
        // currency actually being enforced.
        let padded = self.policy.padded_len(len);
        let cost =
            ep.cost.as_ref().map(|c| c.predict_cycles(len)).unwrap_or(padded as u64);
        self.metrics.record_request_for(model, cost);
        // The push locks only `model`'s shard and `notify_one`s only
        // that model's dispatcher (DESIGN.md §13): a submit never
        // contends with another model's queue and never wakes another
        // model's dispatcher — the global-mutex + `notify_all`
        // thundering herd of the single-batcher pipeline is gone.
        self.batcher.push_costed(
            Request {
                id,
                model,
                tokens,
                padded_len: padded,
                cost,
                submitted: Instant::now(),
                origin: None,
                reply,
            },
            model,
            len,
            cost,
        );
        // Token accounting only for serveable requests, and never more
        // padding than the largest geometry the model's replicas
        // actually run — rejected requests and bucket boundaries beyond
        // the array must not inflate the padding-waste metric.
        if len >= ep.min_len.max(1) && len <= ep.max_len {
            self.metrics.record_tokens(model, len, padded.min(ep.max_len));
        }
        id
    }

    pub fn queue_len(&self) -> usize {
        self.batcher.len()
    }

    /// Chaos test hook: poison `model`'s shard lock exactly as a
    /// dispatcher panicking while holding it would.  Returns whether
    /// the model exists.  The regression in `rust/tests/chaos.rs`
    /// drives this to pin the poisoned-lock blast radius to one tenant
    /// (pre-§13, one poisoned global batcher mutex killed the router).
    #[doc(hidden)]
    pub fn poison_model_shard(&self, model: &str) -> bool {
        match self.model_index(model) {
            Some(idx) => {
                self.batcher.poison_shard(idx);
                true
            }
            None => false,
        }
    }

    /// Drain the queue and stop the pipeline: every per-group
    /// dispatcher finishes its model's backlog and is joined (each
    /// group runtime's executor threads join on drop), then the
    /// autoscaler.  No submitted request is lost — anything queued
    /// before this call is dispatched and replied to (property-tested
    /// in `rust/tests/prop_invariants.rs`).
    pub fn shutdown(mut self) {
        // ShardedBatcher::shutdown stores the flag, then bounces every
        // shard's lock and broadcasts its condvar — no dispatcher can
        // lose the wakeup between its predicate check and its park.
        self.batcher.shutdown();
        for d in self.dispatchers.drain(..) {
            let _ = d.join();
        }
        if let Some(a) = self.autoscaler.take() {
            let _ = a.join();
        }
    }
}

/// One model group's dispatcher: block on the model's own shard for
/// the next dispatch group (fairness charged at pop time), run it on
/// the group runtime's barrier over the shared core budget, report
/// completion.  On shutdown it drains its model's remaining backlog
/// before exiting, so no queued request is ever dropped.
///
/// The dispatch itself runs under `catch_unwind`: the dispatch path is
/// engineered not to panic (replica panics are captured at the job
/// boundary, a fully-retired group answers typed errors), but if an
/// invariant ever breaks anyway, the panic costs this model one group
/// — the completion report still lands, the loop keeps serving, and no
/// other tenant's dispatcher is touched (ISSUE 9: the poisoned-lock
/// cascade this architecture removes).
fn dispatch_group_loop(batcher: Arc<ShardedBatcher<Request>>, rt: Arc<super::pool::GroupRuntime>) {
    let g = rt.model_index();
    while let Some(group) = batcher.next_batch(g) {
        let n = group.len();
        match catch_unwind(AssertUnwindSafe(|| rt.dispatch(group))) {
            Ok((_responses, escalated)) => {
                // Cascade overflow (DESIGN.md §14): requests the margin
                // gate withheld re-enter the queue on their escalation
                // tier's shard — already re-targeted, re-priced, and
                // accounted by the group runtime — and that tier's own
                // dispatcher picks them up.  The push wakes only the
                // target's condvar; this loop goes straight back to its
                // own shard.
                for req in escalated {
                    let (target, len, cost) = (req.model, req.tokens.len(), req.cost);
                    batcher.push_costed(req, target, len, cost);
                }
            }
            Err(_) => eprintln!(
                "swifttron-dispatch-{}: dispatch panicked; {n} request(s) dropped \
                 without replies, pipeline continues",
                rt.model()
            ),
        }
        // Completion report closes the pop's in-flight window: the
        // fairness epoch may reset and the autoscaler's backlog signal
        // drops only once the group has actually drained.
        batcher.complete(g, n);
    }
}

/// The SLO autoscaler control loop: every `policy.interval`, sample
/// each managed group's backlog (queued + in flight, read lock-free
/// off the shard atomics) and apply the hysteresis decision
/// (`coordinator::autoscale`).  Managed means scalable *or* merely
/// respawnable (a factory but no SLO / headroom): the latter never
/// scale with load but still get floor repair after a fault retires a
/// replica.  Exits when the router shuts down.
fn autoscale_loop(
    batcher: Arc<ShardedBatcher<Request>>,
    pool: Arc<ReplicaPool>,
    metrics: Arc<Metrics>,
    policy: AutoscalePolicy,
) {
    let scalable: Vec<_> = pool
        .groups()
        .iter()
        .filter(|g| g.scalable() || g.can_respawn())
        .cloned()
        .collect();
    if scalable.is_empty() {
        // Nothing to manage (the common fixed-size, factory-less
        // configuration): exit instead of waking every interval for
        // the router's whole lifetime.
        return;
    }
    let mut states: Vec<GroupScaleState> =
        scalable.iter().map(|_| GroupScaleState::new()).collect();
    while !batcher.is_shutting_down() {
        std::thread::sleep(policy.interval);
        let backlog: Vec<usize> = scalable
            .iter()
            .map(|rt| {
                let g = rt.model_index();
                batcher.queued_for(g) + batcher.in_flight_for(g)
            })
            .collect();
        for (i, rt) in scalable.iter().enumerate() {
            tick_group(rt, &mut states[i], backlog[i], &metrics, &policy);
        }
    }
}
