//! Fig. 2 — latency/power/area overhead of FP32 operators vs INT8.
//!
//! The paper synthesized single adders/multipliers in both arithmetics at
//! 65 nm and reported ~one order of magnitude overheads; this bench
//! regenerates the figure's bars from the gate-level cost model.

use swifttron::synthesis::{OperatorCost, Operators, Tech65};
use swifttron::util::bench::Table;

fn row(t: &Tech65, name: &str, fp: OperatorCost, int8: OperatorCost, out: &mut Table) {
    let freq = 143e6;
    out.row(&[
        name.to_string(),
        format!("{:.2}x", fp.delay_ns(t) / int8.delay_ns(t)),
        format!("{:.2}x", fp.power_w(t, freq) / int8.power_w(t, freq)),
        format!("{:.2}x", fp.area_mm2(t) / int8.area_mm2(t)),
    ]);
}

fn main() {
    let t = Tech65::new();
    let mut table = Table::new(&["operator", "latency overhead", "power overhead", "area overhead"]);
    row(&t, "adder FP32 vs INT8", Operators::fp32_adder(), Operators::int_adder(8), &mut table);
    row(
        &t,
        "multiplier FP32 vs INT8",
        Operators::fp32_multiplier(),
        Operators::int_multiplier(8, 8),
        &mut table,
    );
    table.print("Fig. 2 — FP32 vs INT8 single-operator overheads (65 nm model)");
    println!("\npaper claim: \"potential savings are about one order of magnitude\"");

    let mut detail = Table::new(&["operator", "gates (GE)", "delay ns", "energy pJ/op"]);
    for (name, op) in [
        ("INT8 adder", Operators::int_adder(8)),
        ("INT8 multiplier", Operators::int_multiplier(8, 8)),
        ("INT32 adder", Operators::int_adder(32)),
        ("INT32 multiplier", Operators::int_multiplier(32, 32)),
        ("FP32 adder", Operators::fp32_adder()),
        ("FP32 multiplier", Operators::fp32_multiplier()),
    ] {
        detail.row(&[
            name.to_string(),
            format!("{:.0}", op.ge),
            format!("{:.3}", op.delay_ns(&t)),
            format!("{:.3}", op.energy_pj(&t)),
        ]);
    }
    detail.print("operator catalog");
}
