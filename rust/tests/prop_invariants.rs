//! Property-based tests (in-repo `util::prop` framework) on coordinator
//! and datapath invariants: batching (no loss, FIFO, bounds), routing
//! state, and the integer-arithmetic laws the hardware relies on.

use std::time::Duration;
use swifttron::coordinator::batcher::{BatchPolicy, Batcher};
use swifttron::quant::{
    i_layernorm, i_softmax, requantize, Dyadic, LayerNormConsts, SoftmaxConsts, SM_UNIT,
};
use swifttron::util::prop::check;
use swifttron::util::rng::Rng;

// --- batcher invariants -------------------------------------------------

#[test]
fn prop_batcher_loses_nothing_and_preserves_fifo() {
    check(
        11,
        200,
        |r| {
            let n = r.below(60) as usize;
            let max_batch = 1 + r.below(10) as usize;
            (n as i64, max_batch as i64)
        },
        |&(n, max_batch)| {
            let mut b = Batcher::new(BatchPolicy {
                max_batch: max_batch as usize,
                max_wait: Duration::ZERO,
                bucket_width: 0,
            });
            for i in 0..n {
                b.push(i);
            }
            let mut drained = Vec::new();
            while !b.is_empty() {
                let batch = b.take_batch();
                if batch.is_empty() || batch.len() > max_batch as usize {
                    return false; // bounds violated
                }
                drained.extend(batch);
            }
            drained == (0..n).collect::<Vec<_>>() // no loss + FIFO
        },
    );
}

#[test]
fn prop_batcher_ready_iff_size_or_deadline() {
    check(
        12,
        200,
        |r| (r.below(20) as i64, 1 + r.below(8) as i64),
        |&(n, max_batch)| {
            let mut b = Batcher::new(BatchPolicy {
                max_batch: max_batch as usize,
                max_wait: Duration::from_secs(3600), // deadline never fires
                bucket_width: 0,
            });
            for i in 0..n {
                b.push(i);
            }
            let ready = b.ready(std::time::Instant::now());
            ready == (n >= max_batch)
        },
    );
}

// --- integer-arithmetic laws the blocks depend on ------------------------

#[test]
fn prop_requantize_monotone() {
    // the Requantization unit must preserve ordering (it feeds argmax
    // heads and attention comparisons downstream)
    check(
        21,
        300,
        |r| {
            let a = r.range_i64(-(1 << 26), 1 << 26);
            let b = r.range_i64(-(1 << 26), 1 << 26);
            (a, b)
        },
        |&(a, b)| {
            let dy = Dyadic::approx16(0.0173);
            let (qa, qb) = (requantize(a, dy), requantize(b, dy));
            if a <= b {
                qa <= qb
            } else {
                qa >= qb
            }
        },
    );
}

#[test]
fn prop_softmax_shift_invariance() {
    // softmax(x + c) == softmax(x): the max-subtraction must make the
    // unit exactly shift-invariant (paper Eq. 3)
    check(
        22,
        100,
        |r| {
            let n = 2 + r.below(24) as usize;
            let shift = r.range_i64(-500, 500);
            let mut v: Vec<i64> = (0..n).map(|_| r.range_i64(-2000, 2000)).collect();
            v.push(shift); // smuggle the shift in the last slot
            v
        },
        |v| {
            let (row, shift) = v.split_at(v.len() - 1);
            let shift = shift[0];
            let c = SoftmaxConsts::design(0.01);
            let shifted: Vec<i64> = row.iter().map(|&x| x + shift).collect();
            let mut a = vec![0i32; row.len()];
            let mut b = vec![0i32; row.len()];
            i_softmax(row, &c, &mut a);
            i_softmax(&shifted, &c, &mut b);
            a == b
        },
    );
}

#[test]
fn prop_softmax_normalized_and_bounded() {
    check(
        23,
        150,
        |r| {
            let n = 1 + r.below(64) as usize;
            (0..n).map(|_| r.range_i64(-3000, 3000)).collect::<Vec<i64>>()
        },
        |row| {
            let c = SoftmaxConsts::design(0.02);
            let mut out = vec![0i32; row.len()];
            i_softmax(row, &c, &mut out);
            let sum: i64 = out.iter().map(|&v| v as i64).sum();
            out.iter().all(|&v| (0..=SM_UNIT as i32).contains(&v))
                && (sum - SM_UNIT).abs() <= row.len() as i64
        },
    );
}

#[test]
fn prop_layernorm_shift_invariance() {
    // LayerNorm(x + c) == LayerNorm(x) (mean removal) — exact in the
    // integer unit up to the floor of the shared mean
    check(
        24,
        100,
        |r| {
            let d = 4 + r.below(60) as usize;
            let shift = r.range_i64(-1000, 1000) * d as i64; // multiple of d => exact
            let mut v: Vec<i64> = (0..d).map(|_| r.range_i64(-2000, 2000)).collect();
            v.push(shift);
            v
        },
        |v| {
            let (row, shift) = v.split_at(v.len() - 1);
            let shift = shift[0];
            let d = row.len();
            let c = LayerNormConsts { s_in: 0.01, s_gamma: 0.01, d };
            let gamma = vec![64i64; d];
            let beta = vec![0i64; d];
            let shifted: Vec<i64> = row.iter().map(|&x| x + shift).collect();
            let mut a = vec![0i32; d];
            let mut b = vec![0i32; d];
            i_layernorm(row, &gamma, &beta, &c, &mut a);
            i_layernorm(&shifted, &gamma, &beta, &c, &mut b);
            a == b
        },
    );
}

#[test]
fn prop_rng_shuffle_is_permutation() {
    check(
        25,
        100,
        |r| {
            let n = r.below(40) as usize;
            (0..n as i64).map(|i| i * 3).collect::<Vec<i64>>()
        },
        |v| {
            let mut rng = Rng::new(7);
            let mut shuffled = v.clone();
            rng.shuffle(&mut shuffled);
            let mut a = v.clone();
            let mut b = shuffled;
            a.sort();
            b.sort();
            a == b
        },
    );
}

#[test]
fn prop_json_number_roundtrip() {
    use swifttron::util::json::Json;
    check(
        26,
        300,
        |r| r.range_i64(-(1 << 52), 1 << 52),
        |&n| {
            let s = Json::from(n).to_string();
            Json::parse(&s).map(|v| v.as_i64() == Some(n)).unwrap_or(false)
        },
    );
}
