//! Zero-copy pull decoding of `SWWIRE1` frames out of fixed buffers
//! (DESIGN.md §11).
//!
//! [`RingBuf`] is a fixed-capacity compacting read buffer: the socket
//! reads into [`RingBuf::write_space`], the decoder parses
//! [`RingBuf::readable`] in place, and [`RingBuf::consume`] retires
//! parsed bytes.  Compaction (one `copy_within`) happens only when the
//! write cursor hits the end with consumed bytes at the front, so a
//! request is parsed from a single contiguous slice — which is what
//! lets [`FrameDecoder::pull`] hand out borrowed
//! [`RequestView`]s with no per-request heap allocation (the
//! picojson-rs `SliceParser` idiom; proved by the counting-allocator
//! test in `rust/tests/workspace_alloc.rs`).
//!
//! Malformed frames are skipped whole-frame via the length prefix and
//! reported as typed [`DecodeEvent::Malformed`] — the connection
//! survives.  A frame whose header names a length beyond
//! [`MAX_FRAME`](super::frame::MAX_FRAME) (or the decoder's configured
//! ceiling) is reported once as [`DecodeEvent::Oversized`] and its
//! body is then discarded incrementally as it streams in, so even a
//! frame larger than the ring itself cannot wedge or tear down the
//! connection.

use super::frame::{RequestView, HEADER_BYTES, KIND_REQUEST, MAX_FRAME, REQUEST_FIXED};

/// Fixed-capacity compacting read buffer backing one connection.
pub struct RingBuf {
    buf: Box<[u8]>,
    head: usize,
    tail: usize,
}

impl RingBuf {
    pub fn new(capacity: usize) -> RingBuf {
        assert!(capacity >= HEADER_BYTES + REQUEST_FIXED, "ring too small for any frame");
        RingBuf { buf: vec![0u8; capacity].into_boxed_slice(), head: 0, tail: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Unparsed bytes, contiguous.
    pub fn readable(&self) -> &[u8] {
        &self.buf[self.head..self.tail]
    }

    pub fn len(&self) -> usize {
        self.tail - self.head
    }

    pub fn is_empty(&self) -> bool {
        self.head == self.tail
    }

    /// Retire `n` parsed bytes from the front of [`readable`](RingBuf::readable).
    pub fn consume(&mut self, n: usize) {
        debug_assert!(n <= self.len());
        self.head += n;
        if self.head == self.tail {
            self.head = 0;
            self.tail = 0;
        }
    }

    /// Writable tail slice for the next socket read (empty when the
    /// ring is full of unparsed bytes — backpressure).  Compacts
    /// first, with one `copy_within`, if consumed front space is all
    /// that's left.
    pub fn write_space(&mut self) -> &mut [u8] {
        if self.tail == self.buf.len() && self.head > 0 {
            self.buf.copy_within(self.head..self.tail, 0);
            self.tail -= self.head;
            self.head = 0;
        }
        &mut self.buf[self.tail..]
    }

    /// Commit `n` bytes just written into [`write_space`](RingBuf::write_space).
    pub fn commit(&mut self, n: usize) {
        debug_assert!(self.tail + n <= self.buf.len());
        self.tail += n;
    }

    /// Copy `src` in (as a socket read would); returns bytes taken.
    /// Test/driver convenience — the mux reads directly into
    /// [`write_space`](RingBuf::write_space).
    pub fn fill_from(&mut self, src: &[u8]) -> usize {
        let space = self.write_space();
        let n = src.len().min(space.len());
        space[..n].copy_from_slice(&src[..n]);
        self.commit(n);
        n
    }
}

/// One pull step's outcome.  `Request` borrows the input buffer —
/// process it before consuming.
#[derive(Debug)]
pub enum DecodeEvent<'a> {
    /// A well-formed request frame, parsed in place.
    Request(RequestView<'a>),
    /// A structurally invalid frame; `id` is the frame id when the
    /// payload was long enough to carry one, else 0.  The frame was
    /// skipped whole; the stream stays aligned.
    Malformed { id: u64, reason: &'static str },
    /// A frame longer than the decoder's ceiling; its body is being
    /// discarded incrementally.  `id` is best-effort (0 unless the
    /// payload head had already arrived).
    Oversized { id: u64, len: u32 },
}

/// Pull decoder over one connection's frame stream.  Holds only
/// fixed-size cursor state — the bytes live in the caller's buffer.
pub struct FrameDecoder {
    max_frame: usize,
    /// oversized-frame bytes still to discard
    discard: u64,
}

impl Default for FrameDecoder {
    fn default() -> FrameDecoder {
        FrameDecoder::new(MAX_FRAME)
    }
}

impl FrameDecoder {
    /// `max_frame` caps the accepted `len` field; it is clamped to
    /// [`MAX_FRAME`] and must leave room for a minimal request.
    pub fn new(max_frame: usize) -> FrameDecoder {
        FrameDecoder { max_frame: max_frame.clamp(REQUEST_FIXED, MAX_FRAME), discard: 0 }
    }

    /// Decode the next frame out of `buf` (a connection's unparsed
    /// prefix).  Returns `(consumed, event)`: the caller must retire
    /// `consumed` bytes after processing the event.  `(0, None)`
    /// means "need more bytes"; `(n, None)` with `n > 0` means
    /// oversized-body bytes were discarded and the caller should call
    /// again.
    pub fn pull<'a>(&mut self, buf: &'a [u8]) -> (usize, Option<DecodeEvent<'a>>) {
        if self.discard > 0 {
            let n = (self.discard).min(buf.len() as u64) as usize;
            self.discard -= n as u64;
            return (n, None);
        }
        if buf.len() < HEADER_BYTES {
            return (0, None);
        }
        let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
        if len > self.max_frame {
            // Reject now (prompt typed reply) and stream the body into
            // the void; id is readable only if kind+id already arrived.
            let id = best_effort_id(&buf[HEADER_BYTES..]);
            let have = buf.len() - HEADER_BYTES;
            let eaten = have.min(len);
            self.discard = (len - eaten) as u64;
            return (HEADER_BYTES + eaten, Some(DecodeEvent::Oversized { id, len: len as u32 }));
        }
        if buf.len() < HEADER_BYTES + len {
            return (0, None);
        }
        let body = &buf[HEADER_BYTES..HEADER_BYTES + len];
        (HEADER_BYTES + len, Some(parse_request_body(body)))
    }
}

fn best_effort_id(body: &[u8]) -> u64 {
    if body.len() >= 9 {
        u64::from_le_bytes(body[1..9].try_into().unwrap())
    } else {
        0
    }
}

/// Validate and slice one request payload.  Every length must be
/// internally consistent — a frame that lies about its own layout is
/// `Malformed`, never a panic or an over-read.
fn parse_request_body(body: &[u8]) -> DecodeEvent<'_> {
    let id = best_effort_id(body);
    if body.len() < REQUEST_FIXED {
        return DecodeEvent::Malformed { id, reason: "frame shorter than request header" };
    }
    if body[0] != KIND_REQUEST {
        return DecodeEvent::Malformed { id, reason: "unexpected frame kind" };
    }
    let model_len = body[9] as usize;
    let ntok_at = 10 + model_len;
    if body.len() < ntok_at + 2 {
        return DecodeEvent::Malformed { id, reason: "model id overruns frame" };
    }
    let model = match std::str::from_utf8(&body[10..ntok_at]) {
        Ok(m) => m,
        Err(_) => return DecodeEvent::Malformed { id, reason: "model id is not utf-8" },
    };
    let n_tokens = u16::from_le_bytes([body[ntok_at], body[ntok_at + 1]]) as usize;
    let tokens = &body[ntok_at + 2..];
    if tokens.len() != 4 * n_tokens {
        return DecodeEvent::Malformed { id, reason: "token count disagrees with frame length" };
    }
    DecodeEvent::Request(RequestView::new(id, model, tokens))
}

#[cfg(test)]
mod tests {
    use super::super::encode;
    use super::super::frame::ResponseFrame;
    use super::*;

    fn frame_bytes(id: u64, model: &str, tokens: &[i32]) -> Vec<u8> {
        let mut out = Vec::new();
        encode::encode_request(&mut out, id, model, tokens);
        out
    }

    #[test]
    fn round_trip_single_frame() {
        let bytes = frame_bytes(7, "tiny", &[3, -17, 42]);
        let mut dec = FrameDecoder::default();
        let (n, ev) = dec.pull(&bytes);
        assert_eq!(n, bytes.len());
        match ev {
            Some(DecodeEvent::Request(r)) => {
                assert_eq!(r.id, 7);
                assert_eq!(r.model, "tiny");
                assert_eq!(r.tokens().collect::<Vec<_>>(), vec![3, -17, 42]);
                assert_eq!(r.token_count(), 3);
            }
            other => panic!("expected request, got {other:?}"),
        }
        // stream exhausted
        assert!(matches!(dec.pull(&bytes[n..]), (0, None)));
    }

    #[test]
    fn empty_model_and_empty_tokens_are_well_formed() {
        let bytes = frame_bytes(1, "", &[]);
        let mut dec = FrameDecoder::default();
        let (n, ev) = dec.pull(&bytes);
        assert_eq!(n, bytes.len());
        match ev {
            Some(DecodeEvent::Request(r)) => {
                assert_eq!(r.model, "");
                assert_eq!(r.token_count(), 0);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn partial_frames_wait_for_more_bytes() {
        let bytes = frame_bytes(9, "deit_s", &[1, 2, 3, 4]);
        let mut dec = FrameDecoder::default();
        for cut in 0..bytes.len() {
            assert!(matches!(dec.pull(&bytes[..cut]), (0, None)), "cut={cut}");
        }
        assert!(matches!(dec.pull(&bytes), (_, Some(DecodeEvent::Request(_)))));
    }

    #[test]
    fn pipelined_frames_decode_back_to_back() {
        let mut stream = Vec::new();
        for id in 0..5u64 {
            stream.extend_from_slice(&frame_bytes(id, "m", &[id as i32]));
        }
        let mut dec = FrameDecoder::default();
        let mut at = 0;
        let mut ids = Vec::new();
        loop {
            let (n, ev) = dec.pull(&stream[at..]);
            match ev {
                Some(DecodeEvent::Request(r)) => ids.push(r.id),
                Some(other) => panic!("{other:?}"),
                None if n == 0 => break,
                None => {}
            }
            at += n;
        }
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
        assert_eq!(at, stream.len());
    }

    #[test]
    fn malformed_frames_are_skipped_without_desync() {
        // token count lies about the payload length
        let mut bad = frame_bytes(3, "x", &[1, 2]);
        // corrupt n_tokens (last 8 payload bytes are the two tokens;
        // the two bytes before them are n_tokens)
        let ntok_at = bad.len() - 8 - 2;
        bad[ntok_at] = 99;
        let good = frame_bytes(4, "x", &[5]);
        let mut stream = bad.clone();
        stream.extend_from_slice(&good);
        let mut dec = FrameDecoder::default();
        let (n1, ev1) = dec.pull(&stream);
        assert_eq!(n1, bad.len(), "whole bad frame skipped");
        match ev1 {
            Some(DecodeEvent::Malformed { id, .. }) => assert_eq!(id, 3),
            other => panic!("{other:?}"),
        }
        let (_, ev2) = dec.pull(&stream[n1..]);
        assert!(
            matches!(ev2, Some(DecodeEvent::Request(r)) if r.id == 4),
            "stream realigned after malformed frame"
        );
    }

    #[test]
    fn wrong_kind_and_bad_utf8_are_malformed() {
        let mut wrong_kind = frame_bytes(1, "", &[]);
        wrong_kind[HEADER_BYTES] = 9;
        let mut dec = FrameDecoder::default();
        assert!(matches!(dec.pull(&wrong_kind), (_, Some(DecodeEvent::Malformed { .. }))));

        let mut bad_utf8 = frame_bytes(2, "ab", &[]);
        bad_utf8[HEADER_BYTES + 10] = 0xff;
        assert!(matches!(
            dec.pull(&bad_utf8),
            (_, Some(DecodeEvent::Malformed { reason: "model id is not utf-8", .. }))
        ));
    }

    #[test]
    fn oversized_frame_streams_to_the_void_then_realigns() {
        let mut dec = FrameDecoder::new(64);
        // header claiming 1000 bytes, body trickling in
        let mut stream = 1000u32.to_le_bytes().to_vec();
        stream.extend_from_slice(&[KIND_REQUEST]);
        stream.extend_from_slice(&77u64.to_le_bytes());
        let (n, ev) = dec.pull(&stream);
        assert_eq!(n, stream.len(), "header + available body consumed");
        match ev {
            Some(DecodeEvent::Oversized { id, len }) => {
                assert_eq!(id, 77);
                assert_eq!(len, 1000);
            }
            other => panic!("{other:?}"),
        }
        // 1000 - 9 bytes still owed; feed them in two chunks, then a
        // good frame — no event until the body is gone, then realigned
        let owed = 1000 - 9;
        let chunk = vec![0u8; owed - 10];
        let (n, ev) = dec.pull(&chunk);
        assert_eq!(n, chunk.len());
        assert!(ev.is_none());
        let mut rest = vec![0u8; 10];
        rest.extend_from_slice(&frame_bytes(5, "ok", &[1]));
        let (n, ev) = dec.pull(&rest);
        assert_eq!(n, 10);
        assert!(ev.is_none());
        let (_, ev) = dec.pull(&rest[n..]);
        assert!(matches!(ev, Some(DecodeEvent::Request(r)) if r.id == 5));
    }

    #[test]
    fn ring_buffer_compacts_and_backpressures() {
        let mut ring = RingBuf::new(32);
        assert_eq!(ring.capacity(), 32);
        assert_eq!(ring.fill_from(&[1; 32]), 32);
        assert!(ring.write_space().is_empty(), "full ring takes nothing");
        assert_eq!(ring.fill_from(&[2; 8]), 0);
        ring.consume(30);
        assert_eq!(ring.readable(), &[1, 1]);
        // compaction moves the 2-byte tail to the front, freeing 30
        assert_eq!(ring.fill_from(&[3; 40]), 30);
        assert_eq!(ring.len(), 32);
        assert_eq!(&ring.readable()[..2], &[1, 1]);
        assert_eq!(ring.readable()[2], 3);
        ring.consume(32);
        assert!(ring.is_empty());
        assert_eq!(ring.write_space().len(), 32, "empty ring resets cursors");
    }

    #[test]
    fn decoder_over_ring_handles_frames_split_across_reads() {
        let mut stream = Vec::new();
        for id in 0..40u64 {
            stream.extend_from_slice(&frame_bytes(id, "tiny", &[1, 2, 3, 4, 5, 6, 7]));
        }
        let mut ring = RingBuf::new(64); // smaller than 2 frames
        let mut dec = FrameDecoder::default();
        let mut fed = 0;
        let mut ids = Vec::new();
        while fed < stream.len() || !ring.is_empty() {
            fed += ring.fill_from(&stream[fed..]);
            loop {
                let (n, ev) = dec.pull(ring.readable());
                if let Some(DecodeEvent::Request(r)) = ev {
                    ids.push(r.id);
                } else if let Some(other) = ev {
                    panic!("{other:?}");
                }
                if n == 0 {
                    break;
                }
                ring.consume(n);
            }
        }
        assert_eq!(ids, (0..40).collect::<Vec<_>>());
    }

    #[test]
    fn client_decode_response_round_trips_every_kind() {
        let mut buf = Vec::new();
        encode::encode_ok(&mut buf, 11, 2, 4, &[9, -9, 3], 0.25, 1234.5);
        encode::encode_error(&mut buf, 12, "bad length");
        encode::encode_overloaded(&mut buf, 13, 88.5, 40.0);
        encode::encode_busy(&mut buf, 256);
        let mut at = 0;
        let mut frames = Vec::new();
        while at < buf.len() {
            let (n, f) = encode::decode_response(&buf[at..]).unwrap().unwrap();
            frames.push(f);
            at += n;
        }
        assert_eq!(frames.len(), 4);
        match &frames[0] {
            ResponseFrame::Ok { id, replica, label, logits, accel_ms, e2e_us } => {
                assert_eq!((*id, *replica, *label), (11, 2, 4));
                assert_eq!(logits, &vec![9, -9, 3]);
                assert!((accel_ms - 0.25).abs() < 1e-12 && (e2e_us - 1234.5).abs() < 1e-9);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(
            frames[1],
            ResponseFrame::Error { id: 12, message: "bad length".into() }
        );
        assert_eq!(
            frames[2],
            ResponseFrame::Overloaded { id: 13, predicted_ms: 88.5, slo_ms: 40.0 }
        );
        assert_eq!(frames[3], ResponseFrame::Busy { limit: 256 });
        // truncated stream: needs more bytes, not an error
        assert!(encode::decode_response(&buf[..3]).unwrap().is_none());
    }
}
