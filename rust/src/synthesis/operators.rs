//! Arithmetic-operator cost models: gate counts, critical-path gate
//! delays, and per-operation energies for the operators SwiftTron (and
//! its FP32 comparison points, Fig. 2) instantiates.
//!
//! Gate counts follow standard structures:
//! * ripple/carry-select INT adders: ~9 GE per full-adder bit (+25%
//!   carry acceleration above 16 bits);
//! * array INT multipliers: ~10 GE per partial-product cell (a AND + FA);
//! * MAC accumulate stages use carry-save compressors (~4.5 GE/bit);
//! * restoring sequential divider: one adder + registers + control;
//! * FP32 (1+8+23): operand-align barrel shifter, 24-bit significand
//!   datapath, LZA + normalize shifter, exponent logic, rounding —
//!   the classic reason the paper's Fig. 2 shows order-of-magnitude
//!   overheads versus INT8.

use super::tech::Tech65;

#[derive(Clone, Copy, Debug)]
pub struct OperatorCost {
    pub ge: f64,
    /// critical path in gate delays (FO4 units)
    pub delay_gates: f64,
    /// toggled fraction of gates per operation (activity)
    pub activity: f64,
}

impl OperatorCost {
    pub fn area_mm2(&self, t: &Tech65) -> f64 {
        t.area_mm2(self.ge)
    }

    pub fn delay_ns(&self, t: &Tech65) -> f64 {
        t.delay_ns(self.delay_gates)
    }

    /// Energy per operation in picojoules.
    pub fn energy_pj(&self, t: &Tech65) -> f64 {
        self.ge * self.activity * t.e_dyn_fj * 1e-3
    }

    /// Average power when issued every cycle at `freq_hz`.
    pub fn power_w(&self, t: &Tech65, freq_hz: f64) -> f64 {
        t.dyn_power_w(self.ge, self.activity, freq_hz) + t.leak_power_w(self.ge)
    }
}

/// Catalog of operator models.
pub struct Operators;

impl Operators {
    pub fn int_adder(bits: u32) -> OperatorCost {
        let accel = if bits > 16 { 1.25 } else { 1.0 };
        OperatorCost {
            ge: 9.0 * bits as f64 * accel,
            // carry-select: ~sqrt structure; model log+const path
            delay_gates: 4.0 + 1.2 * (bits as f64).sqrt(),
            activity: 0.25,
        }
    }

    pub fn int_multiplier(bits_a: u32, bits_b: u32) -> OperatorCost {
        OperatorCost {
            ge: 10.0 * bits_a as f64 * bits_b as f64,
            delay_gates: 3.0 + 1.5 * (bits_a + bits_b) as f64 / 2.0_f64.sqrt() / 4.0,
            activity: 0.3,
        }
    }

    /// Carry-save accumulate stage of a MAC (cheaper than a full adder).
    pub fn csa_accumulator(bits: u32) -> OperatorCost {
        OperatorCost { ge: 4.5 * bits as f64, delay_gates: 6.0, activity: 0.25 }
    }

    pub fn register(bits: u32) -> OperatorCost {
        OperatorCost { ge: 6.0 * bits as f64, delay_gates: 1.0, activity: 0.15 }
    }

    pub fn comparator(bits: u32) -> OperatorCost {
        OperatorCost { ge: 3.5 * bits as f64, delay_gates: 3.0 + (bits as f64).log2(), activity: 0.2 }
    }

    pub fn barrel_shifter(bits: u32) -> OperatorCost {
        let stages = (bits as f64).log2().ceil();
        OperatorCost { ge: 3.0 * bits as f64 * stages, delay_gates: 2.0 * stages, activity: 0.2 }
    }

    /// Restoring sequential divider (one quotient bit per cycle): adder +
    /// three registers + control.  Latency is `bits` iterations — the
    /// "relatively more resources" divider the paper mentions (§III-F).
    pub fn seq_divider(bits: u32) -> OperatorCost {
        let adder = Self::int_adder(bits);
        let regs = 3.0 * Self::register(bits).ge;
        OperatorCost {
            ge: adder.ge + regs + 60.0,
            delay_gates: adder.delay_gates,
            activity: 0.3,
        }
    }

    /// Non-restoring *array* divider: `bits` cascaded conditional
    /// add/subtract rows.  The Softmax and LayerNorm output phases must
    /// sustain one division per cycle inside a 3-stage 7 ns pipeline
    /// (paper §IV-B), which a sequential divider cannot — this is why
    /// those units are area-heavy but power-light in Fig. 18.
    pub fn array_divider(bits: u32) -> OperatorCost {
        let row = Self::int_adder(bits).ge + 2.0 * bits as f64; // CAS row + quotient mux
        OperatorCost {
            ge: bits as f64 * row,
            // pipelined: per-stage path is bits/3 rows deep
            delay_gates: (bits as f64 / 3.0) * 2.5,
            activity: 0.25,
        }
    }

    /// FP32 adder: align shifter + 24b significand adder + LZA +
    /// normalize shifter + exponent datapath + rounding.
    pub fn fp32_adder() -> OperatorCost {
        let align = Self::barrel_shifter(24).ge;
        let mantissa = Self::int_adder(24).ge * 2.0; // add + round increment
        let lza_norm = Self::barrel_shifter(24).ge + 120.0;
        let exponent = Self::int_adder(8).ge * 2.0 + 80.0;
        OperatorCost {
            ge: align + mantissa + lza_norm + exponent,
            delay_gates: 4.0 * Self::int_adder(24).delay_gates,
            activity: 0.25,
        }
    }

    /// FP32 multiplier: 24x24 significand array + exponent add + rounding.
    pub fn fp32_multiplier() -> OperatorCost {
        let significand = Self::int_multiplier(24, 24).ge;
        let exponent = Self::int_adder(8).ge + 60.0;
        let round = Self::int_adder(24).ge + 80.0;
        OperatorCost {
            ge: significand + exponent + round,
            delay_gates: 1.3 * Self::int_multiplier(24, 24).delay_gates,
            activity: 0.3,
        }
    }

    /// One MAC element of the paper's array (Fig. 6): INT8xINT8
    /// multiplier + INT32 carry-save accumulate + INT32 result register.
    pub fn int8_mac() -> OperatorCost {
        let m = Self::int_multiplier(8, 8);
        let a = Self::csa_accumulator(32);
        let r = Self::register(32);
        OperatorCost {
            ge: m.ge + a.ge + r.ge,
            delay_gates: m.delay_gates + a.delay_gates,
            activity: 0.28,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp32_vs_int8_order_of_magnitude() {
        // the paper's Fig. 2 claim: ~10x overheads
        let add_ratio = Operators::fp32_adder().ge / Operators::int_adder(8).ge;
        let mul_ratio = Operators::fp32_multiplier().ge / Operators::int_multiplier(8, 8).ge;
        assert!((5.0..30.0).contains(&add_ratio), "add area ratio {add_ratio}");
        assert!((5.0..30.0).contains(&mul_ratio), "mul area ratio {mul_ratio}");
    }

    #[test]
    fn fp32_slower_than_int8() {
        assert!(Operators::fp32_adder().delay_gates > Operators::int_adder(8).delay_gates);
        assert!(
            Operators::fp32_multiplier().delay_gates
                > Operators::int_multiplier(8, 8).delay_gates
        );
    }

    #[test]
    fn adder_area_grows_with_width() {
        assert!(Operators::int_adder(32).ge > Operators::int_adder(8).ge * 3.0);
    }

    #[test]
    fn mac_fits_65nm_budget() {
        // one INT8 MAC must be well under 1000 GE for a 196k-MAC array
        // to synthesize at a paper-plausible area
        let mac = Operators::int8_mac();
        assert!((400.0..1000.0).contains(&mac.ge), "{}", mac.ge);
    }

    #[test]
    fn energy_positive_and_ordered() {
        let t = Tech65::new();
        assert!(
            Operators::fp32_multiplier().energy_pj(&t)
                > Operators::int_multiplier(8, 8).energy_pj(&t)
        );
    }
}
