//! INT8 x INT8 -> INT32 matrix multiplication (the MatMul block,
//! paper §III-B, Fig. 6) — the functional model the simulator and the
//! integer classifier head use.  Row-major `(m,k) @ (k,n) -> (m,n)`.

/// `out[m][n] = sum_k x[m][k]*w[k][n] (+ bias[n])`, INT32 accumulators.
/// Panics in debug builds if an accumulator leaves the INT32 range (the
/// hardware's accumulator width; paper-scale contractions cannot).
pub fn i_matmul(
    x: &[i32],
    w: &[i32],
    bias: Option<&[i32]>,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    assert_eq!(x.len(), m * k, "x shape");
    assert_eq!(w.len(), k * n, "w shape");
    assert_eq!(out.len(), m * n, "out shape");
    if let Some(b) = bias {
        assert_eq!(b.len(), n, "bias shape");
    }
    // INT8-range operands cannot overflow the INT32 accumulator for the
    // paper's contractions (|x*w| <= 128*128, k <= 3072 => |acc| < 2^26
    // before bias) — same argument the hardware's accumulator width
    // rests on.  Debug builds verify the operand contract.
    debug_assert!(
        x.iter().all(|&v| (-128..=127).contains(&v)),
        "i_matmul operand outside INT8 range"
    );
    debug_assert!(k <= (i32::MAX as usize) / (128 * 128), "contraction too deep for INT32");
    for i in 0..m {
        let xrow = &x[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        // bias folds in at readout (paper: added when reading the output)
        match bias {
            Some(b) => orow.copy_from_slice(b),
            None => orow.fill(0),
        }
        for (kk, &xv) in xrow.iter().enumerate() {
            if xv == 0 {
                continue;
            }
            let wrow = &w[kk * n..(kk + 1) * n];
            // plain i32 multiply-accumulate: autovectorizes (an i64
            // widening here blocks SIMD); a row-blocked variant was tried
            // and reverted — W panels already hit in LLC at these sizes
            // (EXPERIMENTS.md §Perf).
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
}

/// Transposed-B variant: `(m,k) @ (n,k)^T -> (m,n)` — the Attention
/// unit's Q.K^T, where K streams in row-major like Q.
pub fn i_matmul_bt(x: &[i32], w_t: &[i32], m: usize, k: usize, n: usize, out: &mut [i32]) {
    assert_eq!(x.len(), m * k);
    assert_eq!(w_t.len(), n * k);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        let xrow = &x[i * k..(i + 1) * k];
        for j in 0..n {
            let wrow = &w_t[j * k..(j + 1) * k];
            let mut acc: i32 = 0;
            for (xv, wv) in xrow.iter().zip(wrow) {
                acc += *xv * *wv;
            }
            out[i * n + j] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity() {
        let m = 3;
        let x: Vec<i32> = (0..9).map(|v| v - 4).collect();
        let mut eye = vec![0i32; 9];
        for i in 0..m {
            eye[i * m + i] = 1;
        }
        let mut out = vec![0i32; 9];
        i_matmul(&x, &eye, None, m, m, m, &mut out);
        assert_eq!(out, x);
    }

    #[test]
    fn bias_added_per_column() {
        let x = vec![1, 0, 0, 1]; // I2
        let w = vec![5, 6, 7, 8];
        let bias = vec![100, 200];
        let mut out = vec![0i32; 4];
        i_matmul(&x, &w, Some(&bias), 2, 2, 2, &mut out);
        assert_eq!(out, vec![105, 206, 107, 208]);
    }

    #[test]
    fn bt_matches_plain_with_transpose() {
        let (m, k, n) = (4, 5, 3);
        let x: Vec<i32> = (0..m * k).map(|v| (v as i32 * 7 % 13) - 6).collect();
        let w: Vec<i32> = (0..k * n).map(|v| (v as i32 * 11 % 17) - 8).collect();
        let mut wt = vec![0i32; n * k];
        for kk in 0..k {
            for j in 0..n {
                wt[j * k + kk] = w[kk * n + j];
            }
        }
        let mut a = vec![0i32; m * n];
        let mut b = vec![0i32; m * n];
        i_matmul(&x, &w, None, m, k, n, &mut a);
        i_matmul_bt(&x, &wt, m, k, n, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn worst_case_int8_no_overflow_at_dff() {
        // k = 3072 (RoBERTa d_ff) at extreme INT8 operands stays in INT32
        let k = 3072;
        let x = vec![-128i32; k];
        let w = vec![-128i32; k];
        let mut out = vec![0i32; 1];
        i_matmul(&x, &w, None, 1, k, 1, &mut out);
        assert_eq!(out[0], (k as i32) * 128 * 128);
    }
}
