#!/usr/bin/env bash
# Tier-1 gate: release build, tests, and rustdoc with warnings denied —
# the doc pass makes dangling references (e.g. to DESIGN.md sections
# that were renamed away) fail fast instead of rotting.
set -euo pipefail
cd "$(dirname "$0")"

cargo build --release
cargo test -q
cargo clippy --all-targets -- -D warnings
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps
echo "ci.sh: all green"
