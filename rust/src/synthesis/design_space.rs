//! Design-space autotuner over the synthesis layer (DESIGN.md §12).
//!
//! The paper fixes one hardware instance per workload (§IV-B) but
//! stresses that array size and head parallelism are design-time
//! tunables (§III-D).  This module searches that space: for a workload
//! geometry it enumerates a geometry-relative grid of [`HwConfig`]
//! candidates, prices each one with the analytical [`CostModel`]
//! (latency) and the gate-level synthesis model (area, power, critical
//! path), marks the (latency, area, power) Pareto front, and
//! recommends the fastest clock-feasible point inside an area/power
//! [`Budget`] — default headroom around the paper's Table I instance
//! (273 mm², 33.64 W).
//!
//! Candidates whose cost model cannot be built (degenerate unit counts
//! the simulator would reject) are skipped and counted, never
//! silently dropped.  The search is fully deterministic: a fixed grid,
//! closed-form models, and total-order sorting on the scores — two
//! runs produce identical points in identical order (tested below).
//!
//! Consumers: `swifttron tune` prints the per-preset recommendation;
//! the `table1_synthesis` bench sweeps the space and snapshots a smoke
//! subset; `EXPERIMENTS.md` §DesignSpace records the findings.

use super::report::synthesis_report;
use crate::model::Geometry;
use crate::sim::{CostModel, HwConfig};

/// Area/power ceiling for [`explore`]'s recommendation.
#[derive(Clone, Copy, Debug)]
pub struct Budget {
    pub max_area_mm2: f64,
    pub max_power_w: f64,
}

impl Default for Budget {
    /// Headroom around the paper's Table I synthesis (273 mm²,
    /// 33.64 W at 65 nm): a recommended instance may match the paper's
    /// accelerator but not meaningfully exceed it.
    fn default() -> Budget {
        Budget { max_area_mm2: 300.0, max_power_w: 35.0 }
    }
}

/// One evaluated hardware candidate.
#[derive(Clone, Debug)]
pub struct DesignPoint {
    pub hw: HwConfig,
    /// full-sequence single-inference latency ([`CostModel::full_ms`])
    pub latency_ms: f64,
    pub area_mm2: f64,
    pub power_w: f64,
    pub critical_path_ns: f64,
    /// the slowest operator path fits in the candidate's clock period
    pub meets_clock: bool,
    /// on the (latency, area, power) Pareto front among clock-feasible
    /// points
    pub pareto: bool,
}

impl DesignPoint {
    /// Clock-feasible and inside the budget's area/power ceiling.
    pub fn within(&self, b: &Budget) -> bool {
        self.meets_clock && self.area_mm2 <= b.max_area_mm2 && self.power_w <= b.max_power_w
    }
}

/// Result of one design-space search.
#[derive(Clone, Debug)]
pub struct DesignSpace {
    /// workload name (geometry preset on the CLI path)
    pub preset: String,
    pub geo: Geometry,
    pub budget: Budget,
    /// candidates skipped because their cost model would not build
    /// (degenerate unit counts)
    pub skipped: usize,
    /// evaluated points, sorted by (latency, area, power)
    pub points: Vec<DesignPoint>,
    /// index into `points` of the fastest clock-feasible point within
    /// the budget (`None` when nothing fits)
    pub recommended: Option<usize>,
}

impl DesignSpace {
    pub fn recommended_point(&self) -> Option<&DesignPoint> {
        self.recommended.map(|i| &self.points[i])
    }

    pub fn pareto_front(&self) -> Vec<&DesignPoint> {
        self.points.iter().filter(|p| p.pareto).collect()
    }

    /// Human-readable summary for `swifttron tune`.
    pub fn summary(&self) -> String {
        let g = &self.geo;
        let mut s = format!(
            "design space {}: d={} heads={} m={} d_ff={} layers={}\n  \
             {} points evaluated ({} unsimulatable skipped), {} on the Pareto front\n  \
             budget {:.0} mm^2 / {:.1} W\n",
            self.preset,
            g.d,
            g.heads,
            g.m,
            g.d_ff,
            g.layers,
            self.points.len(),
            self.skipped,
            self.points.iter().filter(|p| p.pareto).count(),
            self.budget.max_area_mm2,
            self.budget.max_power_w,
        );
        match self.recommended_point() {
            Some(p) => {
                let hw = &p.hw;
                s.push_str(&format!(
                    "  recommended: {}x{} array, {} head units, {} softmax units, \
                     {} ln lanes, {:.1} ns clock\n  \
                     latency {:.4} ms | area {:.1} mm^2 | power {:.2} W | \
                     critical path {:.2} ns\n",
                    hw.array_rows,
                    hw.array_cols,
                    hw.parallel_heads,
                    hw.softmax_units,
                    hw.layernorm_lanes,
                    hw.clock_ns,
                    p.latency_ms,
                    p.area_mm2,
                    p.power_w,
                    p.critical_path_ns,
                ));
            }
            None => s.push_str("  no candidate meets the budget\n"),
        }
        s
    }
}

/// The geometry-relative candidate grid: array rows over
/// {m/4, m/2, m}, columns over {d/4, d/2, d}, head units over
/// {1, heads/2, heads}, softmax units over {m/4, m}, and the paper
/// clock against a relaxed one.  LayerNorm lanes stay at `d` (the
/// paper's element-parallel row) and the pipeline depth at 3 — both
/// are dictated by the timing closure story, not the workload.
/// Degenerate steps collapse (duplicates are removed), so small
/// geometries yield smaller grids.
pub fn candidate_grid(geo: &Geometry) -> Vec<HwConfig> {
    let steps3 = |full: usize| {
        let mut v = vec![(full / 4).max(1), (full / 2).max(1), full.max(1)];
        v.sort_unstable();
        v.dedup();
        v
    };
    let rows = steps3(geo.m);
    let cols = steps3(geo.d);
    let heads = {
        let mut v = vec![1, (geo.heads / 2).max(1), geo.heads.max(1)];
        v.sort_unstable();
        v.dedup();
        v
    };
    let softmax = {
        let mut v = vec![(geo.m / 4).max(1), geo.m.max(1)];
        v.sort_unstable();
        v.dedup();
        v
    };
    let clocks = [7.0f64, 10.0];
    let mut out = Vec::new();
    for &r in &rows {
        for &c in &cols {
            for &h in &heads {
                for &s in &softmax {
                    for &clk in &clocks {
                        out.push(HwConfig {
                            array_rows: r,
                            array_cols: c,
                            parallel_heads: h,
                            softmax_units: s,
                            layernorm_lanes: geo.d.max(1),
                            clock_ns: clk,
                            pipeline_stages: 3,
                            worst_case_sqrt: true,
                            attn_heads_parallel: true,
                            weight_bits: 8,
                        });
                    }
                }
            }
        }
    }
    out
}

/// Search the design space of a geometry preset.
pub fn explore(preset: &str, budget: Budget) -> Result<DesignSpace, String> {
    let geo = Geometry::preset(preset).ok_or_else(|| {
        format!("unknown preset {preset:?} (expected one of {:?})", Geometry::PRESET_NAMES)
    })?;
    Ok(explore_geometry(preset, &geo, budget))
}

/// Search the design space of an explicit geometry.
pub fn explore_geometry(name: &str, geo: &Geometry, budget: Budget) -> DesignSpace {
    let mut points = Vec::new();
    let mut skipped = 0usize;
    for hw in candidate_grid(geo) {
        // The cost model is the latency authority (and the gate: a
        // candidate it rejects is unsimulatable, not merely slow).
        let cm = match CostModel::build(&hw, geo) {
            Ok(cm) => cm,
            Err(_) => {
                skipped += 1;
                continue;
            }
        };
        let rep = synthesis_report(&hw, geo);
        points.push(DesignPoint {
            hw,
            latency_ms: cm.full_ms(),
            area_mm2: rep.area_mm2,
            power_w: rep.power_w,
            critical_path_ns: rep.critical_path_ns,
            meets_clock: rep.critical_path_ns <= hw.clock_ns,
            pareto: false,
        });
    }
    points.sort_by(|a, b| {
        a.latency_ms
            .total_cmp(&b.latency_ms)
            .then(a.area_mm2.total_cmp(&b.area_mm2))
            .then(a.power_w.total_cmp(&b.power_w))
    });
    let pareto: Vec<bool> = (0..points.len())
        .map(|i| {
            points[i].meets_clock
                && !points.iter().enumerate().any(|(j, q)| {
                    j != i
                        && q.meets_clock
                        && q.latency_ms <= points[i].latency_ms
                        && q.area_mm2 <= points[i].area_mm2
                        && q.power_w <= points[i].power_w
                        && (q.latency_ms < points[i].latency_ms
                            || q.area_mm2 < points[i].area_mm2
                            || q.power_w < points[i].power_w)
                })
        })
        .collect();
    for (p, f) in points.iter_mut().zip(pareto) {
        p.pareto = f;
    }
    // sorted by (latency, area, power): the first in-budget point is
    // the fastest, tie-broken toward the smaller/cooler instance
    let recommended = points.iter().position(|p| p.within(&budget));
    DesignSpace { preset: name.to_string(), geo: *geo, budget, skipped, points, recommended }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hw_key(hw: &HwConfig) -> (usize, usize, usize, usize, usize, u64) {
        (
            hw.array_rows,
            hw.array_cols,
            hw.parallel_heads,
            hw.softmax_units,
            hw.layernorm_lanes,
            hw.clock_ns.to_bits(),
        )
    }

    #[test]
    fn grid_is_deduplicated_and_every_candidate_validates() {
        for name in Geometry::PRESET_NAMES {
            let geo = Geometry::preset(name).unwrap();
            let grid = candidate_grid(&geo);
            assert!(grid.len() >= 8, "{name}: grid too small ({})", grid.len());
            let mut keys: Vec<_> = grid.iter().map(hw_key).collect();
            keys.sort_unstable();
            let n = keys.len();
            keys.dedup();
            assert_eq!(keys.len(), n, "{name}: duplicate candidates");
            for hw in &grid {
                hw.validate(&geo).unwrap_or_else(|e| panic!("{name}: {e}"));
            }
        }
    }

    #[test]
    fn explore_tiny_recommends_the_fastest_in_budget_point() {
        let ds = explore("tiny", Budget::default()).unwrap();
        assert_eq!(ds.skipped, 0, "every tiny candidate simulates");
        assert!(!ds.points.is_empty());
        let best = ds.recommended_point().expect("tiny fits any sane budget");
        assert!(best.within(&ds.budget));
        assert!(best.latency_ms > 0.0 && best.area_mm2 > 0.0 && best.power_w > 0.0);
        for p in &ds.points {
            if p.within(&ds.budget) {
                assert!(
                    p.latency_ms >= best.latency_ms,
                    "recommended point is not the fastest in budget"
                );
            }
        }
        // the recommendation is on the front by construction
        assert!(best.pareto, "a budget-optimal point is never dominated");
    }

    #[test]
    fn pareto_front_is_mutually_nondominated() {
        let ds = explore("tiny", Budget::default()).unwrap();
        let front = ds.pareto_front();
        assert!(!front.is_empty());
        for a in &front {
            for b in &front {
                let dominates = a.latency_ms <= b.latency_ms
                    && a.area_mm2 <= b.area_mm2
                    && a.power_w <= b.power_w
                    && (a.latency_ms < b.latency_ms
                        || a.area_mm2 < b.area_mm2
                        || a.power_w < b.power_w);
                assert!(!dominates, "front point dominates another front point");
            }
        }
    }

    #[test]
    fn explore_is_deterministic() {
        let a = explore("small", Budget::default()).unwrap();
        let b = explore("small", Budget::default()).unwrap();
        assert_eq!(a.points.len(), b.points.len());
        assert_eq!(a.recommended, b.recommended);
        assert_eq!(a.skipped, b.skipped);
        for (p, q) in a.points.iter().zip(&b.points) {
            assert_eq!(hw_key(&p.hw), hw_key(&q.hw));
            assert_eq!(p.latency_ms.to_bits(), q.latency_ms.to_bits());
            assert_eq!(p.area_mm2.to_bits(), q.area_mm2.to_bits());
            assert_eq!(p.power_w.to_bits(), q.power_w.to_bits());
            assert_eq!(p.pareto, q.pareto);
        }
    }

    #[test]
    fn unknown_preset_is_an_error() {
        assert!(explore("bert_xxl", Budget::default()).is_err());
    }

    #[test]
    fn summary_names_the_recommended_instance() {
        let ds = explore("tiny", Budget::default()).unwrap();
        let s = ds.summary();
        assert!(s.contains("design space tiny"), "{s}");
        assert!(s.contains("recommended:"), "{s}");
        assert!(s.contains("Pareto front"), "{s}");
    }
}
