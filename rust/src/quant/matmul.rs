//! INT8 x INT8 -> INT32 matrix multiplication (the MatMul block,
//! paper §III-B, Fig. 6) — the functional model the simulator and the
//! integer classifier head use.  Row-major `(m,k) @ (k,n) -> (m,n)`.
//!
//! Two execution strategies, bit-identical by construction:
//! * the serial kernels [`i_matmul`] / [`i_matmul_bt`], and
//! * row-tiled thread-parallel variants ([`i_matmul_tiled`] /
//!   [`i_matmul_bt_tiled`]) that split the *output rows* across scoped
//!   threads — each tile runs the serial kernel on a disjoint row band,
//!   so no accumulation order changes and the result is exactly the
//!   serial one (asserted by randomized tests below).
//!
//! [`i_matmul_par`] / [`i_matmul_bt_par`] auto-dispatch: contractions at
//! or above [`PAR_MIN_MACS`] multiply-accumulates go parallel, smaller
//! ones stay serial (thread spawn would dominate; EXPERIMENTS.md §Perf).
//!
//! All kernels are shape-agnostic in `m`: the variable-length forward
//! pass (DESIGN.md §6) calls them with the request's live row count
//! `m_eff`, never the padded geometry maximum, so both the work done
//! and the parallel-dispatch decision scale with the actual sequence.

use crate::util::threadpool::{default_parallelism, tile_ranges};

/// `out[m][n] = sum_k x[m][k]*w[k][n] (+ bias[n])`, INT32 accumulators.
/// Panics in debug builds if an accumulator leaves the INT32 range (the
/// hardware's accumulator width; paper-scale contractions cannot).
pub fn i_matmul(
    x: &[i32],
    w: &[i32],
    bias: Option<&[i32]>,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    assert_eq!(x.len(), m * k, "x shape");
    assert_eq!(w.len(), k * n, "w shape");
    assert_eq!(out.len(), m * n, "out shape");
    if let Some(b) = bias {
        assert_eq!(b.len(), n, "bias shape");
    }
    // INT8-range operands cannot overflow the INT32 accumulator for the
    // paper's contractions (|x*w| <= 128*128, k <= 3072 => |acc| < 2^26
    // before bias) — same argument the hardware's accumulator width
    // rests on.  Debug builds verify the operand contract.
    debug_assert!(
        x.iter().all(|&v| (-128..=127).contains(&v)),
        "i_matmul operand outside INT8 range"
    );
    debug_assert!(k <= (i32::MAX as usize) / (128 * 128), "contraction too deep for INT32");
    for i in 0..m {
        let xrow = &x[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        // bias folds in at readout (paper: added when reading the output)
        match bias {
            Some(b) => orow.copy_from_slice(b),
            None => orow.fill(0),
        }
        for (kk, &xv) in xrow.iter().enumerate() {
            if xv == 0 {
                continue;
            }
            let wrow = &w[kk * n..(kk + 1) * n];
            // plain i32 multiply-accumulate: autovectorizes (an i64
            // widening here blocks SIMD); a row-blocked variant was tried
            // and reverted — W panels already hit in LLC at these sizes
            // (EXPERIMENTS.md §Perf).
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
}

/// Transposed-B variant: `(m,k) @ (n,k)^T -> (m,n)` — the Attention
/// unit's Q.K^T, where K streams in row-major like Q.
pub fn i_matmul_bt(x: &[i32], w_t: &[i32], m: usize, k: usize, n: usize, out: &mut [i32]) {
    assert_eq!(x.len(), m * k);
    assert_eq!(w_t.len(), n * k);
    assert_eq!(out.len(), m * n);
    for i in 0..m {
        let xrow = &x[i * k..(i + 1) * k];
        for j in 0..n {
            let wrow = &w_t[j * k..(j + 1) * k];
            let mut acc: i32 = 0;
            for (xv, wv) in xrow.iter().zip(wrow) {
                acc += *xv * *wv;
            }
            out[i * n + j] = acc;
        }
    }
}

/// Minimum multiply-accumulate count for the parallel path to pay for
/// its scoped-thread spawns.  Below this (every tiny-preset contraction,
/// the classifier head) the serial kernel wins; at/above it (the
/// roberta-scale projections and FFN matmuls, ≥ ~2M MACs) row tiling
/// wins even on a few cores.  Swept in EXPERIMENTS.md §Perf.
pub const PAR_MIN_MACS: usize = 1 << 21;

/// Row-tiled parallel [`i_matmul`]: output rows are split into at most
/// `threads` balanced contiguous bands, each computed by the serial
/// kernel on its own scoped thread.  Bit-exact with [`i_matmul`] for
/// every input (the per-row accumulation order is untouched).
pub fn i_matmul_tiled(
    threads: usize,
    x: &[i32],
    w: &[i32],
    bias: Option<&[i32]>,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    assert_eq!(x.len(), m * k, "x shape");
    assert_eq!(w.len(), k * n, "w shape");
    assert_eq!(out.len(), m * n, "out shape");
    let tiles = tile_ranges(m, threads);
    if tiles.len() <= 1 {
        return i_matmul(x, w, bias, m, k, n, out);
    }
    std::thread::scope(|s| {
        let mut rem: &mut [i32] = out;
        for t in tiles {
            let rows = t.len();
            let (tile_out, rest) = std::mem::take(&mut rem).split_at_mut(rows * n);
            rem = rest;
            let x_tile = &x[t.start * k..t.end * k];
            s.spawn(move || i_matmul(x_tile, w, bias, rows, k, n, tile_out));
        }
    });
}

/// Row-tiled parallel [`i_matmul_bt`]; same tiling contract as
/// [`i_matmul_tiled`].
pub fn i_matmul_bt_tiled(
    threads: usize,
    x: &[i32],
    w_t: &[i32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    assert_eq!(x.len(), m * k);
    assert_eq!(w_t.len(), n * k);
    assert_eq!(out.len(), m * n);
    let tiles = tile_ranges(m, threads);
    if tiles.len() <= 1 {
        return i_matmul_bt(x, w_t, m, k, n, out);
    }
    std::thread::scope(|s| {
        let mut rem: &mut [i32] = out;
        for t in tiles {
            let rows = t.len();
            let (tile_out, rest) = std::mem::take(&mut rem).split_at_mut(rows * n);
            rem = rest;
            let x_tile = &x[t.start * k..t.end * k];
            s.spawn(move || i_matmul_bt(x_tile, w_t, rows, k, n, tile_out));
        }
    });
}

/// Auto-dispatching [`i_matmul`]: parallel at/above [`PAR_MIN_MACS`]
/// multiply-accumulates, serial below.
pub fn i_matmul_par(
    x: &[i32],
    w: &[i32],
    bias: Option<&[i32]>,
    m: usize,
    k: usize,
    n: usize,
    out: &mut [i32],
) {
    if m > 1 && m.saturating_mul(k).saturating_mul(n) >= PAR_MIN_MACS {
        i_matmul_tiled(default_parallelism(), x, w, bias, m, k, n, out)
    } else {
        i_matmul(x, w, bias, m, k, n, out)
    }
}

/// Auto-dispatching [`i_matmul_bt`]; see [`i_matmul_par`].
pub fn i_matmul_bt_par(x: &[i32], w_t: &[i32], m: usize, k: usize, n: usize, out: &mut [i32]) {
    if m > 1 && m.saturating_mul(k).saturating_mul(n) >= PAR_MIN_MACS {
        i_matmul_bt_tiled(default_parallelism(), x, w_t, m, k, n, out)
    } else {
        i_matmul_bt(x, w_t, m, k, n, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity() {
        let m = 3;
        let x: Vec<i32> = (0..9).map(|v| v - 4).collect();
        let mut eye = vec![0i32; 9];
        for i in 0..m {
            eye[i * m + i] = 1;
        }
        let mut out = vec![0i32; 9];
        i_matmul(&x, &eye, None, m, m, m, &mut out);
        assert_eq!(out, x);
    }

    #[test]
    fn bias_added_per_column() {
        let x = vec![1, 0, 0, 1]; // I2
        let w = vec![5, 6, 7, 8];
        let bias = vec![100, 200];
        let mut out = vec![0i32; 4];
        i_matmul(&x, &w, Some(&bias), 2, 2, 2, &mut out);
        assert_eq!(out, vec![105, 206, 107, 208]);
    }

    #[test]
    fn bt_matches_plain_with_transpose() {
        let (m, k, n) = (4, 5, 3);
        let x: Vec<i32> = (0..m * k).map(|v| (v as i32 * 7 % 13) - 6).collect();
        let w: Vec<i32> = (0..k * n).map(|v| (v as i32 * 11 % 17) - 8).collect();
        let mut wt = vec![0i32; n * k];
        for kk in 0..k {
            for j in 0..n {
                wt[j * k + kk] = w[kk * n + j];
            }
        }
        let mut a = vec![0i32; m * n];
        let mut b = vec![0i32; m * n];
        i_matmul(&x, &w, None, m, k, n, &mut a);
        i_matmul_bt(&x, &wt, m, k, n, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn tiled_bit_exact_on_randomized_shapes() {
        // The acceptance contract of the parallel path: parallel tiled
        // output == serial output, across random shapes, random INT8
        // operands, with and without bias, for every thread count
        // (including counts exceeding the row count).
        let mut rng = crate::util::rng::Rng::new(0x7117);
        for case in 0..60 {
            let m = 1 + rng.below(17) as usize;
            let k = 1 + rng.below(33) as usize;
            let n = 1 + rng.below(19) as usize;
            let threads = 1 + rng.below(6) as usize;
            let x: Vec<i32> = (0..m * k).map(|_| rng.range_i64(-128, 127) as i32).collect();
            let w: Vec<i32> = (0..k * n).map(|_| rng.range_i64(-128, 127) as i32).collect();
            let bias: Vec<i32> = (0..n).map(|_| rng.range_i64(-5000, 5000) as i32).collect();
            let b = if case % 2 == 0 { Some(&bias[..]) } else { None };

            let mut serial = vec![0i32; m * n];
            let mut tiled = vec![0i32; m * n];
            i_matmul(&x, &w, b, m, k, n, &mut serial);
            i_matmul_tiled(threads, &x, &w, b, m, k, n, &mut tiled);
            assert_eq!(serial, tiled, "m={m} k={k} n={n} threads={threads}");

            // transposed-B variant on the same operands
            let mut wt = vec![0i32; n * k];
            for kk in 0..k {
                for j in 0..n {
                    wt[j * k + kk] = w[kk * n + j];
                }
            }
            let mut serial_bt = vec![0i32; m * n];
            let mut tiled_bt = vec![0i32; m * n];
            i_matmul_bt(&x, &wt, m, k, n, &mut serial_bt);
            i_matmul_bt_tiled(threads, &x, &wt, m, k, n, &mut tiled_bt);
            assert_eq!(serial_bt, tiled_bt, "bt m={m} k={k} n={n} threads={threads}");
        }
    }

    #[test]
    fn par_auto_dispatch_bit_exact_above_threshold() {
        // 128 * 130 * 128 = 2_129_920 MACs >= PAR_MIN_MACS: the _par entry
        // point takes the tiled path and must still match the serial kernel.
        let (m, k, n) = (128, 130, 128);
        assert!(m * k * n >= PAR_MIN_MACS);
        let mut rng = crate::util::rng::Rng::new(11);
        let x: Vec<i32> = (0..m * k).map(|_| rng.range_i64(-128, 127) as i32).collect();
        let w: Vec<i32> = (0..k * n).map(|_| rng.range_i64(-128, 127) as i32).collect();
        let mut serial = vec![0i32; m * n];
        let mut par = vec![0i32; m * n];
        i_matmul(&x, &w, None, m, k, n, &mut serial);
        i_matmul_par(&x, &w, None, m, k, n, &mut par);
        assert_eq!(serial, par);
    }

    #[test]
    fn worst_case_int8_no_overflow_at_dff() {
        // k = 3072 (RoBERTa d_ff) at extreme INT8 operands stays in INT32
        let k = 3072;
        let x = vec![-128i32; k];
        let w = vec![-128i32; k];
        let mut out = vec![0i32; 1];
        i_matmul(&x, &w, None, 1, k, 1, &mut out);
        assert_eq!(out[0], (k as i32) * 128 * 128);
    }
}
