//! Request router: the front half of the parallel serving pipeline
//! (DESIGN.md §2).
//!
//! `submit` enqueues requests into the dynamic [`Batcher`]; a single
//! dispatcher thread waits for the size-or-deadline policy to release a
//! dispatch group and hands it to the [`ReplicaPool`], which fans the
//! group out across N engine replicas on the `util` thread pool.  The
//! dispatcher blocks until the group completes (the pool's join), then
//! takes the next group — so groups are pipelined back to back while
//! requests inside a group run concurrently.

use super::batcher::{BatchPolicy, Batcher};
use super::engine::EngineReplica;
use super::metrics::Metrics;
use super::pool::ReplicaPool;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub submitted: Instant,
    pub reply: Sender<Response>,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// which engine replica served this request
    pub replica: usize,
    pub label: usize,
    pub accel_ms: f64,
    pub e2e_s: f64,
    pub error: Option<String>,
}

struct Shared {
    batcher: Mutex<Batcher<Request>>,
    available: Condvar,
    shutdown: AtomicBool,
}

pub struct Router {
    shared: Arc<Shared>,
    pub metrics: Arc<Metrics>,
    dispatcher: Option<JoinHandle<()>>,
    next_id: AtomicU64,
}

impl Router {
    /// Start the serving pipeline over `replicas` engine replicas (the
    /// replica pool spins one worker thread per replica, plus one
    /// dispatcher thread).
    pub fn start(
        replicas: Vec<Arc<dyn EngineReplica>>,
        policy: BatchPolicy,
        metrics: Arc<Metrics>,
    ) -> Router {
        let shared = Arc::new(Shared {
            batcher: Mutex::new(Batcher::new(policy)),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let pool = ReplicaPool::new(replicas, Arc::clone(&metrics));
        let sh = Arc::clone(&shared);
        let dispatcher = std::thread::Builder::new()
            .name("swifttron-dispatch".into())
            .spawn(move || dispatch_loop(sh, pool))
            .expect("spawn dispatcher");
        Router { shared, metrics, dispatcher: Some(dispatcher), next_id: AtomicU64::new(0) }
    }

    /// Submit a request; the response arrives on `reply`.
    pub fn submit(&self, tokens: Vec<i32>, reply: Sender<Response>) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        self.metrics.record_request();
        {
            let mut b = self.shared.batcher.lock().unwrap();
            b.push(Request { id, tokens, submitted: Instant::now(), reply });
        }
        self.shared.available.notify_one();
        id
    }

    pub fn queue_len(&self) -> usize {
        self.shared.batcher.lock().unwrap().len()
    }

    /// Drain the queue and stop the pipeline (joins the dispatcher,
    /// which in turn joins the replica pool's threads on drop).
    pub fn shutdown(mut self) {
        // The flag must flip while holding the mutex the dispatcher's
        // condvar predicate is checked under, or a store between the
        // predicate check and wait_timeout loses the wakeup and the
        // drain stalls for up to max_wait.
        {
            let _b = self.shared.batcher.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::SeqCst);
        }
        self.shared.available.notify_all();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

fn dispatch_loop(sh: Arc<Shared>, pool: ReplicaPool) {
    loop {
        let group = {
            let mut b = sh.batcher.lock().unwrap();
            loop {
                let shutting = sh.shutdown.load(Ordering::SeqCst);
                if b.is_empty() && shutting {
                    return;
                }
                if b.ready(Instant::now()) || (shutting && !b.is_empty()) {
                    break b.take_batch();
                }
                let timeout = b
                    .next_deadline()
                    .map(|d| d.saturating_duration_since(Instant::now()))
                    .unwrap_or(std::time::Duration::from_millis(50));
                let (guard, _) = sh.available.wait_timeout(b, timeout).unwrap();
                b = guard;
            }
        };
        pool.dispatch(group);
    }
}
