//! Replica pool: per-model-group runtimes that fan dispatch groups out
//! across engine replicas and re-order results per request (DESIGN.md
//! §2, §8, §9).
//!
//! With the concurrent per-group dispatch pipeline each model group is
//! a [`GroupRuntime`]: it owns its replicas and a slot table the
//! autoscaler grows and shrinks at runtime, and it borrows executor
//! threads from the router-owned global core budget
//! (`util::budget::BudgetExec`; DESIGN.md §13) — one pool of
//! `--cores` workers shared by every group, with weighted-fair job
//! pickup, instead of the PR 5 private pools whose total came to
//! Σ `max_replicas`.  Group isolation still holds — a group barrier
//! only ever waits on its own model's jobs, and the executor's DRR
//! pick keeps a heavy `roberta_base` backlog from starving a `tiny`
//! share of worker time (the PR 4 pipeline's shared-pool `run_batch`
//! barrier would have serialized them).  [`ReplicaPool`] is the thin
//! routing facade over the group runtimes that serial drivers
//! (benches, tests) still use.
//!
//! Replica ids are global and *stable under scaling*: group `g`
//! reserves the contiguous id range `base..base + max_replicas`, one id
//! per slot, so the per-replica metrics ledger never renumbers when a
//! replica is retired and a later grow reuses its slot.
//!
//! Fan-out policy within a group: requests are assigned round-robin by
//! position over the *active* slots (request `i` goes to active slot
//! `(start + i) mod A`, with `start` rotating per dispatch so short
//! groups spread across replicas over time).  Each replica processes
//! its share serially — one sequence at a time, as the hardware loads
//! the MAC array per sentence — while the shares run concurrently on
//! the group's executor threads.  Replies go out on each request's
//! channel the moment its prediction completes; the group-level return
//! value is re-ordered back to submission (FIFO) order.
//!
//! Autoscaling (DESIGN.md §9): [`GroupRuntime::grow`] spawns one more
//! replica from the group's factory (sharing the model's `Arc` weight
//! bundle) into the lowest free slot; [`GroupRuntime::shrink`] is
//! drain-then-retire — the slot is removed from the active table
//! immediately, so no *new* dispatch selects it, while any in-flight
//! dispatch keeps its own `Arc` clone until its share drains, after
//! which the replica (and its Workspace arena) is dropped.
//!
//! Dispatch is a barrier per group: throughput scales with a model's
//! replicas once its dispatch-group size reaches the group's active
//! replica count (the operating regime is `max_batch >= replicas`;
//! DESIGN.md §2, EXPERIMENTS.md §Scaling).
//!
//! Fault recovery (DESIGN.md §10): a replica that panics mid-batch is
//! caught at the job boundary, its slot is retired when the group has a
//! factory to respawn from (taking the group below `min` until the
//! autoscaler's floor repair regrows it), and the request it carried is
//! retried exactly once on another active replica — a request is
//! answered with a result or a typed error, never lost.

use super::engine::{EngineReplica, RequestError};
use super::metrics::Metrics;
use super::registry::{ModelGroup, ReplicaFactory};
use super::router::{Request, Response};
use crate::sim::CostModel;
use crate::util::budget::BudgetExec;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Resolved cascade edge from a front (INT4) tier to its escalation
/// target (DESIGN.md §14): responses whose top-1 logit margin falls
/// below `margin` are not replied — the request is re-priced at the
/// target tier's cost model and handed back for re-dispatch there.
#[derive(Clone)]
pub struct EscalateLink {
    /// model index of the escalation target group
    target: usize,
    /// per-tenant confidence threshold on `top1 - top2` logits
    margin: i64,
    /// the target tier's cost model, for re-pricing the escalated
    /// request at its precision (`None` falls back to padded length)
    target_cost: Option<Arc<CostModel>>,
}

/// One model group's runtime: replicas, slot table, and a private
/// executor, so the group's dispatch barrier is isolated from every
/// other group (DESIGN.md §9).
pub struct GroupRuntime {
    model: String,
    /// global id of this group's slot 0 (the group reserves
    /// `base..base + max` ids)
    base: usize,
    min: usize,
    factory: Option<ReplicaFactory>,
    /// target latency class in milliseconds (autoscaler input)
    slo_ms: Option<f64>,
    /// the group's analytical cost model (shared with its replicas and
    /// the router's endpoint): the autoscaler prices this group's
    /// backlog through it (`None` for custom groups — legacy
    /// request-count signal)
    cost: Option<Arc<CostModel>>,
    /// fixed-width slot table (`len == max_replicas`); `Some` slots are
    /// active.  A Mutex, not RwLock: dispatches snapshot the active set
    /// in one short lock and scaling actions are rare.
    slots: Mutex<Vec<Option<Arc<dyn EngineReplica>>>>,
    /// rotating fan-out offset (advances once per dispatch)
    next_start: AtomicUsize,
    /// the router-owned global core budget this group borrows executor
    /// threads from (DESIGN.md §13)
    exec: Arc<BudgetExec>,
    metrics: Arc<Metrics>,
    /// model index in the router/batcher/metrics ledgers
    gidx: usize,
    /// cascade edge to this group's escalation tier, if it is the
    /// front (low-precision) tier of a cascade pair (DESIGN.md §14)
    escalate: Option<EscalateLink>,
}

impl GroupRuntime {
    fn new(
        g: ModelGroup,
        gidx: usize,
        base: usize,
        metrics: Arc<Metrics>,
        exec: Arc<BudgetExec>,
        escalate: Option<EscalateLink>,
    ) -> GroupRuntime {
        assert!(!g.replicas.is_empty(), "model {:?} has no replicas", g.model);
        assert!(
            g.max_replicas >= g.replicas.len() && g.min_replicas <= g.replicas.len(),
            "model {:?}: {} initial replicas outside {}..={}",
            g.model,
            g.replicas.len(),
            g.min_replicas,
            g.max_replicas,
        );
        let max = g.max_replicas;
        let mut slots: Vec<Option<Arc<dyn EngineReplica>>> = vec![None; max];
        for (slot, r) in g.replicas.into_iter().enumerate() {
            slots[slot] = Some(r);
        }
        metrics.set_model_replicas(gidx, slots.iter().flatten().count());
        if let Some(link) = &escalate {
            metrics.set_escalate_margin(gidx, link.margin);
        }
        GroupRuntime {
            model: g.model,
            base,
            min: g.min_replicas.max(1),
            factory: g.factory,
            slo_ms: g.slo_ms,
            cost: g.cost,
            slots: Mutex::new(slots),
            next_start: AtomicUsize::new(0),
            exec,
            metrics,
            gidx,
            escalate,
        }
    }

    pub fn model(&self) -> &str {
        &self.model
    }

    /// Model index in the router/batcher/metrics ledgers.
    pub fn model_index(&self) -> usize {
        self.gidx
    }

    /// Target latency class, if the group is SLO-managed.
    pub fn slo_ms(&self) -> Option<f64> {
        self.slo_ms
    }

    /// The group's analytical cost model, if it was registered with one
    /// (the autoscaler's predicted-work signal; DESIGN.md §12).
    pub fn cost_model(&self) -> Option<&CostModel> {
        self.cost.as_deref()
    }

    /// Replicas currently serving (active slots).
    pub fn active_replicas(&self) -> usize {
        self.slots.lock().unwrap().iter().flatten().count()
    }

    /// `min..=max` replica bounds.
    pub fn replica_bounds(&self) -> (usize, usize) {
        (self.min, self.slots.lock().unwrap().len())
    }

    /// Escalation target group index, if this is a cascade front tier.
    pub fn escalates_to(&self) -> Option<usize> {
        self.escalate.as_ref().map(|l| l.target)
    }

    /// Whether the autoscaler can move this group at all.
    pub fn scalable(&self) -> bool {
        let (min, max) = self.replica_bounds();
        max > min && self.factory.is_some() && self.slo_ms.is_some()
    }

    /// Spawn one more replica into the lowest free slot (up to `max`).
    /// Returns whether the group grew; `Err` only on factory failure.
    pub fn grow(&self) -> Result<bool, String> {
        let Some(factory) = &self.factory else { return Ok(false) };
        // Build outside the slot lock: a factory spawning a replica
        // (arena allocation) must not block an in-flight dispatch's
        // snapshot.
        let replica = factory()?;
        let mut slots = self.slots.lock().unwrap();
        let Some(free) = slots.iter().position(|s| s.is_none()) else {
            return Ok(false); // already at max
        };
        slots[free] = Some(replica);
        let active = slots.iter().flatten().count();
        drop(slots);
        self.metrics.set_model_replicas(self.gidx, active);
        self.metrics.record_scale(self.gidx, true);
        Ok(true)
    }

    /// Drain-then-retire one replica (down to `min`): the
    /// highest-numbered active slot leaves the table immediately — no
    /// new dispatch selects it — and the engine object is dropped once
    /// any in-flight share's `Arc` clone drains.  Returns whether the
    /// group shrank.
    pub fn shrink(&self) -> bool {
        let mut slots = self.slots.lock().unwrap();
        let active: Vec<usize> =
            (0..slots.len()).filter(|&i| slots[i].is_some()).collect();
        if active.len() <= self.min {
            return false;
        }
        slots[*active.last().unwrap()] = None;
        let remaining = active.len() - 1;
        drop(slots);
        self.metrics.set_model_replicas(self.gidx, remaining);
        self.metrics.record_scale(self.gidx, false);
        true
    }

    /// Execute one dispatch group: fan out across the active replicas,
    /// reply per request as it finishes, and return responses
    /// re-ordered to the group's submission order.  The barrier here is
    /// the group's own executor — other model groups dispatch
    /// concurrently.
    ///
    /// The second return value is the cascade overflow: requests whose
    /// low-precision answer fell below the escalation margin.  They
    /// have already been re-targeted (`model`/`cost` rewritten to the
    /// escalation tier, `origin` recording this group) and accounted as
    /// re-enqueued on the target's ledger; the caller must re-dispatch
    /// them there — through the batcher on the concurrent path, or
    /// synchronously via [`ReplicaPool::dispatch`].  Non-cascade groups
    /// always return an empty overflow.
    pub fn dispatch(&self, group: Vec<Request>) -> (Vec<Response>, Vec<Request>) {
        let total = group.len();
        if total == 0 {
            return (Vec::new(), Vec::new());
        }
        debug_assert!(
            group.iter().all(|r| r.model == self.gidx),
            "dispatch group mixes models — batcher invariant broken"
        );
        // Snapshot the active slots: scaling actions after this point
        // affect the next dispatch, not this one (drain-then-retire).
        let active: Vec<(usize, Arc<dyn EngineReplica>)> = self
            .slots
            .lock()
            .unwrap()
            .iter()
            .enumerate()
            .filter_map(|(slot, r)| r.as_ref().map(|r| (slot, Arc::clone(r))))
            .collect();
        let n = active.len();
        if n == 0 {
            // Fault recovery can retire every slot of a respawnable
            // group between dispatches (floor repair regrows it on the
            // next autoscaler tick).  Answer each request with a typed
            // error: panicking here would kill the group's dispatcher
            // thread and hang every later submit (ISSUE 9 — a dead
            // tenant must stay a per-tenant failure).
            let responses = group
                .into_iter()
                .map(|req| {
                    fail_request(
                        self.base,
                        &self.model,
                        &self.metrics,
                        req,
                        "no active replicas (all slots retired); floor repair pending",
                    )
                })
                .collect();
            return (responses, Vec::new());
        }
        let start = self.next_start.fetch_add(1, Ordering::Relaxed) % n;
        let mut shares: Vec<Vec<(usize, Request)>> = (0..n).map(|_| Vec::new()).collect();
        for (i, req) in group.into_iter().enumerate() {
            shares[(start + i) % n].push((i, req));
        }
        let jobs: Vec<_> = shares
            .into_iter()
            .enumerate()
            .filter(|(_, share)| !share.is_empty())
            .map(|(a, share)| {
                let (slot, replica) = (active[a].0, Arc::clone(&active[a].1));
                let metrics = Arc::clone(&self.metrics);
                let replica_id = self.base + slot;
                let model = self.model.clone();
                let escalate = self.escalate.clone();
                // the share's predicted cost drives the executor's
                // weighted-fair pickup across groups
                let cost = share
                    .iter()
                    .fold(0u64, |acc, (_, req)| acc.saturating_add(req.cost));
                let job = move || {
                    share
                        .into_iter()
                        .map(|(i, req)| {
                            let out = serve_one(
                                replica_id,
                                &model,
                                replica.as_ref(),
                                &metrics,
                                req,
                                PanicMode::Capture,
                                escalate.as_ref(),
                            );
                            (i, slot, out)
                        })
                        .collect::<Vec<_>>()
                };
                (cost, job)
            })
            .collect();
        let mut indexed: Vec<(usize, Response)> = Vec::with_capacity(total);
        let mut escalated: Vec<Request> = Vec::new();
        let mut panicked: Vec<(usize, usize, Request)> = Vec::new();
        for (i, slot, outcome) in self.exec.run_batch(self.gidx, jobs).into_iter().flatten() {
            match outcome {
                ServeOutcome::Replied(resp) => indexed.push((i, resp)),
                ServeOutcome::Escalated(req) => escalated.push(req),
                ServeOutcome::Panicked(req) => panicked.push((i, slot, req)),
            }
        }
        // Rare path, after the barrier: requests whose replica panicked
        // are recovered serially on the dispatcher thread.
        for (i, slot, req) in panicked {
            match self.recover(slot, req) {
                ServeOutcome::Replied(resp) => indexed.push((i, resp)),
                ServeOutcome::Escalated(req) => escalated.push(req),
                ServeOutcome::Panicked(_) => unreachable!("recover never re-captures"),
            }
        }
        indexed.sort_unstable_by_key(|&(i, _)| i);
        assert_eq!(
            indexed.len() + escalated.len(),
            total,
            "every request yields exactly one response or escalation"
        );
        // Escalations leave this dispatch bound for the target tier:
        // account them on its queue ledger now, whichever path (batcher
        // loop or serial facade) carries them there.
        for req in &escalated {
            self.metrics.record_reenqueued(req.model, req.cost);
        }
        (indexed.into_iter().map(|(_, resp)| resp).collect(), escalated)
    }

    /// Whether a faulted replica can be replaced (the autoscaler's
    /// floor repair needs a factory — full [`scalable`](Self::scalable)
    /// is not required).
    pub fn can_respawn(&self) -> bool {
        self.factory.is_some()
    }

    /// Retire a faulted replica's slot immediately.  Unlike
    /// [`shrink`](Self::shrink) this may take the group below `min` —
    /// the autoscaler's floor repair regrows it — and it is *not*
    /// counted as a scale-down: it is a fault, not a policy decision.
    fn retire_slot(&self, slot: usize) {
        let mut slots = self.slots.lock().unwrap();
        if slots[slot].is_none() {
            return; // a concurrent dispatch already retired it
        }
        slots[slot] = None;
        let active = slots.iter().flatten().count();
        drop(slots);
        self.metrics.set_model_replicas(self.gidx, active);
    }

    /// Recovery for a request whose replica panicked mid-batch: the
    /// faulted slot is retired (when the group can respawn a
    /// replacement), and the request is retried exactly once on another
    /// active replica.  With no other replica left it gets a typed
    /// error — either way it is answered (or escalated), never lost.
    /// Never returns [`ServeOutcome::Panicked`].
    fn recover(&self, slot: usize, req: Request) -> ServeOutcome {
        if self.can_respawn() {
            self.retire_slot(slot);
        }
        let retry = {
            let slots = self.slots.lock().unwrap();
            let active: Vec<(usize, Arc<dyn EngineReplica>)> = slots
                .iter()
                .enumerate()
                .filter(|&(s, _)| s != slot)
                .filter_map(|(s, r)| r.as_ref().map(|r| (s, Arc::clone(r))))
                .collect();
            if active.is_empty() {
                None
            } else {
                let pick = self.next_start.fetch_add(1, Ordering::Relaxed) % active.len();
                active.into_iter().nth(pick)
            }
        };
        match retry {
            Some((retry_slot, replica)) => {
                self.metrics.record_retry(self.gidx);
                serve_one(
                    self.base + retry_slot,
                    &self.model,
                    replica.as_ref(),
                    &self.metrics,
                    req,
                    PanicMode::TypedError,
                    self.escalate.as_ref(),
                )
            }
            None => ServeOutcome::Replied(fail_request(
                self.base + slot,
                &self.model,
                &self.metrics,
                req,
                "replica panicked while serving request; no active replica left to retry",
            )),
        }
    }
}

/// Routing facade over the per-model [`GroupRuntime`]s for serial
/// drivers (benches, tests) and the router's construction path.
pub struct ReplicaPool {
    groups: Vec<Arc<GroupRuntime>>,
    /// the global core budget every group borrows against
    exec: Arc<BudgetExec>,
}

impl ReplicaPool {
    /// Single-model pool under the default model id (the seed serving
    /// path): one executor thread per replica, so a replica is never
    /// oversubscribed and an idle replica never queues behind a busy
    /// one.
    pub fn new(replicas: Vec<Arc<dyn EngineReplica>>, metrics: Arc<Metrics>) -> ReplicaPool {
        ReplicaPool::new_multi(vec![ModelGroup::fixed("default", replicas, 1)], metrics)
    }

    /// Multi-model pool with the default core budget — Σ group widths
    /// (`max(max_replicas, replicas.len())` summed), i.e. enough
    /// workers that no group ever queues behind another, matching the
    /// PR 5 private-pool concurrency exactly.
    pub fn new_multi(groups: Vec<ModelGroup>, metrics: Arc<Metrics>) -> ReplicaPool {
        ReplicaPool::new_multi_with_budget(groups, metrics, None)
    }

    /// Multi-model pool over an explicit core budget: one
    /// [`GroupRuntime`] per model id, each with a reserved global
    /// replica-id span of `max_replicas` width, all sharing one
    /// [`BudgetExec`] of `cores` worker threads (`None` = Σ group
    /// widths).  With `cores` below Σ widths many tenants oversubscribe
    /// safely: total executor threads stay at the budget and the
    /// weighted-fair pickup splits them by the groups' fair-share
    /// weights (DESIGN.md §13).
    pub fn new_multi_with_budget(
        groups: Vec<ModelGroup>,
        metrics: Arc<Metrics>,
        cores: Option<usize>,
    ) -> ReplicaPool {
        assert!(!groups.is_empty(), "replica pool needs at least one model group");
        for (i, g) in groups.iter().enumerate() {
            assert!(!g.replicas.is_empty(), "model {:?} has no replicas", g.model);
            assert!(
                !groups[..i].iter().any(|o| o.model == g.model),
                "duplicate model id {:?}",
                g.model
            );
        }
        let total_ids: usize = groups.iter().map(|g| g.max_replicas.max(g.replicas.len())).sum();
        metrics.ensure_replicas(total_ids);
        let weights: Vec<u64> = groups.iter().map(|g| g.weight.max(1)).collect();
        let budget = cores.unwrap_or(total_ids).max(1);
        let exec = Arc::new(BudgetExec::new(budget, &weights));
        metrics.set_core_budget(budget);
        // Resolve cascade edges by name before the groups move into
        // their runtimes: a front tier's `escalate_to` must name
        // another registered group, and the link carries the target's
        // cost model for re-pricing escalated requests (DESIGN.md §14).
        let names: Vec<String> = groups.iter().map(|g| g.model.clone()).collect();
        let links: Vec<Option<EscalateLink>> = groups
            .iter()
            .enumerate()
            .map(|(i, g)| {
                g.escalate_to.as_ref().map(|target_name| {
                    let target = names.iter().position(|n| n == target_name).unwrap_or_else(|| {
                        panic!(
                            "model {:?}: escalation target {target_name:?} is not registered",
                            g.model
                        )
                    });
                    assert!(target != i, "model {:?} cannot escalate to itself", g.model);
                    EscalateLink {
                        target,
                        margin: g.escalate_margin,
                        target_cost: groups[target].cost.clone(),
                    }
                })
            })
            .collect();
        let mut base = 0;
        let groups = groups
            .into_iter()
            .zip(links)
            .enumerate()
            .map(|(gidx, (mut g, link))| {
                g.max_replicas = g.max_replicas.max(g.replicas.len());
                let width = g.max_replicas;
                let rt = Arc::new(GroupRuntime::new(
                    g,
                    gidx,
                    base,
                    Arc::clone(&metrics),
                    Arc::clone(&exec),
                    link,
                ));
                base += width;
                rt
            })
            .collect();
        ReplicaPool { groups, exec }
    }

    /// Worker threads in the shared core budget — the total executor
    /// thread count, whatever Σ `max_replicas` comes to.
    pub fn core_budget(&self) -> usize {
        self.exec.threads()
    }

    /// Active replicas across all groups.
    pub fn replicas(&self) -> usize {
        self.groups.iter().map(|g| g.active_replicas()).sum()
    }

    /// Number of model groups.
    pub fn group_count(&self) -> usize {
        self.groups.len()
    }

    /// Model id of group `i`.
    pub fn model_name(&self, i: usize) -> Option<&str> {
        self.groups.get(i).map(|g| g.model())
    }

    /// Runtime of group `i` (the per-group dispatchers and the
    /// autoscaler hold these).
    pub fn group(&self, i: usize) -> Option<&Arc<GroupRuntime>> {
        self.groups.get(i)
    }

    /// All group runtimes, in model-index order.
    pub fn groups(&self) -> &[Arc<GroupRuntime>] {
        &self.groups
    }

    /// Execute one dispatch group on its owning model's runtime
    /// (model-homogeneous by batcher construction; the owner is read
    /// off the first request).  Serial drivers call this directly; the
    /// router's per-group dispatchers call their own
    /// [`GroupRuntime::dispatch`] concurrently.
    ///
    /// Cascade escalations are followed synchronously: requests the
    /// front tier hands back re-dispatch on their target group until
    /// every request has been answered, so serial drivers see one
    /// response per submitted request regardless of precision tier.
    /// (The router's concurrent path re-queues escalations through the
    /// batcher instead.)
    pub fn dispatch(&self, group: Vec<Request>) -> Vec<Response> {
        let Some(first) = group.first() else { return Vec::new() };
        let gidx = first.model;
        assert!(gidx < self.groups.len(), "request for unknown model group {gidx}");
        let (mut responses, mut escalated) = self.groups[gidx].dispatch(group);
        while !escalated.is_empty() {
            // escalations from one group share its single target tier,
            // so the overflow stays model-homogeneous
            let gidx = escalated[0].model;
            assert!(gidx < self.groups.len(), "escalation to unknown model group {gidx}");
            let (more, next) = self.groups[gidx].dispatch(escalated);
            responses.extend(more);
            escalated = next;
        }
        responses
    }
}

/// How [`serve_one`] reacts to a panicking replica.
#[derive(Clone, Copy)]
enum PanicMode {
    /// Hand the un-replied request back to the dispatch barrier, which
    /// retires the faulted slot and retries once on another replica.
    Capture,
    /// Reply with a typed [`RequestError::Backend`] (the retry path is
    /// exhausted — a second fault must not retry forever).
    TypedError,
}

/// Result of [`serve_one`]: the request was answered (reply sent on its
/// channel), its low-margin answer was withheld and the request comes
/// back re-targeted at the escalation tier, or the replica panicked
/// under [`PanicMode::Capture`] and the request comes back untouched
/// for recovery.
enum ServeOutcome {
    Replied(Response),
    Escalated(Request),
    Panicked(Request),
}

/// Top-1 logit margin: the gap between the best and second-best logit.
/// A degenerate head (fewer than two logits) has no runner-up and never
/// escalates.
fn logit_margin(logits: &[i64]) -> i64 {
    if logits.len() < 2 {
        return i64::MAX;
    }
    let (mut top, mut second) = (i64::MIN, i64::MIN);
    for &l in logits {
        if l > top {
            second = top;
            top = l;
        } else if l > second {
            second = l;
        }
    }
    top.saturating_sub(second)
}

/// Serve one request on one replica: predict, account (aggregate,
/// per-replica, and per-model virtual time + latency), reply.
///
/// On a cascade front tier (`escalate` is `Some`), a successful
/// prediction whose top-1 logit margin falls below the link's threshold
/// is *not* replied: the attempt's cycles settle on this tier's ledger
/// ([`Metrics::record_escalated`] — the served-cost comparison must
/// charge the wasted INT4 pass), and the request is handed back
/// re-targeted at the escalation tier with its cost re-priced there.
fn serve_one(
    replica_id: usize,
    model_name: &str,
    engine: &dyn EngineReplica,
    metrics: &Metrics,
    req: Request,
    mode: PanicMode,
    escalate: Option<&EscalateLink>,
) -> ServeOutcome {
    let queued = req.submitted.elapsed().as_secs_f64();
    let t0 = Instant::now();
    // A panicking replica must cost one request (at most one retry),
    // never the dispatcher thread: run_batch treats a panicked job as
    // fatal, which would kill the group's dispatcher and hang every
    // later submit.
    let result = match catch_unwind(AssertUnwindSafe(|| engine.predict(&req.tokens))) {
        Ok(r) => r,
        Err(_) => {
            metrics.record_replica(replica_id, t0.elapsed().as_secs_f64(), 0, 0.0, true);
            metrics.record_fault(req.model);
            match mode {
                PanicMode::Capture => return ServeOutcome::Panicked(req),
                PanicMode::TypedError => {
                    return ServeOutcome::Replied(fail_request(
                        replica_id,
                        model_name,
                        metrics,
                        req,
                        "replica panicked while serving request",
                    ))
                }
            }
        }
    };
    let resp = match result {
        Ok(pred) => {
            let exec = t0.elapsed().as_secs_f64();
            if let Some(link) = escalate {
                if logit_margin(&pred.logits) < link.margin {
                    // Low-confidence answer: withhold the reply and
                    // hand the request to the sibling precision tier.
                    // The replica did real work — its ledger and the
                    // front tier's escalation ledger both settle here.
                    metrics.record_replica(
                        replica_id,
                        exec,
                        pred.accel_cycles,
                        pred.accel_ms,
                        false,
                    );
                    metrics.record_escalated(
                        req.model,
                        req.cost,
                        pred.accel_cycles,
                        pred.accel_ms,
                        exec,
                    );
                    let mut req = req;
                    req.origin = Some(req.model);
                    req.model = link.target;
                    req.cost = link
                        .target_cost
                        .as_ref()
                        .map(|c| c.predict_cycles(req.tokens.len()))
                        .unwrap_or(req.padded_len as u64);
                    return ServeOutcome::Escalated(req);
                }
            }
            let e2e = req.submitted.elapsed().as_secs_f64();
            metrics.record_completion(e2e, queued, exec, pred.accel_ms);
            metrics.record_replica(replica_id, exec, pred.accel_cycles, pred.accel_ms, false);
            metrics.record_model_served(
                req.model,
                req.tokens.len(),
                req.padded_len,
                req.cost,
                pred.accel_cycles,
                pred.accel_ms,
                e2e,
                exec,
                false,
            );
            if req.origin.is_some() {
                // full cascade latency: submit -> INT4 attempt ->
                // re-queue -> INT8 answer (the report's "cascade e2e")
                metrics.record_cascade_e2e(e2e);
            }
            Response {
                id: req.id,
                model: model_name.to_string(),
                replica: replica_id,
                label: pred.label,
                logits: pred.logits,
                accel_ms: pred.accel_ms,
                e2e_s: e2e,
                error: None,
            }
        }
        Err(e) => {
            let exec = t0.elapsed().as_secs_f64();
            metrics.record_error();
            metrics.record_replica(replica_id, exec, 0, 0.0, true);
            metrics.record_model_served(req.model, 0, 0, req.cost, 0, 0.0, 0.0, 0.0, true);
            Response {
                id: req.id,
                model: model_name.to_string(),
                replica: replica_id,
                label: usize::MAX,
                logits: Vec::new(),
                accel_ms: 0.0,
                e2e_s: req.submitted.elapsed().as_secs_f64(),
                error: Some(e.to_string()),
            }
        }
    };
    let _ = req.reply.send(resp.clone());
    ServeOutcome::Replied(resp)
}

/// Account and answer a request that could not be served at all (its
/// replica panicked and no retry path is left): typed error on the
/// reply channel, error bumped on the aggregate and per-model ledgers.
fn fail_request(
    replica_id: usize,
    model_name: &str,
    metrics: &Metrics,
    req: Request,
    msg: &str,
) -> Response {
    metrics.record_error();
    metrics.record_model_served(req.model, 0, 0, req.cost, 0, 0.0, 0.0, 0.0, true);
    let resp = Response {
        id: req.id,
        model: model_name.to_string(),
        replica: replica_id,
        label: usize::MAX,
        logits: Vec::new(),
        accel_ms: 0.0,
        e2e_s: req.submitted.elapsed().as_secs_f64(),
        error: Some(RequestError::Backend(msg.into()).to_string()),
    };
    let _ = req.reply.send(resp.clone());
    resp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::Prediction;
    use std::sync::mpsc::{channel, Receiver};
    use std::time::Duration;

    /// Deterministic-latency replica: predicts after a fixed sleep.
    struct SlowReplica {
        delay: Duration,
    }

    impl EngineReplica for SlowReplica {
        fn predict(&self, tokens: &[i32]) -> Result<Prediction, RequestError> {
            if tokens.is_empty() {
                return Err(RequestError::Backend("empty".into()));
            }
            std::thread::sleep(self.delay);
            Ok(Prediction {
                label: tokens[0] as usize % 2,
                logits: vec![0, 1],
                accel_cycles: 1000,
                accel_ms: 0.007,
            })
        }

        fn seq_len(&self) -> usize {
            4
        }
    }

    fn pool_of(n: usize, delay_ms: u64) -> (ReplicaPool, Arc<Metrics>) {
        let metrics = Arc::new(Metrics::new());
        let replicas: Vec<Arc<dyn EngineReplica>> = (0..n)
            .map(|_| {
                Arc::new(SlowReplica { delay: Duration::from_millis(delay_ms) })
                    as Arc<dyn EngineReplica>
            })
            .collect();
        (ReplicaPool::new(replicas, Arc::clone(&metrics)), metrics)
    }

    fn group_of(n: usize) -> (Vec<Request>, Vec<Receiver<Response>>) {
        group_for_model(0, n)
    }

    fn group_for_model(model: usize, n: usize) -> (Vec<Request>, Vec<Receiver<Response>>) {
        let mut group = Vec::with_capacity(n);
        let mut receivers = Vec::with_capacity(n);
        for id in 0..n as u64 {
            let (tx, rx) = channel();
            group.push(Request {
                id,
                model,
                tokens: vec![id as i32; 4],
                padded_len: 4,
                cost: 4,
                submitted: Instant::now(),
                origin: None,
                reply: tx,
            });
            receivers.push(rx);
        }
        (group, receivers)
    }

    #[test]
    fn dispatch_reorders_to_submission_order_and_replies() {
        let (pool, _metrics) = pool_of(3, 0);
        let (group, receivers) = group_of(10);
        let responses = pool.dispatch(group);
        let ids: Vec<u64> = responses.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..10).collect::<Vec<u64>>(), "submission order restored");
        for (i, rx) in receivers.into_iter().enumerate() {
            let resp = rx.recv().expect("reply sent");
            assert_eq!(resp.id, i as u64);
            assert!(resp.error.is_none());
        }
    }

    #[test]
    fn round_robin_spreads_across_replicas() {
        let (pool, metrics) = pool_of(2, 0);
        let (group, _receivers) = group_of(8);
        let responses = pool.dispatch(group);
        // first dispatch starts at offset 0: position i -> replica i mod 2
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.replica, i % 2);
        }
        assert_eq!(metrics.replica(0).requests.load(std::sync::atomic::Ordering::Relaxed), 4);
        assert_eq!(metrics.replica(1).requests.load(std::sync::atomic::Ordering::Relaxed), 4);
    }

    #[test]
    fn singleton_groups_rotate_across_replicas() {
        // the fan-out offset advances per dispatch, so back-to-back
        // one-request groups do not pin replica 0
        let (pool, _metrics) = pool_of(2, 0);
        let mut served = vec![];
        for _ in 0..4 {
            let (group, _receivers) = group_of(1);
            served.push(pool.dispatch(group)[0].replica);
        }
        assert_eq!(served, vec![0, 1, 0, 1]);
    }

    #[test]
    fn panicking_replica_costs_one_request_not_the_pool() {
        struct PanickyReplica;
        impl EngineReplica for PanickyReplica {
            fn predict(&self, tokens: &[i32]) -> Result<Prediction, RequestError> {
                if tokens[0] == 13 {
                    panic!("boom");
                }
                Ok(Prediction { label: 0, logits: vec![], accel_cycles: 1, accel_ms: 0.001 })
            }
            fn seq_len(&self) -> usize {
                4
            }
        }
        let metrics = Arc::new(Metrics::new());
        let replicas: Vec<Arc<dyn EngineReplica>> =
            vec![Arc::new(PanickyReplica) as Arc<dyn EngineReplica>];
        let pool = ReplicaPool::new(replicas, Arc::clone(&metrics));
        let (mut group, _receivers) = group_of(3);
        group[1].tokens = vec![13; 4]; // triggers the panic
        let responses = pool.dispatch(group);
        assert!(responses[0].error.is_none());
        assert!(responses[1].error.as_deref().unwrap_or("").contains("panicked"));
        assert!(responses[2].error.is_none());
        // the pool survives for the next dispatch
        let (group, _receivers) = group_of(2);
        assert!(pool.dispatch(group).iter().all(|r| r.error.is_none()));
    }

    #[test]
    fn two_replicas_run_a_group_concurrently() {
        // 8 requests x 20 ms: serial would take ~160 ms; two replicas
        // should land near 80 ms.  The generous bound still proves the
        // shares overlapped.
        let (pool, _metrics) = pool_of(2, 20);
        let (group, _receivers) = group_of(8);
        let t0 = Instant::now();
        let responses = pool.dispatch(group);
        let wall = t0.elapsed();
        assert_eq!(responses.len(), 8);
        assert!(
            wall < Duration::from_millis(140),
            "dispatch took {wall:?}, shares did not overlap"
        );
    }

    #[test]
    fn errors_are_per_request_not_per_group() {
        let (pool, metrics) = pool_of(2, 0);
        let (mut group, receivers) = group_of(4);
        group[2].tokens.clear(); // SlowReplica errors on empty tokens
        let responses = pool.dispatch(group);
        assert!(responses[2].error.is_some());
        for (i, resp) in responses.iter().enumerate() {
            assert_eq!(resp.error.is_some(), i == 2);
        }
        drop(receivers);
        assert_eq!(metrics.errors.load(std::sync::atomic::Ordering::Relaxed), 1);
        assert_eq!(metrics.completed.load(std::sync::atomic::Ordering::Relaxed), 3);
    }

    #[test]
    fn named_groups_route_by_model_with_global_replica_ids() {
        use std::sync::atomic::Ordering;
        // group "a": replicas 0..2, group "b": replica 2 — requests of
        // model 1 must land only on b's replica, with the model name on
        // the response and the served tokens on model 1's ledger
        let metrics = Arc::new(Metrics::new());
        let mk = |n: usize| -> Vec<Arc<dyn EngineReplica>> {
            (0..n)
                .map(|_| {
                    Arc::new(SlowReplica { delay: Duration::ZERO }) as Arc<dyn EngineReplica>
                })
                .collect()
        };
        let pool = ReplicaPool::new_multi(
            vec![ModelGroup::fixed("a", mk(2), 1), ModelGroup::fixed("b", mk(1), 1)],
            Arc::clone(&metrics),
        );
        assert_eq!(pool.replicas(), 3);
        assert_eq!(pool.group_count(), 2);
        assert_eq!(pool.model_name(1), Some("b"));

        let (group_b, _rx_b) = group_for_model(1, 3);
        for resp in pool.dispatch(group_b) {
            assert!(resp.error.is_none());
            assert_eq!(resp.model, "b");
            assert_eq!(resp.replica, 2, "model b owns the last global replica id");
        }
        let (group_a, _rx_a) = group_for_model(0, 4);
        for resp in pool.dispatch(group_a) {
            assert_eq!(resp.model, "a");
            assert!(resp.replica < 2);
        }
        assert_eq!(metrics.model(1).completed.load(Ordering::Relaxed), 3);
        assert_eq!(metrics.model(1).served_padded_tokens.load(Ordering::Relaxed), 12);
        assert_eq!(metrics.model(0).completed.load(Ordering::Relaxed), 4);
        assert_eq!(metrics.replica(2).requests.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn two_groups_dispatch_concurrently_not_serially() {
        // The tentpole isolation claim at the runtime layer: a slow
        // group's dispatch barrier must not gate a fast group's.  Two
        // single-replica groups, 4 x 20 ms vs 4 x 0 ms, dispatched from
        // two threads: serial execution would cost ~80 ms for BOTH, the
        // per-group executors finish the fast group almost immediately.
        let metrics = Arc::new(Metrics::new());
        let slow: Vec<Arc<dyn EngineReplica>> =
            vec![Arc::new(SlowReplica { delay: Duration::from_millis(20) })];
        let fast: Vec<Arc<dyn EngineReplica>> =
            vec![Arc::new(SlowReplica { delay: Duration::ZERO })];
        let pool = Arc::new(ReplicaPool::new_multi(
            vec![ModelGroup::fixed("slow", slow, 1), ModelGroup::fixed("fast", fast, 1)],
            metrics,
        ));
        let slow_rt = Arc::clone(pool.group(0).unwrap());
        let slow_thread = std::thread::spawn(move || {
            let (group, _rx) = group_for_model(0, 4);
            slow_rt.dispatch(group);
        });
        std::thread::sleep(Duration::from_millis(5)); // slow group is mid-flight
        let t0 = Instant::now();
        let (group, _rx) = group_for_model(1, 4);
        let (responses, _) = pool.group(1).unwrap().dispatch(group);
        let fast_wall = t0.elapsed();
        slow_thread.join().unwrap();
        assert_eq!(responses.len(), 4);
        assert!(
            fast_wall < Duration::from_millis(40),
            "fast group waited {fast_wall:?} behind the slow group's barrier"
        );
    }

    #[test]
    fn grow_and_shrink_move_between_bounds_with_stable_ids() {
        let metrics = Arc::new(Metrics::new());
        let factory: ReplicaFactory = Arc::new(|| {
            Ok(Arc::new(SlowReplica { delay: Duration::ZERO }) as Arc<dyn EngineReplica>)
        });
        let initial: Vec<Arc<dyn EngineReplica>> = vec![factory().unwrap()];
        let pool = ReplicaPool::new_multi(
            vec![
                ModelGroup {
                    model: "scaled".into(),
                    replicas: initial,
                    weight: 1,
                    min_replicas: 1,
                    max_replicas: 3,
                    slo_ms: Some(10.0),
                    factory: Some(factory),
                    cost: None,
                    escalate_to: None,
                    escalate_margin: 0,
                },
                ModelGroup::fixed(
                    "fixed",
                    vec![Arc::new(SlowReplica { delay: Duration::ZERO })],
                    1,
                ),
            ],
            Arc::clone(&metrics),
        );
        let g = pool.group(0).unwrap();
        assert!(g.scalable());
        assert_eq!(g.active_replicas(), 1);
        assert_eq!(g.replica_bounds(), (1, 3));
        assert!(g.grow().unwrap());
        assert!(g.grow().unwrap());
        assert!(!g.grow().unwrap(), "at max: grow is a no-op");
        assert_eq!(g.active_replicas(), 3);
        assert_eq!(metrics.model(0).replicas.load(std::sync::atomic::Ordering::Relaxed), 3);
        // the scaled group reserves ids 0..3; dispatches spread over
        // all three active slots
        let (group, _rx) = group_for_model(0, 6);
        let mut replicas_hit: Vec<usize> =
            g.dispatch(group).0.iter().map(|r| r.replica).collect();
        replicas_hit.sort_unstable();
        replicas_hit.dedup();
        assert_eq!(replicas_hit, vec![0, 1, 2]);
        // the fixed group's id sits beyond the reserved span
        let (group, _rx) = group_for_model(1, 1);
        assert_eq!(pool.dispatch(group)[0].replica, 3);
        // shrink back to min; dispatches keep working throughout
        assert!(g.shrink());
        assert!(g.shrink());
        assert!(!g.shrink(), "at min: shrink is a no-op");
        assert_eq!(g.active_replicas(), 1);
        let (group, _rx) = group_for_model(0, 4);
        let (responses, _) = g.dispatch(group);
        assert!(responses.iter().all(|r| r.error.is_none() && r.replica == 0));
        assert_eq!(metrics.model(0).scale_ups.load(std::sync::atomic::Ordering::Relaxed), 2);
        assert_eq!(
            metrics.model(0).scale_downs.load(std::sync::atomic::Ordering::Relaxed),
            2
        );
    }

    #[test]
    fn fixed_group_never_scales() {
        let (pool, _metrics) = pool_of(2, 0);
        let g = pool.group(0).unwrap();
        assert!(!g.scalable());
        assert!(!g.grow().unwrap(), "no factory: grow is a no-op");
        assert!(!g.shrink(), "min == len: shrink is a no-op");
        assert_eq!(g.active_replicas(), 2);
    }

    #[test]
    fn default_core_budget_is_the_sum_of_group_widths() {
        // the budget that reproduces the PR 5 private-pool concurrency:
        // one worker per reserved slot
        let (pool, _metrics) = pool_of(3, 0);
        assert_eq!(pool.core_budget(), 3);
    }

    #[test]
    fn core_budget_caps_executor_threads_below_sum_of_maxima() {
        let metrics = Arc::new(Metrics::new());
        let mk = |n: usize| -> Vec<Arc<dyn EngineReplica>> {
            (0..n)
                .map(|_| {
                    Arc::new(SlowReplica { delay: Duration::ZERO }) as Arc<dyn EngineReplica>
                })
                .collect()
        };
        let factory: ReplicaFactory = Arc::new(|| {
            Ok(Arc::new(SlowReplica { delay: Duration::ZERO }) as Arc<dyn EngineReplica>)
        });
        let pool = ReplicaPool::new_multi_with_budget(
            vec![
                ModelGroup {
                    model: "a".into(),
                    replicas: mk(1),
                    weight: 1,
                    min_replicas: 1,
                    max_replicas: 4,
                    slo_ms: Some(10.0),
                    factory: Some(factory),
                    cost: None,
                    escalate_to: None,
                    escalate_margin: 0,
                },
                ModelGroup::fixed("b", mk(2), 1),
            ],
            metrics,
            Some(2),
        );
        assert_eq!(pool.core_budget(), 2, "2 executor threads although Σ max_replicas = 6");
        // both groups still serve correctly through the shared budget
        let (group_a, _rx_a) = group_for_model(0, 4);
        assert!(pool.dispatch(group_a).iter().all(|r| r.error.is_none()));
        let (group_b, _rx_b) = group_for_model(1, 4);
        assert!(pool.dispatch(group_b).iter().all(|r| r.error.is_none()));
    }

    #[test]
    fn cascade_escalates_low_margin_requests_to_target_tier() {
        use std::sync::atomic::Ordering;
        // Margin oracle: logit gap == tokens[0], so the test chooses
        // exactly which requests fall below the front tier's threshold.
        struct MarginReplica {
            cycles: u64,
        }
        impl EngineReplica for MarginReplica {
            fn predict(&self, tokens: &[i32]) -> Result<Prediction, RequestError> {
                let gap = tokens[0] as i64;
                Ok(Prediction {
                    label: 0,
                    logits: vec![1000 + gap, 1000],
                    accel_cycles: self.cycles,
                    accel_ms: 0.001,
                })
            }
            fn seq_len(&self) -> usize {
                4
            }
        }
        let metrics = Arc::new(Metrics::new());
        let front: Vec<Arc<dyn EngineReplica>> = vec![Arc::new(MarginReplica { cycles: 100 })];
        let full: Vec<Arc<dyn EngineReplica>> = vec![Arc::new(MarginReplica { cycles: 400 })];
        let pool = ReplicaPool::new_multi(
            vec![
                ModelGroup {
                    model: "front".into(),
                    replicas: front,
                    weight: 1,
                    min_replicas: 1,
                    max_replicas: 1,
                    slo_ms: None,
                    factory: None,
                    cost: None,
                    escalate_to: Some("full".into()),
                    escalate_margin: 10,
                },
                ModelGroup::fixed("full", full, 1),
            ],
            Arc::clone(&metrics),
        );
        assert_eq!(pool.group(0).unwrap().escalates_to(), Some(1));
        assert_eq!(pool.group(1).unwrap().escalates_to(), None);
        assert_eq!(metrics.model(0).escalate_margin.load(Ordering::Relaxed), 10);

        // gaps 50, 3, 40, 7: requests 1 and 3 escalate
        let (mut group, receivers) = group_for_model(0, 4);
        for (req, gap) in group.iter_mut().zip([50, 3, 40, 7]) {
            req.tokens = vec![gap; 4];
        }
        let responses = pool.dispatch(group);
        assert_eq!(responses.len(), 4, "every request answered through the cascade");
        // Facade ordering: front-tier replies first (submission order),
        // then escalated replies — route each back by id.
        let mut by_id: Vec<&Response> = responses.iter().collect();
        by_id.sort_unstable_by_key(|r| r.id);
        for (id, resp) in by_id.iter().enumerate() {
            assert!(resp.error.is_none());
            let escalated = id == 1 || id == 3;
            assert_eq!(resp.model, if escalated { "full" } else { "front" });
            assert_eq!(resp.replica, if escalated { 1 } else { 0 });
        }
        // exactly one reply per request channel, matching the return
        for (id, rx) in receivers.into_iter().enumerate() {
            let resp = rx.recv().expect("reply sent");
            assert_eq!(resp.id, id as u64);
            assert!(rx.try_recv().is_err(), "no double reply for escalated request");
        }
        // front ledger: 4 attempts, 2 escalated, all 4 costs settled
        let front_stats = metrics.model(0);
        assert_eq!(front_stats.escalated.load(Ordering::Relaxed), 2);
        assert_eq!(front_stats.completed.load(Ordering::Relaxed), 2);
        assert_eq!(front_stats.served_cost.load(Ordering::Relaxed), 16);
        assert_eq!(front_stats.accel_cycles.load(Ordering::Relaxed), 400);
        // full tier saw exactly the two re-enqueued requests
        let full_stats = metrics.model(1);
        assert_eq!(full_stats.requests.load(Ordering::Relaxed), 2);
        assert_eq!(full_stats.completed.load(Ordering::Relaxed), 2);
        assert_eq!(full_stats.backlog.load(Ordering::Relaxed), 0, "re-enqueue settled");
        assert_eq!(full_stats.accel_cycles.load(Ordering::Relaxed), 800);
        assert_eq!(metrics.completed.load(Ordering::Relaxed), 4);
        assert_eq!(metrics.cascade_e2e_s.lock().unwrap().len(), 2);
        let report = metrics.report();
        assert!(report.contains("escalated=2"), "report surfaces escalations: {report}");
    }

    #[test]
    fn cascade_link_to_unknown_target_panics_at_construction() {
        let result = std::panic::catch_unwind(|| {
            let metrics = Arc::new(Metrics::new());
            let replicas: Vec<Arc<dyn EngineReplica>> =
                vec![Arc::new(SlowReplica { delay: Duration::ZERO })];
            ReplicaPool::new_multi(
                vec![ModelGroup {
                    model: "front".into(),
                    replicas,
                    weight: 1,
                    min_replicas: 1,
                    max_replicas: 1,
                    slo_ms: None,
                    factory: None,
                    cost: None,
                    escalate_to: Some("missing".into()),
                    escalate_margin: 10,
                }],
                metrics,
            )
        });
        assert!(result.is_err(), "dangling escalation target must fail fast");
    }

    #[test]
    fn dispatch_with_all_slots_retired_fails_typed_not_panics() {
        // A respawnable group whose only replica panics loses the slot
        // to fault retirement; until floor repair regrows it, a
        // dispatch must answer typed errors — not assert-kill the
        // dispatcher thread (ISSUE 9).
        struct AlwaysPanic;
        impl EngineReplica for AlwaysPanic {
            fn predict(&self, _tokens: &[i32]) -> Result<Prediction, RequestError> {
                panic!("hardware fault");
            }
            fn seq_len(&self) -> usize {
                4
            }
        }
        let metrics = Arc::new(Metrics::new());
        let factory: ReplicaFactory = Arc::new(|| Err("factory offline".into()));
        let pool = ReplicaPool::new_multi(
            vec![ModelGroup {
                model: "doomed".into(),
                replicas: vec![Arc::new(AlwaysPanic) as Arc<dyn EngineReplica>],
                weight: 1,
                min_replicas: 1,
                max_replicas: 2,
                slo_ms: Some(5.0),
                factory: Some(factory),
                cost: None,
                escalate_to: None,
                escalate_margin: 0,
            }],
            Arc::clone(&metrics),
        );
        let g = pool.group(0).unwrap();
        // first dispatch: the panic retires the slot, the request gets
        // the no-retry typed error
        let (group, _rx) = group_of(1);
        let (first, _) = g.dispatch(group);
        assert!(first[0].error.as_deref().unwrap_or("").contains("panicked"));
        assert_eq!(g.active_replicas(), 0);
        // second dispatch: zero active replicas — typed errors, every
        // request answered, dispatcher alive
        let (group, receivers) = group_of(2);
        let (responses, _) = g.dispatch(group);
        assert_eq!(responses.len(), 2);
        for resp in &responses {
            assert!(resp.error.as_deref().unwrap_or("").contains("no active replicas"));
        }
        for rx in receivers {
            assert!(rx.recv().expect("typed reply sent").error.is_some());
        }
    }
}
