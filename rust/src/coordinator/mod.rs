//! Layer-3 coordinator: the deployable serving system around the
//! accelerator model (DESIGN.md §2, §8).
//!
//! Request flow: `server` (TCP, optional `model:` prefix) ->
//! `router::submit_to` -> `batcher` (size-or-deadline dispatch groups
//! keyed by `(model, padded length)`, weighted-fair across models) ->
//! dispatcher thread -> `pool::ReplicaPool` (named per-model replica
//! groups; fan-out over the owning group's replicas on the `util`
//! thread pool, results re-ordered per request) -> reply channels.
//!
//! * [`engine`] — the [`EngineReplica`] trait and its implementations:
//!   the PJRT-backed [`InferenceEngine`] (single-model) and the
//!   artifact-free [`FunctionalEngine`] over a shared
//!   [`SyntheticModel`] weight bundle.
//! * [`registry`] — the multi-tenant model registry: model ids ->
//!   geometry presets + replica groups + fair-share weights.
//! * [`batcher`] — dynamic batcher (size/deadline policy, model- and
//!   length-bucketed, deficit-round-robin model selection).
//! * [`pool`] — the replica pool: per-model group fan-out + per-request
//!   re-ordering on the in-repo thread pool.
//! * [`router`] — request intake, the dispatcher thread, shutdown.
//! * [`server`] — a line-protocol TCP front-end.
//! * [`metrics`] — wall-clock latency/throughput plus per-replica and
//!   per-model virtual-time (simulated accelerator cycle) accounting,
//!   token shares, and per-model padding waste.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod pool;
pub mod registry;
pub mod router;
pub mod server;

pub use batcher::{Batcher, BatchPolicy};
pub use engine::{
    EngineReplica, FunctionalEngine, InferenceEngine, Prediction, RequestError, SyntheticModel,
};
pub use metrics::{Metrics, ModelStats, ReplicaStats};
pub use pool::ReplicaPool;
pub use registry::{ModelGroup, ModelRegistry};
pub use router::{Request, Response, Router};
