"""L2 tests: quantized encoder vs float reference, pallas/intops
equivalence, calibration pipeline, blob round-trips, tiny-task data."""

import numpy as np
import pytest

from compile import model as M
from compile import pipeline as P
from compile import train_tiny as T
from compile.blobs import BlobWriter, read_blob
from compile.quantize import int8_scale, quantize_tensor

GEO = M.GEOMETRIES["tiny"]


@pytest.fixture(scope="module")
def quant_setup():
    rng = np.random.default_rng(7)
    weights = M.init_encoder_weights(3, GEO)
    calib = rng.normal(0, 1.0, (8, GEO.m, GEO.d))
    qm = P.calibrate_and_design(weights, GEO, calib)
    x = rng.normal(0, 1.0, (GEO.m, GEO.d))
    return weights, qm, x


def test_quant_model_tracks_float(quant_setup):
    weights, qm, x = quant_setup
    err = P.quantization_error(qm, weights, GEO, x, use_pallas=False)
    assert err["cos"] > 0.99, err
    assert err["rel"] < 0.15, err


def test_pallas_and_intops_bit_identical(quant_setup):
    _, qm, x = quant_setup
    a = P.run_quant(qm, x, use_pallas=False)
    b = P.run_quant(qm, x, use_pallas=True)
    assert np.array_equal(a, b)


def test_output_is_int8_coded(quant_setup):
    _, qm, x = quant_setup
    q = P.run_quant(qm, x, use_pallas=False)
    assert q.min() >= -128 and q.max() <= 127


def test_unified_calibration_shares_constants():
    rng = np.random.default_rng(11)
    weights = M.init_encoder_weights(5, GEO)
    calib = rng.normal(0, 1.0, (4, GEO.m, GEO.d))
    qm = P.calibrate_and_design(weights, GEO, calib, unify=True)
    l0, l1 = qm.layers[0], qm.layers[1]
    assert l0.dy_q == l1.dy_q
    assert l0.sm == l1.sm
    assert l0.gelu == l1.gelu


def test_scale_block_is_pure_shift_for_dh64():
    """dh = 64 -> 1/sqrt(dh) = 1/8: the paper's claim that the Scale
    block degenerates to a shift must hold in the design output."""
    geo = M.GEOMETRIES["roberta_base"]
    rng = np.random.default_rng(1)
    weights = [M.init_layer_weights(rng, geo)]
    geo1 = M.Geometry(d=geo.d, heads=geo.heads, m=8, d_ff=geo.d_ff, layers=1)
    calib = rng.normal(0, 1.0, (1, geo1.m, geo1.d))
    qm = P.calibrate_and_design(weights, geo1, calib)
    dy = qm.layers[0].dy_scale
    assert dy.b == 1 and dy.c == 3  # >> 3 == / 8 == / sqrt(64)


def test_blob_roundtrip(tmp_path):
    w = BlobWriter()
    a = np.arange(12, dtype=np.int32).reshape(3, 4)
    b = np.linspace(0, 1, 5, dtype=np.float32)
    c = (np.arange(6) - 3).astype(np.int32)
    w.add("a", a, "i32")
    w.add("b", b, "f32")
    w.add("c", c, "i8")
    w.write(str(tmp_path / "t"))
    out = read_blob(str(tmp_path / "t"))
    assert np.array_equal(out["a"], a)
    assert np.allclose(out["b"], b)
    assert np.array_equal(out["c"], c)


def test_blob_rejects_duplicates():
    w = BlobWriter()
    w.add("x", np.zeros(1, dtype=np.int32))
    with pytest.raises(KeyError):
        w.add("x", np.zeros(1, dtype=np.int32))


def test_tiny_task_dataset_properties():
    toks, labels = T.make_dataset(np.random.default_rng(0), 64, GEO.m)
    assert toks.shape == (64, GEO.m)
    assert set(np.unique(labels)) <= {0, 1}
    # every sequence contains the KEY token
    assert all((row == T.KEY_TOKEN).any() for row in toks)
    # class-conditional token distributions differ (the learnable signal)
    m0 = toks[labels == 0].mean()
    m1 = toks[labels == 1].mean()
    assert abs(m0 - m1) > 2.0


def test_quantize_tensor_saturates_and_rounds():
    q = quantize_tensor(np.array([0.0, 1.0, -1.0, 100.0]), 0.01)
    assert list(q) == [0, 100, -100, 127]
    assert int8_scale(12.7) == pytest.approx(0.1)


def test_geometry_presets_match_rust():
    # the same table lives in rust/src/model/geometry.rs
    g = M.GEOMETRIES["roberta_base"]
    assert (g.d, g.heads, g.m, g.d_ff, g.layers) == (768, 12, 256, 3072, 12)
    g = M.GEOMETRIES["deit_s"]
    assert (g.d, g.heads, g.m, g.d_ff, g.layers) == (384, 6, 197, 1536, 12)
