//! PJRT runtime: load the AOT-compiled HLO-text artifacts and execute
//! them on the request path.  Python never runs here — the artifacts were
//! lowered once at build time (`make artifacts`, see `python/compile/`).
//!
//! Wrapping the `xla` crate (PJRT C API): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Interchange is HLO *text*: jax ≥ 0.5 serializes protos with 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! The `xla` crate is vendored, not on crates.io, so this module is
//! compiled against it only under the `pjrt` cargo feature (see
//! Cargo.toml).  While the vendored checkout is absent the feature
//! resolves against the API-compatible in-repo `xla_stub` module (kept
//! honest by ci.sh's check-only `--features pjrt` build), whose client
//! constructor fails at runtime.  Either way, without a real PJRT
//! client [`Engine::cpu`] returns an error and callers use the
//! artifact-free functional serving path
//! (`coordinator::FunctionalEngine`) instead; the default build has no
//! external dependencies at all.

pub mod executable;
pub mod tensor;
#[cfg(feature = "pjrt")]
pub mod xla_stub;

pub use executable::{Engine, Executable};
pub use tensor::Tensor;
