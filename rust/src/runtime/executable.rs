//! PJRT client + compiled-executable cache, behind a dedicated runtime
//! thread.
//!
//! The `xla` crate's handles are not `Send` (internal `Rc` + raw
//! pointers), so one OS thread *owns* the PJRT client and every compiled
//! executable; the rest of the coordinator talks to it over a command
//! channel.  This also matches the hardware story: one host thread feeds
//! one accelerator.  Multiple [`Engine`]s can be created for replica
//! parallelism (each owns an independent PJRT client).

// Without `pjrt` the command-loop side of the channel is compiled out,
// so the command payload fields are constructed but never read.
#![cfg_attr(not(feature = "pjrt"), allow(dead_code))]

use super::tensor::Tensor;
// The vendored `xla` crate is resolved through the in-repo stub so the
// feature keeps compiling without the checkout (see runtime::xla_stub);
// swap this alias for the real crate to link PJRT.
#[cfg(feature = "pjrt")]
use crate::runtime::xla_stub as xla;
#[cfg(feature = "pjrt")]
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};

enum Cmd {
    Load { path: PathBuf, reply: Sender<Result<usize, String>> },
    Run { exe: usize, inputs: Vec<Tensor>, out: OutKind, shape: Vec<usize>, reply: Sender<Result<Tensor, String>> },
    Platform { reply: Sender<String> },
    Shutdown,
}

#[derive(Clone, Copy)]
enum OutKind {
    I32,
    F32,
}

/// Handle to the runtime thread (cheaply cloneable, `Send + Sync`).
#[derive(Clone)]
pub struct Engine {
    tx: Arc<Mutex<Sender<Cmd>>>,
}

/// Handle to one compiled artifact on a specific engine.
#[derive(Clone)]
pub struct Executable {
    engine: Engine,
    id: usize,
    pub path: PathBuf,
}

impl Engine {
    /// Without the `pjrt` feature there is no PJRT client to spawn; the
    /// constructor fails and callers fall back to the functional path
    /// (`coordinator::FunctionalEngine`) or skip, exactly as they do when
    /// the AOT artifacts are absent.
    #[cfg(not(feature = "pjrt"))]
    pub fn cpu() -> Result<Engine, String> {
        Err("PJRT runtime unavailable: built without the `pjrt` feature \
             (see Cargo.toml for how to enable it against a vendored `xla` crate)"
            .into())
    }

    /// Spawn the runtime thread and create its PJRT CPU client.
    #[cfg(feature = "pjrt")]
    pub fn cpu() -> Result<Engine, String> {
        let (tx, rx) = channel::<Cmd>();
        let (ready_tx, ready_rx) = channel();
        std::thread::Builder::new()
            .name("swifttron-pjrt".into())
            .spawn(move || runtime_thread(rx, ready_tx))
            .map_err(|e| format!("spawn runtime thread: {e}"))?;
        ready_rx
            .recv()
            .map_err(|_| "runtime thread died during init".to_string())??;
        Ok(Engine { tx: Arc::new(Mutex::new(tx)) })
    }

    fn send(&self, cmd: Cmd) -> Result<(), String> {
        self.tx
            .lock()
            .unwrap()
            .send(cmd)
            .map_err(|_| "runtime thread gone".to_string())
    }

    pub fn platform(&self) -> Result<String, String> {
        let (tx, rx) = channel();
        self.send(Cmd::Platform { reply: tx })?;
        rx.recv().map_err(|_| "runtime thread gone".to_string())
    }

    /// Load + compile an HLO-text artifact (cached by path on the thread).
    pub fn load(&self, path: &Path) -> Result<Executable, String> {
        let (tx, rx) = channel();
        self.send(Cmd::Load { path: path.to_path_buf(), reply: tx })?;
        let id = rx.recv().map_err(|_| "runtime thread gone".to_string())??;
        Ok(Executable { engine: self.clone(), id, path: path.to_path_buf() })
    }

    pub fn shutdown(&self) {
        let _ = self.send(Cmd::Shutdown);
    }
}

impl Executable {
    fn run(&self, inputs: &[Tensor], out: OutKind, shape: &[usize]) -> Result<Tensor, String> {
        let (tx, rx) = channel();
        self.engine.send(Cmd::Run {
            exe: self.id,
            inputs: inputs.to_vec(),
            out,
            shape: shape.to_vec(),
            reply: tx,
        })?;
        rx.recv().map_err(|_| "runtime thread gone".to_string())?
    }

    /// Execute; read the single tuple output as i32 with `shape`.
    pub fn run_i32(&self, inputs: &[Tensor], shape: &[usize]) -> Result<Tensor, String> {
        self.run(inputs, OutKind::I32, shape)
    }

    /// Execute; read the single tuple output as f32 with `shape`.
    pub fn run_f32(&self, inputs: &[Tensor], shape: &[usize]) -> Result<Tensor, String> {
        self.run(inputs, OutKind::F32, shape)
    }
}

#[cfg(feature = "pjrt")]
fn runtime_thread(rx: std::sync::mpsc::Receiver<Cmd>, ready: Sender<Result<(), String>>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready.send(Err(format!("PjRtClient::cpu: {e}")));
            return;
        }
    };
    let mut exes: Vec<xla::PjRtLoadedExecutable> = Vec::new();
    let mut by_path: BTreeMap<PathBuf, usize> = BTreeMap::new();

    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Platform { reply } => {
                let _ = reply.send(client.platform_name());
            }
            Cmd::Load { path, reply } => {
                if let Some(&id) = by_path.get(&path) {
                    let _ = reply.send(Ok(id));
                    continue;
                }
                let result = (|| -> Result<usize, String> {
                    let proto = xla::HloModuleProto::from_text_file(
                        path.to_str().ok_or("non-utf8 path")?,
                    )
                    .map_err(|e| format!("parse {}: {e}", path.display()))?;
                    let comp = xla::XlaComputation::from_proto(&proto);
                    let exe = client
                        .compile(&comp)
                        .map_err(|e| format!("compile {}: {e}", path.display()))?;
                    exes.push(exe);
                    let id = exes.len() - 1;
                    by_path.insert(path.clone(), id);
                    Ok(id)
                })();
                let _ = reply.send(result);
            }
            Cmd::Run { exe, inputs, out, shape, reply } => {
                let result = (|| -> Result<Tensor, String> {
                    let e = exes.get(exe).ok_or("bad executable id")?;
                    let literals: Vec<xla::Literal> =
                        inputs.iter().map(|t| t.to_literal()).collect::<Result<_, _>>()?;
                    let result = e
                        .execute::<xla::Literal>(&literals)
                        .map_err(|er| format!("execute: {er}"))?;
                    let first = result
                        .into_iter()
                        .next()
                        .and_then(|d| d.into_iter().next())
                        .ok_or("no output buffer")?;
                    let lit =
                        first.to_literal_sync().map_err(|er| format!("to_literal: {er}"))?;
                    let outs = lit.to_tuple().map_err(|er| format!("to_tuple: {er}"))?;
                    let first = outs.first().ok_or("empty tuple")?;
                    match out {
                        OutKind::I32 => Tensor::from_literal_i32(first, &shape),
                        OutKind::F32 => Tensor::from_literal_f32(first, &shape),
                    }
                })();
                let _ = reply.send(result);
            }
            Cmd::Shutdown => break,
        }
    }
}
