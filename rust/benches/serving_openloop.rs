//! Open-loop serving bench (EXPERIMENTS.md §Workload, DESIGN.md §10):
//! latency under *offered* load — seeded arrival traces replayed against
//! the serving pipeline at their recorded timestamps, whether or not
//! earlier requests have completed — plus the three chaos legs: a
//! replica that panics mid-batch, a 10x straggler replica, and a tenant
//! whose rate suddenly 50x's.
//!
//! Run: `cargo bench --bench serving_openloop` — or with `-- --smoke`
//! for the CI-sized subset.  All legs are seeded and deterministic in
//! the *arrival streams*; latencies carry host scheduling noise, which
//! the smoke bounds absorb (see below).
//!
//! The socket-ingest leg (EXPERIMENTS.md §Wire, DESIGN.md §11) floods
//! the two front doors over real loopback connections — the legacy
//! thread-per-connection text server vs the `SWWIRE1` non-blocking
//! binary multiplexer — and reports req/s, p99, and (via this binary's
//! counting `#[global_allocator]`) heap allocations per request on
//! each protocol's decode path.
//!
//! Results merge under the `openloop` and `wire` keys of
//! `BENCH_serving.json` (sibling legs from serving_scaling are
//! preserved).  `--smoke` additionally checks the run against the
//! committed `BENCH_smoke.json` snapshot and exits non-zero on schema
//! drift or a leg regressing past its bound (latency keys: 2x
//! committed + 5 ms; recovery: committed + 0.25 s; throughput keys:
//! half of committed).  After an intentional perf change, rebaseline
//! with `cargo bench --bench serving_openloop -- --smoke --update`.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};
use swifttron::coordinator::server::{parse_tokens, TextServer};
use swifttron::coordinator::{
    AutoscalePolicy, BatchPolicy, EngineReplica, Metrics, ModelRegistry, ReplicaFactory, Router,
};
use swifttron::util::bench::{merge_bench_json, Table};
use swifttron::util::json::{obj, Json};
use swifttron::wire::{encode, DecodeEvent, FrameDecoder, MuxConfig, MuxServer, RingBuf, WireClient};
use swifttron::workload::{replay, ArrivalProcess, ChaosReplica, DelayReplica, RateSpike, Trace};

// Counting allocator (same idiom as rust/tests/workspace_alloc.rs —
// one global allocator per binary, so the bench carries its own copy):
// per-thread event counts make the single-threaded decode microbench
// immune to allocation traffic on the flood worker threads.

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

fn thread_allocs() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

struct CountingAlloc;

impl CountingAlloc {
    fn bump() {
        // try_with: never panic inside the allocator (TLS teardown)
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        CountingAlloc::bump();
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        CountingAlloc::bump();
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        CountingAlloc::bump();
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Mock service time per request; one replica serves 1000/SERVICE_MS
/// requests per second.
const SERVICE_MS: u64 = 2;
/// Per-replica service rate µ (req/s) implied by [`SERVICE_MS`].
const MU: f64 = 1000.0 / SERVICE_MS as f64;
/// Post-submission drain budget; a leg that cannot drain within this is
/// a lost-reply bug, not a slow run.
const DRAIN: Duration = Duration::from_secs(30);

fn policy() -> BatchPolicy {
    BatchPolicy { max_batch: 4, max_wait: Duration::from_micros(500), bucket_width: 0 }
}

fn fast_autoscale() -> AutoscalePolicy {
    AutoscalePolicy {
        interval: Duration::from_millis(2),
        grow_ratio: 1.0,
        shrink_ratio: 0.25,
        hold_ticks: 1,
        default_service_ms: 1.0,
    }
}

/// Router with `tenants` single-replica fixed groups named `tenant{i}`.
fn fixed_router(tenants: usize, metrics: &Arc<Metrics>) -> Router {
    let mut reg = ModelRegistry::new();
    for i in 0..tenants {
        let name = format!("tenant{i}");
        reg.register_group(
            &name,
            vec![Arc::new(DelayReplica::from_ms(SERVICE_MS)) as Arc<dyn EngineReplica>],
            1,
        )
        .unwrap();
    }
    Router::start_multi(reg.into_groups(), policy(), Arc::clone(metrics))
}

/// Latency-under-offered-load curve: two tenants, each offered
/// `rho x µ` req/s of Poisson traffic against its own single replica.
fn offered_load_leg(rhos: &[f64], horizon_s: f64) -> Json {
    let mut table =
        Table::new(&["rho", "offered/tenant", "sent", "t0 p50", "t0 p99", "t1 p50", "t1 p99"]);
    let mut rows = Vec::new();
    for (pi, &rho) in rhos.iter().enumerate() {
        let offered = rho * MU;
        let metrics = Arc::new(Metrics::new());
        let router = fixed_router(2, &metrics);
        let traces: Vec<Trace> = (0..2usize)
            .map(|m| {
                Trace::from_process(
                    &ArrivalProcess::Poisson { rate: offered },
                    1000 + (pi * 2 + m) as u64,
                    horizon_s,
                    m,
                    (1, 16),
                )
            })
            .collect();
        let summary = replay(&router, &Trace::merge(&traces), 1.0, DRAIN);
        assert_eq!(summary.lost, 0, "open-loop run lost replies at rho {rho}");
        assert_eq!(summary.errors, 0, "open-loop run errored at rho {rho}");
        let percentiles: Vec<(f64, f64)> =
            (0..2).map(|m| metrics.model(m).e2e_percentiles_ms()).collect();
        let tenants: Vec<Json> = (0..2usize)
            .map(|m| {
                let (p50, p99) = percentiles[m];
                obj([
                    ("model", format!("tenant{m}").into()),
                    (
                        "completed",
                        (metrics.model(m).completed.load(Ordering::SeqCst) as i64).into(),
                    ),
                    ("p50_ms", p50.into()),
                    ("p99_ms", p99.into()),
                ])
            })
            .collect();
        router.shutdown();
        table.row(&[
            format!("{rho:.1}"),
            format!("{offered:.0}/s"),
            summary.sent.to_string(),
            format!("{:.2}ms", percentiles[0].0),
            format!("{:.2}ms", percentiles[0].1),
            format!("{:.2}ms", percentiles[1].0),
            format!("{:.2}ms", percentiles[1].1),
        ]);
        rows.push(obj([
            ("rho", rho.into()),
            ("offered_rps", offered.into()),
            ("sent", summary.sent.into()),
            ("lost", summary.lost.into()),
            ("wall_s", summary.wall_s.into()),
            ("tenants", Json::Arr(tenants)),
        ]));
    }
    table.print("offered-load curve: 2 tenants, Poisson arrivals, 1 replica each");
    println!(
        "\nopen-loop: arrivals are paced by the recorded trace, never by\n\
         completions, so queueing under offered load is visible — p99 grows\n\
         with rho where a closed-loop driver would flatline at capacity."
    );
    Json::Arr(rows)
}

/// Same mean rate, bursty vs smooth: MMPP-2 arrivals against Poisson.
fn burst_leg(horizon_s: f64) -> Json {
    let mean = 100.0;
    let run = |process: &ArrivalProcess, seed: u64| {
        let metrics = Arc::new(Metrics::new());
        let router = fixed_router(1, &metrics);
        let summary =
            replay(&router, &Trace::from_process(process, seed, horizon_s, 0, (1, 16)), 1.0, DRAIN);
        assert_eq!(summary.lost, 0, "burst leg lost replies");
        assert_eq!(summary.errors, 0);
        let (_, p99) = metrics.model(0).e2e_percentiles_ms();
        router.shutdown();
        (p99, summary.sent)
    };
    let (poisson_p99, poisson_sent) = run(&ArrivalProcess::Poisson { rate: mean }, 7);
    let mmpp = ArrivalProcess::Mmpp2 { rates: [180.0, 20.0], mean_dwell_s: [0.1, 0.1] };
    assert!((mmpp.mean_rate() - mean).abs() < 1e-9, "legs must offer the same mean rate");
    let (mmpp_p99, mmpp_sent) = run(&mmpp, 8);
    println!(
        "\nburst leg: p99 {poisson_p99:.2}ms Poisson vs {mmpp_p99:.2}ms MMPP-2 at the same\n\
         mean rate ({mean:.0} req/s) — burstiness, not volume, drives the tail."
    );
    obj([
        ("mean_rate_rps", mean.into()),
        ("poisson_sent", poisson_sent.into()),
        ("poisson_p99_ms", poisson_p99.into()),
        ("mmpp_sent", mmpp_sent.into()),
        ("mmpp_p99_ms", mmpp_p99.into()),
    ])
}

/// Sample `(elapsed_s, active_replicas, backlog)` for model 0 every
/// millisecond until `stop` flips.
fn monitor(router: &Router, metrics: &Metrics, stop: &AtomicBool) -> Vec<(f64, usize, u64)> {
    let t0 = Instant::now();
    let mut samples = Vec::new();
    while !stop.load(Ordering::SeqCst) {
        samples.push((
            t0.elapsed().as_secs_f64(),
            router.active_replicas("tenant0").unwrap_or(0),
            metrics.model(0).backlog.load(Ordering::SeqCst),
        ));
        std::thread::sleep(Duration::from_millis(1));
    }
    samples
}

/// Replica panic mid-run: the faulted slot is retired, the request is
/// retried on the peer, and the autoscaler's floor repair respawns the
/// group back to its floor — with zero request loss.
fn chaos_panic_leg(horizon_s: f64) -> Json {
    let floor = 2usize;
    let built = Arc::new(AtomicUsize::new(0));
    let factory: ReplicaFactory = {
        let built = Arc::clone(&built);
        Arc::new(move || {
            let n = built.fetch_add(1, Ordering::SeqCst);
            let inner: Arc<dyn EngineReplica> = Arc::new(DelayReplica::from_ms(SERVICE_MS));
            Ok(if n == 0 {
                // the group's first replica panics on its 11th request
                Arc::new(ChaosReplica::panic_at(inner, 10)) as Arc<dyn EngineReplica>
            } else {
                inner
            })
        })
    };
    let mut reg = ModelRegistry::new();
    reg.register_group_scaled("tenant0", floor, 3, 1, Some(50.0), factory).unwrap();
    let metrics = Arc::new(Metrics::new());
    let router = Router::start_multi_with(
        reg.into_groups(),
        policy(),
        fast_autoscale(),
        Arc::clone(&metrics),
    );
    let trace =
        Trace::from_process(&ArrivalProcess::Poisson { rate: 300.0 }, 17, horizon_s, 0, (1, 16));
    let stop = AtomicBool::new(false);
    let (summary, timeline) = std::thread::scope(|s| {
        let mon = s.spawn(|| monitor(&router, &metrics, &stop));
        let summary = replay(&router, &trace, 1.0, DRAIN);
        // sample a beat past the drain so the post-fault regrow is seen
        std::thread::sleep(Duration::from_millis(100));
        stop.store(true, Ordering::SeqCst);
        (summary, mon.join().unwrap())
    });
    assert_eq!(summary.lost, 0, "chaos panic leg lost replies");
    assert_eq!(summary.errors, 0, "the panicked request must be retried, not errored");
    // recovery: first dip below the floor to the first sample back at it
    let dip = timeline.iter().position(|&(_, active, _)| active < floor);
    let recovery_s = dip
        .and_then(|d| {
            timeline[d..]
                .iter()
                .find(|&&(_, active, _)| active >= floor)
                .map(|&(t, _, _)| t - timeline[d].0)
        })
        .unwrap_or(0.0);
    let m = metrics.model(0);
    let faults = m.replica_faults.load(Ordering::SeqCst);
    let retried = m.retries.load(Ordering::SeqCst);
    let scale_ups = m.scale_ups.load(Ordering::SeqCst);
    assert_eq!(faults, 1, "exactly the injected panic");
    assert_eq!(retried, 1, "the panicked request was retried");
    assert!(scale_ups >= 1, "floor repair must regrow the retired slot");
    assert!(
        router.active_replicas("tenant0") >= Some(floor),
        "group must end back at its floor, at {:?}",
        router.active_replicas("tenant0")
    );
    router.shutdown();
    println!(
        "\nchaos panic leg: {} requests, fault retired the replica, retry kept\n\
         loss at 0, floor repair regrew within {recovery_s:.3}s (dip {}observed\n\
         by the 1ms sampler)",
        summary.sent,
        if dip.is_some() { "" } else { "not " }
    );
    obj([
        ("sent", summary.sent.into()),
        ("lost", summary.lost.into()),
        ("faults", (faults as i64).into()),
        ("retried", (retried as i64).into()),
        ("scale_ups", (scale_ups as i64).into()),
        ("recovery_s", recovery_s.into()),
        ("dip_observed", dip.is_some().into()),
    ])
}

/// A replica running 10x slow next to a clean peer: correctness holds
/// (zero loss, zero faults), only the latency tail moves.
fn chaos_straggler_leg(horizon_s: f64) -> Json {
    let trace =
        Trace::from_process(&ArrivalProcess::Poisson { rate: 50.0 }, 23, horizon_s, 0, (1, 16));
    let run = |straggle: bool| {
        let metrics = Arc::new(Metrics::new());
        let mk = || Arc::new(DelayReplica::from_ms(SERVICE_MS)) as Arc<dyn EngineReplica>;
        let second = if straggle {
            Arc::new(ChaosReplica::straggler(mk(), 10.0)) as Arc<dyn EngineReplica>
        } else {
            mk()
        };
        let mut reg = ModelRegistry::new();
        reg.register_group("tenant0", vec![mk(), second], 1).unwrap();
        let router = Router::start_multi(reg.into_groups(), policy(), Arc::clone(&metrics));
        let summary = replay(&router, &trace, 1.0, DRAIN);
        assert_eq!(summary.lost, 0, "straggler leg lost replies (straggle={straggle})");
        assert_eq!(summary.errors, 0);
        assert_eq!(metrics.model(0).replica_faults.load(Ordering::SeqCst), 0, "slow != faulted");
        let (_, p99) = metrics.model(0).e2e_percentiles_ms();
        router.shutdown();
        p99
    };
    let clean_p99 = run(false);
    let straggler_p99 = run(true);
    println!(
        "\nstraggler leg: p99 {clean_p99:.2}ms clean vs {straggler_p99:.2}ms with one\n\
         replica at 10x exec time, same {}-request trace, zero loss in both runs",
        trace.len()
    );
    obj([
        ("sent", trace.len().into()),
        ("clean_p99_ms", clean_p99.into()),
        ("straggler_p99_ms", straggler_p99.into()),
        ("inflation", (straggler_p99 / clean_p99).into()),
    ])
}

/// A tenant that suddenly 50x's its rate: the autoscaler rides the
/// spike up and the backlog drains back to zero after it ends.
fn chaos_spike_leg(horizon_s: f64) -> Json {
    let base = 50.0;
    let factor = 50.0;
    let spike = RateSpike { from_s: 0.3 * horizon_s, until_s: 0.55 * horizon_s, factor };
    let arrivals = ArrivalProcess::Poisson { rate: base }.sample_spiked(29, horizon_s, &spike);
    let trace = Trace::from_arrivals(&arrivals, 0, 31, (1, 16));
    let factory: ReplicaFactory =
        Arc::new(|| Ok(Arc::new(DelayReplica::from_ms(SERVICE_MS)) as Arc<dyn EngineReplica>));
    let mut reg = ModelRegistry::new();
    reg.register_group_scaled("tenant0", 1, 4, 1, Some(25.0), factory).unwrap();
    let metrics = Arc::new(Metrics::new());
    let router = Router::start_multi_with(
        reg.into_groups(),
        policy(),
        fast_autoscale(),
        Arc::clone(&metrics),
    );
    let stop = AtomicBool::new(false);
    let (summary, timeline) = std::thread::scope(|s| {
        let mon = s.spawn(|| monitor(&router, &metrics, &stop));
        let summary = replay(&router, &trace, 1.0, DRAIN);
        std::thread::sleep(Duration::from_millis(100));
        stop.store(true, Ordering::SeqCst);
        (summary, mon.join().unwrap())
    });
    assert_eq!(summary.lost, 0, "spike leg lost replies");
    assert_eq!(summary.errors, 0);
    // recovery: spike end (monitor clock ≈ trace clock at time_scale 1)
    // to the first backlog-free sample after it
    let recovery_s = timeline
        .iter()
        .find(|&&(t, _, backlog)| t >= spike.until_s && backlog == 0)
        .map(|&(t, _, _)| t - spike.until_s)
        .unwrap_or(f64::NAN);
    assert!(recovery_s.is_finite(), "backlog never drained after the spike");
    let max_replicas = timeline.iter().map(|&(_, active, _)| active).max().unwrap_or(1);
    let peak_backlog = timeline.iter().map(|&(_, _, b)| b).max().unwrap_or(0);
    let scale_ups = metrics.model(0).scale_ups.load(Ordering::SeqCst);
    assert!(scale_ups >= 1, "a 50x spike against 1 replica must trigger a grow");
    router.shutdown();
    println!(
        "\nspike leg: {base:.0} req/s base, {factor:.0}x window\n\
         [{:.2}s, {:.2}s): replicas peaked at {max_replicas}, backlog peaked at\n\
         {peak_backlog} and drained {recovery_s:.3}s after the spike ended; zero loss",
        spike.from_s, spike.until_s
    );
    obj([
        ("base_rps", base.into()),
        ("spike_factor", factor.into()),
        ("sent", summary.sent.into()),
        ("lost", summary.lost.into()),
        ("max_replicas", max_replicas.into()),
        ("peak_backlog", (peak_backlog as i64).into()),
        ("scale_ups", (scale_ups as i64).into()),
        ("recovery_s", recovery_s.into()),
    ])
}

// --- socket-ingest leg: text front door vs SWWIRE1 mux ----------------

/// Requests measured by the single-threaded allocation microbench.
const MICRO_REQS: usize = 4096;

/// Flood the legacy text server: every worker owns `share` live
/// connections and drives them in lockstep rounds (one request per
/// connection per round, so concurrency == open connections, never
/// unbounded pipelining).  Returns the wall seconds including connect.
fn flood_text(addr: SocketAddr, conns: usize, per_conn: usize, workers: usize) -> f64 {
    let t0 = Instant::now();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let share = conns / workers + usize::from(w < conns % workers);
                s.spawn(move || {
                    let mut socks: Vec<(BufReader<TcpStream>, TcpStream)> = (0..share)
                        .map(|_| {
                            let stream = TcpStream::connect(addr).unwrap();
                            stream.set_nodelay(true).ok();
                            (BufReader::new(stream.try_clone().unwrap()), stream)
                        })
                        .collect();
                    let mut line = String::new();
                    for _ in 0..per_conn {
                        for (_, wr) in socks.iter_mut() {
                            writeln!(wr, "tenant0:1,2,3,4").unwrap();
                        }
                        for (rd, _) in socks.iter_mut() {
                            line.clear();
                            rd.read_line(&mut line).unwrap();
                            assert!(line.contains("\"label\""), "text flood reply: {line}");
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    t0.elapsed().as_secs_f64()
}

/// Same flood shape over the binary protocol against the mux.
fn flood_binary(addr: SocketAddr, conns: usize, per_conn: usize, workers: usize) -> f64 {
    let t0 = Instant::now();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let share = conns / workers + usize::from(w < conns % workers);
                s.spawn(move || {
                    let mut clients: Vec<WireClient> =
                        (0..share).map(|_| WireClient::connect(addr).unwrap()).collect();
                    for round in 0..per_conn {
                        for c in clients.iter_mut() {
                            c.queue(round as u64, "tenant0", &[1, 2, 3, 4]);
                            c.flush().unwrap();
                        }
                        for c in clients.iter_mut() {
                            let f = c.recv().unwrap();
                            assert!(f.is_ok(), "binary flood reply: {f:?}");
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    t0.elapsed().as_secs_f64()
}

/// Heap allocations per request on each protocol's decode path,
/// measured single-threaded under the counting allocator: the mux's
/// ring -> pull -> tokens -> encoded-reply loop (zero after warm-up,
/// the DESIGN.md §11 contract) vs the text path's owned line +
/// `parse_tokens` + formatted JSON reply.  Returns `(text, binary)`.
fn alloc_microbench() -> (f64, f64) {
    let tokens: Vec<i32> = (0..16).collect();
    let mut frame = Vec::new();
    encode::encode_request(&mut frame, 1, "tenant0", &tokens);
    let mut ring = RingBuf::new(4096);
    let mut dec = FrameDecoder::default();
    let mut scratch: Vec<i32> = Vec::new();
    let mut out: Vec<u8> = Vec::new();
    let logits = [1i64, 2, 3, 4];
    let mut run_binary = |n: usize| {
        let mut decoded = 0usize;
        while decoded < n {
            assert_eq!(ring.fill_from(&frame), frame.len(), "ring drained every iteration");
            loop {
                let (c, ev) = dec.pull(ring.readable());
                if let Some(DecodeEvent::Request(r)) = ev {
                    r.read_tokens_into(&mut scratch);
                    out.clear();
                    encode::encode_ok(&mut out, r.id, 0, 1, &logits, 0.5, 100.0);
                    decoded += 1;
                }
                if c == 0 {
                    break;
                }
                ring.consume(c);
            }
        }
    };
    run_binary(64); // warm-up sizes scratch and out
    let before = thread_allocs();
    run_binary(MICRO_REQS);
    let binary = (thread_allocs() - before) as f64 / MICRO_REQS as f64;

    let mut line = String::from("tenant0:");
    for (i, t) in tokens.iter().enumerate() {
        if i > 0 {
            line.push(',');
        }
        line.push_str(&t.to_string());
    }
    let run_text = |n: usize| {
        for _ in 0..n {
            // BufRead::lines hands the handler an owned String per line
            let owned = line.to_string();
            let (model, toks) = parse_tokens(owned.trim()).unwrap();
            let reply = format!(
                "{{\"model\":{:?},\"tokens\":{}}}",
                model.as_deref().unwrap_or(""),
                toks.len()
            );
            std::hint::black_box(reply);
        }
    };
    run_text(64);
    let before = thread_allocs();
    run_text(MICRO_REQS);
    let text = (thread_allocs() - before) as f64 / MICRO_REQS as f64;
    (text, binary)
}

/// Ingest-bound front-door comparison: `conns` live loopback
/// connections x `per_conn` requests each, against instant replicas —
/// the service time is ~0, so the wall clock measures the front door
/// itself.  Zero accepted-request loss is asserted on both protocols.
fn wire_leg(conns: usize, per_conn: usize) -> Json {
    let workers = conns.min(8);
    let total = conns * per_conn;
    let run = |binary: bool| -> (f64, f64) {
        let metrics = Arc::new(Metrics::new());
        let mut reg = ModelRegistry::new();
        let mk = || Arc::new(DelayReplica::from_ms(0)) as Arc<dyn EngineReplica>;
        reg.register_group("tenant0", vec![mk(), mk()], 1).unwrap();
        let router =
            Arc::new(Router::start_multi(reg.into_groups(), policy(), Arc::clone(&metrics)));
        let wall = if binary {
            let cfg = MuxConfig { max_conns: conns + 64, ..MuxConfig::default() };
            let server = MuxServer::start(Arc::clone(&router), "127.0.0.1:0", cfg).unwrap();
            let wall = flood_binary(server.local_addr(), conns, per_conn, workers);
            server.shutdown();
            wall
        } else {
            let server = TextServer::start(Arc::clone(&router), "127.0.0.1:0", conns + 64).unwrap();
            let wall = flood_text(server.local_addr(), conns, per_conn, workers);
            server.stop();
            wall
        };
        let completed = metrics.model(0).completed.load(Ordering::SeqCst) as usize;
        assert_eq!(completed, total, "front door lost accepted requests (binary={binary})");
        let (_, p99) = metrics.model(0).e2e_percentiles_ms();
        if let Ok(r) = Arc::try_unwrap(router) {
            r.shutdown();
        }
        (total as f64 / wall, p99)
    };
    let (text_rps, text_p99) = run(false);
    let (binary_rps, binary_p99) = run(true);
    let speedup = binary_rps / text_rps;
    let (text_allocs, binary_allocs) = alloc_microbench();
    assert_eq!(
        binary_allocs, 0.0,
        "binary decode path allocated {binary_allocs}/request after warm-up"
    );
    if conns >= 1000 {
        assert!(
            speedup >= 2.0,
            "mux must be >= 2x the text front door at {conns} connections, got {speedup:.2}x"
        );
    }
    let mut table = Table::new(&["front door", "req/s", "p99", "allocs/req (decode)"]);
    table.row(&[
        "text (thread/conn)".into(),
        format!("{text_rps:.0}"),
        format!("{text_p99:.2}ms"),
        format!("{text_allocs:.1}"),
    ]);
    table.row(&[
        "SWWIRE1 mux".into(),
        format!("{binary_rps:.0}"),
        format!("{binary_p99:.2}ms"),
        format!("{binary_allocs:.1}"),
    ]);
    table.print(&format!(
        "socket ingest: {conns} loopback connections x {per_conn} req each, instant replicas"
    ));
    println!("\nwire leg: binary mux at {speedup:.2}x the text front door's throughput");
    obj([
        ("conns", (conns as i64).into()),
        ("per_conn", (per_conn as i64).into()),
        ("requests", (total as i64).into()),
        ("text_rps", text_rps.into()),
        ("binary_rps", binary_rps.into()),
        ("speedup", speedup.into()),
        ("text_p99_ms", text_p99.into()),
        ("binary_p99_ms", binary_p99.into()),
        ("text_allocs_per_req", text_allocs.into()),
        ("binary_allocs_per_req", binary_allocs.into()),
    ])
}

// --- committed-snapshot checking (the `--smoke` contract) -------------

/// Bound for one numeric leaf, keyed by its field name.  Latency and
/// recovery keys get direction-aware regression bounds; counts, factors
/// and seeds are schema-only (their values are run-shaped, not a perf
/// trajectory).
fn leaf_bound(path: &str, key: &str, committed: f64, fresh: f64) -> Option<String> {
    let fail = |bound: String| {
        Some(format!("{path}: fresh {fresh:.4} vs committed {committed:.4} — {bound}"))
    };
    if key == "lost" {
        if fresh != 0.0 {
            return fail("lost replies must be 0".into());
        }
    } else if key == "recovery_s" {
        if fresh > committed + 0.25 {
            return fail(format!("regressed past committed + 0.25s ({:.4})", committed + 0.25));
        }
    } else if key.ends_with("wall_s") {
        if fresh > committed + 1.0 {
            return fail(format!("regressed past committed + 1.0s ({:.4})", committed + 1.0));
        }
    } else if key.ends_with("_ms") {
        if fresh > 2.0 * committed + 5.0 {
            return fail(format!("regressed past 2x committed + 5ms ({:.4})", 2.0 * committed + 5.0));
        }
    } else if key.ends_with("_rps") {
        if committed >= 10.0 && fresh < committed / 2.0 {
            return fail(format!("fell below half of committed ({:.4})", committed / 2.0));
        }
    }
    None
}

/// Recursive schema + regression check of a fresh smoke run against the
/// committed snapshot.  Key paths must match exactly in both directions;
/// numeric leaves are judged by [`leaf_bound`], strings must be equal
/// (schema versions, tenant names), booleans are type-checked only.
fn check_node(path: &str, key: &str, committed: &Json, fresh: &Json, errs: &mut Vec<String>) {
    match (committed, fresh) {
        (Json::Obj(c), Json::Obj(f)) => {
            for k in c.keys().filter(|k| !f.contains_key(*k)) {
                errs.push(format!("{path}.{k}: in committed snapshot, missing from fresh run"));
            }
            for k in f.keys().filter(|k| !c.contains_key(*k)) {
                errs.push(format!("{path}.{k}: new in fresh run, not in committed snapshot"));
            }
            for (k, cv) in c {
                if let Some(fv) = f.get(k) {
                    check_node(&format!("{path}.{k}"), k, cv, fv, errs);
                }
            }
        }
        (Json::Arr(c), Json::Arr(f)) => {
            if c.len() != f.len() {
                errs.push(format!("{path}: {} committed rows vs {} fresh", c.len(), f.len()));
                return;
            }
            for (i, (cv, fv)) in c.iter().zip(f).enumerate() {
                check_node(&format!("{path}[{i}]"), key, cv, fv, errs);
            }
        }
        (Json::Num(c), Json::Num(f)) => {
            if let Some(e) = leaf_bound(path, key, *c, *f) {
                errs.push(e);
            }
        }
        (Json::Str(c), Json::Str(f)) => {
            if c != f {
                errs.push(format!("{path}: {c:?} committed vs {f:?} fresh"));
            }
        }
        (Json::Bool(_), Json::Bool(_)) | (Json::Null, Json::Null) => {}
        (c, f) => {
            errs.push(format!("{path}: type changed ({c} committed vs {f} fresh)"));
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke");
    let update = args.iter().any(|a| a == "--update");
    println!(
        "serving-openloop{}: seeded arrival traces replayed open-loop \
         (mock replicas, {SERVICE_MS}ms service time, µ = {MU:.0} req/s each)",
        if smoke { " [smoke]" } else { "" }
    );

    // smoke keeps every leg but shortens the horizons; the arrival
    // streams stay fully seeded either way
    let (rhos, horizon_s, panic_horizon_s, spike_horizon_s): (&[f64], f64, f64, f64) = if smoke {
        (&[0.2, 0.5], 0.8, 0.5, 1.0)
    } else {
        (&[0.2, 0.5, 0.8], 2.0, 1.0, 1.5)
    };

    // the wire leg floods real loopback sockets; smoke keeps the same
    // round shape at a CI-sized connection count
    let (wire_conns, wire_per_conn) = if smoke { (128, 8) } else { (1000, 8) };

    let offered_load = offered_load_leg(rhos, horizon_s);
    let burst = burst_leg(horizon_s);
    let chaos_panic = chaos_panic_leg(panic_horizon_s);
    let chaos_straggler = chaos_straggler_leg(horizon_s);
    let chaos_spike = chaos_spike_leg(spike_horizon_s);
    let wire = wire_leg(wire_conns, wire_per_conn);

    let legs = [
        ("offered_load", offered_load),
        ("burst", burst),
        ("chaos_panic", chaos_panic),
        ("chaos_straggler", chaos_straggler),
        ("chaos_spike", chaos_spike),
    ];

    let mut openloop: Vec<(&'static str, Json)> = vec![
        ("schema", "swifttron-openloop-bench-v1".into()),
        ("smoke", smoke.into()),
    ];
    openloop.extend(legs.iter().map(|(k, v)| (*k, v.clone())));
    let path = "BENCH_serving.json";
    match merge_bench_json(path, [("openloop", obj(openloop)), ("wire", wire.clone())]) {
        Ok(()) => println!("\nwrote {path} (openloop + wire keys; sibling legs preserved)"),
        Err(e) => eprintln!("\nfailed to write {path}: {e}"),
    }

    if !smoke {
        return;
    }

    // --- committed smoke snapshot: bootstrap, rebaseline, or verify ---
    let mut snapshot: Vec<(&'static str, Json)> =
        vec![("schema", "swifttron-openloop-smoke-v1".into())];
    snapshot.extend(legs);
    snapshot.push(("wire", wire));
    let snapshot = obj(snapshot);
    let snap_path = "BENCH_smoke.json";
    let committed = std::fs::read_to_string(snap_path)
        .ok()
        .and_then(|s| Json::parse(s.trim()).ok());
    match committed {
        Some(committed) if !update => {
            let mut errs = Vec::new();
            check_node("smoke", "", &committed, &snapshot, &mut errs);
            if errs.is_empty() {
                println!("{snap_path}: schema matches, no leg regressed past its bound");
            } else {
                eprintln!("\n{snap_path}: smoke snapshot check FAILED:");
                for e in &errs {
                    eprintln!("  {e}");
                }
                eprintln!(
                    "if this change is intentional, rebaseline with\n  \
                     cargo bench --bench serving_openloop -- --smoke --update"
                );
                std::process::exit(1);
            }
        }
        _ => match std::fs::write(snap_path, format!("{snapshot}\n")) {
            Ok(()) => println!(
                "{snap_path}: snapshot {} — commit it",
                if update { "rebaselined" } else { "bootstrapped" }
            ),
            Err(e) => {
                eprintln!("failed to write {snap_path}: {e}");
                std::process::exit(1);
            }
        },
    }
}
