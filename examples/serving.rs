//! Serving demo: Poisson open-loop workload against the router +
//! dynamic batcher + engine replicas; reports throughput and the
//! latency distribution (the coordinator story of DESIGN.md §2).
//!
//! Run: `cargo run --release --example serving -- [requests] [rate_hz]`

use std::sync::mpsc::channel;
use std::sync::Arc;
use swifttron::coordinator::{
    BatchPolicy, EngineReplica, FunctionalEngine, InferenceEngine, Metrics, Router,
};
use swifttron::model::Manifest;
use swifttron::runtime::Engine;
use swifttron::sim::HwConfig;
use swifttron::util::rng::Rng;

fn main() -> Result<(), String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_requests: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(200);
    let rate_hz: f64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300.0);
    let replicas = 3;

    let dir = Manifest::default_dir();
    let artifact_backed = dir.join("manifest.json").exists();
    let engines: Vec<Arc<dyn EngineReplica>> = if artifact_backed {
        let engine = Engine::cpu()?;
        (0..replicas)
            .map(|_| {
                InferenceEngine::load(&dir, &engine, HwConfig::paper())
                    .map(|e| Arc::new(e) as Arc<dyn EngineReplica>)
            })
            .collect::<Result<_, _>>()?
    } else {
        eprintln!("(artifacts missing: serving synthetic functional replicas instead)");
        (0..replicas)
            .map(|_| {
                FunctionalEngine::synthetic("tiny", 7, HwConfig::paper())
                    .map(|e| Arc::new(e) as Arc<dyn EngineReplica>)
            })
            .collect::<Result<_, _>>()?
    };
    let m = engines[0].seq_len();
    let min_len = engines[0].min_seq_len();
    let metrics = Arc::new(Metrics::new());
    // The functional replicas serve any live length, so the demo sends
    // variable-length traffic through length-bucketed dispatch; the
    // fixed-shape PJRT artifact path stays at exactly m tokens.
    let policy = if min_len < m {
        BatchPolicy { bucket_width: (m / 4).max(1), ..BatchPolicy::default() }
    } else {
        BatchPolicy::default()
    };
    let router = Arc::new(Router::start(engines, policy, Arc::clone(&metrics)));

    println!(
        "open-loop Poisson workload: {n_requests} requests at {rate_hz} req/s, {replicas} replicas, \
         lengths {min_len}..={m}"
    );
    let mut rng = Rng::new(2024);
    let t0 = std::time::Instant::now();
    let mut receivers = Vec::with_capacity(n_requests);
    for _ in 0..n_requests {
        let len = if min_len < m { min_len + rng.below((m - min_len + 1) as u64) as usize } else { m };
        let tokens: Vec<i32> = (0..len).map(|_| rng.below(63) as i32).collect();
        let (tx, rx) = channel();
        router.submit(tokens, tx);
        receivers.push(rx);
        std::thread::sleep(std::time::Duration::from_secs_f64(rng.exponential(rate_hz)));
    }
    let mut errors = 0;
    for rx in receivers {
        if rx.recv().map(|r| r.error.is_some()).unwrap_or(true) {
            errors += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("\ncompleted in {wall:.2}s  ({:.1} req/s sustained, {errors} errors)", n_requests as f64 / wall);
    println!("{}", metrics.report());

    let r = Arc::try_unwrap(router).ok().expect("router still shared");
    r.shutdown();
    Ok(())
}
