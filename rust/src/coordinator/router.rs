//! Request router: accepts requests, batches them, and dispatches batches
//! onto a pool of engine replicas (each replica modeling one SwiftTron
//! accelerator attached to the host).

use super::batcher::{BatchPolicy, Batcher};
use super::engine::InferenceEngine;
use super::metrics::Metrics;
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub tokens: Vec<i32>,
    pub submitted: Instant,
    pub reply: Sender<Response>,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub label: usize,
    pub accel_ms: f64,
    pub e2e_s: f64,
    pub error: Option<String>,
}

struct Shared {
    batcher: Mutex<Batcher<Request>>,
    available: Condvar,
    shutdown: Mutex<bool>,
}

pub struct Router {
    shared: Arc<Shared>,
    pub metrics: Arc<Metrics>,
    workers: Vec<JoinHandle<()>>,
    next_id: Mutex<u64>,
}

impl Router {
    /// Spawn `replicas` worker threads, each owning one engine replica.
    pub fn start(
        engines: Vec<Arc<InferenceEngine>>,
        policy: BatchPolicy,
        metrics: Arc<Metrics>,
    ) -> Router {
        let shared = Arc::new(Shared {
            batcher: Mutex::new(Batcher::new(policy)),
            available: Condvar::new(),
            shutdown: Mutex::new(false),
        });
        let workers = engines
            .into_iter()
            .enumerate()
            .map(|(i, engine)| {
                let sh = Arc::clone(&shared);
                let mt = Arc::clone(&metrics);
                std::thread::Builder::new()
                    .name(format!("swifttron-replica-{i}"))
                    .spawn(move || worker_loop(sh, engine, mt))
                    .expect("spawn replica")
            })
            .collect();
        Router { shared, metrics, workers, next_id: Mutex::new(0) }
    }

    /// Submit a request; the response arrives on `reply`.
    pub fn submit(&self, tokens: Vec<i32>, reply: Sender<Response>) -> u64 {
        let id = {
            let mut n = self.next_id.lock().unwrap();
            *n += 1;
            *n
        };
        self.metrics.record_request();
        {
            let mut b = self.shared.batcher.lock().unwrap();
            b.push(Request { id, tokens, submitted: Instant::now(), reply });
        }
        self.shared.available.notify_one();
        id
    }

    pub fn queue_len(&self) -> usize {
        self.shared.batcher.lock().unwrap().len()
    }

    pub fn shutdown(mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.available.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: Arc<Shared>, engine: Arc<InferenceEngine>, metrics: Arc<Metrics>) {
    loop {
        // wait for work or shutdown
        let batch = {
            let mut b = sh.batcher.lock().unwrap();
            loop {
                if *sh.shutdown.lock().unwrap() && b.is_empty() {
                    return;
                }
                if b.ready(Instant::now()) || (!b.is_empty() && *sh.shutdown.lock().unwrap()) {
                    break b.take_batch();
                }
                let timeout = b
                    .next_deadline()
                    .map(|d| d.saturating_duration_since(Instant::now()))
                    .unwrap_or(std::time::Duration::from_millis(50));
                let (guard, _) = sh.available.wait_timeout(b, timeout).unwrap();
                b = guard;
            }
        };

        for req in batch {
            let queued = req.submitted.elapsed().as_secs_f64();
            let t0 = Instant::now();
            match engine.predict(&req.tokens) {
                Ok(pred) => {
                    let exec = t0.elapsed().as_secs_f64();
                    let e2e = req.submitted.elapsed().as_secs_f64();
                    metrics.record_completion(e2e, queued, exec, pred.accel_ms);
                    let _ = req.reply.send(Response {
                        id: req.id,
                        label: pred.label,
                        accel_ms: pred.accel_ms,
                        e2e_s: e2e,
                        error: None,
                    });
                }
                Err(e) => {
                    metrics.record_error();
                    let _ = req.reply.send(Response {
                        id: req.id,
                        label: usize::MAX,
                        accel_ms: 0.0,
                        e2e_s: req.submitted.elapsed().as_secs_f64(),
                        error: Some(e),
                    });
                }
            }
        }
    }
}
