//! Control unit: the paper's cooperating FSMs (Fig. 16).
//!
//! Three dedicated finite state machines (MHSA, LayerNorm, FFN) sequence
//! the hardware blocks with Start/Done/Valid handshakes.  The simulator
//! models each FSM as an explicit state walker that advances a shared
//! cycle counter and records a handshake trace — the trace is what the
//! paper's QuestaSim waveforms would show, and the tests assert its
//! well-formedness (every Start matched by a Done, monotonic time).

use std::fmt;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsmKind {
    Mhsa,
    LayerNorm,
    Ffn,
}

impl fmt::Display for FsmKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            FsmKind::Mhsa => "MHSA",
            FsmKind::LayerNorm => "LN",
            FsmKind::Ffn => "FFN",
        };
        write!(f, "{s}")
    }
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Event {
    /// FSM asserted Start for a named block at `cycle`.
    Start { fsm: FsmKind, block: &'static str, cycle: u64 },
    /// Block raised Done/Valid at `cycle`.
    Done { fsm: FsmKind, block: &'static str, cycle: u64 },
}

impl Event {
    pub fn cycle(&self) -> u64 {
        match self {
            Event::Start { cycle, .. } | Event::Done { cycle, .. } => *cycle,
        }
    }
}

/// Handshake trace of one simulation.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub events: Vec<Event>,
}

impl Trace {
    pub fn check_well_formed(&self) -> Result<(), String> {
        let mut open: Vec<(&FsmKind, &&'static str)> = Vec::new();
        let mut last = 0u64;
        for e in &self.events {
            if e.cycle() < last {
                return Err(format!("time went backwards at {e:?}"));
            }
            last = e.cycle();
            match e {
                Event::Start { fsm, block, .. } => open.push((fsm, block)),
                Event::Done { fsm, block, .. } => {
                    let pos = open
                        .iter()
                        .position(|(f, b)| *f == fsm && *b == block)
                        .ok_or_else(|| format!("Done without Start: {e:?}"))?;
                    open.remove(pos);
                }
            }
        }
        if open.is_empty() {
            Ok(())
        } else {
            Err(format!("unmatched Starts: {open:?}"))
        }
    }
}

/// One FSM walking through its block sequence, advancing a shared clock.
pub struct Fsm<'a> {
    pub kind: FsmKind,
    trace: &'a mut Trace,
    /// The FSM's own notion of "now" (cycles since inference start).
    pub now: u64,
}

impl<'a> Fsm<'a> {
    pub fn new(kind: FsmKind, trace: &'a mut Trace, start_cycle: u64) -> Self {
        Fsm { kind, trace, now: start_cycle }
    }

    /// Run one block: Start handshake, occupy `cycles`, Done handshake.
    /// Returns the completion cycle.
    pub fn run_block(&mut self, block: &'static str, cycles: u64) -> u64 {
        self.trace.events.push(Event::Start { fsm: self.kind, block, cycle: self.now });
        self.now += cycles;
        self.trace.events.push(Event::Done { fsm: self.kind, block, cycle: self.now });
        self.now
    }

    /// Wait for another FSM's completion (handshake join).
    pub fn join(&mut self, other_done_at: u64) {
        self.now = self.now.max(other_done_at);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fsm_records_matched_handshakes() {
        let mut t = Trace::default();
        {
            let mut fsm = Fsm::new(FsmKind::Mhsa, &mut t, 0);
            fsm.run_block("qkv", 100);
            fsm.run_block("attention", 50);
        }
        assert_eq!(t.events.len(), 4);
        t.check_well_formed().unwrap();
        assert_eq!(t.events.last().unwrap().cycle(), 150);
    }

    #[test]
    fn join_advances_to_latest() {
        let mut t = Trace::default();
        let mut fsm = Fsm::new(FsmKind::Ffn, &mut t, 10);
        fsm.join(500);
        assert_eq!(fsm.now, 500);
        fsm.join(100); // joining an earlier event must not move time back
        assert_eq!(fsm.now, 500);
    }

    #[test]
    fn malformed_trace_detected() {
        let mut t = Trace::default();
        t.events.push(Event::Done { fsm: FsmKind::Mhsa, block: "x", cycle: 5 });
        assert!(t.check_well_formed().is_err());
    }
}
