//! Request router: the front half of the parallel serving pipeline
//! (DESIGN.md §2, §8).
//!
//! `submit` / `submit_to` enqueue requests into the dynamic [`Batcher`]
//! (keyed by `(model, padded length)`; DESIGN.md §6, §8); a single
//! dispatcher thread waits for the size-or-deadline policy to release a
//! model-homogeneous dispatch group — chosen across models by the
//! batcher's weighted-fair ledger — and hands it to the
//! [`ReplicaPool`], which fans the group out across the owning model's
//! replicas on the `util` thread pool.  The dispatcher blocks until the
//! group completes (the pool's join), then takes the next group — so
//! groups are pipelined back to back while requests inside a group run
//! concurrently.

use super::batcher::{BatchPolicy, Batcher};
use super::engine::EngineReplica;
use super::metrics::Metrics;
use super::pool::ReplicaPool;
use super::registry::ModelGroup;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    /// model index (position of the model's group in the router)
    pub model: usize,
    pub tokens: Vec<i32>,
    /// tokens the dispatch bucket charges for this request
    /// (== `tokens.len()` when bucketing is off); fed to the per-model
    /// served-token ledger on completion
    pub padded_len: usize,
    pub submitted: Instant,
    pub reply: Sender<Response>,
}

#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// model id that served (or rejected) this request
    pub model: String,
    /// which engine replica served this request (global index)
    pub replica: usize,
    pub label: usize,
    /// classifier logits (empty on error) — lets callers check
    /// byte-identical outputs across replica counts and backends
    pub logits: Vec<i64>,
    pub accel_ms: f64,
    pub e2e_s: f64,
    pub error: Option<String>,
}

struct Shared {
    batcher: Mutex<Batcher<Request>>,
    available: Condvar,
    shutdown: AtomicBool,
}

/// Per-model endpoint bookkeeping: the serveable length range of the
/// model's replica group (max of `min_seq_len`, min of `seq_len`,
/// because fan-out within the group is length-blind round-robin) plus
/// the name and fair-share weight.
struct Endpoint {
    name: String,
    weight: u64,
    min_len: usize,
    max_len: usize,
}

pub struct Router {
    shared: Arc<Shared>,
    pub metrics: Arc<Metrics>,
    dispatcher: Option<JoinHandle<()>>,
    next_id: AtomicU64,
    policy: BatchPolicy,
    endpoints: Vec<Endpoint>,
}

impl Router {
    /// Start the single-model serving pipeline over `replicas` engine
    /// replicas under the default model id (the replica pool spins one
    /// worker thread per replica, plus one dispatcher thread).
    pub fn start(
        replicas: Vec<Arc<dyn EngineReplica>>,
        policy: BatchPolicy,
        metrics: Arc<Metrics>,
    ) -> Router {
        Router::start_multi(
            vec![ModelGroup { model: "default".into(), replicas, weight: 1 }],
            policy,
            metrics,
        )
    }

    /// Start the multi-tenant serving pipeline: one named replica group
    /// per model (typically [`super::ModelRegistry::into_groups`]), a
    /// shared batcher keyed by `(model, padded length)` with the
    /// groups' fair-share weights, and one dispatcher thread over one
    /// pool of all replicas.
    pub fn start_multi(
        groups: Vec<ModelGroup>,
        policy: BatchPolicy,
        metrics: Arc<Metrics>,
    ) -> Router {
        assert!(!groups.is_empty(), "router needs at least one model group");
        for (i, g) in groups.iter().enumerate() {
            assert!(!g.replicas.is_empty(), "model {:?} has no replicas", g.model);
            assert!(
                !groups[..i].iter().any(|o| o.model == g.model),
                "duplicate model id {:?}",
                g.model
            );
        }
        let endpoints: Vec<Endpoint> = groups
            .iter()
            .map(|g| Endpoint {
                name: g.model.clone(),
                weight: g.weight.max(1),
                min_len: g.replicas.iter().map(|r| r.min_seq_len()).max().unwrap_or(0),
                max_len: g.replicas.iter().map(|r| r.seq_len()).min().unwrap_or(0),
            })
            .collect();
        let specs: Vec<(&str, u64)> =
            endpoints.iter().map(|e| (e.name.as_str(), e.weight)).collect();
        metrics.ensure_models(&specs);
        let weights: Vec<u64> = endpoints.iter().map(|e| e.weight).collect();
        let mut batcher = Batcher::new(policy);
        batcher.set_model_weights(&weights);
        let shared = Arc::new(Shared {
            batcher: Mutex::new(batcher),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let pool = ReplicaPool::new_multi(groups, Arc::clone(&metrics));
        let sh = Arc::clone(&shared);
        let dispatcher = std::thread::Builder::new()
            .name("swifttron-dispatch".into())
            .spawn(move || dispatch_loop(sh, pool))
            .expect("spawn dispatcher");
        Router {
            shared,
            metrics,
            dispatcher: Some(dispatcher),
            next_id: AtomicU64::new(0),
            policy,
            endpoints,
        }
    }

    /// Registered model ids, in model-index order.
    pub fn model_names(&self) -> Vec<&str> {
        self.endpoints.iter().map(|e| e.name.as_str()).collect()
    }

    /// Submit a request to the first (default) model; the response
    /// arrives on `reply`.
    pub fn submit(&self, tokens: Vec<i32>, reply: Sender<Response>) -> u64 {
        self.submit_idx(0, tokens, reply)
    }

    /// Submit a request to the named model.  An unknown model id is
    /// answered immediately with an error response (and counted as an
    /// error) instead of entering the queue.
    pub fn submit_to(&self, model: &str, tokens: Vec<i32>, reply: Sender<Response>) -> u64 {
        match self.endpoints.iter().position(|e| e.name == model) {
            Some(idx) => self.submit_idx(idx, tokens, reply),
            None => {
                let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
                self.metrics.record_request();
                self.metrics.record_error();
                let _ = reply.send(Response {
                    id,
                    model: model.to_string(),
                    replica: usize::MAX,
                    label: usize::MAX,
                    logits: Vec::new(),
                    accel_ms: 0.0,
                    e2e_s: 0.0,
                    error: Some(format!(
                        "unknown model {model:?} (resident: {:?})",
                        self.model_names()
                    )),
                });
                id
            }
        }
    }

    /// Submit to model index `model`.  The token count is the request's
    /// live sequence length: the batcher groups it with
    /// length-compatible requests of the same model (same padded
    /// bucket) and the padding the bucket charges is accounted in the
    /// per-model metrics.
    fn submit_idx(&self, model: usize, tokens: Vec<i32>, reply: Sender<Response>) -> u64 {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed) + 1;
        self.metrics.record_request_for(model);
        let ep = &self.endpoints[model];
        let len = tokens.len();
        // `padded_len` is the request's scheduler charge and must equal
        // what the batcher's deficit ledger counts (the unclamped
        // bucket boundary), or the reported served-token shares would
        // drift from the fairness currency actually being enforced.
        let padded = self.policy.padded_len(len);
        {
            let mut b = self.shared.batcher.lock().unwrap();
            b.push_keyed(
                Request { id, model, tokens, padded_len: padded, submitted: Instant::now(), reply },
                model,
                len,
            );
        }
        // Token accounting only for serveable requests, and never more
        // padding than the largest geometry the model's replicas
        // actually run — rejected requests and bucket boundaries beyond
        // the array must not inflate the padding-waste metric.
        if len >= ep.min_len.max(1) && len <= ep.max_len {
            self.metrics.record_tokens(model, len, padded.min(ep.max_len));
        }
        self.shared.available.notify_one();
        id
    }

    pub fn queue_len(&self) -> usize {
        self.shared.batcher.lock().unwrap().len()
    }

    /// Drain the queue and stop the pipeline (joins the dispatcher,
    /// which in turn joins the replica pool's threads on drop).
    pub fn shutdown(mut self) {
        // The flag must flip while holding the mutex the dispatcher's
        // condvar predicate is checked under, or a store between the
        // predicate check and wait_timeout loses the wakeup and the
        // drain stalls for up to max_wait.
        {
            let _b = self.shared.batcher.lock().unwrap();
            self.shared.shutdown.store(true, Ordering::SeqCst);
        }
        self.shared.available.notify_all();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

fn dispatch_loop(sh: Arc<Shared>, pool: ReplicaPool) {
    loop {
        let group = {
            let mut b = sh.batcher.lock().unwrap();
            loop {
                let shutting = sh.shutdown.load(Ordering::SeqCst);
                if b.is_empty() && shutting {
                    return;
                }
                if b.ready(Instant::now()) || (shutting && !b.is_empty()) {
                    break b.take_batch();
                }
                // park_duration never panics, whatever the queue did
                // between the predicate check and here (drained by a
                // racing shutdown flush, refilled by a submit): empty
                // queues park the bounded default, expired deadlines
                // park zero.
                let timeout = b.park_duration(Instant::now());
                let (guard, _) = sh.available.wait_timeout(b, timeout).unwrap();
                b = guard;
            }
        };
        pool.dispatch(group);
    }
}
