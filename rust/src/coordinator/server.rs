//! TCP front-end: newline-delimited requests of comma-separated token
//! ids; responses are single JSON lines.  One thread per connection
//! (connections are few; the router pool does the real work).

use super::router::{Response, Router};
use crate::util::json::{obj, Json};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc::channel;
use std::sync::Arc;

/// Serve until the listener errors or the process exits.
pub fn serve(router: Arc<Router>, addr: &str) -> Result<(), String> {
    let listener = TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
    eprintln!("swifttron serving on {addr}");
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let r = Arc::clone(&router);
                std::thread::spawn(move || {
                    let _ = handle(r, s);
                });
            }
            Err(e) => eprintln!("accept error: {e}"),
        }
    }
    Ok(())
}

fn response_json(resp: &Response) -> String {
    let mut fields = vec![
        ("id", Json::from(resp.id as i64)),
        ("replica", Json::from(resp.replica as i64)),
        ("accel_ms", Json::from(resp.accel_ms)),
        ("e2e_us", Json::from(resp.e2e_s * 1e6)),
    ];
    match &resp.error {
        Some(e) => fields.push(("error", Json::from(e.as_str()))),
        None => fields.push(("label", Json::from(resp.label as i64))),
    }
    obj(fields).to_string()
}

fn handle(router: Arc<Router>, stream: TcpStream) -> std::io::Result<()> {
    let peer = stream.peer_addr()?;
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if line == "quit" {
            break;
        }
        match parse_tokens(line) {
            Ok(tokens) => {
                let (tx, rx) = channel();
                router.submit(tokens, tx);
                match rx.recv() {
                    Ok(resp) => writeln!(writer, "{}", response_json(&resp))?,
                    Err(_) => writeln!(writer, "{{\"error\":\"router gone\"}}")?,
                }
            }
            Err(e) => writeln!(writer, "{}", obj([("error", Json::from(e.as_str()))]))?,
        }
    }
    eprintln!("connection {peer} closed");
    Ok(())
}

/// Parse "3,17,42,..." into token ids.
pub fn parse_tokens(line: &str) -> Result<Vec<i32>, String> {
    line.split(',')
        .map(|t| t.trim().parse::<i32>().map_err(|_| format!("bad token {t:?}")))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_tokens_ok_and_err() {
        assert_eq!(parse_tokens("1, 2,3").unwrap(), vec![1, 2, 3]);
        assert!(parse_tokens("1,x").is_err());
    }

    #[test]
    fn response_json_shapes() {
        let ok =
            Response { id: 1, replica: 0, label: 0, accel_ms: 0.5, e2e_s: 0.001, error: None };
        let s = response_json(&ok);
        assert!(s.contains("\"label\":0") && s.contains("\"accel_ms\":0.5"));
        assert!(s.contains("\"replica\":0"));
        let err = Response {
            id: 2,
            replica: 1,
            label: usize::MAX,
            accel_ms: 0.0,
            e2e_s: 0.0,
            error: Some("bad".into()),
        };
        assert!(response_json(&err).contains("\"error\":\"bad\""));
    }
}
