//! Fault-injecting engine replicas for chaos legs: a replica that
//! panics mid-batch and a straggler running at a multiple of its inner
//! exec time, plus the deterministic delay mock both the chaos tests
//! and the open-loop bench drive them with.
//!
//! These wrap any [`EngineReplica`], so the faults exercise the real
//! recovery path in `coordinator::pool` (panic capture → slot
//! retirement → retry) and `coordinator::autoscale` (floor repair)
//! rather than a parallel mock of it.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::coordinator::{EngineReplica, Prediction, RequestError};

/// Deterministic mock replica: sleeps a fixed service time, then
/// returns a prediction derived from the first token.  Rejects empty
/// requests so error paths stay testable.
pub struct DelayReplica {
    delay: Duration,
}

impl DelayReplica {
    pub fn new(delay: Duration) -> Self {
        DelayReplica { delay }
    }

    pub fn from_ms(ms: u64) -> Self {
        DelayReplica::new(Duration::from_millis(ms))
    }
}

impl EngineReplica for DelayReplica {
    fn predict(&self, tokens: &[i32]) -> Result<Prediction, RequestError> {
        if tokens.is_empty() {
            return Err(RequestError::BadLength { got: 0, min: 1, max: self.seq_len() });
        }
        std::thread::sleep(self.delay);
        Ok(Prediction {
            label: (tokens[0].unsigned_abs() as usize) % 2,
            logits: vec![tokens[0] as i64, tokens.len() as i64],
            accel_cycles: 100,
            accel_ms: 0.001,
        })
    }

    fn seq_len(&self) -> usize {
        1 << 20
    }

    fn min_seq_len(&self) -> usize {
        1
    }
}

enum FaultMode {
    /// Panic on the n-th request served (0-based), serve cleanly
    /// otherwise — one fault, then permanently healthy, so a zero-loss
    /// run proves recovery rather than avoidance.
    PanicAt(usize),
    /// Multiply exec time by sleeping `(factor - 1) ×` the inner
    /// replica's measured latency after each successful call.
    Straggle(f64),
}

/// An [`EngineReplica`] wrapper that injects one fault mode around an
/// inner replica.
pub struct ChaosReplica {
    inner: Arc<dyn EngineReplica>,
    mode: FaultMode,
    served: AtomicUsize,
}

impl ChaosReplica {
    /// Panics on the `request`-th call (0-based), serves normally
    /// before and after.
    pub fn panic_at(inner: Arc<dyn EngineReplica>, request: usize) -> Self {
        ChaosReplica { inner, mode: FaultMode::PanicAt(request), served: AtomicUsize::new(0) }
    }

    /// Runs every request at `factor ×` the inner replica's exec time.
    pub fn straggler(inner: Arc<dyn EngineReplica>, factor: f64) -> Self {
        assert!(factor >= 1.0, "straggler factor must be >= 1");
        ChaosReplica { inner, mode: FaultMode::Straggle(factor), served: AtomicUsize::new(0) }
    }
}

impl EngineReplica for ChaosReplica {
    fn predict(&self, tokens: &[i32]) -> Result<Prediction, RequestError> {
        let n = self.served.fetch_add(1, Ordering::SeqCst);
        match self.mode {
            FaultMode::PanicAt(at) if n == at => {
                panic!("chaos: injected replica panic on request {n}")
            }
            FaultMode::PanicAt(_) => self.inner.predict(tokens),
            FaultMode::Straggle(factor) => {
                let t0 = Instant::now();
                let out = self.inner.predict(tokens);
                let extra = t0.elapsed().as_secs_f64() * (factor - 1.0);
                if extra > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(extra));
                }
                out
            }
        }
    }

    fn seq_len(&self) -> usize {
        self.inner.seq_len()
    }

    fn min_seq_len(&self) -> usize {
        self.inner.min_seq_len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panic_at_fires_exactly_once() {
        let r = ChaosReplica::panic_at(Arc::new(DelayReplica::from_ms(0)), 1);
        assert!(r.predict(&[1, 2]).is_ok());
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = r.predict(&[1, 2]);
        }));
        assert!(panicked.is_err(), "second request panics");
        assert!(r.predict(&[1, 2]).is_ok(), "healthy again after the fault");
    }

    #[test]
    fn straggler_multiplies_exec_time() {
        let inner = Arc::new(DelayReplica::from_ms(5));
        let straggler = ChaosReplica::straggler(Arc::clone(&inner) as Arc<dyn EngineReplica>, 4.0);
        let t0 = Instant::now();
        straggler.predict(&[1]).unwrap();
        // 5ms inner × 4 = 20ms; allow generous scheduler noise downward
        assert!(t0.elapsed() >= Duration::from_millis(14), "took {:?}", t0.elapsed());
    }
}
