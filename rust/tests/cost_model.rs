//! Property suite for the analytical cost model (`sim::cost`).
//!
//! The in-file unit tests cover the shipped presets; these properties
//! hammer the claim that makes `CostModel` safe to put behind every
//! scheduling decision (DESIGN.md §12): the closed form is **exact**
//! against `simulate_encoder_m(.., None)` at *every* length — including
//! randomly drawn geometries and hardware shapes the presets never
//! visit — while spending only a handful of anchor simulations to build.

use swifttron::model::Geometry;
use swifttron::sim::{simulate_encoder_m, CostModel, HwConfig};
use swifttron::util::rng::Rng;

/// Draw a random (geometry, hardware) pair the simulator accepts.
/// Head dim divides d exactly (the geometry invariant every preset
/// holds); everything else — array shape, unit counts, clock, pipeline
/// depth, both scheduling flags — is drawn freely from the valid range.
fn random_case(rng: &mut Rng) -> (Geometry, HwConfig) {
    let dh = [8usize, 16, 32][rng.below(3) as usize];
    let heads = 1 + rng.below(4) as usize;
    let d = dh * heads;
    let m = 8 + rng.below(33) as usize; // 8..=40: exhaustive check stays fast
    let d_ff = d * if rng.bool() { 4 } else { 2 };
    let layers = 1 + rng.below(3) as usize;
    let geo = Geometry::new(d, heads, m, d_ff, layers);
    let hw = HwConfig {
        array_rows: 1 + rng.below(m as u64) as usize,
        array_cols: 1 + rng.below(d as u64) as usize,
        parallel_heads: 1 + rng.below(heads as u64) as usize,
        softmax_units: 1 + rng.below(m as u64) as usize,
        layernorm_lanes: 1 + rng.below(d as u64) as usize,
        clock_ns: [5.0, 7.0, 10.0][rng.below(3) as usize],
        pipeline_stages: 1 + rng.below(4),
        worst_case_sqrt: rng.bool(),
        attn_heads_parallel: rng.bool(),
        weight_bits: if rng.bool() { 8 } else { 4 },
    };
    (geo, hw)
}

#[test]
fn exact_against_the_simulator_on_random_shapes() {
    let mut rng = Rng::new(0xC057);
    for case in 0..12 {
        let (geo, hw) = random_case(&mut rng);
        hw.validate(&geo).unwrap();
        let cm = CostModel::build(&hw, &geo)
            .unwrap_or_else(|e| panic!("case {case} {geo:?} {hw:?}: {e}"));
        for m in 1..=geo.m {
            assert_eq!(
                cm.predict_cycles(m),
                simulate_encoder_m(&hw, &geo, m, None).total_cycles,
                "case {case} m={m} {geo:?} {hw:?}"
            );
        }
        assert!(
            cm.anchor_sims() < 4 * cm.segments().len() + 4,
            "case {case}: {} anchor sims for {} segments",
            cm.anchor_sims(),
            cm.segments().len()
        );
    }
}

#[test]
fn exact_at_segment_boundaries_of_the_paper_instance() {
    // The paper configuration on its headline workload: check every
    // segment endpoint and midpoint — the lengths where a wrong cut or
    // slope would first show — without paying 256 full-stack sims.
    let geo = Geometry::preset("roberta_base").unwrap();
    let hw = HwConfig::paper();
    let cm = CostModel::build(&hw, &geo).unwrap();
    assert!(!cm.segments().is_empty());
    let mut covered = 0usize;
    for s in cm.segments() {
        for m in [s.lo, s.lo + (s.hi - s.lo) / 2, s.hi] {
            assert_eq!(
                cm.predict_cycles(m),
                simulate_encoder_m(&hw, &geo, m, None).total_cycles,
                "m={m} in segment {}..={}",
                s.lo,
                s.hi
            );
        }
        covered = covered.max(s.hi);
    }
    assert_eq!(covered, geo.m, "segments must tile 1..=geo.m");
}

#[test]
fn rebuilds_are_bit_identical() {
    let mut rng = Rng::new(0xDE7E_12);
    for _ in 0..4 {
        let (geo, hw) = random_case(&mut rng);
        let a = CostModel::build(&hw, &geo).unwrap();
        let b = CostModel::build(&hw, &geo).unwrap();
        assert_eq!(a.anchor_sims(), b.anchor_sims());
        assert_eq!(a.segments().len(), b.segments().len());
        for (s, t) in a.segments().iter().zip(b.segments()) {
            assert_eq!((s.lo, s.hi, s.g_lo, s.slope), (t.lo, t.hi, t.g_lo, t.slope));
        }
        for m in 1..=geo.m {
            assert_eq!(a.predict_cycles(m), b.predict_cycles(m), "m={m}");
            assert_eq!(a.predict_ms(m).to_bits(), b.predict_ms(m).to_bits(), "m={m}");
        }
    }
}

#[test]
fn predictions_clamp_and_grow_monotonically() {
    let mut rng = Rng::new(0x5EED);
    for _ in 0..6 {
        let (geo, hw) = random_case(&mut rng);
        let cm = CostModel::build(&hw, &geo).unwrap();
        assert_eq!(cm.predict_cycles(0), cm.predict_cycles(1), "below-range clamps to 1");
        assert_eq!(cm.predict_cycles(geo.m + 1000), cm.full_cycles(), "above-range clamps");
        let mut prev = 0u64;
        for m in 1..=geo.m {
            let c = cm.predict_cycles(m);
            assert!(c >= prev, "cycles shrank from {prev} to {c} at m={m}");
            assert!(c > 0);
            prev = c;
        }
    }
}

#[test]
fn layer_count_multiplies_the_per_layer_cost() {
    // FSM stacks are purely additive (each layer joins its
    // predecessor), so an L-layer model costs exactly L times its
    // 1-layer twin at every length — the identity `build` exploits.
    let mut rng = Rng::new(0x1A9E);
    for _ in 0..4 {
        let (geo, hw) = random_case(&mut rng);
        let one = Geometry { layers: 1, ..geo };
        let cm_l = CostModel::build(&hw, &geo).unwrap();
        let cm_1 = CostModel::build(&hw, &one).unwrap();
        for m in 1..=geo.m {
            assert_eq!(
                cm_l.predict_cycles(m),
                geo.layers as u64 * cm_1.predict_cycles(m),
                "m={m} layers={}",
                geo.layers
            );
        }
    }
}

#[test]
fn int4_anchors_are_exact_against_the_simulator_at_every_length() {
    // Per-precision anchors (DESIGN.md §14): the INT4 tier's CostModel
    // must stay *exact* against `simulate_encoder_m` under the halved
    // weight-feed phase and the doubled equal-area array, at every
    // length of every preset — DRR fairness, autoscaling, and mux
    // admission all price INT4 work through this model.
    for name in Geometry::PRESET_NAMES {
        let geo = Geometry::preset(name).unwrap();
        let hw4 = HwConfig::sized_to(&geo).int4_variant();
        let cm4 = CostModel::build(&hw4, &geo).unwrap();
        for m in 1..=geo.m {
            assert_eq!(
                cm4.predict_cycles(m),
                simulate_encoder_m(&hw4, &geo, m, None).total_cycles,
                "{name} m={m}"
            );
        }
    }
}

#[test]
fn int4_tier_undercuts_int8_for_every_preset() {
    // The cascade's economics: at equal silicon the INT4 instance must
    // be strictly cheaper than the INT8 instance it derives from, for
    // every preset, at full length and at short lengths where the
    // cascade bench operates.
    for name in Geometry::PRESET_NAMES {
        let geo = Geometry::preset(name).unwrap();
        let hw8 = HwConfig::sized_to(&geo);
        let cm8 = CostModel::build(&hw8, &geo).unwrap();
        let cm4 = CostModel::build(&hw8.int4_variant(), &geo).unwrap();
        assert!(
            cm4.full_cycles() < cm8.full_cycles(),
            "{name}: int4 full {} !< int8 full {}",
            cm4.full_cycles(),
            cm8.full_cycles()
        );
        for m in [1usize, 8, geo.m / 2, geo.m] {
            let (c4, c8) = (cm4.predict_cycles(m), cm8.predict_cycles(m));
            assert!(c4 < c8, "{name} m={m}: int4 {c4} !< int8 {c8}");
        }
    }
}

#[test]
fn milliseconds_are_cycles_times_the_clock() {
    let geo = Geometry::preset("small").unwrap();
    let hw = HwConfig::sized_to(&geo);
    let cm = CostModel::build(&hw, &geo).unwrap();
    for m in [1usize, 7, 32, geo.m] {
        let want = hw.cycles_to_ms(cm.predict_cycles(m));
        assert!((cm.predict_ms(m) - want).abs() < 1e-12, "m={m}");
        let via_rate = cm.predict_cycles(m) as f64 * cm.ms_per_cycle();
        assert!(
            (cm.predict_ms(m) - via_rate).abs() <= 1e-9 * via_rate.abs(),
            "ms_per_cycle prior disagrees with predict_ms at m={m}"
        );
    }
    assert_eq!(cm.full_cycles(), cm.predict_cycles(geo.m));
    assert!(cm.full_ms() > 0.0);
}
