"""Hypothesis property sweeps over shapes, dtypes, scales, and block sizes.

These complement the fixed-case tests: the kernel/oracle agreement and the
spec's algebraic invariants must hold for *arbitrary* legal inputs.
"""

import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import intops
from compile import kernels as K
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def matmul_case(draw):
    m = draw(st.integers(1, 24))
    k = draw(st.integers(1, 48))
    n = draw(st.integers(1, 24))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, (m, k)).astype(np.int8)
    w = rng.integers(-128, 128, (k, n)).astype(np.int8)
    bias = None
    if draw(st.booleans()):
        bias = rng.integers(-(2**16), 2**16, (n,)).astype(np.int32)
    return x, w, bias


@given(matmul_case())
@settings(**SETTINGS)
def test_matmul_any_shape(case):
    x, w, bias = case
    got = np.asarray(K.int_matmul(x, w, bias))
    assert np.array_equal(got, ref.np_i_matmul(x, w, bias))


@given(
    st.floats(1e-4, 10.0),
    st.integers(1, 16),
    st.integers(2, 64),
    st.integers(0, 2**31 - 1),
)
@settings(**SETTINGS)
def test_softmax_any_scale_shape(s_in, m, n, seed):
    c = intops.SoftmaxConsts.design(s_in)
    rng = np.random.default_rng(seed)
    lim = max(2, min(int(8.0 / s_in), 2**20))
    q = rng.integers(-lim, lim, (m, n)).astype(np.int32)
    got = np.asarray(K.i_softmax(q, c))
    want = ref.np_i_softmax(q, c)
    assert np.array_equal(got, want)
    # invariants: range, near-normalization, order preservation per row
    assert got.min() >= 0 and got.max() <= intops.SM_UNIT
    for r in range(m):
        order = np.argsort(q[r], kind="stable")
        sorted_out = got[r][order]
        assert np.all(np.diff(sorted_out) >= 0), "softmax must be monotone"


@given(st.floats(1e-3, 1.0), st.integers(1, 16), st.integers(1, 64), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_gelu_any_scale_shape(s_in, m, n, seed):
    c = intops.GeluConsts.design(s_in)
    rng = np.random.default_rng(seed)
    lim = max(2, min(int(6.0 / s_in), 2**18))
    q = rng.integers(-lim, lim, (m, n)).astype(np.int32)
    got = np.asarray(K.i_gelu(q, c))
    assert np.array_equal(got, ref.np_i_gelu(q, c))


@given(st.integers(2, 256), st.integers(1, 8), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_layernorm_any_shape(d, m, seed):
    c = intops.LayerNormConsts(s_in=0.01, s_gamma=0.01, d=d)
    rng = np.random.default_rng(seed)
    q = rng.integers(-3000, 3000, (m, d)).astype(np.int32)
    g = rng.integers(-127, 128, (d,)).astype(np.int32)
    b = rng.integers(-5000, 5000, (d,)).astype(np.int32)
    got = np.asarray(K.i_layernorm(q, g, b, c))
    assert np.array_equal(got, ref.np_i_layernorm(q, g, b, c))


@given(st.integers(0, 2**62), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_isqrt_floor_contract(n, _seed):
    got, iters = ref.np_i_sqrt_scalar(n)
    assert got >= 0 and got * got <= n < (got + 1) * (got + 1)
    assert iters <= intops.ISQRT_MAX_ITERS


@given(st.floats(1e-5, 1e4), st.integers(0, 2**31 - 1))
@settings(**SETTINGS)
def test_dyadic_always_close(x, seed):
    dy = intops.Dyadic.approximate(x)
    assert dy.b >= 1 and 0 <= dy.c <= 30
    assert abs(dy.value() - x) / x < 2**-13


@given(st.integers(-(2**26), 2**26), st.floats(1e-3, 100.0))
@settings(**SETTINGS)
def test_requant_scalar_consistency(v, ratio):
    """requantize == floor(v * DN(ratio)) clamped, for any single value."""
    dy = intops.Dyadic.approximate(ratio)
    q = np.array([[v]], dtype=np.int32)
    got = int(np.asarray(K.requantize(q, dy))[0, 0])
    want = min(max((v * dy.b) >> dy.c, -128), 127)
    assert got == want
