//! SwiftTron CLI: simulate | synth | compare | infer | serve | tune | report.

use std::process::exit;
use std::sync::Arc;
use swifttron::baselines::{comparison_table, fp32_asic_report, gpu_inference_ms, GpuModel};
use swifttron::coordinator::{
    AutoscalePolicy, BatchPolicy, EngineReplica, FunctionalEngine, InferenceEngine, Metrics,
    ModelGroup, ModelRegistry, Router, DEFAULT_ESCALATE_MARGIN,
};
use swifttron::model::{Geometry, Manifest};
use swifttron::runtime::Engine;
use swifttron::sim::{simulate_encoder, HwConfig};
use swifttron::synthesis::{explore, synthesis_report, Budget};
use swifttron::util::cli::Args;
use swifttron::wire::MuxConfig;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, rest) = match argv.split_first() {
        Some((c, r)) => (c.as_str(), r.to_vec()),
        None => {
            eprintln!("{}", usage());
            exit(2);
        }
    };
    let result = match cmd {
        "simulate" => cmd_simulate(&rest),
        "synth" => cmd_synth(&rest),
        "compare" => cmd_compare(&rest),
        "infer" => cmd_infer(&rest),
        "serve" => cmd_serve(&rest),
        "tune" => cmd_tune(&rest),
        "report" => cmd_report(&rest),
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(format!("unknown command {other:?}\n{}", usage())),
    };
    if let Err(e) = result {
        eprintln!("{e}");
        exit(1);
    }
}

fn usage() -> String {
    "swifttron — integer-only Transformer accelerator (paper reproduction)\n\n\
     commands:\n\
     \x20 simulate --model <preset>        cycle-accurate latency\n\
     \x20 synth    --model <preset>        65 nm synthesis report (Table I / Fig 18)\n\
     \x20 compare                          Table III feature matrix + GPU/FP32 baselines\n\
     \x20 infer    --tokens 1,2,3,...      one tiny-task inference via PJRT\n\
     \x20 serve    --addr 127.0.0.1:7077   TCP serving front-end\n\
     \x20          [--replicas N --max-batch B --engine pjrt|functional]\n\
     \x20          [--models name=preset[@int4][:min-max[:weight[:slo_ms]]],...]  multi-tenant\n\
     \x20          (replicas as N pins the group; MIN-MAX + slo_ms enables the\n\
     \x20           SLO autoscaler; request lines may carry a model prefix:\n\
     \x20           \"tiny:3,17,42\"; preset@int4 registers the tenant as a\n\
     \x20           confidence-gated INT4/INT8 cascade pair)\n\
     \x20          [--escalate-margin M]  cascade threshold on the top-1 logit\n\
     \x20          gap: @int4 tenants escalate answers below it to INT8\n\
     \x20          [--front mux|threads --max-conns N]  front door + connection cap\n\
     \x20          (mux = non-blocking SWWIRE1 binary multiplexer with text\n\
     \x20           auto-detection and SLO load shedding; threads = legacy\n\
     \x20           thread-per-connection text server)\n\
     \x20          [--cores N]  global executor core budget shared by every\n\
     \x20          model group (default: sum of group max replicas; smaller\n\
     \x20          values let many tenants oversubscribe safely)\n\
     \x20 tune     [--model <preset>]       design-space autotuner: search HwConfig\n\
     \x20          [--area MM2 --power W]   candidates under an area/power budget\n\
     \x20          (latency from the analytical CostModel, cost from the\n\
     \x20           synthesis layer; prints the Pareto front + recommendation;\n\
     \x20           omit --model to sweep every preset)\n\
     \x20 report                           full paper reproduction summary\n"
        .into()
}

fn geometry(name: &str) -> Result<Geometry, String> {
    Geometry::preset(name).ok_or_else(|| format!("unknown preset {name:?}"))
}

fn cmd_simulate(rest: &[String]) -> Result<(), String> {
    let p = Args::new("swifttron simulate", "cycle-accurate latency")
        .opt("model", "roberta_base", "geometry preset")
        .parse(rest)?;
    let geo = geometry(p.get("model"))?;
    let cfg = HwConfig::paper();
    cfg.validate(&geo)?;
    let r = simulate_encoder(&cfg, &geo);
    println!(
        "{}: {} cycles at {:.0} MHz = {:.3} ms",
        p.get("model"),
        r.total_cycles,
        cfg.clock_mhz(),
        r.ms(&cfg)
    );
    for (k, v) in &r.per_block {
        println!("  {k:12} {v:>12} busy unit-cycles");
    }
    Ok(())
}

fn cmd_synth(rest: &[String]) -> Result<(), String> {
    let p = Args::new("swifttron synth", "synthesis report")
        .opt("model", "roberta_base", "geometry preset")
        .parse(rest)?;
    let geo = geometry(p.get("model"))?;
    let r = synthesis_report(&HwConfig::paper(), &geo);
    println!("{}", r.table1());
    println!(
        "\n{:12} {:>10} {:>8} {:>10} {:>8}",
        "component", "area mm^2", "area %", "power W", "power %"
    );
    for c in &r.components {
        println!(
            "{:12} {:>10.2} {:>7.1}% {:>10.3} {:>7.1}%",
            c.name, c.area_mm2, r.area_pct[c.name], c.power_w, r.power_pct[c.name]
        );
    }
    Ok(())
}

fn cmd_compare(_rest: &[String]) -> Result<(), String> {
    println!("Table III — feature comparison:");
    for w in comparison_table() {
        println!(
            "  {:24} hw_ok={} int8={} complete={} nonlinear_ok={}  => all={}",
            w.name,
            w.hw_ok(),
            w.bitwidth_ok,
            w.complete_architecture,
            w.nonlinear_ok(),
            w.all_features()
        );
    }
    let cfg = HwConfig::paper();
    let gpu = GpuModel::rtx_2080_ti();
    println!("\nGPU baseline (RTX 2080 Ti roofline model):");
    for name in ["roberta_base", "roberta_large", "deit_s"] {
        let geo = geometry(name)?;
        let acc = simulate_encoder(&cfg, &geo).ms(&cfg);
        let g = gpu_inference_ms(&gpu, &geo);
        println!(
            "  {name:15} accel {acc:8.3} ms   gpu {g:8.3} ms   speedup {:.2}x",
            g / acc
        );
    }
    let fp = fp32_asic_report(&cfg, &geometry("roberta_base")?);
    println!(
        "\nFP32-datapath twin: area x{:.1}, power x{:.1}, latency x{:.1} (Fig. 2 at system level)",
        fp.area_ratio, fp.power_ratio, fp.latency_ratio
    );
    Ok(())
}

fn engine_from_artifacts() -> Result<InferenceEngine, String> {
    let dir = Manifest::default_dir();
    let engine = Engine::cpu()?;
    InferenceEngine::load(&dir, &engine, HwConfig::paper())
}

fn cmd_infer(rest: &[String]) -> Result<(), String> {
    let p = Args::new("swifttron infer", "single tiny-task inference")
        .opt("tokens", "", "comma-separated token ids (default: random)")
        .opt("seed", "7", "rng seed for random tokens")
        .parse(rest)?;
    let eng = engine_from_artifacts()?;
    let tokens: Vec<i32> = if p.get("tokens").is_empty() {
        let mut rng = swifttron::util::rng::Rng::new(p.get_u64("seed")?);
        (0..eng.geo.m).map(|_| rng.below(63) as i32).collect()
    } else {
        let (model, tokens) = swifttron::coordinator::server::parse_tokens(p.get("tokens"))?;
        if model.is_some() {
            return Err("infer takes bare token ids; model prefixes are for serve".into());
        }
        tokens
    };
    let pred = eng.predict(&tokens)?;
    println!(
        "label={} logits={:?} accel={:.3} ms ({} cycles)",
        pred.label, pred.logits, pred.accel_ms, pred.accel_cycles
    );
    Ok(())
}

/// One parsed `--models` entry.
struct ModelSpec {
    name: String,
    preset: String,
    min_replicas: usize,
    max_replicas: usize,
    weight: u64,
    slo_ms: Option<f64>,
    /// `preset@int4`: register the tenant as an INT4/INT8 cascade pair
    /// (DESIGN.md §14) instead of a single INT8 group
    int4: bool,
}

/// Parse one `--models` entry: `name=preset[:min-max[:weight[:slo_ms]]]`.
/// The replica field accepts a plain `N` (fixed group, the PR 4 form)
/// or a `MIN-MAX` range the SLO autoscaler moves within; `slo_ms` is
/// the model's target latency class in milliseconds.  A `@int4` suffix
/// on the preset (`name=preset@int4:...`) registers the tenant as a
/// confidence-gated INT4/INT8 cascade pair.
fn parse_model_spec(part: &str) -> Result<ModelSpec, String> {
    let bad = || {
        format!("bad model spec {part:?} (want name=preset[@int4][:min-max[:weight[:slo_ms]]])")
    };
    let (name, rest) = part.split_once('=').ok_or_else(bad)?;
    let mut it = rest.split(':');
    let mut preset = it.next().ok_or_else(bad)?.trim().to_string();
    let int4 = match preset.strip_suffix("@int4") {
        Some(base) => {
            preset = base.trim().to_string();
            true
        }
        None => false,
    };
    let (min_replicas, max_replicas) = match it.next() {
        Some(s) => match s.trim().split_once('-') {
            Some((lo, hi)) => (
                lo.trim().parse::<usize>().map_err(|_| bad())?,
                hi.trim().parse::<usize>().map_err(|_| bad())?,
            ),
            None => {
                let n = s.trim().parse::<usize>().map_err(|_| bad())?;
                (n, n)
            }
        },
        None => (1, 1),
    };
    let weight = match it.next() {
        Some(s) => s.trim().parse::<u64>().map_err(|_| bad())?,
        None => 1,
    };
    let slo_ms = match it.next() {
        Some(s) => Some(s.trim().parse::<f64>().map_err(|_| bad())?),
        None => None,
    };
    if it.next().is_some() {
        return Err(bad());
    }
    Ok(ModelSpec {
        name: name.trim().to_string(),
        preset,
        min_replicas,
        max_replicas,
        weight,
        slo_ms,
        int4,
    })
}

fn cmd_serve(rest: &[String]) -> Result<(), String> {
    let p = Args::new("swifttron serve", "TCP serving front-end")
        .opt("addr", "127.0.0.1:7077", "listen address")
        .opt("replicas", "2", "engine replicas (simulated accelerators)")
        .opt("max-batch", "8", "dispatch group size")
        .opt("engine", "pjrt", "replica backend: pjrt | functional")
        .opt(
            "models",
            "",
            "multi-tenant spec name=preset[@int4][:min-max[:weight[:slo_ms]]],... \
             (functional backend; @int4 = confidence-gated INT4/INT8 cascade pair)",
        )
        .opt(
            "escalate-margin",
            "",
            "cascade confidence threshold on the top-1 logit gap: @int4 tenants \
             escalate lower-margin answers to their INT8 tier (default: tuned \
             on the synthetic workload)",
        )
        .opt("front", "threads", "front door: mux (SWWIRE1 binary multiplexer) | threads")
        .opt("max-conns", "1024", "concurrent-connection cap (typed busy rejection past it)")
        .opt("cores", "", "global executor core budget (default: sum of group max replicas)")
        .parse(rest)?;
    let front = p.get("front").to_string();
    let max_conns = p.get_usize("max-conns")?;
    let cores = if p.get("cores").is_empty() {
        None
    } else {
        let n = p.get_usize("cores")?;
        if n == 0 {
            return Err("--cores must be positive".into());
        }
        Some(n)
    };
    let metrics = Arc::new(Metrics::new());
    let policy = BatchPolicy { max_batch: p.get_usize("max-batch")?, ..Default::default() };

    // Multi-tenant path: a registry of named functional models with
    // per-model replica groups and fair-share weights (DESIGN.md §8).
    // PJRT replicas stay single-model (one AOT artifact per process).
    if !p.get("models").is_empty() {
        if p.get("engine") == "pjrt" {
            return Err(
                "--models drives the functional backend; PJRT replicas stay single-model \
                 (pass --engine functional)"
                    .into(),
            );
        }
        let escalate_margin = if p.get("escalate-margin").is_empty() {
            DEFAULT_ESCALATE_MARGIN
        } else {
            let m = p
                .get("escalate-margin")
                .parse::<i64>()
                .map_err(|_| "--escalate-margin must be an integer".to_string())?;
            if m < 0 {
                return Err("--escalate-margin must be non-negative".into());
            }
            m
        };
        let mut reg = ModelRegistry::new();
        for part in p.get("models").split(',') {
            let spec = parse_model_spec(part.trim())?;
            if spec.int4 {
                reg.register_cascade_scaled(
                    &spec.name,
                    &spec.preset,
                    spec.min_replicas,
                    spec.max_replicas,
                    spec.weight,
                    spec.slo_ms,
                    7,
                    escalate_margin,
                )?;
            } else {
                reg.register_scaled(
                    &spec.name,
                    &spec.preset,
                    spec.min_replicas,
                    spec.max_replicas,
                    spec.weight,
                    spec.slo_ms,
                    7,
                )?;
            }
        }
        let router = Arc::new(Router::start_multi_cores(
            reg.into_groups(),
            policy,
            AutoscalePolicy::default(),
            metrics,
            cores,
        ));
        return front_serve(router, p.get("addr"), &front, max_conns);
    }

    let replicas = p.get_usize("replicas")?;
    let engines: Vec<Arc<dyn EngineReplica>> = match p.get("engine") {
        // artifact-free synthetic-weight replicas (no PJRT needed)
        "functional" => (0..replicas)
            .map(|_| {
                FunctionalEngine::synthetic("tiny", 7, HwConfig::paper())
                    .map(|e| Arc::new(e) as Arc<dyn EngineReplica>)
            })
            .collect::<Result<_, _>>()?,
        "pjrt" => {
            let dir = Manifest::default_dir();
            let engine = Engine::cpu()?;
            (0..replicas)
                .map(|_| {
                    InferenceEngine::load(&dir, &engine, HwConfig::paper())
                        .map(|e| Arc::new(e) as Arc<dyn EngineReplica>)
                })
                .collect::<Result<_, _>>()?
        }
        other => return Err(format!("unknown engine {other:?} (expected pjrt | functional)")),
    };
    let router = Arc::new(Router::start_multi_cores(
        vec![ModelGroup::fixed("default", engines, 1)],
        policy,
        AutoscalePolicy::default(),
        metrics,
        cores,
    ));
    front_serve(router, p.get("addr"), &front, max_conns)
}

/// Hand the router to the selected front door (DESIGN.md §11): the
/// non-blocking binary multiplexer (which auto-detects legacy text
/// clients) or the legacy thread-per-connection text server.
fn front_serve(
    router: Arc<Router>,
    addr: &str,
    front: &str,
    max_conns: usize,
) -> Result<(), String> {
    match front {
        "mux" => swifttron::wire::mux::serve_mux(
            router,
            addr,
            MuxConfig { max_conns, ..MuxConfig::default() },
        ),
        "threads" => swifttron::coordinator::server::serve_with(router, addr, max_conns),
        other => Err(format!("unknown front {other:?} (expected mux | threads)")),
    }
}

/// Design-space autotuner (DESIGN.md §12): sweep `HwConfig` candidates
/// for one preset (or all of them) under an area/power budget and print
/// each space's Pareto front size and recommended instance.
fn cmd_tune(rest: &[String]) -> Result<(), String> {
    let p = Args::new("swifttron tune", "design-space autotuner")
        .opt("model", "", "geometry preset (default: sweep every preset)")
        .opt("area", "300", "max area budget in mm^2")
        .opt("power", "35", "max power budget in W")
        .parse(rest)?;
    let budget = Budget { max_area_mm2: p.get_f64("area")?, max_power_w: p.get_f64("power")? };
    if budget.max_area_mm2 <= 0.0 || budget.max_power_w <= 0.0 {
        return Err("--area and --power must be positive".into());
    }
    let presets: Vec<&str> = if p.get("model").is_empty() {
        Geometry::PRESET_NAMES.to_vec()
    } else {
        vec![p.get("model")]
    };
    for (i, name) in presets.iter().enumerate() {
        if i > 0 {
            println!();
        }
        let ds = explore(name, budget)?;
        print!("{}", ds.summary());
    }
    Ok(())
}

fn cmd_report(_rest: &[String]) -> Result<(), String> {
    cmd_synth(&[])?;
    println!();
    cmd_compare(&[])
}

#[cfg(test)]
mod tests {
    use super::parse_model_spec;

    #[test]
    fn model_spec_parses_fixed_and_ranged_forms() {
        // bare preset: one pinned replica, weight 1, no SLO
        let s = parse_model_spec("tiny=tiny").unwrap();
        assert_eq!((s.min_replicas, s.max_replicas, s.weight, s.slo_ms), (1, 1, 1, None));
        // the PR 4 fixed form still parses
        let s = parse_model_spec("a=roberta_base:3:2").unwrap();
        assert_eq!(s.preset, "roberta_base");
        assert_eq!((s.min_replicas, s.max_replicas, s.weight, s.slo_ms), (3, 3, 2, None));
        // the autoscaled form: min-max range + SLO class
        let s = parse_model_spec(" big = roberta_base : 1-4 : 2 : 25.5 ").unwrap();
        assert_eq!(s.name, "big");
        assert_eq!((s.min_replicas, s.max_replicas, s.weight), (1, 4, 2));
        assert_eq!(s.slo_ms, Some(25.5));
        assert!(!s.int4, "no @int4 suffix: plain INT8 group");
    }

    #[test]
    fn model_spec_parses_int4_cascade_suffix() {
        let s = parse_model_spec("t=tiny@int4").unwrap();
        assert_eq!(s.preset, "tiny");
        assert!(s.int4);
        assert_eq!((s.min_replicas, s.max_replicas), (1, 1));
        // suffix composes with the ranged + SLO form
        let s = parse_model_spec("big=roberta_base@int4:1-4:2:25.5").unwrap();
        assert_eq!(s.preset, "roberta_base");
        assert!(s.int4);
        assert_eq!((s.min_replicas, s.max_replicas, s.weight), (1, 4, 2));
        assert_eq!(s.slo_ms, Some(25.5));
    }

    #[test]
    fn model_spec_rejects_malformed_entries() {
        assert!(parse_model_spec("noequals").is_err());
        assert!(parse_model_spec("a=p:x").is_err(), "non-numeric replicas");
        assert!(parse_model_spec("a=p:1-x").is_err(), "non-numeric max");
        assert!(parse_model_spec("a=p:1:2:bad").is_err(), "non-numeric slo");
        assert!(parse_model_spec("a=p:1:2:3:4").is_err(), "trailing field");
    }
}
