//! Synthesis report: the paper's Table I summary plus the Fig. 18
//! area/power breakdown, generated from the cost model + simulator.

use super::components::{component_breakdown, percentages, totals, ComponentCost};
use super::operators::Operators;
use super::tech::Tech65;
use crate::model::Geometry;
use crate::sim::{simulate_encoder, HwConfig};
use std::collections::BTreeMap;

#[derive(Clone, Debug)]
pub struct SynthesisReport {
    pub clock_mhz: f64,
    pub tech_node: &'static str,
    pub area_mm2: f64,
    pub power_w: f64,
    pub components: Vec<ComponentCost>,
    pub area_pct: BTreeMap<&'static str, f64>,
    pub power_pct: BTreeMap<&'static str, f64>,
    /// achievable clock period from the slowest operator path (ns)
    pub critical_path_ns: f64,
}

/// Run the full "synthesis" of a SwiftTron instance for a workload
/// geometry (Table I is the paper configuration + roberta_base).
pub fn synthesis_report(cfg: &HwConfig, geo: &Geometry) -> SynthesisReport {
    let t = Tech65::new();
    let sim = simulate_encoder(cfg, geo);
    let components = component_breakdown(&t, cfg, geo, &sim);
    let (area, power) = totals(&components);
    let (area_pct, power_pct) = percentages(&components);

    // critical path: the MAC (multiply + accumulate) or the LayerNorm
    // divider stage, whichever is slower — the paper pipelines Softmax
    // and LayerNorm into 3 stages to meet 7 ns (§IV-B); we model the
    // pipelined stage as 1/3 of the un-pipelined nonlinear path.
    let mac_path = Operators::int8_mac().delay_ns(&t);
    let nonlinear_path = Operators::array_divider(64)
        .delay_ns(&t)
        .max(Operators::int_multiplier(32, 32).delay_ns(&t));
    let critical = mac_path.max(nonlinear_path);

    SynthesisReport {
        clock_mhz: cfg.clock_mhz(),
        tech_node: "65 nm",
        area_mm2: area,
        power_w: power,
        components,
        area_pct,
        power_pct,
        critical_path_ns: critical,
    }
}

impl SynthesisReport {
    /// Render the paper's Table I.
    pub fn table1(&self) -> String {
        format!(
            "Clock Frequency  {:.0} MHz | Technology Node {} \n\
             Power Consumption {:.2} W | Area {:.1} mm^2\n\
             (critical path {:.2} ns)",
            self.clock_mhz, self.tech_node, self.power_w, self.area_mm2, self.critical_path_ns
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_meets_its_own_clock() {
        let r = synthesis_report(&HwConfig::paper(), &Geometry::preset("roberta_base").unwrap());
        assert!(
            r.critical_path_ns <= 7.0,
            "critical path {} ns exceeds the 7 ns clock",
            r.critical_path_ns
        );
    }

    #[test]
    fn report_is_self_consistent() {
        let r = synthesis_report(&HwConfig::paper(), &Geometry::preset("roberta_base").unwrap());
        let sum: f64 = r.area_pct.values().sum();
        assert!((sum - 100.0).abs() < 1e-6);
        let sum: f64 = r.power_pct.values().sum();
        assert!((sum - 100.0).abs() < 1e-6);
        assert!(r.power_w > 0.0 && r.area_mm2 > 0.0);
    }

    #[test]
    fn edge_config_is_smaller_and_cooler() {
        let geo = Geometry::preset("roberta_base").unwrap();
        let paper = synthesis_report(&HwConfig::paper(), &geo);
        let edge = synthesis_report(&HwConfig::edge(), &geo);
        assert!(edge.area_mm2 < paper.area_mm2);
        assert!(edge.power_w < paper.power_w);
    }
}
