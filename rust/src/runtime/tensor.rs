//! Minimal host-side tensor: shape + flat storage, convertible to/from
//! `xla::Literal` at the PJRT boundary.  INT8-coded values travel as i32
//! (the `xla` crate's `NativeType` set has no i8).

// Resolved through the in-repo stub so `--features pjrt` compiles
// without the vendored checkout (see runtime::xla_stub).
#[cfg(feature = "pjrt")]
use crate::runtime::xla_stub as xla;

#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    I32 { shape: Vec<usize>, data: Vec<i32> },
    F32 { shape: Vec<usize>, data: Vec<f32> },
}

impl Tensor {
    pub fn i32(shape: &[usize], data: Vec<i32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::I32 { shape: shape.to_vec(), data }
    }

    pub fn f32(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor::F32 { shape: shape.to_vec(), data }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::I32 { shape, .. } | Tensor::F32 { shape, .. } => shape,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::I32 { data, .. } => data.len(),
            Tensor::F32 { data, .. } => data.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Tensor::I32 { data, .. } => Some(data),
            _ => None,
        }
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Tensor::F32 { data, .. } => Some(data),
            _ => None,
        }
    }

    /// Build the device literal (reshaped to this tensor's shape).
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal, String> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::I32 { data, .. } => xla::Literal::vec1(data),
            Tensor::F32 { data, .. } => xla::Literal::vec1(data),
        };
        lit.reshape(&dims).map_err(|e| format!("reshape: {e}"))
    }

    /// Read back a literal of known element type.
    #[cfg(feature = "pjrt")]
    pub fn from_literal_i32(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor, String> {
        let data = lit.to_vec::<i32>().map_err(|e| format!("to_vec<i32>: {e}"))?;
        Ok(Tensor::i32(shape, data))
    }

    #[cfg(feature = "pjrt")]
    pub fn from_literal_f32(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor, String> {
        let data = lit.to_vec::<f32>().map_err(|e| format!("to_vec<f32>: {e}"))?;
        Ok(Tensor::f32(shape, data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_product_enforced() {
        let t = Tensor::i32(&[2, 3], vec![1, 2, 3, 4, 5, 6]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.len(), 6);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn mismatch_panics() {
        Tensor::f32(&[2, 2], vec![1.0]);
    }

    #[test]
    fn accessors_typed() {
        let t = Tensor::i32(&[1], vec![7]);
        assert!(t.as_i32().is_some());
        assert!(t.as_f32().is_none());
    }
}
