//! Model registry: the multi-tenant front door of the serving stack
//! (DESIGN.md §8).
//!
//! A [`ModelRegistry`] maps *model ids* (tenant-facing names) to
//! [`Geometry`] presets and owns one replica group per model — a set of
//! identical [`FunctionalEngine`] replicas sharing a single
//! [`SyntheticModel`](super::engine::SyntheticModel) weight bundle,
//! each replica sized by its own [`HwConfig::sized_to`] hardware
//! instance (the paper's §III-D design-time tunables: array rows = m,
//! columns = d, one head unit per model head).  The finished registry
//! converts into the [`ModelGroup`] list that
//! [`Router::start_multi`](super::Router::start_multi) serves, with
//! each group's fair-share `weight` feeding the batcher's deficit
//! round-robin dispatcher.
//!
//! PJRT-backed [`InferenceEngine`](super::InferenceEngine) replicas
//! stay single-model (one AOT artifact per process); heterogeneous
//! custom backends can still join a registry through
//! [`ModelRegistry::register_group`].

use super::engine::{EngineReplica, FunctionalEngine};
use crate::model::Geometry;
use crate::sim::HwConfig;
use std::sync::Arc;

/// One model's serving group, ready for the router: the tenant-facing
/// name, its (identical) replicas, and its fair-share weight.
pub struct ModelGroup {
    pub model: String,
    pub replicas: Vec<Arc<dyn EngineReplica>>,
    pub weight: u64,
}

struct Entry {
    name: String,
    preset: Option<String>,
    geometry: Option<Geometry>,
    weight: u64,
    replicas: Vec<Arc<dyn EngineReplica>>,
}

/// Registry of resident models, built once at startup and converted
/// into router groups.  Model ids are unique; registration order is the
/// model-index order used by the batcher and metrics ledgers.
#[derive(Default)]
pub struct ModelRegistry {
    entries: Vec<Entry>,
}

impl ModelRegistry {
    pub fn new() -> ModelRegistry {
        ModelRegistry::default()
    }

    fn check(&self, name: &str, replicas: usize, weight: u64) -> Result<(), String> {
        if name.is_empty() {
            return Err("model id must be non-empty".into());
        }
        if self.entries.iter().any(|e| e.name == name) {
            return Err(format!("model {name:?} already registered"));
        }
        if replicas == 0 {
            return Err(format!("model {name:?} needs at least one replica"));
        }
        if weight == 0 {
            return Err(format!("model {name:?} needs a positive fair-share weight"));
        }
        Ok(())
    }

    /// Register `replicas` identical synthetic replicas of a geometry
    /// preset under `name`, with fair-share `weight`.  The hardware
    /// instance is sized to the preset ([`HwConfig::sized_to`]); the
    /// weight bundle is generated once from `seed` and shared across
    /// the group's replicas.
    pub fn register(
        &mut self,
        name: &str,
        preset: &str,
        replicas: usize,
        weight: u64,
        seed: u64,
    ) -> Result<&mut Self, String> {
        let geo = Geometry::preset(preset).ok_or_else(|| {
            format!("unknown preset {preset:?} (expected one of {:?})", Geometry::PRESET_NAMES)
        })?;
        self.register_with_hw(name, preset, replicas, weight, seed, HwConfig::sized_to(&geo))
    }

    /// [`register`](ModelRegistry::register) with an explicit hardware
    /// configuration (benchmarks and tests pin the instance).
    pub fn register_with_hw(
        &mut self,
        name: &str,
        preset: &str,
        replicas: usize,
        weight: u64,
        seed: u64,
        hw: HwConfig,
    ) -> Result<&mut Self, String> {
        self.check(name, replicas, weight)?;
        let geo = Geometry::preset(preset).ok_or_else(|| {
            format!("unknown preset {preset:?} (expected one of {:?})", Geometry::PRESET_NAMES)
        })?;
        hw.validate(&geo)?;
        let group = FunctionalEngine::replica_group(preset, seed, hw, replicas)?;
        self.entries.push(Entry {
            name: name.to_string(),
            preset: Some(preset.to_string()),
            geometry: Some(geo),
            weight,
            replicas: group,
        });
        Ok(self)
    }

    /// Register a custom replica group (mock engines, or a single-model
    /// PJRT group).  All replicas must serve the same model; the
    /// registry has no preset geometry for such a group.
    pub fn register_group(
        &mut self,
        name: &str,
        replicas: Vec<Arc<dyn EngineReplica>>,
        weight: u64,
    ) -> Result<&mut Self, String> {
        self.check(name, replicas.len(), weight)?;
        self.entries.push(Entry {
            name: name.to_string(),
            preset: None,
            geometry: None,
            weight,
            replicas,
        });
        Ok(self)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Registered model ids, in model-index order.
    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|e| e.name.as_str()).collect()
    }

    /// Geometry preset backing `name` (None for custom groups or
    /// unknown ids).
    pub fn geometry(&self, name: &str) -> Option<Geometry> {
        self.entries.iter().find(|e| e.name == name).and_then(|e| e.geometry)
    }

    /// Preset name backing `name`.
    pub fn preset(&self, name: &str) -> Option<&str> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .and_then(|e| e.preset.as_deref())
    }

    /// Fair-share weight of `name`.
    pub fn weight(&self, name: &str) -> Option<u64> {
        self.entries.iter().find(|e| e.name == name).map(|e| e.weight)
    }

    /// Longest request `name`'s group can serve (the intersection of
    /// its replicas' ranges).
    pub fn max_seq_len(&self, name: &str) -> Option<usize> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .and_then(|e| e.replicas.iter().map(|r| r.seq_len()).min())
    }

    /// Consume the registry into router-ready model groups.
    pub fn into_groups(self) -> Vec<ModelGroup> {
        self.entries
            .into_iter()
            .map(|e| ModelGroup { model: e.name, replicas: e.replicas, weight: e.weight })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registers_presets_with_shared_groups() {
        let mut reg = ModelRegistry::new();
        reg.register("tiny", "tiny", 2, 2, 7).unwrap();
        reg.register("small", "small", 1, 1, 11).unwrap();
        assert_eq!(reg.len(), 2);
        assert_eq!(reg.names(), vec!["tiny", "small"]);
        assert_eq!(reg.geometry("tiny"), Geometry::preset("tiny"));
        assert_eq!(reg.preset("small"), Some("small"));
        assert_eq!(reg.weight("tiny"), Some(2));
        assert_eq!(reg.max_seq_len("small"), Some(Geometry::preset("small").unwrap().m));
        assert_eq!(reg.geometry("nope"), None);
        let groups = reg.into_groups();
        assert_eq!(groups[0].replicas.len(), 2);
        assert_eq!(groups[1].model, "small");
    }

    #[test]
    fn rejects_bad_configurations() {
        let mut reg = ModelRegistry::new();
        reg.register("tiny", "tiny", 1, 1, 7).unwrap();
        assert!(reg.register("tiny", "small", 1, 1, 7).is_err(), "duplicate id");
        assert!(reg.register("x", "gpt5", 1, 1, 7).is_err(), "unknown preset");
        assert!(reg.register("y", "tiny", 0, 1, 7).is_err(), "zero replicas");
        assert!(reg.register("z", "tiny", 1, 0, 7).is_err(), "zero weight");
        assert!(reg.register("", "tiny", 1, 1, 7).is_err(), "empty id");
        assert!(reg.register_group("g", vec![], 1).is_err(), "empty custom group");
        assert_eq!(reg.len(), 1, "failed registrations leave no residue");
    }
}
