"""Pallas integer LayerNorm kernel (paper Fig. 15).

Three phases per row: integer mean, integer variance + iterative square
root (Babylonian, fixed trip count with frozen lanes so it lowers to
static HLO), divider + affine.  Row-panel blocking like the Softmax unit;
gamma/beta stream in as INT8/INT32 operands.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from ..intops import ISQRT_MAX_ITERS, LN_P, LayerNormConsts


def _bit_length(n):
    bl = jnp.zeros_like(n)
    for shift in (32, 16, 8, 4, 2, 1):
        big = n >= (jnp.int64(1) << shift)
        bl = jnp.where(big, bl + shift, bl)
        n = jnp.where(big, n >> shift, n)
    return bl + jnp.where(n > 0, 1, 0)


def _i_sqrt(n):
    x0 = jnp.int64(1) << ((_bit_length(n) + 1) >> 1)
    x0 = jnp.maximum(x0, 1)

    def body(_, state):
        x, done = state
        x1 = (x + n // x) >> 1
        stop = x1 >= x
        return jnp.where(done | stop, x, x1), done | stop

    x, _ = lax.fori_loop(0, ISQRT_MAX_ITERS, body, (x0, jnp.zeros_like(n, dtype=bool)))
    return jnp.where(n == 0, jnp.int64(0), x)


def _layernorm_kernel(q_ref, g_ref, b_ref, o_ref, *, d: int):
    q = q_ref[...].astype(jnp.int64)
    # Phase 1: mean.
    mean = jnp.sum(q, axis=-1, keepdims=True) // jnp.int64(d)
    y = q - mean
    # Phase 2: variance + iterative sqrt.
    var = jnp.sum(y * y, axis=-1, keepdims=True) // jnp.int64(d)
    std = jnp.maximum(_i_sqrt(var), 1)
    # Phase 3: divider + affine.
    qn = (y << LN_P) // std
    out = qn * g_ref[...].astype(jnp.int64) + b_ref[...].astype(jnp.int64)
    o_ref[...] = jnp.clip(out, -(2**31), 2**31 - 1).astype(jnp.int32)


def _pick_block(dim: int, preferred: int) -> int:
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("consts", "bm"))
def i_layernorm(q, q_gamma, q_beta, consts: LayerNormConsts, *, bm: int = 128):
    """Integer LayerNorm over the last axis of an INT32 (m, d) tensor."""
    m, d = q.shape
    assert d == consts.d
    bm = _pick_block(m, bm)
    row_spec = pl.BlockSpec((bm, d), lambda i: (i, 0))
    vec_spec = pl.BlockSpec((1, d), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_layernorm_kernel, d=d),
        grid=(m // bm,),
        in_specs=[row_spec, vec_spec, vec_spec],
        out_specs=row_spec,
        out_shape=jax.ShapeDtypeStruct((m, d), jnp.int32),
        interpret=True,
    )(q, q_gamma.reshape(1, d).astype(jnp.int32), q_beta.reshape(1, d).astype(jnp.int32))
