//! Table II — accuracy, latency, and GPU speedup per model.
//!
//! Latency: cycle-accurate simulator at the paper clock.  GPU: RTX 2080
//! Ti roofline model (DESIGN.md §5).  Accuracy: the tiny-task float-vs-
//! quantized experiment when artifacts are present (the paper's GLUE /
//! ImageNet numbers require the original checkpoints; the *claim* under
//! test is that integer-only inference preserves the float accuracy).

use swifttron::baselines::{gpu_inference_ms, GpuModel};
use swifttron::coordinator::InferenceEngine;
use swifttron::model::{Blob, Geometry, Manifest};
use swifttron::runtime::Engine;
use swifttron::sim::{simulate_encoder, HwConfig};
use swifttron::util::bench::Table;

fn main() {
    let cfg = HwConfig::paper();
    let gpu = GpuModel::rtx_2080_ti();

    let paper: &[(&str, &str, f64, f64)] = &[
        ("roberta_base", "RoBERTa-base (SST-2)", 1.83, 3.81),
        ("roberta_large", "RoBERTa-large (SST-2)", 45.70, 3.90),
        ("deit_s", "DeiT-S (ImageNet)", 1.13, 3.58),
    ];

    let mut t = Table::new(&[
        "model", "paper ms", "sim ms", "gpu ms (model)", "paper speedup", "our speedup",
    ]);
    for &(preset, label, paper_ms, paper_speedup) in paper {
        let geo = Geometry::preset(preset).unwrap();
        let sim = simulate_encoder(&cfg, &geo);
        let acc_ms = sim.ms(&cfg);
        let gpu_ms = gpu_inference_ms(&gpu, &geo);
        t.row(&[
            label.to_string(),
            format!("{paper_ms:.2}"),
            format!("{acc_ms:.2}"),
            format!("{gpu_ms:.2}"),
            format!("{paper_speedup:.2}x"),
            format!("{:.2}x", gpu_ms / acc_ms),
        ]);
    }
    t.print("Table II — latency & speedup vs GPU");

    // accuracy leg (needs artifacts)
    let dir = Manifest::default_dir();
    if dir.join("manifest.json").exists() {
        let manifest = Manifest::load(&dir).unwrap();
        let engine = Engine::cpu().unwrap();
        let eng = InferenceEngine::load(&dir, &engine, cfg).unwrap();
        let blob = Blob::load(&manifest.blob_prefix("tiny").unwrap()).unwrap();
        let toks = blob.i32("test_toks").unwrap();
        let labels = blob.i32("test_labels").unwrap();
        let m = eng.geo.m;
        let n = labels.len();
        let (mut cq, mut cf) = (0usize, 0usize);
        for i in 0..n {
            let tkn = &toks[i * m..(i + 1) * m];
            cq += (eng.predict(tkn).unwrap().label == labels[i] as usize) as usize;
            cf += (eng.predict_f32(tkn).unwrap() == labels[i] as usize) as usize;
        }
        let mut a = Table::new(&["datapath", "accuracy"]);
        a.row(&["float twin".into(), format!("{:.2} %", 100.0 * cf as f64 / n as f64)]);
        a.row(&["integer-only (SwiftTron)".into(), format!("{:.2} %", 100.0 * cq as f64 / n as f64)]);
        a.print("Table II accuracy leg — tiny-task substitution (DESIGN.md §5)");
        println!("paper shape: RoBERTa-base 95.2% float-comparable after I-BERT quantization");
    } else {
        println!("\n(accuracy leg skipped: run `make artifacts`)");
    }
}
