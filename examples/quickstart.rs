//! Quickstart: the three layers in one page.
//!
//! 1. load the AOT-compiled integer encoder artifact (Pallas kernels,
//!    lowered once at build time) onto the PJRT CPU client,
//! 2. run one inference end to end (tokens -> label),
//! 3. ask the cycle-accurate simulator + 65 nm synthesis model what the
//!    same inference costs on the SwiftTron ASIC.
//!
//! Run: `cargo run --release --example quickstart`
//! (needs `make artifacts` first)

use swifttron::coordinator::InferenceEngine;
use swifttron::model::{Geometry, Manifest};
use swifttron::runtime::Engine;
use swifttron::sim::{simulate_encoder, HwConfig};
use swifttron::synthesis::synthesis_report;
use swifttron::util::rng::Rng;

fn main() -> Result<(), String> {
    // --- numerics: PJRT execution of the integer model ---
    let engine = Engine::cpu()?;
    println!("PJRT platform: {}", engine.platform()?);
    let eng = InferenceEngine::load(&Manifest::default_dir(), &engine, HwConfig::paper())?;

    let mut rng = Rng::new(42);
    let tokens: Vec<i32> = (0..eng.geo.m).map(|_| rng.below(63) as i32).collect();
    let pred = eng.predict(&tokens)?;
    println!(
        "tiny-task inference: label={} logits={:?}",
        pred.label, pred.logits
    );

    // --- timing: the cycle-accurate SwiftTron simulator ---
    let cfg = HwConfig::paper();
    let geo = Geometry::preset("roberta_base").unwrap();
    let sim = simulate_encoder(&cfg, &geo);
    println!(
        "\nRoBERTa-base on SwiftTron: {} cycles @ {:.0} MHz = {:.3} ms  (paper: 1.83 ms)",
        sim.total_cycles,
        cfg.clock_mhz(),
        sim.ms(&cfg)
    );

    // --- cost: the 65 nm synthesis model ---
    let synth = synthesis_report(&cfg, &geo);
    println!("\n{}", synth.table1());
    Ok(())
}
