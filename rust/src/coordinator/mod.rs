//! Layer-3 coordinator: the deployable serving system around the
//! accelerator model (DESIGN.md §2).
//!
//! Request flow: `server` (TCP) -> `router::submit` -> `batcher`
//! (size-or-deadline dispatch groups) -> dispatcher thread ->
//! `pool::ReplicaPool` (fan-out over N engine replicas on the `util`
//! thread pool, results re-ordered per request) -> reply channels.
//!
//! * [`engine`] — the [`EngineReplica`] trait and its implementations:
//!   the PJRT-backed [`InferenceEngine`] and the artifact-free
//!   [`FunctionalEngine`].
//! * [`batcher`] — dynamic batcher (size/deadline policy).
//! * [`pool`] — the replica pool: dispatch-group fan-out + per-request
//!   re-ordering on the in-repo thread pool.
//! * [`router`] — request intake, the dispatcher thread, shutdown.
//! * [`server`] — a line-protocol TCP front-end.
//! * [`metrics`] — wall-clock latency/throughput plus per-replica
//!   virtual-time (simulated accelerator cycle) accounting.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod pool;
pub mod router;
pub mod server;

pub use batcher::{Batcher, BatchPolicy};
pub use engine::{EngineReplica, FunctionalEngine, InferenceEngine, Prediction, RequestError};
pub use metrics::{Metrics, ReplicaStats};
pub use pool::ReplicaPool;
pub use router::{Request, Response, Router};
