//! Dynamic batcher: groups queued requests so the worker pool stays busy
//! without letting early arrivals wait unboundedly.
//!
//! SwiftTron processes one sequence at a time (the array is loaded per
//! sentence), so a "batch" here is a *dispatch group*: up to
//! `max_batch` requests released together to the engine replicas, or
//! whatever has queued when `max_wait` elapses — the standard
//! size-or-deadline policy of serving systems.
//!
//! With variable-length requests (DESIGN.md §6) the batcher additionally
//! buckets by sequence length: requests whose lengths round up to the
//! same multiple of [`BatchPolicy::bucket_width`] share a dispatch
//! group, so a group's per-request cost is uniform (no short request
//! rides behind a full-length straggler at the group barrier) and the
//! padding a bucket-configured accelerator would waste is bounded by the
//! bucket width and reported by `coordinator::metrics`.  A width of 0
//! disables bucketing — every request shares one queue, the seed
//! behavior.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

/// Fallback park interval for a dispatcher polling an empty queue (no
/// deadline to sleep toward): bounds how long a lost wakeup can stall
/// the drain.  See [`Batcher::park_duration`].
pub const DEFAULT_PARK: Duration = Duration::from_millis(50);

#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    pub max_batch: usize,
    pub max_wait: Duration,
    /// Sequence-length bucket width for length-bucketed dispatch: a
    /// request of `len` tokens queues under the bucket boundary
    /// `ceil(len / bucket_width) * bucket_width`, and a dispatch group
    /// only ever contains one bucket.  0 disables bucketing.
    pub bucket_width: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2), bucket_width: 0 }
    }
}

impl BatchPolicy {
    /// The bucket boundary a request of `len` tokens pads up to
    /// (identity when bucketing is disabled).
    pub fn padded_len(&self, len: usize) -> usize {
        if self.bucket_width == 0 || len == 0 {
            len
        } else {
            len.div_ceil(self.bucket_width) * self.bucket_width
        }
    }

    /// Queue key for a request of `len` tokens: the bucket boundary, or
    /// the single shared queue when bucketing is off — width 0 must
    /// never split lengths into separate queues (the seed behavior).
    fn bucket_key(&self, len: usize) -> usize {
        if self.bucket_width == 0 {
            0
        } else {
            self.padded_len(len)
        }
    }
}

#[derive(Debug)]
pub struct Batcher<T> {
    policy: BatchPolicy,
    /// Per-bucket FIFO queues keyed by padded length.  Length-agnostic
    /// callers ([`Batcher::push`]) share bucket 0.
    buckets: BTreeMap<usize, VecDeque<(T, Instant)>>,
    queued: usize,
}

impl<T> Batcher<T> {
    pub fn new(policy: BatchPolicy) -> Self {
        Batcher { policy, buckets: BTreeMap::new(), queued: 0 }
    }

    /// Enqueue into the single default bucket (length-agnostic callers).
    pub fn push(&mut self, item: T) {
        self.push_len(item, 0);
    }

    /// Enqueue a request of sequence length `len`; returns the padded
    /// bucket boundary (== `len` when bucketing is disabled), which the
    /// caller can feed to the padding-waste metric.  With bucketing off
    /// every length shares one queue, so mixed-length groups still form
    /// exactly as in the unbucketed seed.
    pub fn push_len(&mut self, item: T, len: usize) -> usize {
        let key = self.policy.bucket_key(len);
        self.buckets.entry(key).or_default().push_back((item, Instant::now()));
        self.queued += 1;
        self.policy.padded_len(len)
    }

    pub fn len(&self) -> usize {
        self.queued
    }

    pub fn is_empty(&self) -> bool {
        self.queued == 0
    }

    /// The bucket whose front (oldest) request arrived earliest.
    fn oldest_bucket(&self) -> Option<(usize, Instant)> {
        self.buckets
            .iter()
            .filter_map(|(k, q)| q.front().map(|(_, t)| (*k, *t)))
            .min_by_key(|&(_, t)| t)
    }

    /// Whether a batch should be released now: some bucket reached
    /// `max_batch`, or the oldest queued request's deadline expired.
    pub fn ready(&self, now: Instant) -> bool {
        if self.buckets.values().any(|q| q.len() >= self.policy.max_batch) {
            return true;
        }
        match self.oldest_bucket() {
            Some((_, t)) => now.duration_since(t) >= self.policy.max_wait,
            None => false,
        }
    }

    /// Pop one dispatch group (oldest first within its bucket).  A
    /// deadline-expired oldest request outranks any full bucket — a
    /// minority-length bucket must never be starved past `max_wait` by
    /// a hot bucket that keeps refilling to `max_batch`.  Otherwise a
    /// full bucket goes first (ties broken by oldest front), then the
    /// bucket holding the oldest request; other buckets stay queued for
    /// their own group.
    pub fn take_batch(&mut self) -> Vec<T> {
        let now = Instant::now();
        let key = match self.oldest_bucket() {
            None => return Vec::new(),
            Some((k, t)) if now.duration_since(t) >= self.policy.max_wait => k,
            Some((oldest_key, _)) => self
                .buckets
                .iter()
                .filter(|(_, q)| q.len() >= self.policy.max_batch)
                .filter_map(|(k, q)| q.front().map(|(_, t)| (*k, *t)))
                .min_by_key(|&(_, t)| t)
                .map_or(oldest_key, |(k, _)| k),
        };
        // `key` was just derived from a live entry, so the bucket
        // exists today; stay total anyway — an empty batch beats
        // panicking the dispatcher thread if that invariant ever
        // drifts (ISSUE 3 hardening; the cross-call races live in
        // ready()/park_duration()/take_batch() sequencing, covered by
        // the regression test below).
        let Some(q) = self.buckets.get_mut(&key) else {
            return Vec::new();
        };
        let n = q.len().min(self.policy.max_batch);
        let out: Vec<T> = q.drain(..n).map(|(t, _)| t).collect();
        if q.is_empty() {
            self.buckets.remove(&key);
        }
        self.queued -= out.len();
        out
    }

    /// Deadline of the oldest queued request (for poll sleeping).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.oldest_bucket().map(|(_, t)| t + self.policy.max_wait)
    }

    /// How long a dispatcher may park before re-checking: the time
    /// until the oldest queued request's deadline (zero if already
    /// expired), or [`DEFAULT_PARK`] when the queue is empty.  Never
    /// panics — the queue draining between an emptiness check and this
    /// call just yields the default (ISSUE 3: the dispatcher path must
    /// not `unwrap()` a deadline it observed one lock ago).
    pub fn park_duration(&self, now: Instant) -> Duration {
        match self.next_deadline() {
            Some(d) => d.saturating_duration_since(now),
            None => DEFAULT_PARK,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn unbucketed(max_batch: usize, max_wait: Duration) -> BatchPolicy {
        BatchPolicy { max_batch, max_wait, bucket_width: 0 }
    }

    #[test]
    fn releases_on_size() {
        let mut b = Batcher::new(unbucketed(3, Duration::from_secs(60)));
        b.push(1);
        b.push(2);
        assert!(!b.ready(Instant::now()));
        b.push(3);
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch(), vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn releases_on_deadline() {
        let mut b = Batcher::new(unbucketed(100, Duration::ZERO));
        b.push("x");
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch(), vec!["x"]);
    }

    #[test]
    fn batch_is_fifo_and_bounded() {
        let mut b = Batcher::new(unbucketed(2, Duration::ZERO));
        for i in 0..5 {
            b.push(i);
        }
        assert_eq!(b.take_batch(), vec![0, 1]);
        assert_eq!(b.take_batch(), vec![2, 3]);
        assert_eq!(b.take_batch(), vec![4]);
    }

    #[test]
    fn empty_queue_not_ready() {
        let b: Batcher<i32> = Batcher::new(BatchPolicy::default());
        assert!(!b.ready(Instant::now()));
        assert!(b.next_deadline().is_none());
        assert!(b.take_batch().is_empty());
    }

    #[test]
    fn releases_when_max_wait_expires() {
        // below max_batch, the group is held until the oldest request's
        // deadline passes — then released even though the batch is short
        let wait = Duration::from_millis(15);
        let mut b = Batcher::new(unbucketed(100, wait));
        b.push(1);
        b.push(2);
        let t0 = Instant::now();
        assert!(!b.ready(t0), "not ready before the deadline");
        assert!(!b.ready(t0 + wait / 2), "still inside the wait window");
        assert!(b.ready(t0 + wait + Duration::from_millis(1)), "deadline expired");
        // and with real elapsed time, not just a synthetic clock
        std::thread::sleep(wait + Duration::from_millis(2));
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch(), vec![1, 2]);
    }

    #[test]
    fn next_deadline_is_oldest_push_plus_max_wait() {
        let wait = Duration::from_millis(20);
        let mut b = Batcher::new(unbucketed(100, wait));
        let before = Instant::now();
        b.push("old");
        let after = Instant::now();
        std::thread::sleep(Duration::from_millis(2));
        b.push("new"); // must not move the deadline: oldest item governs
        let d = b.next_deadline().unwrap();
        assert!(d >= before + wait && d <= after + wait, "deadline follows the oldest item");
        // draining the oldest moves the deadline later
        let first = b.take_batch();
        assert_eq!(first, vec!["old", "new"]);
        assert!(b.next_deadline().is_none());
    }

    #[test]
    fn park_duration_defaults_when_empty_and_tracks_the_deadline() {
        let wait = Duration::from_millis(20);
        let mut b: Batcher<i32> = Batcher::new(unbucketed(8, wait));
        assert_eq!(b.park_duration(Instant::now()), DEFAULT_PARK);
        b.push(1);
        let after = Instant::now(); // push time <= after, so deadline <= after + wait
        assert!(b.park_duration(after) <= wait, "parks no longer than the deadline");
        // an already-expired deadline parks zero — never negative, never a panic
        assert_eq!(b.park_duration(after + wait + Duration::from_millis(5)), Duration::ZERO);
        // draining restores the empty-queue default
        b.take_batch();
        assert_eq!(b.park_duration(Instant::now()), DEFAULT_PARK);
    }

    #[test]
    fn dispatcher_race_between_enqueue_and_expiry_never_panics() {
        // Regression (ISSUE 3): the dispatcher reads ready() /
        // park_duration() / take_batch() under a lock it releases and
        // re-acquires between calls, so the queue can drain or refill
        // between any two of them.  Hammer that interleaving with
        // producers racing a consumer under a zero deadline (every item
        // expires the instant it lands): no call may panic, and every
        // pushed item must come back exactly once.
        use std::sync::{Arc, Mutex};
        const PRODUCERS: usize = 3;
        const PER_PRODUCER: usize = 200;
        let b = Arc::new(Mutex::new(Batcher::new(BatchPolicy {
            max_batch: 3,
            max_wait: Duration::ZERO,
            bucket_width: 4,
        })));
        let producers: Vec<_> = (0..PRODUCERS)
            .map(|p| {
                let b = Arc::clone(&b);
                std::thread::spawn(move || {
                    for i in 0..PER_PRODUCER {
                        b.lock().unwrap().push_len(p * PER_PRODUCER + i, 1 + (i % 9));
                        if i % 16 == 0 {
                            std::thread::yield_now();
                        }
                    }
                })
            })
            .collect();
        let mut seen = Vec::new();
        let give_up = Instant::now() + Duration::from_secs(30);
        while seen.len() < PRODUCERS * PER_PRODUCER {
            assert!(
                Instant::now() < give_up,
                "consumer starved at {} of {}",
                seen.len(),
                PRODUCERS * PER_PRODUCER
            );
            let now = Instant::now();
            {
                // the dispatcher's read sequence, with the lock dropped
                // in between — the drain/refill window under test
                let q = b.lock().unwrap();
                let _ = q.ready(now);
                let _ = q.park_duration(now);
            }
            seen.extend(b.lock().unwrap().take_batch());
        }
        for p in producers {
            p.join().unwrap();
        }
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), PRODUCERS * PER_PRODUCER, "each request delivered exactly once");
    }

    #[test]
    fn padded_len_rounds_up_to_bucket_boundary() {
        let p = BatchPolicy { max_batch: 8, max_wait: Duration::ZERO, bucket_width: 8 };
        assert_eq!(p.padded_len(1), 8);
        assert_eq!(p.padded_len(8), 8);
        assert_eq!(p.padded_len(9), 16);
        assert_eq!(p.padded_len(0), 0);
        let off = BatchPolicy { bucket_width: 0, ..p };
        assert_eq!(off.padded_len(13), 13);
    }

    #[test]
    fn width_zero_shares_one_queue_across_lengths() {
        // bucketing off: mixed lengths form one dispatch group exactly
        // as in the unbucketed seed, and no padding is charged
        let mut b = Batcher::new(unbucketed(3, Duration::from_secs(60)));
        assert_eq!(b.push_len("a", 3), 3);
        assert_eq!(b.push_len("b", 5), 5);
        assert!(!b.ready(Instant::now()));
        assert_eq!(b.push_len("c", 7), 7);
        assert!(b.ready(Instant::now()), "shared queue reached max_batch");
        assert_eq!(b.take_batch(), vec!["a", "b", "c"], "cross-length FIFO preserved");
    }

    #[test]
    fn buckets_group_compatible_lengths_only() {
        // widths 8: lengths 3 and 5 share the 8-bucket, 12 goes to 16 —
        // a dispatch group never mixes buckets
        let p = BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(60), bucket_width: 8 };
        let mut b = Batcher::new(p);
        assert_eq!(b.push_len("len3", 3), 8);
        assert_eq!(b.push_len("len12", 12), 16);
        assert!(!b.ready(Instant::now()), "no bucket full yet");
        assert_eq!(b.push_len("len5", 5), 8);
        assert!(b.ready(Instant::now()), "the 8-bucket is full");
        assert_eq!(b.take_batch(), vec!["len3", "len5"], "FIFO within the full bucket");
        assert_eq!(b.len(), 1);
        assert_eq!(b.take_batch(), vec!["len12"]);
        assert!(b.is_empty());
    }

    #[test]
    fn expired_minority_bucket_is_not_starved_by_a_full_bucket() {
        // max_wait ZERO: the lone long request's deadline has expired,
        // so it dispatches ahead of the short bucket even though the
        // short bucket is full — a hot bucket refilling to max_batch
        // must not starve minority lengths past their deadline.
        let p = BatchPolicy { max_batch: 2, max_wait: Duration::ZERO, bucket_width: 8 };
        let mut b = Batcher::new(p);
        b.push_len("long", 20);
        std::thread::sleep(Duration::from_millis(2));
        b.push_len("short-a", 3);
        b.push_len("short-b", 5); // the 8-bucket is now full
        assert!(b.ready(Instant::now()));
        assert_eq!(b.take_batch(), vec!["long"], "expired request outranks the full bucket");
        assert_eq!(b.take_batch(), vec!["short-a", "short-b"]);
    }

    #[test]
    fn full_bucket_dispatches_before_unexpired_older_request() {
        // long deadline: nothing has expired, so the full bucket goes
        // first even though another bucket holds an older request
        let p = BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(60), bucket_width: 8 };
        let mut b = Batcher::new(p);
        b.push_len("long", 20);
        b.push_len("short-a", 3);
        b.push_len("short-b", 5);
        assert!(b.ready(Instant::now()), "a bucket is full");
        assert_eq!(b.take_batch(), vec!["short-a", "short-b"]);
        assert_eq!(b.take_batch(), vec!["long"]);
    }

    #[test]
    fn deadline_releases_the_oldest_bucket_first() {
        let p = BatchPolicy { max_batch: 100, max_wait: Duration::ZERO, bucket_width: 4 };
        let mut b = Batcher::new(p);
        b.push_len("first-long", 10);
        std::thread::sleep(Duration::from_millis(2));
        b.push_len("second-short", 2);
        // nothing is full; the oldest request's bucket goes first even
        // though its key (12) sorts after the short bucket's key (4)
        assert_eq!(b.take_batch(), vec!["first-long"]);
        assert_eq!(b.take_batch(), vec!["second-short"]);
    }
}
