//! Open-loop workload harness (DESIGN.md §10): seeded arrival
//! processes, recorded-trace replay, fault-injecting replicas, and the
//! driver that paces a trace against the coordinator under *offered*
//! (not closed-loop) load.
//!
//! * [`arrival`] — Poisson, bursty 2-state MMPP, and diurnal-ramp
//!   arrival generators plus tenant rate spikes, all deterministic in
//!   their seed.
//! * [`trace`] — the compact `(t_arrival, model, len)` record format:
//!   record a live run once, replay it bit-identically.
//! * [`chaos`] — [`EngineReplica`](crate::coordinator::EngineReplica)
//!   wrappers that panic mid-batch or straggle at a multiple of exec
//!   time, exercising the pool's retire-and-retry recovery path and
//!   the autoscaler's floor repair.
//! * [`driver`] — open-loop replay over a
//!   [`Router`](crate::coordinator::Router): arrivals are paced by the
//!   trace, not by completions, so latency-under-offered-load and
//!   recovery-after-fault are measurable.  [`replay_wire`] is the
//!   full-stack variant: the same trace paced over a real socket
//!   through the `SWWIRE1` front door (DESIGN.md §11), where
//!   admission-control rejections surface as
//!   [`ReplaySummary::shed`].

pub mod arrival;
pub mod chaos;
pub mod driver;
pub mod trace;

pub use arrival::{ArrivalProcess, Dwell, RateSpike};
pub use chaos::{ChaosReplica, DelayReplica};
pub use driver::{replay, replay_wire, run_process, tokens_for, ReplaySummary};
pub use trace::{Trace, TraceEvent};
