//! Layer-3 coordinator: the deployable serving system around the
//! accelerator model (DESIGN.md §2, §8, §9).
//!
//! Request flow: a front door — the non-blocking binary multiplexer
//! ([`crate::wire::mux`], with SLO admission control and text
//! auto-detection) or the legacy thread-per-connection text `server`
//! (bounded accept, optional `model:` prefix) ->
//! `router::submit_to`/`submit_index` -> `batcher` (size-or-deadline dispatch groups
//! keyed by `(model, padded length)`, weighted-fair across models) ->
//! one dispatcher thread *per model group* popping its own model's
//! shard concurrently (per-shard lock and wakeup, no global batcher
//! mutex; DESIGN.md §13) -> that group's
//! [`GroupRuntime`](pool::GroupRuntime) (fan-out over the group's
//! active replicas on the router's shared core-budget executor,
//! results re-ordered per request) -> reply channels.  An SLO
//! autoscaler thread ([`autoscale`]) moves each scalable group's
//! replica count with its backlog.
//!
//! * [`engine`] — the [`EngineReplica`] trait and its implementations:
//!   the PJRT-backed [`InferenceEngine`] (single-model) and the
//!   artifact-free [`FunctionalEngine`] over a shared
//!   [`SyntheticModel`] weight bundle.
//! * [`registry`] — the multi-tenant model registry: model ids ->
//!   geometry presets + replica groups + fair-share weights +
//!   `min..=max` replica ranges, SLO classes, and replica factories.
//! * [`batcher`] — dynamic batcher (size/deadline policy, model- and
//!   length-bucketed, deficit-round-robin model selection charged in
//!   the caller's cost unit — predicted accelerator cycles on the
//!   serving path; per-model pop contract with in-flight accounting
//!   for concurrent poppers), in two forms: the serial [`Batcher`]
//!   reference and the per-model-shard [`ShardedBatcher`] the router
//!   serves from (DESIGN.md §13).
//! * [`pool`] — per-model group runtimes: fan-out + per-request
//!   re-ordering over the router-owned global core budget
//!   (`util::budget`), replica slots the autoscaler grows and drains.
//! * [`autoscale`] — the SLO-aware backlog autoscaler policy and
//!   control loop, scoring each group's backlog in predicted work
//!   (`sim::cost::CostModel` cycles) rather than request counts.
//! * [`router`] — request intake, the per-group dispatcher threads,
//!   the autoscaler thread, shutdown.
//! * [`server`] — the legacy line-protocol TCP front-end (bounded
//!   accept path with a typed `busy` rejection; the scalable binary
//!   front door lives in [`crate::wire`]).
//! * [`metrics`] — wall-clock latency/throughput plus per-replica and
//!   per-model virtual-time (simulated accelerator cycle) accounting,
//!   token shares, per-model padding waste, per-model p50/p99 latency,
//!   backlog and replica gauges, per-model shed counters and
//!   front-door connection gauges.

pub mod autoscale;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod pool;
pub mod registry;
pub mod router;
pub mod server;

pub use autoscale::{
    decide, predicted_work_ms, tick_group, AutoscalePolicy, GroupScaleState, ScaleDecision,
};
pub use batcher::{Batcher, BatchPolicy, ShardedBatcher};
pub use engine::{
    EngineReplica, FunctionalEngine, InferenceEngine, Prediction, RequestError, SyntheticModel,
};
pub use metrics::{Metrics, ModelStats, ReplicaStats};
pub use pool::{GroupRuntime, ReplicaPool};
pub use registry::{ModelGroup, ModelRegistry, ReplicaFactory, DEFAULT_ESCALATE_MARGIN};
pub use router::{Request, Response, Router};
