//! Latency/throughput statistics for the coordinator's metrics and the
//! bench harness: online mean/min/max plus exact percentiles on demand.

#[derive(Clone, Debug, Default)]
pub struct Series {
    samples: Vec<f64>,
}

impl Series {
    pub fn new() -> Self {
        Series::default()
    }

    pub fn push(&mut self, v: f64) {
        self.samples.push(v);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (n - 1) as f64)
            .sqrt()
    }

    /// Exact percentile (nearest-rank on the sorted samples), p in [0,100].
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
        s[rank.min(s.len() - 1)]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn summary(&self, unit: &str) -> String {
        format!(
            "n={} mean={:.3}{u} p50={:.3}{u} p99={:.3}{u} min={:.3}{u} max={:.3}{u}",
            self.len(),
            self.mean(),
            self.p50(),
            self.p99(),
            self.min(),
            self.max(),
            u = unit
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments() {
        let mut s = Series::new();
        for v in [1.0, 2.0, 3.0, 4.0] {
            s.push(v);
        }
        assert_eq!(s.mean(), 2.5);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert!((s.stddev() - 1.2909944).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let mut s = Series::new();
        for v in 1..=100 {
            s.push(v as f64);
        }
        assert!((50.0..=51.0).contains(&s.p50()), "{}", s.p50());
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
        assert!(s.p99() >= 98.0);
    }

    #[test]
    fn empty_is_nan() {
        assert!(Series::new().mean().is_nan());
        assert!(Series::new().percentile(50.0).is_nan());
    }
}
