//! Compact recorded-trace format: a run's `(t_arrival, model, len)`
//! stream as fixed-width little-endian records behind an 8-byte magic,
//! so a live run can be recorded once and replayed bit-identically.
//!
//! On-disk layout (everything little-endian):
//!
//! ```text
//! offset  size  field
//! 0       8     magic  b"SWTRACE1"
//! 8       4     u32    record count
//! 12      12·n  records: u64 t_ns | u16 model | u16 len
//! ```
//!
//! Timestamps are integer nanoseconds from run start — no floats on
//! disk, so `save(load(x)) == x` byte-for-byte, which the property
//! suite asserts.

use std::path::Path;

use super::arrival::ArrivalProcess;
use crate::util::rng::Rng;

const MAGIC: &[u8; 8] = b"SWTRACE1";
const RECORD_BYTES: usize = 12;

/// One recorded arrival: nanoseconds from run start, model group
/// index, and request token length.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceEvent {
    pub t_ns: u64,
    pub model: u16,
    pub len: u16,
}

/// An ordered arrival stream, recordable to and replayable from disk.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Trace {
    events: Vec<TraceEvent>,
}

impl Trace {
    pub fn new() -> Self {
        Trace::default()
    }

    /// Record one arrival at `t_s` seconds from run start.
    pub fn push(&mut self, t_s: f64, model: usize, len: usize) {
        assert!(t_s >= 0.0, "arrival time must be non-negative");
        assert!(model <= u16::MAX as usize, "model index overflows the trace format");
        assert!(len <= u16::MAX as usize, "request length overflows the trace format");
        self.events.push(TraceEvent {
            t_ns: (t_s * 1e9).round() as u64,
            model: model as u16,
            len: len as u16,
        });
    }

    pub fn push_event(&mut self, ev: TraceEvent) {
        self.events.push(ev);
    }

    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Timestamp of the last event, seconds (0 for an empty trace).
    pub fn duration_s(&self) -> f64 {
        self.events.last().map_or(0.0, |e| e.t_ns as f64 / 1e9)
    }

    /// Record an arrival process for one tenant: arrival times from the
    /// process, request lengths uniform in `len_range` (inclusive) from
    /// an independent RNG derived from `seed`.
    pub fn from_process(
        process: &ArrivalProcess,
        seed: u64,
        horizon_s: f64,
        model: usize,
        len_range: (usize, usize),
    ) -> Trace {
        Trace::from_arrivals(&process.sample(seed, horizon_s), model, seed, len_range)
    }

    /// Record a pre-sampled arrival-time stream for one tenant.
    pub fn from_arrivals(
        arrivals: &[f64],
        model: usize,
        seed: u64,
        len_range: (usize, usize),
    ) -> Trace {
        let (lo, hi) = len_range;
        assert!(lo >= 1 && lo <= hi, "need 1 <= lo <= hi for request lengths");
        let mut rng = Rng::new(seed ^ 0x1E4A_11E4_0F5E_ED00);
        let mut trace = Trace::new();
        for &t in arrivals {
            let len = rng.range_i64(lo as i64, hi as i64) as usize;
            trace.push(t, model, len);
        }
        trace
    }

    /// Interleave per-tenant traces into one run, ordered by time
    /// (ties broken by model index so merges are deterministic).
    pub fn merge(traces: &[Trace]) -> Trace {
        let mut events: Vec<TraceEvent> =
            traces.iter().flat_map(|t| t.events.iter().copied()).collect();
        events.sort_by_key(|e| (e.t_ns, e.model));
        Trace { events }
    }

    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(MAGIC.len() + 4 + self.events.len() * RECORD_BYTES);
        out.extend_from_slice(MAGIC);
        out.extend_from_slice(&(self.events.len() as u32).to_le_bytes());
        for ev in &self.events {
            out.extend_from_slice(&ev.t_ns.to_le_bytes());
            out.extend_from_slice(&ev.model.to_le_bytes());
            out.extend_from_slice(&ev.len.to_le_bytes());
        }
        out
    }

    pub fn from_bytes(bytes: &[u8]) -> Result<Trace, String> {
        if bytes.len() < MAGIC.len() + 4 {
            return Err(format!("trace truncated: {} bytes", bytes.len()));
        }
        if &bytes[..MAGIC.len()] != MAGIC {
            return Err("bad trace magic (not a SWTRACE1 file)".into());
        }
        let count = u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        let body = &bytes[12..];
        if body.len() != count * RECORD_BYTES {
            return Err(format!(
                "trace body is {} bytes, header promises {} records ({} bytes)",
                body.len(),
                count,
                count * RECORD_BYTES
            ));
        }
        let mut events = Vec::with_capacity(count);
        for rec in body.chunks_exact(RECORD_BYTES) {
            events.push(TraceEvent {
                t_ns: u64::from_le_bytes(rec[0..8].try_into().unwrap()),
                model: u16::from_le_bytes(rec[8..10].try_into().unwrap()),
                len: u16::from_le_bytes(rec[10..12].try_into().unwrap()),
            });
        }
        Ok(Trace { events })
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_bytes())
    }

    pub fn load(path: &Path) -> Result<Trace, String> {
        let bytes = std::fs::read(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        Trace::from_bytes(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_round_trip_exactly() {
        let mut t = Trace::new();
        t.push(0.001, 0, 12);
        t.push(0.25, 1, 64);
        t.push(3.5, 0, 1);
        let bytes = t.to_bytes();
        let back = Trace::from_bytes(&bytes).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.to_bytes(), bytes, "re-serialization is byte-identical");
    }

    #[test]
    fn bad_magic_and_truncation_are_typed_errors() {
        assert!(Trace::from_bytes(b"nope").is_err());
        let mut bytes = Trace::new().to_bytes();
        bytes[0] = b'X';
        assert!(Trace::from_bytes(&bytes).is_err());
        let mut t = Trace::new();
        t.push(1.0, 0, 8);
        let mut bytes = t.to_bytes();
        bytes.pop();
        assert!(Trace::from_bytes(&bytes).is_err());
    }

    #[test]
    fn merge_orders_by_time_then_model() {
        let mut a = Trace::new();
        a.push(0.2, 0, 8);
        a.push(0.4, 0, 8);
        let mut b = Trace::new();
        b.push(0.1, 1, 8);
        b.push(0.2, 1, 8);
        let m = Trace::merge(&[a, b]);
        let order: Vec<(u64, u16)> = m.events().iter().map(|e| (e.t_ns, e.model)).collect();
        assert_eq!(
            order,
            vec![(100_000_000, 1), (200_000_000, 0), (200_000_000, 1), (400_000_000, 0)]
        );
    }
}
