//! End-to-end serving tests: engine accuracy on the held-out tiny-task
//! test set (the Table II accuracy experiment, DESIGN.md §5) and the
//! router / batcher / replica-pool pipeline under concurrent load.
//!
//! The artifact-backed tests skip when `make artifacts` has not run; the
//! pipeline tests use the artifact-free `FunctionalEngine`, so the
//! parallel serving path is exercised on every `cargo test`.

use std::sync::atomic::Ordering;
use std::sync::mpsc::channel;
use std::sync::Arc;
use swifttron::coordinator::{
    BatchPolicy, EngineReplica, FunctionalEngine, InferenceEngine, Metrics, Router,
};
use swifttron::model::{Blob, Manifest};
use swifttron::runtime::Engine;
use swifttron::sim::HwConfig;

fn setup() -> Option<(Manifest, Engine)> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping serving tests: run `make artifacts` first");
        return None;
    }
    Some((Manifest::load(&dir).unwrap(), Engine::cpu().unwrap()))
}

fn functional_replicas(n: usize) -> Vec<Arc<dyn EngineReplica>> {
    (0..n)
        .map(|_| {
            Arc::new(FunctionalEngine::synthetic("tiny", 7, HwConfig::paper()).unwrap())
                as Arc<dyn EngineReplica>
        })
        .collect()
}

#[test]
fn quantized_accuracy_matches_float_within_one_point() {
    let Some((manifest, engine)) = setup() else { return };
    let eng = InferenceEngine::load(&manifest.dir, &engine, HwConfig::paper()).unwrap();
    let blob = Blob::load(&manifest.blob_prefix("tiny").unwrap()).unwrap();
    let toks = blob.i32("test_toks").unwrap();
    let labels = blob.i32("test_labels").unwrap();
    let m = eng.geo.m;
    let n = 128.min(labels.len()); // fast subset; the example runs all 512

    let mut correct_q = 0;
    let mut correct_f = 0;
    for i in 0..n {
        let t = &toks[i * m..(i + 1) * m];
        let pred = eng.predict(t).unwrap();
        if pred.label == labels[i] as usize {
            correct_q += 1;
        }
        if eng.predict_f32(t).unwrap() == labels[i] as usize {
            correct_f += 1;
        }
    }
    let acc_q = correct_q as f64 / n as f64;
    let acc_f = correct_f as f64 / n as f64;
    // the paper's Table II claim shape: quantization costs ~nothing
    assert!(acc_f > 0.9, "float accuracy {acc_f}");
    assert!(acc_q > acc_f - 0.05, "quantized {acc_q} vs float {acc_f}");
}

#[test]
fn pjrt_router_serves_concurrent_requests() {
    let Some((manifest, engine)) = setup() else { return };
    let eng = Arc::new(InferenceEngine::load(&manifest.dir, &engine, HwConfig::paper()).unwrap());
    let metrics = Arc::new(Metrics::new());
    let router = Router::start(
        vec![Arc::clone(&eng) as Arc<dyn EngineReplica>, eng],
        BatchPolicy::default(),
        Arc::clone(&metrics),
    );

    let m = 32;
    let mut receivers = vec![];
    for i in 0..24 {
        let (tx, rx) = channel();
        let tokens: Vec<i32> = (0..m).map(|j| ((i * 7 + j * 3) % 62) as i32).collect();
        router.submit(tokens, tx);
        receivers.push(rx);
    }
    for rx in receivers {
        let resp = rx.recv().expect("response");
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert!(resp.label < 2);
        assert!(resp.accel_ms > 0.0);
    }
    assert_eq!(metrics.completed.load(Ordering::Relaxed), 24);
    router.shutdown();
}

#[test]
fn functional_router_serves_concurrent_requests_across_replicas() {
    // Artifact-free: always runs.  Two synthetic replicas of the same
    // model must serve every request, agree with a direct reference
    // prediction, and both appear in the per-replica ledgers.
    let reference = FunctionalEngine::synthetic("tiny", 7, HwConfig::paper()).unwrap();
    let m = reference.seq_len();
    let metrics = Arc::new(Metrics::new());
    let router = Router::start(functional_replicas(2), BatchPolicy::default(), Arc::clone(&metrics));

    let mut expected = vec![];
    let mut receivers = vec![];
    for i in 0..24 {
        let tokens: Vec<i32> = (0..m).map(|j| ((i * 11 + j * 5) % 60) as i32).collect();
        expected.push(reference.predict(&tokens).unwrap().label);
        let (tx, rx) = channel();
        router.submit(tokens, tx);
        receivers.push(rx);
    }
    for (rx, want) in receivers.into_iter().zip(expected) {
        let resp = rx.recv().expect("response");
        assert!(resp.error.is_none(), "{:?}", resp.error);
        assert_eq!(resp.label, want, "replica disagrees with reference model");
        assert!(resp.replica < 2);
        assert!(resp.accel_ms > 0.0);
    }
    assert_eq!(metrics.completed.load(Ordering::Relaxed), 24);
    // both replicas served part of the load, and their virtual time adds up
    let (r0, r1) = (metrics.replica(0), metrics.replica(1));
    assert!(r0.requests.load(Ordering::Relaxed) > 0);
    assert!(r1.requests.load(Ordering::Relaxed) > 0);
    assert_eq!(
        r0.requests.load(Ordering::Relaxed) + r1.requests.load(Ordering::Relaxed),
        24
    );
    assert!(metrics.total_accel_ms() > 0.0);
    router.shutdown();
}

#[test]
fn router_reports_errors_for_bad_requests() {
    // short requests are now legal (variable-length serving), so the
    // malformed cases are an empty request and an out-of-vocab token
    let reference = FunctionalEngine::synthetic("tiny", 7, HwConfig::paper()).unwrap();
    let metrics = Arc::new(Metrics::new());
    let router = Router::start(functional_replicas(1), BatchPolicy::default(), Arc::clone(&metrics));
    let (tx, rx) = channel();
    router.submit(vec![], tx); // zero-length request
    let resp = rx.recv().unwrap();
    assert!(resp.error.as_deref().unwrap_or("").contains("length"), "{:?}", resp.error);
    let (tx, rx) = channel();
    let mut tokens = vec![0i32; reference.seq_len()];
    tokens[0] = 9999; // out of vocab
    router.submit(tokens, tx);
    let resp = rx.recv().unwrap();
    assert!(resp.error.as_deref().unwrap_or("").contains("vocab"), "{:?}", resp.error);
    assert_eq!(metrics.errors.load(Ordering::Relaxed), 2);
    router.shutdown();
}

#[test]
fn shutdown_drains_queued_requests() {
    let metrics = Arc::new(Metrics::new());
    let reference = FunctionalEngine::synthetic("tiny", 7, HwConfig::paper()).unwrap();
    let m = reference.seq_len();
    // huge batch + long deadline: requests sit queued until shutdown drains
    let policy = BatchPolicy {
        max_batch: 1000,
        max_wait: std::time::Duration::from_secs(60),
        bucket_width: 0,
    };
    let router = Router::start(functional_replicas(2), policy, Arc::clone(&metrics));
    let mut receivers = vec![];
    for i in 0..6 {
        let (tx, rx) = channel();
        router.submit(vec![(i % 60) as i32; m], tx);
        receivers.push(rx);
    }
    router.shutdown();
    for rx in receivers {
        let resp = rx.recv().expect("drained response");
        assert!(resp.error.is_none(), "{:?}", resp.error);
    }
    assert_eq!(metrics.completed.load(Ordering::Relaxed), 6);
}
