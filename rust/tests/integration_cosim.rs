//! Co-simulation: the rust functional model of the SwiftTron datapath
//! must agree **bit-for-bit** with the PJRT-executed Pallas artifact for
//! the roberta_base-shaped encoder layer — the same software-vs-RTL
//! validation triangle the paper runs with QuestaSim (§IV-B), closed
//! across three implementations (jnp spec == Pallas kernels == rust).

use swifttron::model::{Blob, Manifest};
use swifttron::runtime::{Engine, Tensor};
use swifttron::sim::functional::{layer_forward, LayerWeights};
use swifttron::util::rng::Rng;

#[test]
fn pjrt_layer_matches_rust_functional_model_bit_exact() {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping co-sim: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let preset = manifest.preset("roberta_base").unwrap();
    let geo = preset.geometry;
    let consts = &preset.layers[0]; // unified: every layer shares these

    let blob = Blob::load(&manifest.blob_prefix("roberta_base").unwrap()).unwrap();
    let w = LayerWeights::from_blob(&blob, 0).unwrap();

    // random INT8 input
    let mut rng = Rng::new(99);
    let q_x: Vec<i32> = (0..geo.m * geo.d).map(|_| rng.range_i64(-127, 127) as i32).collect();

    // rust functional model
    let rust_out = layer_forward(&q_x, &w, consts, &geo);

    // PJRT execution of the Pallas artifact (weights as arguments)
    let engine = Engine::cpu().unwrap();
    let exe = engine
        .load(&manifest.artifact_path("roberta_base", "int8_layer").unwrap())
        .unwrap();
    let mut inputs = vec![Tensor::i32(&[geo.m, geo.d], q_x)];
    for key in [
        "wq", "bq", "wk", "bk", "wv", "bv", "wo", "bo", "w1", "b1", "w2", "b2", "gamma1",
        "beta1", "gamma2", "beta2",
    ] {
        let data = blob.i32(&format!("L0.{key}")).unwrap();
        let shape = blob.shape(&format!("L0.{key}")).unwrap().to_vec();
        inputs.push(Tensor::i32(&shape, data));
    }
    let pjrt_out = exe.run_i32(&inputs, &[geo.m, geo.d]).unwrap();

    assert_eq!(
        pjrt_out.as_i32().unwrap(),
        &rust_out.q_out[..],
        "PJRT artifact and rust functional model diverged"
    );
}

#[test]
fn multi_layer_stack_runs_and_stays_int8() {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        return;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let preset = manifest.preset("roberta_base").unwrap();
    let geo = preset.geometry;
    let consts = preset.layers[0].clone();
    let blob = Blob::load(&manifest.blob_prefix("roberta_base").unwrap()).unwrap();

    let mut rng = Rng::new(5);
    let mut h: Vec<i32> = (0..geo.m * geo.d).map(|_| rng.range_i64(-127, 127) as i32).collect();
    // two layers through the rust functional model (full 12 reserved for
    // the example binary; tests stay fast)
    for layer in 0..2 {
        let w = LayerWeights::from_blob(&blob, layer).unwrap();
        let out = layer_forward(&h, &w, &consts, &geo);
        assert!(out.q_out.iter().all(|&v| (-128..=127).contains(&v)));
        assert!(out.sqrt_iters.iter().all(|&it| it <= 32));
        h = out.q_out;
    }
}
