//! Engine replicas: the per-accelerator end of the parallel serving
//! pipeline (DESIGN.md §2).
//!
//! A *replica* models one SwiftTron accelerator attached to the host.
//! The [`Router`](super::Router) batches incoming requests into dispatch
//! groups and the [`ReplicaPool`](super::ReplicaPool) fans each group
//! out across N replicas on the in-repo `util` thread pool; every
//! replica executes its share of the group serially, exactly as the
//! hardware would (the array is loaded per sentence).  Anything that
//! implements [`EngineReplica`] can sit in the pool; two
//! implementations ship:
//!
//! * [`InferenceEngine`] — the artifact-backed path (paper Fig. 1b):
//!   tokens -> embedding + positional add (host f32, outside the
//!   accelerator per Fig. 4's "inputs taken after positional encoding")
//!   -> symmetric INT8 quantization at the calibrated `s_in` -> PJRT
//!   execution of the AOT integer encoder artifact -> integer mean-pool
//!   + INT8 classifier head (`quant::i_matmul`) -> argmax label.
//! * [`FunctionalEngine`] — the same integer request path executed by
//!   the in-crate functional model (`sim::functional`) on synthetic
//!   weights: no artifacts, no PJRT, no external dependencies.  It
//!   drives the serving tests and the replica-scaling bench offline.
//!
//! Each prediction carries the cycle-accurate SwiftTron latency for the
//! same computation; the pool aggregates it per replica as virtual time
//! next to wall-clock throughput (`coordinator::metrics`).

use crate::model::{Blob, Geometry, Manifest};
use crate::quant::i_matmul;
use crate::runtime::{Engine, Executable, Tensor};
use crate::sim::functional::{encoder_forward, synthetic_consts, LayerWeights};
use crate::sim::{simulate_encoder, HwConfig};
use crate::util::rng::Rng;
use std::path::Path;

/// One engine replica: the unit of parallelism of the serving layer.
/// A replica owns everything needed to serve a request end to end and
/// is driven from one pool thread at a time.
pub trait EngineReplica: Send + Sync {
    /// Run one request end to end (numerics + simulated accelerator time).
    fn predict(&self, tokens: &[i32]) -> Result<Prediction, String>;

    /// Sequence length `m` this replica's model expects.
    fn seq_len(&self) -> usize;
}

#[derive(Clone, Debug)]
pub struct Prediction {
    pub label: usize,
    pub logits: Vec<i64>,
    /// simulated accelerator latency for this inference
    pub accel_cycles: u64,
    pub accel_ms: f64,
}

pub struct InferenceEngine {
    pub geo: Geometry,
    exe_int8: Executable,
    exe_f32: Option<Executable>,
    emb: Vec<f32>,    // (vocab, d)
    pos: Vec<f32>,    // (m, d)
    q_w_head: Vec<i32>, // (d, 2)
    q_b_head: Vec<i32>,
    f_w_head: Vec<f32>,
    f_b_head: Vec<f32>,
    s_in: f64,
    vocab: usize,
    hw: HwConfig,
    accel_cycles: u64,
}

impl InferenceEngine {
    /// Build from the artifacts directory (tiny preset).
    pub fn load(artifacts: &Path, engine: &Engine, hw: HwConfig) -> Result<InferenceEngine, String> {
        let manifest = Manifest::load(artifacts)?;
        let preset = manifest.preset("tiny")?;
        let geo = preset.geometry;
        let blob = Blob::load(&manifest.blob_prefix("tiny")?)?;
        let exe_int8 = engine.load(&manifest.artifact_path("tiny", "int8")?)?;
        let exe_f32 = manifest
            .artifact_path("tiny", "f32")
            .ok()
            .and_then(|p| engine.load(&p).ok());
        let sim = simulate_encoder(&hw, &geo);
        Ok(InferenceEngine {
            geo,
            exe_int8,
            exe_f32,
            emb: blob.f32("emb")?,
            pos: blob.f32("pos")?,
            q_w_head: blob.i32("q_w_head")?,
            q_b_head: blob.i32("q_b_head")?,
            f_w_head: blob.f32("f_w_head")?,
            f_b_head: blob.f32("f_b_head")?,
            s_in: preset.s_in.ok_or("tiny preset missing s_in")?,
            vocab: blob.shape("emb")?[0],
            hw,
            accel_cycles: sim.total_cycles,
        })
    }

    /// Embedding + positional add + INT8 quantization (host side).
    pub fn embed_quantize(&self, tokens: &[i32]) -> Result<Vec<i32>, String> {
        let (m, d) = (self.geo.m, self.geo.d);
        if tokens.len() != m {
            return Err(format!("expected {m} tokens, got {}", tokens.len()));
        }
        let mut q = vec![0i32; m * d];
        for (i, &t) in tokens.iter().enumerate() {
            let t = t as usize;
            if t >= self.vocab {
                return Err(format!("token {t} out of vocab {}", self.vocab));
            }
            for j in 0..d {
                let x = self.emb[t * d + j] as f64 + self.pos[i * d + j] as f64;
                q[i * d + j] = (x / self.s_in).round().clamp(-128.0, 127.0) as i32;
            }
        }
        Ok(q)
    }

    /// Integer mean-pool (shift when m is a power of two) + INT8 head.
    fn head(&self, q_out: &[i32]) -> (usize, Vec<i64>) {
        integer_head(q_out, &self.q_w_head, &self.q_b_head, self.geo.m, self.geo.d)
    }

    /// Full integer-path prediction via the PJRT artifact.
    pub fn predict(&self, tokens: &[i32]) -> Result<Prediction, String> {
        let (m, d) = (self.geo.m, self.geo.d);
        let q_x = self.embed_quantize(tokens)?;
        let out = self.exe_int8.run_i32(&[Tensor::i32(&[m, d], q_x)], &[m, d])?;
        let (label, logits) = self.head(out.as_i32().unwrap());
        Ok(Prediction {
            label,
            logits,
            accel_cycles: self.accel_cycles,
            accel_ms: self.hw.cycles_to_ms(self.accel_cycles),
        })
    }

    /// Float-twin prediction (accuracy baseline).
    pub fn predict_f32(&self, tokens: &[i32]) -> Result<usize, String> {
        let exe = self.exe_f32.as_ref().ok_or("no f32 artifact")?;
        let (m, d) = (self.geo.m, self.geo.d);
        let mut x = vec![0f32; m * d];
        for (i, &t) in tokens.iter().enumerate() {
            let t = t as usize;
            for j in 0..d {
                x[i * d + j] = self.emb[t * d + j] + self.pos[i * d + j];
            }
        }
        let out = exe.run_f32(&[Tensor::f32(&[m, d], x)], &[m, d])?;
        let h = out.as_f32().unwrap();
        let n_cls = self.f_b_head.len();
        let mut pooled = vec![0f64; d];
        for j in 0..d {
            pooled[j] = (0..m).map(|i| h[i * d + j] as f64).sum::<f64>() / m as f64;
        }
        let mut logits = vec![0f64; n_cls];
        for (c, l) in logits.iter_mut().enumerate() {
            *l = self.f_b_head[c] as f64
                + (0..d).map(|j| pooled[j] * self.f_w_head[j * n_cls + c] as f64).sum::<f64>();
        }
        Ok((0..n_cls).max_by_key(|&i| (logits[i] * 1e9) as i64).unwrap_or(0))
    }

    pub fn hw(&self) -> &HwConfig {
        &self.hw
    }
}

impl EngineReplica for InferenceEngine {
    fn predict(&self, tokens: &[i32]) -> Result<Prediction, String> {
        InferenceEngine::predict(self, tokens)
    }

    fn seq_len(&self) -> usize {
        self.geo.m
    }
}

/// Shared integer readout: mean-pool over rows + INT8 classifier head.
fn integer_head(
    q_out: &[i32],
    w_head: &[i32],
    b_head: &[i32],
    m: usize,
    d: usize,
) -> (usize, Vec<i64>) {
    let mut pooled = vec![0i32; d];
    for j in 0..d {
        let mut s: i64 = 0;
        for i in 0..m {
            s += q_out[i * d + j] as i64;
        }
        pooled[j] = crate::quant::div_floor(s, m as i64) as i32;
    }
    let n_cls = b_head.len();
    let mut logits32 = vec![0i32; n_cls];
    i_matmul(&pooled, w_head, Some(b_head), 1, d, n_cls, &mut logits32);
    let logits: Vec<i64> = logits32.iter().map(|&v| v as i64).collect();
    let label = (0..n_cls).max_by_key(|&i| logits[i]).unwrap_or(0);
    (label, logits)
}

/// Artifact-free engine replica: the bit-exact functional model
/// (`sim::functional`) over synthetic weights, with the same integer
/// request path and virtual-time accounting as [`InferenceEngine`].
///
/// Every replica built from the same `(preset, seed)` is an identical
/// model, so a pool of them is a true replica set.  Above the
/// [`crate::quant::PAR_MIN_MACS`] threshold its contractions take the
/// row-tiled parallel `i_matmul`; the tiny preset stays below it, so
/// replica-level parallelism is the only concurrency in play there (no
/// nested oversubscription in the scaling bench).
pub struct FunctionalEngine {
    pub geo: Geometry,
    layers: Vec<(LayerWeights, crate::model::LayerConsts)>,
    emb: Vec<i32>, // (vocab, d), INT8-coded
    pos: Vec<i32>, // (m, d), small ints
    w_head: Vec<i32>, // (d, 2)
    b_head: Vec<i32>,
    vocab: usize,
    hw: HwConfig,
    accel_cycles: u64,
}

impl FunctionalEngine {
    /// Build a synthetic replica for a geometry preset.  Same seed =>
    /// identical replica (weights, embedding, head).
    pub fn synthetic(preset: &str, seed: u64, hw: HwConfig) -> Result<FunctionalEngine, String> {
        let geo =
            Geometry::preset(preset).ok_or_else(|| format!("unknown preset {preset:?}"))?;
        let mut rng = Rng::new(seed);
        let vocab = 64;
        let emb: Vec<i32> =
            (0..vocab * geo.d).map(|_| rng.range_i64(-100, 100) as i32).collect();
        let pos: Vec<i32> =
            (0..geo.m * geo.d).map(|_| rng.range_i64(-27, 27) as i32).collect();
        let layers = (0..geo.layers)
            .map(|_| (LayerWeights::synthetic(&mut rng, &geo), synthetic_consts(&geo)))
            .collect();
        let w_head: Vec<i32> =
            (0..geo.d * 2).map(|_| rng.range_i64(-127, 127) as i32).collect();
        let b_head: Vec<i32> = (0..2).map(|_| rng.range_i64(-1000, 1000) as i32).collect();
        let sim = simulate_encoder(&hw, &geo);
        Ok(FunctionalEngine {
            geo,
            layers,
            emb,
            pos,
            w_head,
            b_head,
            vocab,
            hw,
            accel_cycles: sim.total_cycles,
        })
    }
}

impl EngineReplica for FunctionalEngine {
    fn predict(&self, tokens: &[i32]) -> Result<Prediction, String> {
        let (m, d) = (self.geo.m, self.geo.d);
        if tokens.len() != m {
            return Err(format!("expected {m} tokens, got {}", tokens.len()));
        }
        // integer embedding + positional add, saturated to INT8
        let mut q_x = vec![0i32; m * d];
        for (i, &t) in tokens.iter().enumerate() {
            let t = t as usize;
            if t >= self.vocab {
                return Err(format!("token {t} out of vocab {}", self.vocab));
            }
            for j in 0..d {
                q_x[i * d + j] =
                    (self.emb[t * d + j] + self.pos[i * d + j]).clamp(-128, 127);
            }
        }
        let (q_out, _) = encoder_forward(&q_x, &self.layers, &self.geo);
        let (label, logits) = integer_head(&q_out, &self.w_head, &self.b_head, m, d);
        Ok(Prediction {
            label,
            logits,
            accel_cycles: self.accel_cycles,
            accel_ms: self.hw.cycles_to_ms(self.accel_cycles),
        })
    }

    fn seq_len(&self) -> usize {
        self.geo.m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn functional_engine_is_deterministic_per_seed() {
        let a = FunctionalEngine::synthetic("tiny", 7, HwConfig::paper()).unwrap();
        let b = FunctionalEngine::synthetic("tiny", 7, HwConfig::paper()).unwrap();
        let tokens: Vec<i32> = (0..a.seq_len()).map(|i| (i % 60) as i32).collect();
        let pa = EngineReplica::predict(&a, &tokens).unwrap();
        let pb = EngineReplica::predict(&b, &tokens).unwrap();
        assert_eq!(pa.label, pb.label);
        assert_eq!(pa.logits, pb.logits);
        assert!(pa.accel_cycles > 0);
        assert!(pa.accel_ms > 0.0);
    }

    #[test]
    fn functional_engine_rejects_bad_requests() {
        let e = FunctionalEngine::synthetic("tiny", 7, HwConfig::paper()).unwrap();
        assert!(EngineReplica::predict(&e, &[1, 2, 3]).is_err(), "wrong length");
        let mut tokens: Vec<i32> = vec![0; e.seq_len()];
        tokens[0] = 9999;
        assert!(EngineReplica::predict(&e, &tokens).is_err(), "out of vocab");
    }
}
