//! Deterministic PRNG (xoshiro256**) — workload generation and property
//! tests need reproducible randomness; the offline crate set has no `rand`.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. Uses rejection sampling to avoid modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - u64::MAX % n;
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u64;
        lo.wrapping_add(self.below(span) as i64)
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = (self.f64()).max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with the given rate (Poisson inter-arrival times for the
    /// serving workload generator).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        -(1.0 - self.f64()).ln() / rate
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive_endpoints() {
        let mut r = Rng::new(3);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..2000 {
            let v = r.range_i64(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }
}
